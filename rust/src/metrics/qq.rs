//! Q-Q analysis against the standard normal — Figure 3's "per-group sizes
//! are log-normal" evidence. We compute (Phi^-1(p_i), log-quantile_i)
//! pairs and the least-squares line fit; near-unity R^2 is the paper's
//! "nearly straight line in the Q-Q plot".

/// Inverse standard-normal CDF (Acklam's rational approximation,
/// |relative error| < 1.15e-9 — far below plotting precision).
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "normal_quantile domain");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -((((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0))
    }
}

/// Q-Q points of `xs` vs the standard normal: (theoretical, observed)
/// using the Blom plotting positions (i - 0.375) / (n + 0.25).
pub fn qq_points(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    v.iter()
        .enumerate()
        .map(|(i, &x)| {
            let p = (i as f64 + 1.0 - 0.375) / (n as f64 + 0.25);
            (normal_quantile(p), x)
        })
        .collect()
}

/// Least-squares line fit through Q-Q points with R^2 — the "how straight
/// is the line" statistic.
#[derive(Debug, Clone, PartialEq)]
pub struct QqFit {
    pub slope: f64,
    pub intercept: f64,
    pub r2: f64,
}

pub fn fit_line(points: &[(f64, f64)]) -> QqFit {
    let n = points.len() as f64;
    assert!(n >= 2.0);
    let mx = points.iter().map(|p| p.0).sum::<f64>() / n;
    let my = points.iter().map(|p| p.1).sum::<f64>() / n;
    let sxy: f64 = points.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let sxx: f64 = points.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    let syy: f64 = points.iter().map(|p| (p.1 - my) * (p.1 - my)).sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    QqFit { slope, intercept, r2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quantile_symmetry_and_known_values() {
        assert!((normal_quantile(0.5)).abs() < 1e-9);
        assert!((normal_quantile(0.975) - 1.959964).abs() < 1e-5);
        assert!((normal_quantile(0.9) - 1.281552).abs() < 1e-5);
        for p in [0.001, 0.01, 0.1, 0.3, 0.7, 0.99, 0.999] {
            assert!((normal_quantile(p) + normal_quantile(1.0 - p)).abs() < 1e-8, "{p}");
        }
    }

    #[test]
    #[should_panic(expected = "domain")]
    fn quantile_rejects_zero() {
        normal_quantile(0.0);
    }

    #[test]
    fn gaussian_sample_fits_line() {
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal_with(2.0, 3.0)).collect();
        let pts = qq_points(&xs);
        let fit = fit_line(&pts);
        assert!(fit.r2 > 0.995, "r2 {}", fit.r2);
        assert!((fit.slope - 3.0).abs() < 0.15, "slope {}", fit.slope);
        assert!((fit.intercept - 2.0).abs() < 0.15, "intercept {}", fit.intercept);
    }

    #[test]
    fn lognormal_log_quantiles_fit_but_raw_do_not() {
        // The paper's Figure 3 claim, in test form.
        let mut rng = Rng::new(5);
        let raw: Vec<f64> = (0..3000).map(|_| rng.log_normal(5.0, 1.5)).collect();
        let logged: Vec<f64> = raw.iter().map(|x| x.ln()).collect();
        let fit_log = fit_line(&qq_points(&logged));
        let fit_raw = fit_line(&qq_points(&raw));
        assert!(fit_log.r2 > 0.995, "log r2 {}", fit_log.r2);
        assert!(fit_raw.r2 < 0.9, "raw r2 {} unexpectedly linear", fit_raw.r2);
    }

    #[test]
    fn qq_points_sorted_and_sized() {
        let pts = qq_points(&[3.0, 1.0, 2.0]);
        assert_eq!(pts.len(), 3);
        assert!(pts[0].0 < pts[1].0 && pts[1].0 < pts[2].0);
        assert_eq!(pts[0].1, 1.0);
        assert_eq!(pts[2].1, 3.0);
    }
}
