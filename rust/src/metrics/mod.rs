//! Statistics for the paper's tables and figures: percentiles (Tables
//! 1/5/6/7), histograms (Figures 5/7/11/13), letter-value plots (Figure
//! 9), Q-Q analysis vs a normal distribution (Figure 3), and loss-curve
//! bookkeeping (Figures 4/6/8).

pub mod histogram;
pub mod letter_values;
pub mod percentile;
pub mod qq;

pub use histogram::Histogram;
pub use letter_values::letter_values;
pub use percentile::{percentile, percentiles, Summary};
pub use qq::{normal_quantile, qq_points, QqFit};
