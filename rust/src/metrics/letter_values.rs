//! Letter-value summaries (Hofmann, Wickham & Kafadar [92]) — the
//! boxplot-for-big-data behind the paper's Figure 9 (words per client
//! across the four datasets).
//!
//! Letter values are successive tail quantiles: M (median), F (fourths,
//! 25/75), E (eighths), D (sixteenths), ... stopping when the tail regions
//! contain too few points to estimate reliably (the standard rule: stop
//! when the depth falls below ~ log2(n) trustworthiness).

use super::percentile::percentile_sorted;

/// One letter-value level: label + lower/upper quantile values.
#[derive(Debug, Clone, PartialEq)]
pub struct LetterValue {
    pub label: char,
    /// Tail probability of this level (0.25 for F, 0.125 for E, ...).
    pub tail: f64,
    pub lower: f64,
    pub upper: f64,
}

/// Compute letter values of `xs`. Returns (median, levels from F outward).
pub fn letter_values(xs: &[f64]) -> (f64, Vec<LetterValue>) {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let median = percentile_sorted(&v, 50.0);

    // Number of levels per the letter-value rule: k = floor(log2 n) - 3,
    // at least 1 (F) when n >= 2.
    let max_levels = if n < 2 {
        0
    } else {
        (((n as f64).log2()).floor() as i64 - 3).max(1) as usize
    };
    let labels = ['F', 'E', 'D', 'C', 'B', 'A', 'Z', 'Y', 'X', 'W'];
    let mut out = Vec::new();
    let mut tail = 0.25;
    for i in 0..max_levels.min(labels.len()) {
        out.push(LetterValue {
            label: labels[i],
            tail,
            lower: percentile_sorted(&v, tail * 100.0),
            upper: percentile_sorted(&v, (1.0 - tail) * 100.0),
        });
        tail /= 2.0;
    }
    (median, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, gen_vec, prop_assert};

    #[test]
    fn median_and_fourths() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let (m, lv) = letter_values(&xs);
        assert!((m - 50.5).abs() < 1e-9);
        assert_eq!(lv[0].label, 'F');
        assert!((lv[0].lower - 25.75).abs() < 1e-9);
        assert!((lv[0].upper - 75.25).abs() < 1e-9);
    }

    #[test]
    fn level_count_grows_with_n() {
        let small: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let big: Vec<f64> = (0..10_000).map(|i| i as f64).collect();
        let (_, a) = letter_values(&small);
        let (_, b) = letter_values(&big);
        assert!(b.len() > a.len());
    }

    #[test]
    fn nesting_property() {
        // Each deeper letter value must contain the shallower one.
        check(50, |rng| {
            let xs = gen_vec(rng, 16..=500, |r| r.log_normal(3.0, 1.5));
            let (m, lv) = letter_values(&xs);
            let mut prev_lo = m;
            let mut prev_hi = m;
            for l in &lv {
                prop_assert(l.lower <= prev_lo + 1e-9, "lower not nested")?;
                prop_assert(l.upper >= prev_hi - 1e-9, "upper not nested")?;
                prev_lo = l.lower;
                prev_hi = l.upper;
            }
            Ok(())
        });
    }

    #[test]
    fn single_point() {
        let (m, lv) = letter_values(&[7.0]);
        assert_eq!(m, 7.0);
        assert!(lv.is_empty());
    }
}
