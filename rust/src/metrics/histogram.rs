//! Fixed-bin histograms for Figures 5/7/11/13 (pre-/post-personalization
//! loss distributions across clients) with log-scale support for Figure 1.

/// Equal-width histogram over [lo, hi]; out-of-range values clamp to the
/// edge bins (the paper's loss histograms have finite axes).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub n: u64,
    /// Bin values in log10 space (Figure 1's per-group-size axes).
    pub log_scale: bool,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Histogram { lo, hi, counts: vec![0; bins], n: 0, log_scale: false }
    }

    pub fn new_log10(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(lo > 0.0 && hi > lo);
        Histogram { lo: lo.log10(), hi: hi.log10(), counts: vec![0; bins], n: 0, log_scale: true }
    }

    pub fn add(&mut self, x: f64) {
        let x = if self.log_scale {
            if x <= 0.0 {
                self.lo
            } else {
                x.log10()
            }
        } else {
            x
        };
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let i = ((t * bins as f64).floor() as i64).clamp(0, bins as i64 - 1) as usize;
        self.counts[i] += 1;
        self.n += 1;
    }

    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Bin centers in data space.
    pub fn centers(&self) -> Vec<f64> {
        let bins = self.counts.len();
        let w = (self.hi - self.lo) / bins as f64;
        (0..bins)
            .map(|i| {
                let c = self.lo + (i as f64 + 0.5) * w;
                if self.log_scale {
                    10f64.powf(c)
                } else {
                    c
                }
            })
            .collect()
    }

    /// Fraction of mass in each bin.
    pub fn density(&self) -> Vec<f64> {
        let n = self.n.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / n).collect()
    }

    /// Fraction of mass at or below `x` — used to compare tails
    /// ("post-personalization distribution for FedAvg is extremely
    /// light-tailed", §5.2).
    pub fn cdf_at(&self, x: f64) -> f64 {
        let xv = if self.log_scale { x.max(1e-300).log10() } else { x };
        let bins = self.counts.len();
        let w = (self.hi - self.lo) / bins as f64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let right = self.lo + (i as f64 + 1.0) * w;
            if right <= xv {
                acc += c;
            }
        }
        acc as f64 / self.n.max(1) as f64
    }

    /// ASCII rendering for terminal reports.
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1);
        let centers = self.centers();
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width) / max as usize);
            out.push_str(&format!("{:>12.3} | {:<width$} {}\n", centers[i], bar, c));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add_all(&[0.5, 1.5, 9.5, -5.0, 50.0]);
        assert_eq!(h.n, 5);
        assert_eq!(h.counts[0], 2); // 0.5 and clamped -5.0
        assert_eq!(h.counts[9], 2); // 9.5 and clamped 50.0
        assert_eq!(h.counts[1], 1);
    }

    #[test]
    fn density_sums_to_one() {
        let mut h = Histogram::new(0.0, 1.0, 7);
        for i in 0..100 {
            h.add(i as f64 / 100.0);
        }
        let s: f64 = h.density().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn log_scale_bins() {
        let mut h = Histogram::new_log10(1.0, 1e6, 6);
        h.add(10.0);
        h.add(1e5);
        assert_eq!(h.counts[1], 1);
        assert_eq!(h.counts[5], 1);
        let centers = h.centers();
        assert!(centers[0] > 1.0 && centers[0] < 10.0);
    }

    #[test]
    fn cdf_monotone() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.add(i as f64 / 10.0);
        }
        let mut prev = -1.0;
        for x in [1.0, 3.0, 5.0, 9.0, 10.0] {
            let c = h.cdf_at(x);
            assert!(c >= prev);
            prev = c;
        }
        assert!((h.cdf_at(10.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn render_contains_bars() {
        let mut h = Histogram::new(0.0, 1.0, 2);
        h.add(0.1);
        h.add(0.2);
        h.add(0.9);
        let s = h.render(10);
        assert!(s.contains('#'));
        assert_eq!(s.lines().count(), 2);
    }
}
