//! Percentiles and distribution summaries (the 10th/25th/50th/75th/90th
//! columns of Tables 1, 6, 7 and the quantile rows of Table 5).

/// Linear-interpolated percentile of unsorted data, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Same, for pre-sorted data (no copy).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty data");
    assert!((0.0..=100.0).contains(&p));
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Several percentiles in one sort.
pub fn percentiles(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ps.iter().map(|&p| percentile_sorted(&v, p)).collect()
}

/// The paper's standard per-distribution summary row.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub total: f64,
    pub mean: f64,
    pub p10: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p90: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty data");
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total: f64 = v.iter().sum();
        Summary {
            count: v.len(),
            total,
            mean: total / v.len() as f64,
            p10: percentile_sorted(&v, 10.0),
            p25: percentile_sorted(&v, 25.0),
            median: percentile_sorted(&v, 50.0),
            p75: percentile_sorted(&v, 75.0),
            p90: percentile_sorted(&v, 90.0),
            min: v[0],
            max: v[v.len() - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, gen_vec, prop_assert};

    #[test]
    fn single_element() {
        assert_eq!(percentile(&[5.0], 0.0), 5.0);
        assert_eq!(percentile(&[5.0], 100.0), 5.0);
        assert_eq!(percentile(&[5.0], 50.0), 5.0);
    }

    #[test]
    fn interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn unsorted_input_ok() {
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), 2.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_panics() {
        percentile(&[], 50.0);
    }

    #[test]
    fn summary_consistency() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.total, 15.0);
    }

    #[test]
    fn percentile_monotone_property() {
        check(100, |rng| {
            let xs = gen_vec(rng, 1..=50, |r| r.next_f64() * 1000.0);
            let p1 = rng.next_f64() * 100.0;
            let p2 = rng.next_f64() * 100.0;
            let (lo, hi) = if p1 < p2 { (p1, p2) } else { (p2, p1) };
            prop_assert(
                percentile(&xs, lo) <= percentile(&xs, hi) + 1e-9,
                "percentile not monotone in p",
            )
        });
    }

    #[test]
    fn percentile_within_range_property() {
        check(100, |rng| {
            let xs = gen_vec(rng, 1..=50, |r| r.next_f64() * 10.0 - 5.0);
            let p = rng.next_f64() * 100.0;
            let v = percentile(&xs, p);
            let mn = xs.iter().cloned().fold(f64::INFINITY, f64::min);
            let mx = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            prop_assert(v >= mn - 1e-9 && v <= mx + 1e-9, "percentile outside data range")
        });
    }
}
