//! The paged storage engine: the substrate that turns the repo's dataset
//! formats from bulk-load-only artifacts into a real, appendable,
//! crash-safe store (the SQLite-lineage design the paper's TFF/SQL-backed
//! hierarchical format alludes to).
//!
//! Five layers, bottom-up:
//!
//! * [`page`] — the fixed 4 KiB page, shared with the immutable
//!   [`crate::formats::btree_index`];
//! * [`cache`] — an LRU page cache with pin/dirty tracking and hit/miss
//!   counters: the single knob that governs group-access cost;
//! * [`pager`] — page allocation, read-through-cache access, ordered
//!   flush;
//! * [`wal`] — a CRC-framed append-only log (reusing the TFRecord
//!   CRC32C) with replay-on-open, torn-tail-truncating recovery;
//! * [`btree`] — a mutable B+tree over the pager with page splits and
//!   copy-on-write above a committed watermark, so a crashed writer can
//!   always be recovered by replaying the WAL over the last durable
//!   tree.
//!
//! [`crate::formats::paged`] assembles these into the appendable group
//! store (`PagedStore`/`PagedReader`); [`crate::formats::hierarchical`]
//! reads its immutable B-tree through the same pager so its cache
//! behavior is configurable rather than hardcoded root-only.

pub mod btree;
pub mod cache;
pub mod page;
pub mod pager;
pub mod wal;

pub use btree::BTree;
pub use cache::{CacheStats, PageCache};
pub use page::{Page, PageId, NO_PAGE, PAGE_SIZE};
pub use pager::Pager;
pub use wal::{ReplayReport, WalWriter};
