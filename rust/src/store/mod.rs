//! The paged storage engine: the substrate that turns the repo's dataset
//! formats from bulk-load-only artifacts into a real, appendable,
//! crash-safe store (the SQLite-lineage design the paper's TFF/SQL-backed
//! hierarchical format alludes to).
//!
//! Seven layers, bottom-up:
//!
//! * [`vfs`] — the virtual filesystem: every store/format byte goes
//!   through the [`vfs::Vfs`]/[`vfs::VfsFile`] trait pair (SQLite's VFS
//!   design), with [`vfs::StdVfs`] (real disk, the default),
//!   [`vfs::MemVfs`] (in-memory files for disk-free tests/benches) and
//!   [`vfs::FaultVfs`] (deterministic fail/tear/crash injection — the
//!   substrate of the crash-matrix proof in
//!   `rust/tests/crash_matrix.rs`);
//! * [`page`] — the fixed 4 KiB page, shared with the immutable
//!   [`crate::formats::btree_index`];
//! * [`cache`] — an LRU page cache with pin/dirty tracking and hit/miss
//!   counters: the single knob that governs group-access cost;
//! * [`pager`] — page allocation, read-through-cache access, ordered
//!   flush (the exclusive write path), plus the [`pager::PageRead`]
//!   trait that lets tree walkers run over either pager;
//! * [`freelist`] — crash-safe space reclamation: pages the COW B+tree
//!   supersedes are freed into an epoch-tagged free list (durable as a
//!   SQLite-style linked trunk chain, published by each checkpoint's
//!   header swap), reused lowest-first by the pager, and gated so a
//!   pinned snapshot reader never sees a reachable page rewritten;
//! * [`shared`] — the concurrent read path: a `Send + Sync`
//!   [`shared::SharedPager`] with a sharded lock-per-bucket cache, and
//!   snapshot-bounded [`shared::SnapshotReader`] handles that keep every
//!   reader inside one committed checkpoint epoch;
//! * [`pins`] — cross-process snapshot pins: on-disk epoch pin files
//!   that extend the in-process snapshot registry across process
//!   boundaries, so a separate writer's reuse gate honors readers in
//!   other processes (the `grouper serve` deployment);
//! * [`wal`] — a CRC-framed append-only log (reusing the TFRecord
//!   CRC32C) with replay-on-open, torn-tail-truncating recovery;
//! * [`btree`] — a mutable B+tree over the pager with page splits and
//!   copy-on-write above a committed watermark, so a crashed writer can
//!   always be recovered by replaying the WAL over the last durable
//!   tree — and so concurrent readers of a committed root never see a
//!   page change under them.
//!
//! [`crate::formats::paged`] assembles these into the appendable group
//! store (`PagedStore`/`PagedReader`); [`crate::formats::hierarchical`]
//! reads its immutable B-tree through the same shared pager so its cache
//! behavior is configurable rather than hardcoded root-only. The full
//! layered narrative, including the crash-recovery and snapshot
//! invariants, lives in `docs/ARCHITECTURE.md` at the repo root.
#![deny(missing_docs)]

pub mod btree;
pub mod cache;
pub mod freelist;
pub mod page;
pub mod pager;
pub mod pins;
pub mod shared;
pub mod vfs;
pub mod wal;

pub use btree::BTree;
pub use cache::{CacheStats, PageCache};
pub use freelist::Freelist;
pub use page::{Page, PageId, NO_PAGE, PAGE_SIZE};
pub use pager::{PageRead, Pager};
pub use shared::{
    min_pinned_epoch, min_pinned_epoch_for, pin_count, pin_epoch, EpochPin, ReadSnapshot,
    SharedPager, SnapshotReader,
};
pub use vfs::{
    CrashImage, FaultPlan, FaultVfs, MemVfs, OpenMode, StdVfs, Vfs, VfsCursor, VfsFile,
};
pub use wal::{ReplayReport, WalMark, WalWriter};
