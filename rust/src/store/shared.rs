//! The concurrent read path: a shareable, `Send + Sync` pager over one
//! immutable-once-committed paged file.
//!
//! The exclusive [`super::pager::Pager`] is the write path: one owner,
//! `&mut self` everywhere, a single LRU cache. That is the right shape
//! for the appending store, but it serializes every reader — and a
//! FedAvg round reads its whole cohort's client datasets *concurrently*.
//! [`SharedPager`] is the read path the cohort needs:
//!
//! * the page cache is **sharded**: pages hash to one of a handful of
//!   `Mutex<PageCache>` buckets by page id, so concurrent readers on
//!   different pages rarely contend on the same lock (the shared-cache
//!   design SQLite/libsql use);
//! * disk reads use positional I/O (the [`super::vfs`] layer's
//!   `read_exact_at`, backed by `pread` on Unix), so no seek state is
//!   shared between threads at all;
//! * hit/miss/eviction counters and the disk-read counter survive the
//!   refactor: stats are summed across shards on demand.
//!
//! **Snapshot semantics.** A [`SharedPager`] by itself has no notion of
//! "current": readers go through a [`SnapshotReader`], a cheap handle
//! carrying a page-count *bound* taken from a committed store header
//! (see [`ReadSnapshot`]). The storage engine's copy-on-write contract —
//! pages below a committed watermark are never modified in place, and a
//! checkpoint publishes new state via a single header-page swap — means
//! every page below that bound is immutable for the lifetime of the
//! file. Two consequences:
//!
//! 1. caching is always safe: a cached committed page can never go
//!    stale, even while a writer appends to the same file;
//! 2. a reader opened at checkpoint epoch `E` (bound `B`) can never
//!    observe pages from a later epoch, because those live at ids
//!    `>= B` and the bound check rejects them.
//!
//! Page 0 (the header) is deliberately **never cached** here — it is the
//! one page a checkpoint rewrites in place. Snapshot acquisition reads
//! it fresh from disk via [`SharedPager::read_header_fresh`].
//!
//! **The snapshot registry.** With the free-list
//! ([`super::freelist`]), "committed pages are immutable" weakens to
//! "immutable while any snapshot can still reach them": a page freed at
//! epoch `F` may later be *reused* (rewritten) or truncated away. The
//! process-wide registry here tracks, per `(VFS instance, index path)`,
//! the epochs pinned by live readers ([`pin_epoch`] — `PagedReader`
//! holds a pin for its lifetime). The writer reads
//! [`min_pinned_epoch`] as its reuse gate: a page freed at `F` is
//! rewritten or truncated only when every pinned epoch is `>= F`, so a
//! pinned snapshot can never observe a page it can reach changing under
//! it. The registry here is per-process; readers in **other** processes
//! are covered by the on-disk pin layer ([`super::pins`]) — real-fs
//! readers hold a pin file alongside this registry entry, and the
//! writer's gate takes the minimum over both.
//! One consequence for cache soundness: a `SharedPager`'s cache is only
//! guaranteed fresh for snapshots whose epoch is pinned for the cache's
//! whole lifetime — which is exactly how `PagedReader` uses it (one
//! pager, one snapshot, one pin).

use std::collections::{BTreeMap, HashMap};
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use super::cache::{CachePolicy, CacheStats, FrameBudget, PageCache};
use super::page::{Page, PageId, PAGE_SIZE};
use super::pager::PageRead;
use super::vfs::{OpenMode, StdVfs, Vfs, VfsFile};

/// Number of independently-locked cache buckets. Small: the goal is to
/// let a handful of reader threads miss on different pages without
/// queueing on one mutex, not to scale to hundreds of cores.
const CACHE_SHARDS: usize = 8;

/// Opt-in tuning for the hot read path, threaded from the CLI through
/// the `PagedReader`/`ShardedPagedReader` open paths down to the
/// [`SharedPager`]. The default is the classic behavior: no mmap, no
/// vectored prefetch, strict per-shard LRU.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReadOpts {
    /// Map read-only files so cache misses on warm files are a memcpy.
    /// The pager maps its own index handle; callers that open further
    /// read-only files (the paged data file, whole-VFS wrapping via
    /// [`super::vfs::MmapVfs`]) apply the same mapping themselves.
    /// Always best-effort: files without an OS descriptor (MemVfs,
    /// FaultVfs) are served through the plain handle unchanged.
    pub mmap: bool,
    /// Maximum pages fetched per batched prefetch read; 0 disables
    /// vectored group scans.
    pub vectored_batch: usize,
    /// Replacement policy for the shared cache.
    /// [`CachePolicy::TwoQ`] also switches the shards from fixed
    /// per-shard capacities to one cross-shard [`FrameBudget`].
    pub policy: CachePolicy,
}

/// Pinned-epoch multiset per `(VFS instance id, index path)`.
type PinMap = HashMap<(u64, PathBuf), BTreeMap<u64, u32>>;

fn pin_registry() -> &'static Mutex<PinMap> {
    static PINS: OnceLock<Mutex<PinMap>> = OnceLock::new();
    PINS.get_or_init(|| Mutex::new(HashMap::new()))
}

fn lock_pins() -> std::sync::MutexGuard<'static, PinMap> {
    pin_registry().lock().unwrap_or_else(PoisonError::into_inner)
}

/// An RAII pin on one store's checkpoint epoch: while it lives, the
/// writer's free-list will neither reuse nor truncate any page freed at
/// a later epoch — every page this snapshot can reach stays byte-stable.
/// Dropped automatically when the owning reader goes away.
#[derive(Debug)]
pub struct EpochPin {
    vfs_id: u64,
    path: PathBuf,
    epoch: u64,
}

impl EpochPin {
    /// The pinned epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

impl Drop for EpochPin {
    fn drop(&mut self) {
        let mut pins = lock_pins();
        let key = (self.vfs_id, std::mem::take(&mut self.path));
        if let Some(epochs) = pins.get_mut(&key) {
            if let Some(n) = epochs.get_mut(&self.epoch) {
                *n -= 1;
                if *n == 0 {
                    epochs.remove(&self.epoch);
                }
            }
            if epochs.is_empty() {
                pins.remove(&key);
            }
        }
    }
}

/// Register a live snapshot at `epoch` on the store identified by
/// `(vfs_id, path)` — use the index file's path and
/// [`super::vfs::Vfs::instance_id`]. The pin lasts until the returned
/// guard is dropped.
pub fn pin_epoch(vfs_id: u64, path: &Path, epoch: u64) -> EpochPin {
    let mut pins = lock_pins();
    *pins
        .entry((vfs_id, path.to_path_buf()))
        .or_default()
        .entry(epoch)
        .or_insert(0) += 1;
    EpochPin { vfs_id, path: path.to_path_buf(), epoch }
}

/// The smallest epoch currently pinned on `(vfs_id, path)`, or `None`
/// when no reader is pinned — the writer's reuse gate (`None` means
/// every free entry is fair game, i.e. a gate of `u64::MAX`).
pub fn min_pinned_epoch(vfs_id: u64, path: &Path) -> Option<u64> {
    min_pinned_epoch_for(&(vfs_id, path.to_path_buf()))
}

/// Allocation-free variant of [`min_pinned_epoch`] for callers that
/// cache their registry key — the writer refreshes its reuse gate on
/// the append hot path, which should not rebuild a `PathBuf` per call.
pub fn min_pinned_epoch_for(key: &(u64, PathBuf)) -> Option<u64> {
    lock_pins().get(key).and_then(|epochs| epochs.keys().next().copied())
}

/// Live pins on `(vfs_id, path)` across all epochs — introspection for
/// tests and diagnostics (e.g. asserting that a sharded reader holds one
/// pin **per shard store**, so each shard's reuse gate sees it).
pub fn pin_count(vfs_id: u64, path: &Path) -> u32 {
    lock_pins()
        .get(&(vfs_id, path.to_path_buf()))
        .map(|epochs| epochs.values().sum())
        .unwrap_or(0)
}

/// A committed read snapshot: everything a reader handle needs to stay
/// inside one checkpoint's state.
///
/// Taken from a store header at open time. `bound` is the header's
/// committed page count — the first page id the snapshot must *not*
/// read; `epoch` is the WAL checkpoint epoch the header carried, kept
/// for introspection (readers over different epochs of one file report
/// which state they see).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReadSnapshot {
    /// Committed page count: ids `< bound` are frozen, ids `>= bound`
    /// belong to a later (possibly uncommitted) epoch.
    pub bound: u32,
    /// The checkpoint epoch that published this snapshot.
    pub epoch: u64,
}

/// A shareable, read-only pager: one open file + a sharded LRU page
/// cache. `Send + Sync`: share it (e.g. behind `std::sync::Arc`) and
/// read from as many threads as you like via [`SharedPager::reader`].
pub struct SharedPager {
    file: Arc<dyn VfsFile>,
    /// Pages the backing file held when last checked; grows on demand
    /// (a live writer appends to the same file).
    num_pages: AtomicU32,
    shards: Vec<Mutex<PageCache>>,
    /// Pages fetched from disk, header and cache misses alike.
    disk_reads: AtomicU64,
    /// Uncached header (page 0) fetches — the slice of `disk_reads`
    /// that no cache miss accounts for, kept separate so the identity
    /// `disk_reads == misses + header_reads` is checkable.
    header_reads: AtomicU64,
    /// Max pages per batched prefetch; 0 = vectored reads disabled.
    vectored_batch: usize,
    /// The `cache_pages` this pager was opened with (introspection: the
    /// hard bound on resident frames across all shards).
    frame_budget: usize,
}

fn lock_shard(shard: &Mutex<PageCache>) -> std::sync::MutexGuard<'_, PageCache> {
    // A panic inside PageCache would poison the mutex; the cache holds
    // only clean pages, so recovering the guard is always safe.
    shard.lock().unwrap_or_else(PoisonError::into_inner)
}

impl SharedPager {
    /// Open a paged file read-only for concurrent access on the real
    /// filesystem (equivalent to [`SharedPager::open_with`] over
    /// [`StdVfs`]). Exactly `cache_pages` cache frames are allocated in
    /// total, split across the lock shards (`cache_pages == 0` disables
    /// caching: every read goes to disk and counts a miss).
    ///
    /// # Errors
    /// Fails when the file cannot be opened or its metadata read.
    pub fn open(path: &Path, cache_pages: usize) -> io::Result<SharedPager> {
        SharedPager::open_with(&StdVfs, path, cache_pages)
    }

    /// Open a paged file read-only for concurrent access on `vfs`, with
    /// the default [`ReadOpts`] (strict per-shard LRU, no prefetch).
    ///
    /// # Errors
    /// Fails when the file cannot be opened or its metadata read.
    pub fn open_with(vfs: &dyn Vfs, path: &Path, cache_pages: usize) -> io::Result<SharedPager> {
        SharedPager::open_with_opts(vfs, path, cache_pages, ReadOpts::default())
    }

    /// Open a paged file read-only for concurrent access on `vfs` with
    /// explicit hot-read-path options.
    ///
    /// The frame budget is exact: across all shards, at most
    /// `cache_pages` frames are ever resident, and under the default
    /// LRU policy every one of them is allocated up front (the
    /// remainder of `cache_pages / nshards` goes one-per-shard to the
    /// first shards). Under [`CachePolicy::TwoQ`] each shard prepays
    /// one frame and draws the rest from one shared [`FrameBudget`],
    /// so a hot shard can use frames an idle shard never claims.
    ///
    /// # Errors
    /// Fails when the file cannot be opened or its metadata read.
    pub fn open_with_opts(
        vfs: &dyn Vfs,
        path: &Path,
        cache_pages: usize,
        opts: ReadOpts,
    ) -> io::Result<SharedPager> {
        let file = vfs.open(path, OpenMode::Read)?;
        let file = if opts.mmap {
            // Best-effort: falls back to the plain handle when the file
            // exposes no OS descriptor (MemVfs/FaultVfs) or the kernel
            // refuses the map. Reads are bit-identical either way.
            super::vfs::map_read_only(&file).unwrap_or(file)
        } else {
            file
        };
        let num_pages = (file.len()? / PAGE_SIZE as u64) as u32;
        // At least two frames per shard: a single-frame shard thrashes
        // on any strided pattern that alternates two pages of one
        // bucket. With no frames at all, one stats-only shard remains
        // so misses keep being counted.
        let nshards = if cache_pages == 0 {
            1
        } else {
            CACHE_SHARDS.min((cache_pages / 2).max(1))
        };
        let shards: Vec<Mutex<PageCache>> = match opts.policy {
            CachePolicy::Lru => {
                // Fixed split summing exactly to cache_pages: base
                // frames everywhere, remainder one-per-shard from the
                // front.
                let base = cache_pages / nshards;
                let rem = cache_pages % nshards;
                (0..nshards)
                    .map(|i| {
                        let cap = base + usize::from(i < rem);
                        Mutex::new(PageCache::with_policy(cap, CachePolicy::Lru))
                    })
                    .collect()
            }
            CachePolicy::TwoQ => {
                if cache_pages == 0 {
                    vec![Mutex::new(PageCache::with_policy(0, CachePolicy::TwoQ))]
                } else {
                    // One prepaid frame per shard (nshards <= cache_pages
                    // by construction), the rest in a shared pool any
                    // shard may claim.
                    let pool = Arc::new(FrameBudget::new(cache_pages - nshards));
                    (0..nshards)
                        .map(|_| {
                            Mutex::new(PageCache::with_budget(
                                cache_pages,
                                CachePolicy::TwoQ,
                                1,
                                pool.clone(),
                            ))
                        })
                        .collect()
                }
            }
        };
        Ok(SharedPager {
            file,
            num_pages: AtomicU32::new(num_pages),
            shards,
            disk_reads: AtomicU64::new(0),
            header_reads: AtomicU64::new(0),
            vectored_batch: opts.vectored_batch,
            frame_budget: cache_pages,
        })
    }

    /// Pages in the backing file as of the last bounds check (a live
    /// writer may have appended more since).
    pub fn num_pages(&self) -> u32 {
        self.num_pages.load(Ordering::Acquire)
    }

    /// A cheap per-thread (or per-call) read handle bounded by
    /// `snapshot`: ids `>= snapshot.bound` error instead of leaking a
    /// later epoch's pages.
    pub fn reader(&self, snapshot: ReadSnapshot) -> SnapshotReader<'_> {
        SnapshotReader { pager: self, snapshot }
    }

    /// Read page 0 straight from disk, bypassing the cache — the header
    /// is the one page a checkpoint rewrites in place, so a cached copy
    /// could describe a superseded epoch. Counted in
    /// [`SharedPager::header_reads`], not as a cache miss.
    ///
    /// # Errors
    /// Fails on I/O error or when the file has no complete page 0.
    pub fn read_header_fresh(&self) -> io::Result<Page> {
        let page = self.read_from_disk(0)?;
        self.header_reads.fetch_add(1, Ordering::Relaxed);
        Ok(page)
    }

    /// Aggregate hit/miss/eviction counters, summed across shards.
    ///
    /// Absent I/O errors the counters satisfy the identity
    /// `disk_reads == misses + header_reads` — every non-header disk
    /// fetch is accounted to exactly one tracked miss, including racing
    /// double-fills (each racer counts its own miss *and* its own disk
    /// read) and batched prefetch fetches.
    pub fn cache_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for shard in &self.shards {
            let s = lock_shard(shard).stats();
            total.hits += s.hits;
            total.misses += s.misses;
            total.evictions += s.evictions;
        }
        total
    }

    /// Pages fetched from disk so far (across all threads), including
    /// uncached header reads.
    pub fn disk_reads(&self) -> u64 {
        self.disk_reads.load(Ordering::Relaxed)
    }

    /// Uncached header (page 0) fetches so far — subtract from
    /// [`SharedPager::disk_reads`] to get the miss-driven fetch count.
    pub fn header_reads(&self) -> u64 {
        self.header_reads.load(Ordering::Relaxed)
    }

    /// The exact frame budget this pager was opened with: resident
    /// frames across all shards never exceed it.
    pub fn frame_budget(&self) -> usize {
        self.frame_budget
    }

    /// Frames currently resident across all shards.
    pub fn resident_frames(&self) -> usize {
        self.shards.iter().map(|s| lock_shard(s).len()).sum()
    }

    /// Sum of the shards' local frame capacities (under LRU this equals
    /// the full budget; under TwoQ each shard may locally grow to the
    /// whole budget, bounded globally by the shared pool).
    pub fn shard_capacity_total(&self) -> usize {
        self.shards.iter().map(|s| lock_shard(s).capacity()).sum()
    }

    /// True when `id` lies within the backing file, re-checking the file
    /// length once if the cached count says no (the writer may have
    /// grown the file since open).
    fn in_file(&self, id: PageId) -> io::Result<bool> {
        if id < self.num_pages.load(Ordering::Acquire) {
            return Ok(true);
        }
        let pages = (self.file.len()? / PAGE_SIZE as u64) as u32;
        self.num_pages.fetch_max(pages, Ordering::AcqRel);
        Ok(id < pages)
    }

    fn read_from_disk(&self, id: PageId) -> io::Result<Page> {
        let offset = id as u64 * PAGE_SIZE as u64;
        let mut buf = vec![0u8; PAGE_SIZE];
        self.file.read_exact_at(&mut buf, offset)?;
        self.disk_reads.fetch_add(1, Ordering::Relaxed);
        Page::from_vec(buf)
    }

    /// Cache-through read. Only called via a bounds-checked
    /// [`SnapshotReader`], so every page that lands in the cache is
    /// committed and immutable.
    fn read_cached(&self, id: PageId) -> io::Result<Page> {
        let shard = &self.shards[id as usize % self.shards.len()];
        {
            let mut cache = lock_shard(shard);
            if let Some(page) = cache.lookup(id) {
                return Ok(page.clone());
            }
        } // lock released across the disk read
        let page = self.read_from_disk(id)?;
        // Two threads can race the same miss; both inserts are the same
        // immutable bytes, so last-writer-wins is harmless. The victim
        // is never dirty (read-only cache), so there is no write-back.
        lock_shard(shard).insert(id, page.clone(), false)?;
        Ok(page)
    }

    /// Batched prefetch: fetch every absent page among `ids` (sorted,
    /// deduped, bound-checked by the caller) from disk, coalescing runs
    /// of adjacent ids into one positional read each. Each fetched page
    /// counts one miss and one disk read — the same accounting a demand
    /// miss produces — and is admitted cold (see
    /// [`PageCache::insert_prefetched`]).
    ///
    /// # Errors
    /// Any underlying read failure (callers treat prefetch as
    /// best-effort and fall back to demand reads).
    fn prefetch_pages(&self, ids: &[PageId]) -> io::Result<()> {
        let mut missing: Vec<PageId> = Vec::with_capacity(ids.len());
        for &id in ids {
            if !self.in_file(id)? {
                break; // sorted: every later id is even farther out
            }
            let shard = &self.shards[id as usize % self.shards.len()];
            let mut cache = lock_shard(shard);
            if !cache.contains(id) {
                cache.count_prefetch_misses(1);
                missing.push(id);
            }
        }
        let mut i = 0;
        while i < missing.len() {
            let mut j = i + 1;
            while j < missing.len() && missing[j] == missing[j - 1] + 1 {
                j += 1;
            }
            let run = &missing[i..j];
            let mut buf = vec![0u8; run.len() * PAGE_SIZE];
            self.file.read_exact_at(&mut buf, run[0] as u64 * PAGE_SIZE as u64)?;
            self.disk_reads.fetch_add(run.len() as u64, Ordering::Relaxed);
            for (k, &id) in run.iter().enumerate() {
                let page = Page::from_vec(buf[k * PAGE_SIZE..(k + 1) * PAGE_SIZE].to_vec())?;
                let shard = &self.shards[id as usize % self.shards.len()];
                lock_shard(shard).insert_prefetched(id, page)?;
            }
            i = j;
        }
        Ok(())
    }
}

/// A per-thread (or per-call) read handle borrowing a [`SharedPager`],
/// bounded by a [`ReadSnapshot`]. Cheap to create and clone; implements
/// [`PageRead`] so tree walkers are agnostic to which pager serves them.
#[derive(Clone)]
pub struct SnapshotReader<'p> {
    pager: &'p SharedPager,
    snapshot: ReadSnapshot,
}

impl SnapshotReader<'_> {
    /// The snapshot this handle is bounded by.
    pub fn snapshot(&self) -> ReadSnapshot {
        self.snapshot
    }
}

impl PageRead for SnapshotReader<'_> {
    /// # Errors
    /// `InvalidData` when `id` is outside the snapshot (it belongs to a
    /// later epoch, or past the end of the file); otherwise any I/O
    /// error from the underlying read.
    fn read_page(&mut self, id: PageId) -> io::Result<Page> {
        if id >= self.snapshot.bound {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "page {id} is outside this read snapshot (bound {}, epoch {})",
                    self.snapshot.bound, self.snapshot.epoch
                ),
            ));
        }
        if !self.pager.in_file(id)? {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("page {id} past the end of the backing file"),
            ));
        }
        self.pager.read_cached(id)
    }

    /// Vectored batched read of upcoming pages (no-op unless the pager
    /// was opened with a non-zero `vectored_batch`). Best-effort: I/O
    /// errors are swallowed here and resurface on the demand read.
    fn prefetch(&mut self, ids: &[PageId]) {
        let batch = self.pager.vectored_batch;
        if batch == 0 || ids.is_empty() {
            return;
        }
        let mut want: Vec<PageId> =
            ids.iter().copied().filter(|&id| id < self.snapshot.bound).collect();
        want.sort_unstable();
        want.dedup();
        want.truncate(batch);
        let _ = self.pager.prefetch_pages(&want);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::pager::Pager;
    use std::sync::Arc;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grouper_shared_pager_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Write `n` pages, page `i` tagged with `1000 + i`, and flush.
    fn build(name: &str, n: u32) -> std::path::PathBuf {
        let path = tmp(name);
        let _ = std::fs::remove_file(&path);
        let mut p = Pager::create(&path, 8).unwrap();
        for i in 0..n {
            let id = p.allocate().unwrap();
            p.update(id, |pg| pg.put_u32(0, 1000 + i)).unwrap();
        }
        p.flush().unwrap();
        path
    }

    #[test]
    fn shared_reads_match_disk_and_count_stats() {
        let path = build("basic.pages", 16);
        // Cache holds the whole file: the second pass must be all hits.
        let sp = Arc::new(SharedPager::open(&path, 32).unwrap());
        let mut r = sp.reader(ReadSnapshot { bound: 16, epoch: 0 });
        for i in 0..16u32 {
            assert_eq!(r.read_page(i).unwrap().get_u32(0), 1000 + i);
        }
        for i in 0..16u32 {
            assert_eq!(r.read_page(i).unwrap().get_u32(0), 1000 + i);
        }
        let s = sp.cache_stats();
        assert_eq!(s.hits + s.misses, 32);
        assert!(s.hits > 0, "second pass must hit the cache");
        assert!(sp.disk_reads() >= 16);
    }

    #[test]
    fn snapshot_bound_is_enforced() {
        let path = build("bound.pages", 8);
        let sp = Arc::new(SharedPager::open(&path, 8).unwrap());
        let mut r = sp.reader(ReadSnapshot { bound: 4, epoch: 3 });
        assert!(r.read_page(3).is_ok());
        let err = r.read_page(4).unwrap_err();
        assert!(err.to_string().contains("snapshot"), "{err}");
        // Past the end of the file entirely.
        let mut wide = sp.reader(ReadSnapshot { bound: 100, epoch: 3 });
        assert!(wide.read_page(50).is_err());
    }

    #[test]
    fn many_threads_agree_with_serial() {
        let path = build("threads.pages", 64);
        let sp = Arc::new(SharedPager::open(&path, 16).unwrap());
        let snap = ReadSnapshot { bound: 64, epoch: 0 };
        std::thread::scope(|scope| {
            for t in 0..8u32 {
                let sp = &sp;
                scope.spawn(move || {
                    let mut r = sp.reader(snap);
                    // Overlapping strided walks from different offsets.
                    for k in 0..256u32 {
                        let id = (k * 7 + t) % 64;
                        assert_eq!(r.read_page(id).unwrap().get_u32(0), 1000 + id);
                    }
                });
            }
        });
        let s = sp.cache_stats();
        assert_eq!(s.hits + s.misses, 8 * 256);
    }

    #[test]
    fn sees_pages_a_writer_appended_after_open() {
        let path = build("grow.pages", 4);
        let sp = Arc::new(SharedPager::open(&path, 8).unwrap());
        assert_eq!(sp.num_pages(), 4);
        // A writer (separate handle) appends and flushes 4 more pages.
        let mut w = Pager::open(&path, 8).unwrap();
        for i in 4..8u32 {
            let id = w.allocate().unwrap();
            w.update(id, |pg| pg.put_u32(0, 1000 + i)).unwrap();
        }
        w.flush().unwrap();
        // A snapshot taken after the append can read the new pages.
        let mut r = sp.reader(ReadSnapshot { bound: 8, epoch: 1 });
        assert_eq!(r.read_page(7).unwrap().get_u32(0), 1007);
    }

    #[test]
    fn pin_registry_tracks_the_minimum_and_releases_on_drop() {
        let path = std::path::Path::new("/registry/test.pstore");
        // Unique vfs id so parallel tests never share an entry.
        let vfs_id = 0xDEAD_0001;
        assert_eq!(min_pinned_epoch(vfs_id, path), None);
        assert_eq!(pin_count(vfs_id, path), 0);
        let p5 = pin_epoch(vfs_id, path, 5);
        let p3 = pin_epoch(vfs_id, path, 3);
        let p3b = pin_epoch(vfs_id, path, 3);
        assert_eq!(min_pinned_epoch(vfs_id, path), Some(3));
        assert_eq!(pin_count(vfs_id, path), 3);
        assert_eq!(p3.epoch(), 3);
        drop(p3);
        assert_eq!(min_pinned_epoch(vfs_id, path), Some(3), "second epoch-3 pin holds");
        drop(p3b);
        assert_eq!(min_pinned_epoch(vfs_id, path), Some(5));
        drop(p5);
        assert_eq!(min_pinned_epoch(vfs_id, path), None, "registry entry fully released");
        // Different vfs instances (same path) are independent stores.
        let other = pin_epoch(vfs_id + 1, path, 1);
        assert_eq!(min_pinned_epoch(vfs_id, path), None);
        assert_eq!(min_pinned_epoch(vfs_id + 1, path), Some(1));
        drop(other);
    }

    /// Satellite regression: the cache budget is exact. The old split
    /// truncated `cache_pages / nshards` (15 frames over 7 shards
    /// allocated 14) and `.max(1)` exceeded a zero budget.
    #[test]
    fn frame_budget_is_exact_for_adversarial_combos() {
        let path = build("budget.pages", 8);
        for cache_pages in [0usize, 1, 2, 3, 5, 7, 8, 13, 15, 16, 17, 31, 33, 64, 101] {
            let sp = SharedPager::open(&path, cache_pages).unwrap();
            assert_eq!(
                sp.shard_capacity_total(),
                cache_pages,
                "LRU shard split must sum exactly to the budget (cache_pages={cache_pages})"
            );
            assert_eq!(sp.frame_budget(), cache_pages);
        }
    }

    #[test]
    fn zero_budget_disables_caching_but_reads_still_work() {
        let path = build("zero.pages", 8);
        let sp = SharedPager::open(&path, 0).unwrap();
        let mut r = sp.reader(ReadSnapshot { bound: 8, epoch: 0 });
        for pass in 0..2 {
            for i in 0..8u32 {
                assert_eq!(r.read_page(i).unwrap().get_u32(0), 1000 + i, "pass {pass}");
            }
        }
        assert_eq!(sp.resident_frames(), 0, "nothing may be cached");
        let s = sp.cache_stats();
        assert_eq!((s.hits, s.misses), (0, 16), "every read is a tracked miss");
        assert_eq!(sp.disk_reads(), 16);
    }

    #[test]
    fn two_q_shared_budget_bounds_and_fills_resident_frames() {
        let path = build("twoq.pages", 64);
        for cache_pages in [1usize, 7, 15, 16, 33] {
            let opts = ReadOpts { policy: CachePolicy::TwoQ, ..Default::default() };
            let sp = SharedPager::open_with_opts(&StdVfs, &path, cache_pages, opts).unwrap();
            let mut r = sp.reader(ReadSnapshot { bound: 64, epoch: 0 });
            for pass in 0..2 {
                for i in 0..64u32 {
                    assert_eq!(r.read_page(i).unwrap().get_u32(0), 1000 + i, "pass {pass}");
                }
            }
            assert_eq!(
                sp.resident_frames(),
                cache_pages,
                "a saturating workload must use exactly the budget (cache_pages={cache_pages})"
            );
        }
    }

    /// Satellite regression: hits + misses, disk reads and header reads
    /// stay mutually consistent — `disk_reads == misses + header_reads`
    /// on the classic path, the vectored path, and under TwoQ.
    #[test]
    fn stats_identity_holds_across_policies_and_prefetch() {
        let path = build("identity.pages", 16);
        let variants = [
            ReadOpts::default(),
            ReadOpts { vectored_batch: 8, ..Default::default() },
            ReadOpts { policy: CachePolicy::TwoQ, ..Default::default() },
            ReadOpts { vectored_batch: 8, policy: CachePolicy::TwoQ, ..Default::default() },
        ];
        for opts in variants {
            let sp = SharedPager::open_with_opts(&StdVfs, &path, 8, opts).unwrap();
            sp.read_header_fresh().unwrap();
            let mut r = sp.reader(ReadSnapshot { bound: 16, epoch: 0 });
            r.prefetch(&(0..16u32).collect::<Vec<PageId>>());
            for i in 0..16u32 {
                assert_eq!(r.read_page(i).unwrap().get_u32(0), 1000 + i, "{opts:?}");
            }
            sp.read_header_fresh().unwrap();
            for i in (0..16u32).rev() {
                assert_eq!(r.read_page(i).unwrap().get_u32(0), 1000 + i, "{opts:?}");
            }
            let s = sp.cache_stats();
            assert_eq!(sp.header_reads(), 2, "{opts:?}");
            assert_eq!(
                sp.disk_reads(),
                s.misses + sp.header_reads(),
                "disk reads must equal misses + header reads ({opts:?})"
            );
        }
    }

    #[test]
    fn prefetch_is_a_noop_when_vectored_reads_are_off() {
        let path = build("noprefetch.pages", 8);
        let sp = SharedPager::open(&path, 8).unwrap();
        let mut r = sp.reader(ReadSnapshot { bound: 8, epoch: 0 });
        r.prefetch(&[0, 1, 2, 3]);
        assert_eq!(sp.disk_reads(), 0, "no batch size, no I/O");
        assert_eq!(sp.cache_stats().misses, 0);
        assert_eq!(sp.resident_frames(), 0);
    }

    #[test]
    fn shared_pager_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SharedPager>();
        assert_send_sync::<SnapshotReader<'static>>();
        assert_send_sync::<ReadSnapshot>();
    }
}
