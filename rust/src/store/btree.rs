//! A *mutable* B+tree over the pager — the successor of the bulk-load-only
//! [`crate::formats::btree_index`].
//!
//! Properties:
//!
//! * insert with page splits, so the tree grows incrementally — the
//!   appendable store's index never needs a rebuild;
//! * **copy-on-write above a committed watermark**: pages with id below
//!   [`BTree::watermark`] belong to the last durable checkpoint and are
//!   never modified in place — mutating one first copies it to a freshly
//!   allocated page (LMDB-style path copying). The previously committed
//!   tree therefore stays byte-identical on disk until the single-page
//!   header swap commits a new root, which is what makes WAL replay over
//!   a crashed store sound. Pages allocated since the last checkpoint
//!   ([`super::pager::Pager::is_fresh`] — fresh pages can sit *below*
//!   the watermark when the allocation reused a freed page) are mutated
//!   in place, so COW costs at most one copy per page per checkpoint
//!   interval. Each COW copy **frees** the superseded committed page
//!   into the pager's free list ([`super::freelist`]); the free becomes
//!   durable — and the page reusable — at the next checkpoint.
//!
//! Page layout (all little-endian):
//!
//! * leaf: `u8 tag=1 | u16 count | (u16 klen | u16 vlen | key | value)*`
//! * internal: `u8 tag=2 | u16 count | (u16 klen | key | u32 child)*`,
//!   where an entry's child covers keys `>=` its key and the first
//!   entry covers everything below the second (its key is the empty
//!   string at the root, so descent never falls off the left edge).
//!
//! No sibling pointers: range scans keep an explicit ancestor stack
//! (sibling links would dangle under COW, since copying a leaf would
//! invalidate its left neighbor's pointer).
//!
//! Duplicate keys are tolerated structurally but lookups return an
//! arbitrary matching row; the paged store only ever inserts unique
//! `group \0 seq` keys.

use std::io;

use super::page::{Page, PageId, NO_PAGE, PAGE_SIZE};
use super::pager::{PageRead, Pager};

const LEAF: u8 = 1;
const INTERNAL: u8 = 2;
const HDR: usize = 3; // tag + u16 count

/// Maximum `key.len() + value.len()` for one row. Sized so that **two**
/// max-size entries always fit one page (`3 + 2*(6 + MAX_ROW_BYTES) <=
/// PAGE_SIZE`): that is what guarantees an overflowing page always has a
/// split point where both halves fit, no matter how entry sizes are
/// distributed around the byte midpoint.
pub const MAX_ROW_BYTES: usize = 2000;

fn corrupt(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("btree: {msg}"))
}

type LeafEntries = Vec<(Vec<u8>, Vec<u8>)>;
type InternalEntries = Vec<(Vec<u8>, PageId)>;

fn decode_leaf(page: &Page) -> io::Result<LeafEntries> {
    let b = page.as_slice();
    let count = page.get_u16(1) as usize;
    let mut out = Vec::with_capacity(count);
    let mut p = HDR;
    for _ in 0..count {
        if p + 4 > PAGE_SIZE {
            return Err(corrupt("leaf entry header past page end"));
        }
        let klen = u16::from_le_bytes(b[p..p + 2].try_into().unwrap()) as usize;
        let vlen = u16::from_le_bytes(b[p + 2..p + 4].try_into().unwrap()) as usize;
        p += 4;
        if p + klen + vlen > PAGE_SIZE {
            return Err(corrupt("leaf entry body past page end"));
        }
        out.push((b[p..p + klen].to_vec(), b[p + klen..p + klen + vlen].to_vec()));
        p += klen + vlen;
    }
    Ok(out)
}

fn leaf_size(entries: &[(Vec<u8>, Vec<u8>)]) -> usize {
    HDR + entries.iter().map(|(k, v)| 4 + k.len() + v.len()).sum::<usize>()
}

fn encode_leaf(entries: &[(Vec<u8>, Vec<u8>)]) -> Page {
    debug_assert!(leaf_size(entries) <= PAGE_SIZE);
    let mut page = Page::zeroed();
    page.put_u8(0, LEAF);
    page.put_u16(1, entries.len() as u16);
    let mut p = HDR;
    for (k, v) in entries {
        page.put_u16(p, k.len() as u16);
        page.put_u16(p + 2, v.len() as u16);
        p += 4;
        page.put_bytes(p, k);
        p += k.len();
        page.put_bytes(p, v);
        p += v.len();
    }
    page
}

fn decode_internal(page: &Page) -> io::Result<InternalEntries> {
    let b = page.as_slice();
    let count = page.get_u16(1) as usize;
    let mut out = Vec::with_capacity(count);
    let mut p = HDR;
    for _ in 0..count {
        if p + 2 > PAGE_SIZE {
            return Err(corrupt("internal entry header past page end"));
        }
        let klen = u16::from_le_bytes(b[p..p + 2].try_into().unwrap()) as usize;
        p += 2;
        if p + klen + 4 > PAGE_SIZE {
            return Err(corrupt("internal entry body past page end"));
        }
        let key = b[p..p + klen].to_vec();
        p += klen;
        let child = u32::from_le_bytes(b[p..p + 4].try_into().unwrap());
        p += 4;
        out.push((key, child));
    }
    Ok(out)
}

fn internal_size(entries: &[(Vec<u8>, PageId)]) -> usize {
    HDR + entries.iter().map(|(k, _)| 6 + k.len()).sum::<usize>()
}

fn encode_internal(entries: &[(Vec<u8>, PageId)]) -> Page {
    debug_assert!(internal_size(entries) <= PAGE_SIZE);
    let mut page = Page::zeroed();
    page.put_u8(0, INTERNAL);
    page.put_u16(1, entries.len() as u16);
    let mut p = HDR;
    for (k, child) in entries {
        page.put_u16(p, k.len() as u16);
        p += 2;
        page.put_bytes(p, k);
        p += k.len();
        page.put_u32(p, *child);
        p += 4;
    }
    page
}

/// Split index for an overflowing entry list: near the byte midpoint,
/// adjusted so BOTH halves fit a page. Both halves are non-empty.
/// [`MAX_ROW_BYTES`] guarantees an adjusted point exists: two halves
/// overflowing at once would need more than two pages of entries, but an
/// overflowing page holds at most one previously-fitting page plus one
/// bounded entry.
fn split_index<T>(entries: &[T], size_of: impl Fn(&T) -> usize) -> usize {
    debug_assert!(entries.len() >= 2);
    let sizes: Vec<usize> = entries.iter().map(&size_of).collect();
    let total: usize = sizes.iter().sum();
    let fits = |s: usize| HDR + s <= PAGE_SIZE;
    // Walk to the byte midpoint.
    let mut at = 1usize;
    let mut left = sizes[0];
    while at < entries.len() - 1 && left * 2 < total {
        left += sizes[at];
        at += 1;
    }
    // Shrink the left half until it fits.
    while at > 1 && !fits(left) {
        at -= 1;
        left -= sizes[at];
    }
    // Grow the left half while the right overflows (cannot reintroduce a
    // left overflow — see above).
    while at < entries.len() - 1 && !fits(total - left) {
        left += sizes[at];
        at += 1;
    }
    debug_assert!(fits(left) && fits(total - left), "unsplittable page");
    at
}

enum Ins {
    /// Subtree absorbed the row; its (possibly COW-copied) root is the id.
    Done(PageId),
    /// Subtree split: (left id, first key of right, right id).
    Split(PageId, Vec<u8>, PageId),
}

/// A page's entries, decoded. Decoding straight off the cache's borrowed
/// page (one statement, borrow released immediately) avoids cloning the
/// 4 KiB page on every visit.
enum Decoded {
    Leaf(LeafEntries),
    Internal(InternalEntries),
}

fn decode_page(page: &Page) -> io::Result<Decoded> {
    match page.get_u8(0) {
        LEAF => Ok(Decoded::Leaf(decode_leaf(page)?)),
        INTERNAL => {
            let entries = decode_internal(page)?;
            if entries.is_empty() {
                return Err(corrupt("empty internal page"));
            }
            Ok(Decoded::Internal(entries))
        }
        t => Err(corrupt(&format!("bad page tag {t}"))),
    }
}

/// The mutable B+tree. Holds no pager — every operation borrows one, so a
/// store can own both without self-reference.
pub struct BTree {
    root: PageId,
    num_rows: u64,
    watermark: u32,
}

impl BTree {
    /// An empty tree; pages with id below `watermark` are committed and
    /// will be copied rather than mutated.
    pub fn new_empty(watermark: u32) -> BTree {
        BTree { root: NO_PAGE, num_rows: 0, watermark }
    }

    /// Re-attach to a tree persisted in a header.
    pub fn from_header(root: PageId, num_rows: u64, watermark: u32) -> BTree {
        BTree { root, num_rows, watermark }
    }

    /// Current root page id ([`NO_PAGE`] when empty).
    pub fn root(&self) -> PageId {
        self.root
    }

    /// Rows inserted so far.
    pub fn num_rows(&self) -> u64 {
        self.num_rows
    }

    /// The committed watermark: pages below it are copy-on-write.
    pub fn watermark(&self) -> u32 {
        self.watermark
    }

    /// Advance the committed watermark (after a checkpoint flushed and
    /// published every current page).
    pub fn set_watermark(&mut self, watermark: u32) {
        self.watermark = watermark;
    }

    /// A page is mutable in place when it belongs to no committed state:
    /// either its id is past the committed watermark, or it was
    /// (re)allocated since the last checkpoint — a reused free page
    /// carries a low id but is just as uncommitted as a tail page.
    fn is_mutable(&self, pager: &Pager, id: PageId) -> bool {
        id >= self.watermark || pager.is_fresh(id)
    }

    /// Write a page image to `id` when mutable, else copy-on-write to a
    /// fresh page (freeing the superseded committed page into the
    /// pager's free list); returns the id actually holding the data.
    fn write_page(&self, pager: &mut Pager, id: Option<PageId>, page: Page) -> io::Result<PageId> {
        match id {
            Some(id) if self.is_mutable(pager, id) => {
                pager.put(id, page)?;
                Ok(id)
            }
            Some(id) => {
                let nid = pager.allocate()?;
                pager.put(nid, page)?;
                pager.free(id)?;
                Ok(nid)
            }
            None => {
                let nid = pager.allocate()?;
                pager.put(nid, page)?;
                Ok(nid)
            }
        }
    }

    /// Insert one row. Keys need not be unique, but see the module note.
    /// Insertion requires the exclusive [`Pager`] (it allocates and
    /// writes pages); reads are generic over [`PageRead`] instead.
    ///
    /// # Errors
    /// `InvalidInput` when `key.len() + value.len()` exceeds
    /// [`MAX_ROW_BYTES`]; otherwise any pager I/O error (the tree may
    /// have grown pages, but the row is not counted until success).
    pub fn insert(&mut self, pager: &mut Pager, key: &[u8], value: &[u8]) -> io::Result<()> {
        if key.len() + value.len() > MAX_ROW_BYTES {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "btree row of {} bytes (key {} + value {}) exceeds the {} byte page budget",
                    key.len() + value.len(),
                    key.len(),
                    value.len(),
                    MAX_ROW_BYTES
                ),
            ));
        }
        if self.root == NO_PAGE {
            let entries = vec![(key.to_vec(), value.to_vec())];
            self.root = self.write_page(pager, None, encode_leaf(&entries))?;
            self.num_rows = 1;
            return Ok(());
        }
        match self.insert_rec(pager, self.root, key, value)? {
            Ins::Done(new_root) => self.root = new_root,
            Ins::Split(left, sep, right) => {
                let entries = vec![(Vec::new(), left), (sep, right)];
                self.root = self.write_page(pager, None, encode_internal(&entries))?;
            }
        }
        self.num_rows += 1;
        Ok(())
    }

    fn insert_rec(
        &self,
        pager: &mut Pager,
        id: PageId,
        key: &[u8],
        value: &[u8],
    ) -> io::Result<Ins> {
        // Bind before matching: a match-scrutinee temporary would keep
        // the cache borrow alive through the arms, which re-borrow pager.
        let decoded = decode_page(pager.read(id)?)?;
        match decoded {
            Decoded::Leaf(mut entries) => {
                let pos = entries.partition_point(|(k, _)| k.as_slice() <= key);
                entries.insert(pos, (key.to_vec(), value.to_vec()));
                if leaf_size(&entries) <= PAGE_SIZE {
                    let nid = self.write_page(pager, Some(id), encode_leaf(&entries))?;
                    Ok(Ins::Done(nid))
                } else {
                    let at = split_index(&entries, |(k, v)| 4 + k.len() + v.len());
                    let right: LeafEntries = entries.split_off(at);
                    let sep = right[0].0.clone();
                    let left_id = self.write_page(pager, Some(id), encode_leaf(&entries))?;
                    let right_id = self.write_page(pager, None, encode_leaf(&right))?;
                    Ok(Ins::Split(left_id, sep, right_id))
                }
            }
            Decoded::Internal(mut entries) => {
                let idx = match entries.partition_point(|(k, _)| k.as_slice() <= key) {
                    0 => 0,
                    n => n - 1,
                };
                let child = entries[idx].1;
                match self.insert_rec(pager, child, key, value)? {
                    Ins::Done(new_child) => {
                        if new_child == child {
                            return Ok(Ins::Done(id));
                        }
                        entries[idx].1 = new_child;
                        let nid = self.write_page(pager, Some(id), encode_internal(&entries))?;
                        Ok(Ins::Done(nid))
                    }
                    Ins::Split(left, sep, right) => {
                        entries[idx].1 = left;
                        entries.insert(idx + 1, (sep, right));
                        if internal_size(&entries) <= PAGE_SIZE {
                            let nid =
                                self.write_page(pager, Some(id), encode_internal(&entries))?;
                            Ok(Ins::Done(nid))
                        } else {
                            let at = split_index(&entries, |(k, _)| 6 + k.len());
                            let right_half: InternalEntries = entries.split_off(at);
                            let sep2 = right_half[0].0.clone();
                            let left_id =
                                self.write_page(pager, Some(id), encode_internal(&entries))?;
                            let right_id =
                                self.write_page(pager, None, encode_internal(&right_half))?;
                            Ok(Ins::Split(left_id, sep2, right_id))
                        }
                    }
                }
            }
        }
    }

    /// Visit rows with key `>= start` in order while `f` returns true.
    /// Generic over [`PageRead`]: pass the exclusive [`Pager`] or a
    /// concurrent [`super::shared::SnapshotReader`].
    ///
    /// # Errors
    /// Any page-read failure, or `InvalidData` on a corrupt node.
    pub fn scan_from<R: PageRead>(
        &self,
        pager: &mut R,
        start: &[u8],
        mut f: impl FnMut(&[u8], &[u8]) -> bool,
    ) -> io::Result<()> {
        if self.root == NO_PAGE {
            return Ok(());
        }
        // Ancestor stack: (decoded internal entries, child index in use).
        let mut stack: Vec<(InternalEntries, usize)> = Vec::new();
        let mut node = self.root;
        let mut entries: LeafEntries;
        loop {
            match decode_page(&pager.read_page(node)?)? {
                Decoded::Leaf(l) => {
                    entries = l;
                    break;
                }
                Decoded::Internal(ents) => {
                    let idx = match ents.partition_point(|(k, _)| k.as_slice() <= start) {
                        0 => 0,
                        n => n - 1,
                    };
                    // The scan will walk this node's children left to
                    // right from `idx`: batch-read the run ahead of the
                    // chain (no-op on pagers without a vectored path).
                    let ahead: Vec<PageId> = ents[idx..].iter().map(|(_, c)| *c).collect();
                    pager.prefetch(&ahead);
                    node = ents[idx].1;
                    stack.push((ents, idx));
                }
            }
        }
        let mut i = entries.partition_point(|(k, _)| k.as_slice() < start);
        'leaves: loop {
            while i < entries.len() {
                let (k, v) = &entries[i];
                if !f(k, v) {
                    return Ok(());
                }
                i += 1;
            }
            // Advance to the next leaf: climb until an ancestor has a
            // right sibling, then descend its leftmost path.
            loop {
                let (ents, idx) = match stack.pop() {
                    None => return Ok(()), // past the last leaf
                    Some(level) => level,
                };
                if idx + 1 < ents.len() {
                    // Read ahead over the siblings the chain will visit
                    // next (already-resident pages cost one untracked
                    // probe each).
                    let ahead: Vec<PageId> = ents[idx + 1..].iter().map(|(_, c)| *c).collect();
                    pager.prefetch(&ahead);
                    let mut node = ents[idx + 1].1;
                    stack.push((ents, idx + 1));
                    loop {
                        match decode_page(&pager.read_page(node)?)? {
                            Decoded::Leaf(l) => {
                                entries = l;
                                i = 0;
                                continue 'leaves;
                            }
                            Decoded::Internal(es) => {
                                let ahead: Vec<PageId> = es.iter().map(|(_, c)| *c).collect();
                                pager.prefetch(&ahead);
                                node = es[0].1;
                                stack.push((es, 0));
                            }
                        }
                    }
                }
            }
        }
    }

    /// Visit every row whose key starts with `prefix`, in key order;
    /// returns how many were visited.
    ///
    /// # Errors
    /// Same conditions as [`BTree::scan_from`].
    pub fn scan_prefix<R: PageRead>(
        &self,
        pager: &mut R,
        prefix: &[u8],
        mut f: impl FnMut(&[u8], &[u8]),
    ) -> io::Result<usize> {
        let mut n = 0usize;
        self.scan_from(pager, prefix, |k, v| {
            if k.starts_with(prefix) {
                f(k, v);
                n += 1;
                true
            } else {
                false // keys are ordered: once past the prefix, stop
            }
        })?;
        Ok(n)
    }

    /// Exact-match lookup (first matching row).
    ///
    /// # Errors
    /// Same conditions as [`BTree::scan_from`].
    pub fn get<R: PageRead>(&self, pager: &mut R, key: &[u8]) -> io::Result<Option<Vec<u8>>> {
        let mut out = None;
        self.scan_from(pager, key, |k, v| {
            if k == key {
                out = Some(v.to_vec());
            }
            false // only the first row >= key can match exactly
        })?;
        Ok(out)
    }

    /// The compaction pass: copy every page of the tree through a fresh
    /// allocation — children first, so each copied internal node points
    /// at its children's new homes — and free every superseded page into
    /// the pager's free list. Because [`Pager::allocate`] prefers the
    /// *lowest* reusable free page, a rewrite migrates the tree toward
    /// the file head; repeated rewrite → checkpoint rounds (see
    /// `formats::paged`'s `compact`) converge on a dense prefix whose
    /// freed tail can be truncated. Returns the number of pages copied.
    ///
    /// Call on a just-checkpointed tree (every page committed): the old
    /// tree stays intact on disk until the caller's next header swap, so
    /// a crash mid-rewrite recovers the pre-rewrite state.
    ///
    /// # Errors
    /// Any pager allocation/read/write failure, or `InvalidData` on a
    /// corrupt node. On error the tree handle must be discarded (the
    /// rewrite is half-applied in memory); the durable state is
    /// untouched.
    pub fn rewrite(&mut self, pager: &mut Pager) -> io::Result<u32> {
        if self.root == NO_PAGE {
            return Ok(0);
        }
        let (new_root, copied) = self.rewrite_rec(pager, self.root)?;
        self.root = new_root;
        Ok(copied)
    }

    fn rewrite_rec(&self, pager: &mut Pager, id: PageId) -> io::Result<(PageId, u32)> {
        let decoded = decode_page(pager.read(id)?)?;
        let (page, copied) = match decoded {
            Decoded::Leaf(entries) => (encode_leaf(&entries), 1),
            Decoded::Internal(mut entries) => {
                let mut copied = 1;
                for entry in &mut entries {
                    let (nid, c) = self.rewrite_rec(pager, entry.1)?;
                    entry.1 = nid;
                    copied += c;
                }
                (encode_internal(&entries), copied)
            }
        };
        let nid = pager.allocate()?;
        pager.put(nid, page)?;
        pager.free(id)?;
        Ok((nid, copied))
    }

    /// Tree depth (1 = a single leaf; 0 = empty).
    ///
    /// # Errors
    /// Same conditions as [`BTree::scan_from`].
    pub fn depth<R: PageRead>(&self, pager: &mut R) -> io::Result<u32> {
        if self.root == NO_PAGE {
            return Ok(0);
        }
        let mut node = self.root;
        let mut depth = 1u32;
        loop {
            match decode_page(&pager.read_page(node)?)? {
                Decoded::Leaf(_) => return Ok(depth),
                Decoded::Internal(ents) => {
                    node = ents[0].1;
                    depth += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, gen_bytes, prop_assert, prop_assert_eq};
    use std::collections::BTreeMap;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grouper_store_btree_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Pager with a header page already allocated (mirrors real usage
    /// where page 0 is a file header, never a tree node).
    fn fresh_pager(name: &str, cache: usize) -> Pager {
        let path = tmp(name);
        let _ = std::fs::remove_file(&path);
        let mut pager = Pager::create(&path, cache).unwrap();
        let hdr = pager.allocate().unwrap();
        assert_eq!(hdr, 0);
        pager
    }

    #[test]
    fn empty_tree() {
        let mut pager = fresh_pager("empty.pages", 8);
        let tree = BTree::new_empty(1);
        assert_eq!(tree.get(&mut pager, b"x").unwrap(), None);
        assert_eq!(tree.num_rows(), 0);
        assert_eq!(tree.depth(&mut pager).unwrap(), 0);
        let mut n = 0;
        tree.scan_from(&mut pager, b"", |_, _| {
            n += 1;
            true
        })
        .unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn insert_and_lookup_small() {
        let mut pager = fresh_pager("small.pages", 8);
        let mut tree = BTree::new_empty(1);
        tree.insert(&mut pager, b"b", b"2").unwrap();
        tree.insert(&mut pager, b"a", b"1").unwrap();
        tree.insert(&mut pager, b"c", b"3").unwrap();
        assert_eq!(tree.get(&mut pager, b"a").unwrap(), Some(b"1".to_vec()));
        assert_eq!(tree.get(&mut pager, b"b").unwrap(), Some(b"2".to_vec()));
        assert_eq!(tree.get(&mut pager, b"c").unwrap(), Some(b"3".to_vec()));
        assert_eq!(tree.get(&mut pager, b"d").unwrap(), None);
        assert_eq!(tree.get(&mut pager, b"0").unwrap(), None);
        assert_eq!(tree.num_rows(), 3);
        assert_eq!(tree.depth(&mut pager).unwrap(), 1);
    }

    #[test]
    fn oversized_row_is_a_clean_error() {
        let mut pager = fresh_pager("oversize.pages", 8);
        let mut tree = BTree::new_empty(1);
        let err = tree
            .insert(&mut pager, &vec![b'k'; 3000], &vec![b'v'; 2000])
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("exceeds"));
        assert_eq!(tree.num_rows(), 0);
    }

    #[test]
    fn many_inserts_split_pages_and_scan_in_order() {
        let mut pager = fresh_pager("splits.pages", 16);
        let mut tree = BTree::new_empty(1);
        // Interleaved insertion order; values bulky enough to force many
        // leaf splits and at least one internal level.
        let n = 3000u32;
        for i in 0..n {
            let key = format!("k{:06}", (i * 7919) % n).into_bytes();
            let val = vec![(i % 251) as u8; 40];
            tree.insert(&mut pager, &key, &val).unwrap();
        }
        assert_eq!(tree.num_rows(), n as u64);
        assert!(tree.depth(&mut pager).unwrap() >= 2, "expected splits");
        // Full scan is sorted and complete.
        let mut prev: Option<Vec<u8>> = None;
        let mut count = 0u32;
        tree.scan_from(&mut pager, b"", |k, _| {
            if let Some(p) = &prev {
                assert!(p.as_slice() <= k, "scan out of order");
            }
            prev = Some(k.to_vec());
            count += 1;
            true
        })
        .unwrap();
        assert_eq!(count, n);
        // Point lookup: (i * 7919) % n == 0 only for i == 0, value 0u8s.
        assert_eq!(tree.get(&mut pager, b"k000000").unwrap(), Some(vec![0u8; 40]));
        assert_eq!(tree.get(&mut pager, b"k999999").unwrap(), None);
    }

    #[test]
    fn near_max_rows_split_without_overflow() {
        // Entries at the row-size ceiling (~2004 bytes each: at most two
        // per page) in a size-varying interleaved order — the adversarial
        // input for the fit-aware split. Must never panic in encode_*.
        let mut pager = fresh_pager("bigrows.pages", 32);
        let mut tree = BTree::new_empty(1);
        for i in 0..120u32 {
            let klen = 500 + ((i as usize * 379) % 1400);
            let mut key = vec![b'k'; klen];
            key.extend_from_slice(&i.to_be_bytes());
            let vlen = MAX_ROW_BYTES - key.len();
            tree.insert(&mut pager, &key, &vec![7u8; vlen]).unwrap();
        }
        let mut n = 0u32;
        let mut prev: Option<Vec<u8>> = None;
        tree.scan_from(&mut pager, b"", |k, _| {
            if let Some(p) = &prev {
                assert!(p.as_slice() <= k, "scan out of order");
            }
            prev = Some(k.to_vec());
            n += 1;
            true
        })
        .unwrap();
        assert_eq!(n, 120);
        assert!(tree.depth(&mut pager).unwrap() >= 2);
    }

    #[test]
    fn prefix_scan_returns_exactly_the_prefix_range() {
        let mut pager = fresh_pager("prefix.pages", 16);
        let mut tree = BTree::new_empty(1);
        for g in 0..40u32 {
            for s in 0..25u32 {
                let key = format!("group-{g:03}/{s:04}").into_bytes();
                tree.insert(&mut pager, &key, &s.to_le_bytes()).unwrap();
            }
        }
        let mut got = Vec::new();
        let n = tree
            .scan_prefix(&mut pager, b"group-017/", |_k, v| {
                got.push(u32::from_le_bytes(v.try_into().unwrap()));
            })
            .unwrap();
        assert_eq!(n, 25);
        assert_eq!(got, (0..25).collect::<Vec<u32>>());
        assert_eq!(tree.scan_prefix(&mut pager, b"group-999/", |_, _| {}).unwrap(), 0);
    }

    #[test]
    fn property_equivalent_to_btreemap() {
        check(12, |rng| {
            let mut pager = fresh_pager(&format!("prop{}.pages", rng.next_u32()), 32);
            let mut tree = BTree::new_empty(1);
            let mut model: BTreeMap<Vec<u8>, Vec<u8>> = BTreeMap::new();
            let n = 1 + rng.gen_range_usize(500);
            for i in 0..n {
                let mut key = gen_bytes(rng, 1..=24);
                key.extend_from_slice(&(i as u32).to_be_bytes()); // unique
                let val = gen_bytes(rng, 0..=60);
                tree.insert(&mut pager, &key, &val).unwrap();
                model.insert(key, val);
            }
            prop_assert_eq(tree.num_rows(), model.len() as u64, "row count")?;
            // Lookups agree (present and absent keys).
            for (k, v) in model.iter().take(50) {
                prop_assert_eq(tree.get(&mut pager, k).unwrap(), Some(v.clone()), "get")?;
            }
            for _ in 0..20 {
                let absent = gen_bytes(rng, 25..=30);
                prop_assert_eq(
                    tree.get(&mut pager, &absent).unwrap(),
                    model.get(&absent).cloned(),
                    "absent get",
                )?;
            }
            // Full scan equals the model's sorted iteration.
            let mut scanned: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
            tree.scan_from(&mut pager, b"", |k, v| {
                scanned.push((k.to_vec(), v.to_vec()));
                true
            })
            .unwrap();
            let want: Vec<(Vec<u8>, Vec<u8>)> =
                model.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
            prop_assert_eq(scanned, want, "full scan")
        });
    }

    #[test]
    fn cow_preserves_committed_snapshot() {
        let path = tmp("cow.pages");
        let _ = std::fs::remove_file(&path);
        let mut pager = Pager::create(&path, 64).unwrap();
        pager.allocate().unwrap(); // header page 0
        let mut tree = BTree::new_empty(1);
        for i in 0..800u32 {
            let key = format!("row{:05}", i).into_bytes();
            tree.insert(&mut pager, &key, &vec![7u8; 30]).unwrap();
        }
        // "Checkpoint": flush, advance the watermark, clear freshness.
        pager.flush().unwrap();
        let committed_root = tree.root();
        let committed_rows = tree.num_rows();
        let committed_pages = pager.num_pages();
        tree.set_watermark(committed_pages);
        pager.mark_committed();
        // Keep appending beyond the checkpoint.
        for i in 800..1600u32 {
            let key = format!("row{:05}", i).into_bytes();
            tree.insert(&mut pager, &key, &vec![8u8; 30]).unwrap();
        }
        pager.flush().unwrap();
        // The live tree sees everything…
        let mut live = 0u64;
        tree.scan_from(&mut pager, b"", |_, _| {
            live += 1;
            true
        })
        .unwrap();
        assert_eq!(live, 1600);
        // …while the committed snapshot, re-read from its old root, is
        // still exactly the first 800 rows: no committed page was touched.
        let snapshot = BTree::from_header(committed_root, committed_rows, committed_pages);
        let mut snap_keys: Vec<Vec<u8>> = Vec::new();
        snapshot
            .scan_from(&mut pager, b"", |k, _| {
                snap_keys.push(k.to_vec());
                true
            })
            .unwrap();
        assert_eq!(snap_keys.len(), 800, "snapshot must be isolated from later inserts");
        for (i, k) in snap_keys.iter().enumerate() {
            assert_eq!(k, &format!("row{:05}", i).into_bytes());
        }
    }

    #[test]
    fn cow_frees_superseded_pages_and_reuse_stops_file_growth() {
        let path = tmp("cowfree.pages");
        let _ = std::fs::remove_file(&path);
        let mut pager = Pager::create(&path, 64).unwrap();
        pager.allocate().unwrap(); // header page 0
        let mut tree = BTree::new_empty(1);
        for i in 0..600u32 {
            tree.insert(&mut pager, format!("k{i:05}").as_bytes(), &[7u8; 30]).unwrap();
        }
        // Checkpoint, then mutate across the watermark: every COW copy
        // must free its superseded page.
        let checkpoint = |pager: &mut Pager, tree: &mut BTree, epoch: u64| {
            pager.write_freelist(epoch).unwrap();
            pager.flush().unwrap();
            tree.set_watermark(pager.num_pages());
            pager.mark_committed();
        };
        checkpoint(&mut pager, &mut tree, 1);
        assert_eq!(pager.free_page_count(), 0);
        for i in 600..700u32 {
            tree.insert(&mut pager, format!("k{i:05}").as_bytes(), &[8u8; 30]).unwrap();
        }
        assert!(
            pager.free_page_count() > 0,
            "COW supersessions must land in the free list"
        );
        // Steady-state churn with periodic checkpoints, run twice with
        // the same per-round workload: first with reuse blocked (gate 0
        // simulates a reader pinned forever — the pre-free-list leak
        // slope), then with reuse open. Reuse must grow the file
        // strictly slower.
        checkpoint(&mut pager, &mut tree, 2);
        let churn = |pager: &mut Pager, tree: &mut BTree, tag: u64, epoch0: u64| {
            let before = pager.num_pages();
            for round in 0..3u64 {
                for i in 0..120u32 {
                    let key = format!("r{tag}-{round}-{i:05}");
                    tree.insert(pager, key.as_bytes(), &[9u8; 30]).unwrap();
                }
                checkpoint(pager, tree, epoch0 + round);
            }
            pager.num_pages() - before
        };
        pager.set_reuse_gate(0);
        let grown_gated = churn(&mut pager, &mut tree, 0, 3);
        pager.set_reuse_gate(u64::MAX);
        let grown_reusing = churn(&mut pager, &mut tree, 1, 6);
        assert!(
            grown_reusing < grown_gated,
            "reuse ({grown_reusing} pages) must grow the file slower than the \
             leak-everything slope ({grown_gated} pages)"
        );
        // The tree is still exactly right.
        let mut count = 0u32;
        let mut prev: Option<Vec<u8>> = None;
        tree.scan_from(&mut pager, b"", |k, _| {
            if let Some(p) = &prev {
                assert!(p.as_slice() <= k);
            }
            prev = Some(k.to_vec());
            count += 1;
            true
        })
        .unwrap();
        assert_eq!(count, 700 + 6 * 120);
    }

    #[test]
    fn rewrite_copies_the_tree_and_frees_every_old_page() {
        let path = tmp("rewrite.pages");
        let _ = std::fs::remove_file(&path);
        let mut pager = Pager::create(&path, 64).unwrap();
        pager.allocate().unwrap(); // header page 0
        let mut tree = BTree::new_empty(1);
        for i in 0..500u32 {
            tree.insert(&mut pager, format!("k{i:05}").as_bytes(), &[5u8; 40]).unwrap();
        }
        pager.flush().unwrap();
        tree.set_watermark(pager.num_pages());
        pager.mark_committed();
        let old_root = tree.root();
        let tree_pages = pager.num_pages() - 1; // all pages but the header
        let free_before = pager.free_page_count();
        let copied = tree.rewrite(&mut pager).unwrap();
        assert_eq!(copied, tree_pages, "every tree page is copied exactly once");
        assert_ne!(tree.root(), old_root);
        assert_eq!(
            pager.free_page_count() - free_before,
            copied,
            "every superseded page is freed"
        );
        // Contents are untouched.
        let mut count = 0u32;
        tree.scan_from(&mut pager, b"", |_, _| {
            count += 1;
            true
        })
        .unwrap();
        assert_eq!(count, 500);
        assert_eq!(tree.get(&mut pager, b"k00123").unwrap(), Some(vec![5u8; 40]));
    }

    #[test]
    fn property_scan_from_is_a_suffix() {
        check(10, |rng| {
            let mut pager = fresh_pager(&format!("suffix{}.pages", rng.next_u32()), 32);
            let mut tree = BTree::new_empty(1);
            let mut model: BTreeMap<Vec<u8>, Vec<u8>> = BTreeMap::new();
            for i in 0..300usize {
                let mut key = gen_bytes(rng, 1..=10);
                key.extend_from_slice(&(i as u32).to_be_bytes());
                let val = gen_bytes(rng, 0..=20);
                tree.insert(&mut pager, &key, &val).unwrap();
                model.insert(key, val);
            }
            let start = gen_bytes(rng, 0..=8);
            let mut got: Vec<Vec<u8>> = Vec::new();
            tree.scan_from(&mut pager, &start, |k, _| {
                got.push(k.to_vec());
                true
            })
            .unwrap();
            let want: Vec<Vec<u8>> =
                model.range(start.clone()..).map(|(k, _)| k.clone()).collect();
            prop_assert_eq(got.len(), want.len(), "suffix length")?;
            prop_assert(got == want, "scan_from must equal BTreeMap::range(start..)")
        });
    }
}
