//! CRC-framed append-only write-ahead log with replay-on-open recovery.
//!
//! Frame layout, reusing the TFRecord checksum machinery
//! ([`crate::records::crc32c`]):
//!
//! ```text
//! u32 LE  payload length
//! u32 LE  masked crc32c(payload)
//! [u8]    payload
//! ```
//!
//! Recovery contract (SQLite-journal style, by valid prefix): [`replay`]
//! visits every intact frame in order and stops at the first torn or
//! corrupt one — a partial header, a partial payload, or a checksum
//! mismatch all mean "the log ends here". [`WalWriter::open`] then
//! truncates the torn tail away so new appends continue from the last
//! valid frame. A log is bounded by one checkpoint interval, so replay
//! reads it whole.

use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Seek, SeekFrom, Write};
use std::path::Path;

use crate::records::crc32c::{crc32c, masked_crc32c, unmask};

/// What [`replay`] found.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplayReport {
    /// Intact frames visited.
    pub records: u64,
    /// Byte length of the valid prefix.
    pub valid_bytes: u64,
    /// Bytes of torn/corrupt tail beyond the valid prefix.
    pub torn_bytes: u64,
}

/// Scan the log at `path`, calling `f` for every intact frame in order.
/// A missing file is an empty log, not an error.
///
/// # Errors
/// Fails only on a real I/O error reading the file, or when `f` itself
/// errors; torn/corrupt tails end the scan without erroring.
pub fn replay(
    path: &Path,
    mut f: impl FnMut(&[u8]) -> io::Result<()>,
) -> io::Result<ReplayReport> {
    let data = match std::fs::read(path) {
        Ok(d) => d,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(ReplayReport::default()),
        Err(e) => return Err(e),
    };
    let mut pos = 0usize;
    let mut records = 0u64;
    while pos + 8 <= data.len() {
        let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
        if pos + 8 + len > data.len() {
            break; // torn payload
        }
        let payload = &data[pos + 8..pos + 8 + len];
        if unmask(crc) != crc32c(payload) {
            break; // corrupt frame: treat as end of log
        }
        f(payload)?;
        pos += 8 + len;
        records += 1;
    }
    Ok(ReplayReport {
        records,
        valid_bytes: pos as u64,
        torn_bytes: (data.len() - pos) as u64,
    })
}

/// Cheap hot-journal probe: does the log start with at least one intact
/// frame? Reads only the first frame instead of replaying the whole log.
///
/// # Errors
/// Fails only on a real I/O error; a missing or torn log is `Ok(false)`.
pub fn has_valid_records(path: &Path) -> io::Result<bool> {
    use std::io::Read;
    let mut f = match File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(false),
        Err(e) => return Err(e),
    };
    let mut header = [0u8; 8];
    let mut filled = 0usize;
    while filled < header.len() {
        match f.read(&mut header[filled..])? {
            0 => return Ok(false), // shorter than one frame header
            n => filled += n,
        }
    }
    let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as u64;
    let crc = u32::from_le_bytes(header[4..8].try_into().unwrap());
    // A garbage length (torn header) must not drive a huge allocation.
    if 8 + len > f.metadata()?.len() {
        return Ok(false);
    }
    let mut payload = vec![0u8; len as usize];
    if f.read_exact(&mut payload).is_err() {
        return Ok(false); // torn first payload
    }
    Ok(unmask(crc) == crc32c(&payload))
}

/// Appender over a log file. Appends are buffered; [`WalWriter::commit`]
/// is the durability point (flush + fsync).
pub struct WalWriter {
    w: BufWriter<File>,
    len: u64,
    appended: u64,
}

impl WalWriter {
    /// Open for appending, truncating everything past `valid_bytes` (as
    /// reported by [`replay`]) so a torn tail never survives.
    ///
    /// # Errors
    /// Fails when the parent directory cannot be created or the file
    /// cannot be opened/truncated.
    pub fn open(path: &Path, valid_bytes: u64) -> io::Result<WalWriter> {
        if let Some(d) = path.parent() {
            std::fs::create_dir_all(d)?;
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)?;
        file.set_len(valid_bytes)?;
        file.seek(SeekFrom::Start(valid_bytes))?;
        Ok(WalWriter { w: BufWriter::new(file), len: valid_bytes, appended: 0 })
    }

    /// Append one frame (buffered).
    ///
    /// # Errors
    /// `InvalidInput` when the payload exceeds the u32 length field;
    /// otherwise any buffered-write failure.
    pub fn append(&mut self, payload: &[u8]) -> io::Result<()> {
        if payload.len() > u32::MAX as usize {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "wal payload exceeds u32 length",
            ));
        }
        self.w.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.w.write_all(&masked_crc32c(payload).to_le_bytes())?;
        self.w.write_all(payload)?;
        self.len += 8 + payload.len() as u64;
        self.appended += 1;
        Ok(())
    }

    /// Total valid log bytes (including frames appended this session).
    pub fn len_bytes(&self) -> u64 {
        self.len
    }

    /// Frames appended by this writer (not counting pre-existing ones).
    pub fn records_appended(&self) -> u64 {
        self.appended
    }

    /// Durability point: flush buffers and fsync.
    ///
    /// # Errors
    /// Any flush or fsync failure; nothing is durable until it returns
    /// `Ok`.
    pub fn commit(&mut self) -> io::Result<()> {
        self.w.flush()?;
        self.w.get_ref().sync_data()
    }

    /// Checkpoint: everything logged is now reflected in the main file —
    /// drop the log.
    ///
    /// # Errors
    /// Any truncation, seek or fsync failure.
    pub fn reset(&mut self) -> io::Result<()> {
        self.w.flush()?;
        let f = self.w.get_mut();
        f.set_len(0)?;
        f.seek(SeekFrom::Start(0))?;
        f.sync_data()?;
        self.len = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, gen_bytes, gen_vec, prop_assert_eq};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grouper_wal_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn collect(path: &Path) -> (Vec<Vec<u8>>, ReplayReport) {
        let mut out = Vec::new();
        let report = replay(path, |p| {
            out.push(p.to_vec());
            Ok(())
        })
        .unwrap();
        (out, report)
    }

    #[test]
    fn missing_log_is_empty() {
        let (recs, report) = collect(&tmp("nonexistent.wal"));
        assert!(recs.is_empty());
        assert_eq!(report, ReplayReport::default());
    }

    #[test]
    fn append_commit_replay_roundtrip() {
        let path = tmp("roundtrip.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path, 0).unwrap();
        w.append(b"alpha").unwrap();
        w.append(b"").unwrap();
        w.append(&[9u8; 300]).unwrap();
        w.commit().unwrap();
        let (recs, report) = collect(&path);
        assert_eq!(recs, vec![b"alpha".to_vec(), Vec::new(), vec![9u8; 300]]);
        assert_eq!(report.records, 3);
        assert_eq!(report.torn_bytes, 0);
        assert_eq!(report.valid_bytes, w.len_bytes());
    }

    #[test]
    fn torn_tail_is_dropped_then_truncated() {
        let path = tmp("torn.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path, 0).unwrap();
        w.append(b"one").unwrap();
        w.append(b"two").unwrap();
        w.commit().unwrap();
        drop(w);
        // Simulate a torn write: half a frame of garbage at the tail.
        {
            use std::io::Write;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0x44, 0x33, 0x22]).unwrap();
        }
        let (recs, report) = collect(&path);
        assert_eq!(recs, vec![b"one".to_vec(), b"two".to_vec()]);
        assert_eq!(report.torn_bytes, 3);
        // Reopen at the valid prefix: tail is truncated, appends continue.
        let mut w = WalWriter::open(&path, report.valid_bytes).unwrap();
        w.append(b"three").unwrap();
        w.commit().unwrap();
        let (recs, report) = collect(&path);
        assert_eq!(recs, vec![b"one".to_vec(), b"two".to_vec(), b"three".to_vec()]);
        assert_eq!(report.torn_bytes, 0);
    }

    #[test]
    fn corrupt_frame_ends_the_log() {
        let path = tmp("corrupt.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path, 0).unwrap();
        w.append(b"good").unwrap();
        w.append(b"bad").unwrap();
        w.commit().unwrap();
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF; // flip a payload bit in the second frame
        std::fs::write(&path, &bytes).unwrap();
        let (recs, report) = collect(&path);
        assert_eq!(recs, vec![b"good".to_vec()]);
        assert!(report.torn_bytes > 0);
    }

    #[test]
    fn has_valid_records_probe() {
        let path = tmp("probe.wal");
        let _ = std::fs::remove_file(&path);
        assert!(!has_valid_records(&path).unwrap(), "missing log");
        let mut w = WalWriter::open(&path, 0).unwrap();
        assert!(!has_valid_records(&path).unwrap(), "empty log");
        w.append(b"rec").unwrap();
        w.commit().unwrap();
        assert!(has_valid_records(&path).unwrap());
        drop(w);
        // Garbage-only log (torn header with a huge claimed length).
        std::fs::write(&path, [0xFFu8; 6]).unwrap();
        assert!(!has_valid_records(&path).unwrap());
        std::fs::write(&path, [0xFFu8; 20]).unwrap();
        assert!(!has_valid_records(&path).unwrap());
    }

    #[test]
    fn reset_empties_the_log() {
        let path = tmp("reset.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path, 0).unwrap();
        w.append(b"x").unwrap();
        w.reset().unwrap();
        assert_eq!(w.len_bytes(), 0);
        w.append(b"y").unwrap();
        w.commit().unwrap();
        let (recs, _) = collect(&path);
        assert_eq!(recs, vec![b"y".to_vec()]);
    }

    /// Property: replay of a randomly truncated log is exactly the longest
    /// frame-prefix that fits.
    #[test]
    fn property_truncation_yields_prefix() {
        check(40, |rng| {
            let records = gen_vec(rng, 1..=12, |r| gen_bytes(r, 0..=60));
            let path = tmp(&format!("prop{}.wal", rng.next_u32()));
            let _ = std::fs::remove_file(&path);
            let mut w = WalWriter::open(&path, 0).unwrap();
            let mut boundaries = vec![0u64];
            for rec in &records {
                w.append(rec).unwrap();
                boundaries.push(w.len_bytes());
            }
            w.commit().unwrap();
            drop(w);
            let full = std::fs::read(&path).unwrap();
            let cut = rng.gen_range_usize(full.len() + 1);
            std::fs::write(&path, &full[..cut]).unwrap();
            // Expected: all records whose frame end <= cut.
            let expect: Vec<Vec<u8>> = records
                .iter()
                .zip(boundaries.iter().skip(1))
                .filter(|(_, end)| **end <= cut as u64)
                .map(|(r, _)| r.clone())
                .collect();
            let (got, report) = collect(&path);
            std::fs::remove_file(&path).ok();
            prop_assert_eq(got.len(), expect.len(), "record count")?;
            prop_assert_eq(got, expect, "prefix property")?;
            prop_assert_eq(
                report.valid_bytes + report.torn_bytes,
                cut as u64,
                "byte accounting",
            )
        });
    }
}
