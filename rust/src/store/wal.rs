//! CRC-framed append-only write-ahead log with replay-on-open recovery.
//!
//! Frame layout, reusing the TFRecord checksum machinery
//! ([`crate::records::crc32c`]):
//!
//! ```text
//! u32 LE  payload length
//! u32 LE  masked crc32c(payload)
//! [u8]    payload
//! ```
//!
//! Recovery contract (SQLite-journal style, by valid prefix): [`replay`]
//! visits every intact frame in order and stops at the first torn or
//! corrupt one — a partial header, a partial payload, or a checksum
//! mismatch all mean "the log ends here". [`WalWriter::open`] then
//! truncates the torn tail away so new appends continue from the last
//! valid frame. A log is bounded by one checkpoint interval, so replay
//! reads it whole.

use std::io;
use std::path::Path;
use std::sync::Arc;

use super::vfs::{OpenMode, StdVfs, Vfs, VfsFile};
use crate::records::crc32c::{crc32c, masked_crc32c, unmask};

/// Appends are buffered in memory and written out in chunks of at least
/// this size (or at [`WalWriter::commit`]/[`WalWriter::reset`]).
const WAL_FLUSH_BYTES: usize = 64 * 1024;

/// What [`replay`] found.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplayReport {
    /// Intact frames visited.
    pub records: u64,
    /// Byte length of the valid prefix.
    pub valid_bytes: u64,
    /// Bytes of torn/corrupt tail beyond the valid prefix.
    pub torn_bytes: u64,
}

/// Scan the log at `path`, calling `f` for every intact frame in order.
/// A missing file is an empty log, not an error.
///
/// # Errors
/// Fails only on a real I/O error reading the file, or when `f` itself
/// errors; torn/corrupt tails end the scan without erroring.
pub fn replay(
    path: &Path,
    f: impl FnMut(&[u8]) -> io::Result<()>,
) -> io::Result<ReplayReport> {
    replay_with(&StdVfs, path, f)
}

/// [`replay`] over an explicit [`Vfs`].
///
/// # Errors
/// Same conditions as [`replay`].
pub fn replay_with(
    vfs: &dyn Vfs,
    path: &Path,
    f: impl FnMut(&[u8]) -> io::Result<()>,
) -> io::Result<ReplayReport> {
    let data = match vfs.read(path) {
        Ok(d) => d,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(ReplayReport::default()),
        Err(e) => return Err(e),
    };
    scan_slice(&data, f)
}

/// [`replay`] over an in-memory byte slice instead of a file: visit
/// every intact frame in order and report the valid prefix. This is the
/// replay seam replication ships bytes through — a primary uses it to
/// find the frame boundary it may stream up to, and a follower uses it
/// to prove a received chunk is whole frames (all bytes consumed, zero
/// torn tail) *before* appending any of them to its own log.
///
/// # Errors
/// Fails only when `f` itself errors; torn/corrupt tails end the scan
/// without erroring.
pub fn scan_slice(
    data: &[u8],
    mut f: impl FnMut(&[u8]) -> io::Result<()>,
) -> io::Result<ReplayReport> {
    let mut pos = 0usize;
    let mut records = 0u64;
    while pos + 8 <= data.len() {
        let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
        if pos + 8 + len > data.len() {
            break; // torn payload
        }
        let payload = &data[pos + 8..pos + 8 + len];
        if unmask(crc) != crc32c(payload) {
            break; // corrupt frame: treat as end of log
        }
        f(payload)?;
        pos += 8 + len;
        records += 1;
    }
    Ok(ReplayReport {
        records,
        valid_bytes: pos as u64,
        torn_bytes: (data.len() - pos) as u64,
    })
}

/// Cheap hot-journal probe: does the log start with at least one intact
/// frame? Reads only the first frame instead of replaying the whole log.
///
/// # Errors
/// Fails only on a real I/O error; a missing or torn log is `Ok(false)`.
pub fn has_valid_records(path: &Path) -> io::Result<bool> {
    has_valid_records_with(&StdVfs, path)
}

/// [`has_valid_records`] over an explicit [`Vfs`].
///
/// # Errors
/// Same conditions as [`has_valid_records`].
pub fn has_valid_records_with(vfs: &dyn Vfs, path: &Path) -> io::Result<bool> {
    let f = match vfs.open(path, OpenMode::Read) {
        Ok(f) => f,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(false),
        Err(e) => return Err(e),
    };
    let file_len = f.len()?;
    if file_len < 8 {
        return Ok(false); // shorter than one frame header
    }
    let mut header = [0u8; 8];
    f.read_exact_at(&mut header, 0)?;
    let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as u64;
    let crc = u32::from_le_bytes(header[4..8].try_into().unwrap());
    // A garbage length (torn header) must not drive a huge allocation.
    if 8 + len > file_len {
        return Ok(false);
    }
    let mut payload = vec![0u8; len as usize];
    if f.read_exact_at(&mut payload, 8).is_err() {
        return Ok(false); // torn first payload
    }
    Ok(unmask(crc) == crc32c(&payload))
}

/// A log position captured by [`WalWriter::mark`] for
/// [`WalWriter::rewind`].
#[derive(Clone, Copy, Debug)]
pub struct WalMark {
    len: u64,
    appended: u64,
}

/// Appender over a log file. Appends are buffered; [`WalWriter::commit`]
/// is the durability point (flush + fsync).
pub struct WalWriter {
    file: Arc<dyn VfsFile>,
    /// Log bytes already written to the file (valid prefix + flushed
    /// appends); the next buffer flush lands here.
    flushed: u64,
    /// Frames appended but not yet written out.
    buf: Vec<u8>,
    appended: u64,
    /// True when bytes at or past `flushed` may hold garbage (a torn
    /// chunk) or withdrawn frames that an immediate truncation failed to
    /// remove. [`WalWriter::commit`] must truncate them away before it
    /// promises durability, so they can never be fsynced and replayed.
    dirty_tail: bool,
}

impl WalWriter {
    /// Open for appending on the real filesystem (equivalent to
    /// [`WalWriter::open_with`] over [`StdVfs`]), truncating everything
    /// past `valid_bytes` (as reported by [`replay`]) so a torn tail
    /// never survives.
    ///
    /// # Errors
    /// Fails when the parent directory cannot be created or the file
    /// cannot be opened/truncated.
    pub fn open(path: &Path, valid_bytes: u64) -> io::Result<WalWriter> {
        WalWriter::open_with(&StdVfs, path, valid_bytes)
    }

    /// Open for appending on `vfs`, truncating everything past
    /// `valid_bytes`.
    ///
    /// # Errors
    /// Fails when the parent directory cannot be created or the file
    /// cannot be opened/truncated.
    pub fn open_with(vfs: &dyn Vfs, path: &Path, valid_bytes: u64) -> io::Result<WalWriter> {
        if let Some(d) = path.parent() {
            vfs.create_dir_all(d)?;
        }
        let file = vfs.open(path, OpenMode::Create)?;
        file.set_len(valid_bytes)?;
        Ok(WalWriter {
            file,
            flushed: valid_bytes,
            buf: Vec::new(),
            appended: 0,
            dirty_tail: false,
        })
    }

    /// Write the append buffer out at the current tail. On failure the
    /// buffer is kept (and `flushed` not advanced), so a retry rewrites
    /// the same bytes at the same offset; the possibly-torn chunk is
    /// truncated away immediately (best effort) or at the latest by the
    /// next [`WalWriter::commit`] — it could contain complete frames
    /// that a later rollback means to withdraw.
    fn flush_buf(&mut self) -> io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        if let Err(e) = self.file.write_all_at(&self.buf, self.flushed) {
            if self.file.set_len(self.flushed).is_err() {
                self.dirty_tail = true;
            }
            return Err(e);
        }
        self.flushed += self.buf.len() as u64;
        self.buf.clear();
        Ok(())
    }

    /// Append one frame (buffered).
    ///
    /// # Errors
    /// `InvalidInput` when the payload exceeds the u32 length field;
    /// otherwise any buffered-write failure. On failure the frame is
    /// rolled back out of the buffer: an append reported as failed can
    /// never become durable at a later commit.
    pub fn append(&mut self, payload: &[u8]) -> io::Result<()> {
        if payload.len() > u32::MAX as usize {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "wal payload exceeds u32 length",
            ));
        }
        let rollback = self.buf.len();
        self.buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(&masked_crc32c(payload).to_le_bytes());
        self.buf.extend_from_slice(payload);
        self.appended += 1;
        if self.buf.len() >= WAL_FLUSH_BYTES {
            if let Err(e) = self.flush_buf() {
                // Earlier frames stay queued (their appends were reported
                // Ok); only this frame is withdrawn. Any torn bytes past
                // `flushed` are overwritten by the next flush or dropped
                // as a torn tail at the next open.
                self.buf.truncate(rollback);
                self.appended -= 1;
                return Err(e);
            }
        }
        Ok(())
    }

    /// Total valid log bytes (including frames appended this session).
    pub fn len_bytes(&self) -> u64 {
        self.flushed + self.buf.len() as u64
    }

    /// A log position to [`WalWriter::rewind`] back to. Take it *before*
    /// appending a frame whose application might still fail.
    pub fn mark(&self) -> WalMark {
        WalMark { len: self.len_bytes(), appended: self.appended }
    }

    /// Rewind the log to `mark`, withdrawing every frame appended after
    /// it — the store's escape hatch when *applying* a logged operation
    /// fails: a withdrawn frame can never become durable at a later
    /// commit, so recovery can never replay an append the caller was
    /// told failed.
    ///
    /// Infallible: frames still in the memory buffer are dropped for
    /// free; frames a flush already carried into the file are truncated
    /// away immediately when possible, and otherwise marked as a dirty
    /// tail that [`WalWriter::commit`] removes before it promises
    /// anything. (One residual, inherent to a redo-only log: if both the
    /// truncation here *and* every later commit fail, and the process
    /// then crashes while the kernel flushes the sick disk's pages
    /// anyway, recovery will replay the withdrawn frames.)
    ///
    /// # Panics
    /// Debug-asserts that `mark` is not in the future of the log.
    pub fn rewind(&mut self, mark: WalMark) {
        debug_assert!(mark.len <= self.len_bytes(), "rewind mark is ahead of the log");
        if mark.len >= self.flushed {
            // Everything past the mark is still buffered.
            self.buf.truncate((mark.len - self.flushed) as usize);
        } else {
            // A flush carried frames past the mark into the file: drop
            // the buffered tail and truncate the file back.
            self.buf.clear();
            self.flushed = mark.len;
            if self.file.set_len(mark.len).is_err() {
                self.dirty_tail = true;
            }
        }
        self.appended = mark.appended;
    }

    /// Frames appended by this writer (not counting pre-existing ones).
    pub fn records_appended(&self) -> u64 {
        self.appended
    }

    /// Durability point: flush buffers and fsync. Equivalent to
    /// [`WalWriter::commit_no_sync`] followed by [`WalWriter::sync`] —
    /// the split a group-commit coordinator uses to flush many logs
    /// first and amortize the fsyncs afterwards.
    ///
    /// # Errors
    /// Any truncation, flush or fsync failure; nothing is durable until
    /// it returns `Ok`.
    pub fn commit(&mut self) -> io::Result<()> {
        self.commit_no_sync()?;
        self.sync()
    }

    /// The write half of a commit: truncate any dirty tail and flush the
    /// append buffer, but do **not** fsync — nothing becomes durable
    /// until a later [`WalWriter::sync`] (or full [`WalWriter::commit`])
    /// succeeds. Crash-wise this is indistinguishable from buffered
    /// appends: recovery sees either a valid prefix or a torn tail it
    /// discards.
    ///
    /// # Errors
    /// Any truncation or flush failure.
    pub fn commit_no_sync(&mut self) -> io::Result<()> {
        if self.dirty_tail {
            // Garbage or withdrawn frames may sit past the logical tail;
            // they must never survive into a durability promise.
            self.file.set_len(self.flushed)?;
            self.dirty_tail = false;
        }
        self.flush_buf()
    }

    /// The durability half of a commit: fsync the log file. Only a
    /// meaningful promise after [`WalWriter::commit_no_sync`] returned
    /// `Ok` with no appends in between.
    ///
    /// # Errors
    /// Any fsync failure; on error nothing new is durable.
    pub fn sync(&mut self) -> io::Result<()> {
        self.file.sync()
    }

    /// Checkpoint: everything logged is now reflected in the main file —
    /// drop the log (including any appends still buffered in memory).
    ///
    /// # Errors
    /// Any truncation or fsync failure.
    pub fn reset(&mut self) -> io::Result<()> {
        self.buf.clear();
        self.file.set_len(0)?;
        // The tail moves the moment the truncation lands — before the
        // fsync. If the sync below fails and the caller keeps appending,
        // the next flush must write at offset 0 of the truncated file,
        // not past a zero-filled gap at the old tail (which replay would
        // reject as a torn frame, silently losing committed appends).
        self.flushed = 0;
        self.dirty_tail = false; // the truncation removed any dirty tail
        self.file.sync()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, gen_bytes, gen_vec, prop_assert_eq};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grouper_wal_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn collect(path: &Path) -> (Vec<Vec<u8>>, ReplayReport) {
        let mut out = Vec::new();
        let report = replay(path, |p| {
            out.push(p.to_vec());
            Ok(())
        })
        .unwrap();
        (out, report)
    }

    #[test]
    fn missing_log_is_empty() {
        let (recs, report) = collect(&tmp("nonexistent.wal"));
        assert!(recs.is_empty());
        assert_eq!(report, ReplayReport::default());
    }

    #[test]
    fn append_commit_replay_roundtrip() {
        let path = tmp("roundtrip.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path, 0).unwrap();
        w.append(b"alpha").unwrap();
        w.append(b"").unwrap();
        w.append(&[9u8; 300]).unwrap();
        w.commit().unwrap();
        let (recs, report) = collect(&path);
        assert_eq!(recs, vec![b"alpha".to_vec(), Vec::new(), vec![9u8; 300]]);
        assert_eq!(report.records, 3);
        assert_eq!(report.torn_bytes, 0);
        assert_eq!(report.valid_bytes, w.len_bytes());
    }

    #[test]
    fn torn_tail_is_dropped_then_truncated() {
        let path = tmp("torn.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path, 0).unwrap();
        w.append(b"one").unwrap();
        w.append(b"two").unwrap();
        w.commit().unwrap();
        drop(w);
        // Simulate a torn write: half a frame of garbage at the tail.
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0x44, 0x33, 0x22]).unwrap();
        }
        let (recs, report) = collect(&path);
        assert_eq!(recs, vec![b"one".to_vec(), b"two".to_vec()]);
        assert_eq!(report.torn_bytes, 3);
        // Reopen at the valid prefix: tail is truncated, appends continue.
        let mut w = WalWriter::open(&path, report.valid_bytes).unwrap();
        w.append(b"three").unwrap();
        w.commit().unwrap();
        let (recs, report) = collect(&path);
        assert_eq!(recs, vec![b"one".to_vec(), b"two".to_vec(), b"three".to_vec()]);
        assert_eq!(report.torn_bytes, 0);
    }

    #[test]
    fn scan_slice_matches_file_replay() {
        let path = tmp("slice.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path, 0).unwrap();
        w.append(b"one").unwrap();
        w.append(&[7u8; 90]).unwrap();
        w.commit().unwrap();
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        let (file_recs, file_report) = collect(&path);
        bytes.extend_from_slice(&[0xAA, 0xBB]); // torn tail
        let mut got = Vec::new();
        let report = scan_slice(&bytes, |p| {
            got.push(p.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, file_recs);
        assert_eq!(report.valid_bytes, file_report.valid_bytes);
        assert_eq!(report.torn_bytes, 2);
    }

    #[test]
    fn corrupt_frame_ends_the_log() {
        let path = tmp("corrupt.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path, 0).unwrap();
        w.append(b"good").unwrap();
        w.append(b"bad").unwrap();
        w.commit().unwrap();
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF; // flip a payload bit in the second frame
        std::fs::write(&path, &bytes).unwrap();
        let (recs, report) = collect(&path);
        assert_eq!(recs, vec![b"good".to_vec()]);
        assert!(report.torn_bytes > 0);
    }

    #[test]
    fn has_valid_records_probe() {
        let path = tmp("probe.wal");
        let _ = std::fs::remove_file(&path);
        assert!(!has_valid_records(&path).unwrap(), "missing log");
        let mut w = WalWriter::open(&path, 0).unwrap();
        assert!(!has_valid_records(&path).unwrap(), "empty log");
        w.append(b"rec").unwrap();
        w.commit().unwrap();
        assert!(has_valid_records(&path).unwrap());
        drop(w);
        // Garbage-only log (torn header with a huge claimed length).
        std::fs::write(&path, [0xFFu8; 6]).unwrap();
        assert!(!has_valid_records(&path).unwrap());
        std::fs::write(&path, [0xFFu8; 20]).unwrap();
        assert!(!has_valid_records(&path).unwrap());
    }

    #[test]
    fn reset_empties_the_log() {
        let path = tmp("reset.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path, 0).unwrap();
        w.append(b"x").unwrap();
        w.reset().unwrap();
        assert_eq!(w.len_bytes(), 0);
        w.append(b"y").unwrap();
        w.commit().unwrap();
        let (recs, _) = collect(&path);
        assert_eq!(recs, vec![b"y".to_vec()]);
    }

    #[test]
    fn sync_failure_surfaces_and_nothing_is_durable() {
        use crate::store::vfs::{CrashImage, FaultPlan, FaultVfs, MemVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let path = Path::new("/wal/sync.pwal");
        let mut w = WalWriter::open_with(&fv, path, 0).unwrap();
        w.append(b"alpha").unwrap();
        w.append(b"beta").unwrap();
        fv.set_plan(FaultPlan { fail_sync: Some(fv.syncs_attempted() + 1), ..Default::default() });
        assert!(w.commit().is_err(), "injected fsync failure must surface");
        // Crash now: the synced-only image replays ZERO records — a failed
        // commit promised nothing.
        let mem = MemVfs::from_map(fv.crash_snapshot(CrashImage::SyncedOnly));
        let report = replay_with(&mem, path, |_| Ok(())).unwrap();
        assert_eq!(report.records, 0, "failed commit must not be durable");
        // Retry succeeds and makes both frames durable.
        fv.disarm();
        w.commit().unwrap();
        let mem = MemVfs::from_map(fv.crash_snapshot(CrashImage::SyncedOnly));
        let mut got = Vec::new();
        replay_with(&mem, path, |p| {
            got.push(p.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, vec![b"alpha".to_vec(), b"beta".to_vec()]);
    }

    #[test]
    fn rewind_withdraws_frames_buffered_or_already_flushed() {
        use crate::store::vfs::MemVfs;
        let mem = MemVfs::new();
        let path = Path::new("/wal/rewind.pwal");
        let mut w = WalWriter::open_with(&mem, path, 0).unwrap();
        w.append(b"keep").unwrap();
        // Withdraw a frame that is still buffered.
        let mark = w.mark();
        w.append(b"drop-buffered").unwrap();
        w.rewind(mark);
        w.append(b"keep2").unwrap();
        w.commit().unwrap();
        // Withdraw a frame that a flush already carried into the file.
        let mark = w.mark();
        w.append(b"drop-flushed").unwrap();
        w.commit().unwrap();
        w.rewind(mark);
        w.append(b"keep3").unwrap();
        w.commit().unwrap();
        assert_eq!(w.records_appended(), 3);
        let mut recs = Vec::new();
        let report = replay_with(&mem, path, |p| {
            recs.push(p.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(
            recs,
            vec![b"keep".to_vec(), b"keep2".to_vec(), b"keep3".to_vec()]
        );
        assert_eq!(report.torn_bytes, 0);
    }

    #[test]
    fn reset_sync_failure_does_not_strand_the_tail() {
        // Regression: reset()'s truncation lands but its fsync fails.
        // Later appends must write at offset 0 of the truncated file,
        // not past a zero-filled gap at the old tail (replay would stop
        // at the gap and silently lose committed appends).
        use crate::store::vfs::{CrashImage, FaultPlan, FaultVfs, MemVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let path = Path::new("/wal/resetfail.pwal");
        let mut w = WalWriter::open_with(&fv, path, 0).unwrap();
        w.append(b"old-frame-one").unwrap();
        w.append(b"old-frame-two").unwrap();
        w.commit().unwrap();
        fv.set_plan(FaultPlan { fail_sync: Some(fv.syncs_attempted() + 1), ..Default::default() });
        assert!(w.reset().is_err(), "reset's fsync failure must surface");
        fv.disarm();
        w.append(b"new").unwrap();
        w.commit().unwrap();
        // The new frame is the whole durable log, readable from offset 0.
        let mem = MemVfs::from_map(fv.crash_snapshot(CrashImage::SyncedOnly));
        let mut got = Vec::new();
        replay_with(&mem, path, |p| {
            got.push(p.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, vec![b"new".to_vec()]);
    }

    #[test]
    fn torn_flush_is_truncated_immediately_and_a_retry_commits_everything() {
        use crate::store::vfs::{CrashImage, FaultPlan, FaultVfs, MemVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let path = Path::new("/wal/torn.pwal");
        let mut w = WalWriter::open_with(&fv, path, 0).unwrap();
        w.append(b"first").unwrap(); // frame: 8 + 5 = 13 bytes
        w.append(b"second").unwrap(); // frame: 8 + 6 = 14 bytes
        // Tear the commit's buffer write 3 bytes into the second frame.
        fv.set_plan(FaultPlan {
            torn_write: Some((fv.writes_attempted() + 1, 16)),
            ..Default::default()
        });
        assert!(w.commit().is_err(), "torn write must surface");
        // The torn chunk was truncated away on the spot: even if every
        // completed write survives a crash, nothing of the failed flush
        // is visible.
        let mem = MemVfs::from_map(fv.crash_snapshot(CrashImage::AllApplied));
        let report = replay_with(&mem, path, |_| Ok(())).unwrap();
        assert_eq!((report.records, report.torn_bytes), (0, 0));
        // The buffer was kept, so a retried commit rewrites both frames.
        fv.disarm();
        w.commit().unwrap();
        let mem = MemVfs::from_map(fv.crash_snapshot(CrashImage::SyncedOnly));
        let mut got = Vec::new();
        replay_with(&mem, path, |p| {
            got.push(p.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, vec![b"first".to_vec(), b"second".to_vec()]);
    }

    #[test]
    fn dirty_tail_is_latched_when_cleanup_fails_and_cleared_by_commit() {
        use crate::store::vfs::{CrashImage, FaultPlan, FaultVfs, MemVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let path = Path::new("/wal/dirty.pwal");
        let mut w = WalWriter::open_with(&fv, path, 0).unwrap();
        w.append(b"first").unwrap(); // frame: 13 bytes
        w.append(b"second").unwrap(); // frame: 14 bytes
        // Tear the flush mid-second-frame AND fail the immediate cleanup
        // truncation, so the torn chunk stays on disk behind the latch.
        let n = fv.writes_attempted();
        fv.set_plan(FaultPlan {
            torn_write: Some((n + 1, 16)),
            fail_write: Some(n + 2),
            ..Default::default()
        });
        assert!(w.commit().is_err(), "torn write must surface");
        let mem = MemVfs::from_map(fv.crash_snapshot(CrashImage::AllApplied));
        let mut got = Vec::new();
        let report = replay_with(&mem, path, |p| {
            got.push(p.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, vec![b"first".to_vec()], "the torn chunk is an ordinary torn tail");
        assert_eq!(report.torn_bytes, 3);
        // A later successful commit first clears the dirty tail, then
        // rewrites the whole buffer: the log ends clean.
        fv.disarm();
        w.commit().unwrap();
        let mem = MemVfs::from_map(fv.crash_snapshot(CrashImage::SyncedOnly));
        let mut got = Vec::new();
        let report = replay_with(&mem, path, |p| {
            got.push(p.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, vec![b"first".to_vec(), b"second".to_vec()]);
        assert_eq!(report.torn_bytes, 0);
    }

    /// Property: replay of a randomly truncated log is exactly the longest
    /// frame-prefix that fits.
    #[test]
    fn property_truncation_yields_prefix() {
        check(40, |rng| {
            let records = gen_vec(rng, 1..=12, |r| gen_bytes(r, 0..=60));
            let path = tmp(&format!("prop{}.wal", rng.next_u32()));
            let _ = std::fs::remove_file(&path);
            let mut w = WalWriter::open(&path, 0).unwrap();
            let mut boundaries = vec![0u64];
            for rec in &records {
                w.append(rec).unwrap();
                boundaries.push(w.len_bytes());
            }
            w.commit().unwrap();
            drop(w);
            let full = std::fs::read(&path).unwrap();
            let cut = rng.gen_range_usize(full.len() + 1);
            std::fs::write(&path, &full[..cut]).unwrap();
            // Expected: all records whose frame end <= cut.
            let expect: Vec<Vec<u8>> = records
                .iter()
                .zip(boundaries.iter().skip(1))
                .filter(|(_, end)| **end <= cut as u64)
                .map(|(r, _)| r.clone())
                .collect();
            let (got, report) = collect(&path);
            std::fs::remove_file(&path).ok();
            prop_assert_eq(got.len(), expect.len(), "record count")?;
            prop_assert_eq(got, expect, "prefix property")?;
            prop_assert_eq(
                report.valid_bytes + report.torn_bytes,
                cut as u64,
                "byte accounting",
            )
        });
    }
}
