//! Cross-process snapshot pins: on-disk epoch pins that extend the
//! in-process registry ([`super::shared`]) across process boundaries.
//!
//! The in-process registry is enough while reader and writer share one
//! address space, but the serving deployment ([`crate::serve`]) is
//! exactly the opposite: `grouper serve` pins snapshots in its own
//! process while a separate writer process appends, checkpoints and
//! compacts. A writer that consulted only its local registry would see
//! no pins at all and could reuse or truncate pages a remote snapshot
//! can still reach. This module closes that gap with the simplest
//! durable mechanism the VFS contract allows: a sidecar pin directory
//! next to the index file.
//!
//! ## Mechanism
//!
//! A reader holding a snapshot at epoch `E` on `<path>.pstore` owns one
//! file `<path>.pstore.pins/pin-<pid>-<seq>.pin` containing `E` (plus
//! the owning process id, all CRC-framed). The file is written to a
//! temp name and renamed into place, so a concurrent scan never sees a
//! torn pin; it is removed when the pin guard drops. The writer's reuse
//! gate takes the **minimum** epoch over every live pin file, exactly
//! like [`super::shared::min_pinned_epoch`] — the two minima are simply
//! combined.
//!
//! ## Why scanning only at checkpoints is sound
//!
//! The writer rescans the pin directory when it opens the store and
//! **immediately after every checkpoint's header swap** (then caches
//! the minimum for the append hot path). That is sufficient, not just
//! convenient: a reader pins with the same pin-then-confirm protocol as
//! in-process readers — write the pin file, then re-read the header and
//! proceed only if the epoch is unchanged. If the confirm read still
//! saw epoch `E`, the swap to `E+1` had not completed, so the pin file
//! existed **before** the swap — and therefore before the writer's
//! post-swap rescan, which consequently observes it before any page
//! freed at `E+1` (the first frees a snapshot at `E` can reach) becomes
//! eligible for reuse. Pins registered after a rescan are at the
//! then-current epoch or later and constrain only frees that later
//! checkpoints publish — each behind its own rescan.
//!
//! ## Liveness
//!
//! A pin file whose owner crashed would block reclamation forever, so
//! the scan checks owner liveness: on Linux, a recorded pid with no
//! `/proc/<pid>` entry is provably dead and the pin is deleted on the
//! spot; on other Unixes (macOS) a `kill(pid, 0)` probe that answers
//! `ESRCH` proves the same thing. Every other answer — the probe
//! succeeding, `EPERM` (someone lives there, just not ours to signal),
//! a pid too large for the platform's `pid_t`, or any platform without
//! a probe at all (Windows) — is **live-ambiguous**, and a
//! live-ambiguous pin is never swept: it blocks reclamation until its
//! owner removes it or the directory is cleaned by hand. The same goes
//! for unparseable files, which carry no readable pid. Both errors
//! this policy can make are in the safe direction: a recycled pid or
//! an unreadable file delays reclamation; neither can unprotect a live
//! snapshot.
//!
//! Pins exist only on the real filesystem ([`super::vfs::StdVfs`],
//! instance id 0): a [`super::vfs::MemVfs`] store is unreachable from
//! another process by construction, so its readers need no durable
//! pins. On read-only media pin creation degrades to a no-op — where
//! nothing can write, there is no writer to coordinate with.

use std::fs;
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::records::crc32c::crc32c;

/// Pin file layout: magic, epoch, owner pid, CRC32C of the first 20
/// bytes. 24 bytes total, written whole and renamed into place.
const MAGIC: &[u8; 8] = b"GRPPIN1\0";
const PIN_LEN: usize = 24;

/// The sidecar pin directory for the store indexed by `index_path`:
/// the index file's own name with `.pins` appended (so `data.pstore`
/// gets `data.pstore.pins/`). Call with the VFS's canonical spelling
/// ([`super::vfs::Vfs::registry_key`]) so reader and writer agree on
/// one directory even through symlinks.
pub fn pins_dir(index_path: &Path) -> PathBuf {
    let mut name = index_path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".pins");
    index_path.with_file_name(name)
}

/// An errors-where-no-writer-can-exist kind: pin creation on read-only
/// media is pointless (the coordination target cannot run there), so it
/// degrades to "no pin" instead of failing the open.
fn degradable(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::PermissionDenied | io::ErrorKind::Unsupported)
}

/// RAII guard for one on-disk pin: the pin file lives exactly as long
/// as this value. Dropping it deletes the file (and the pin directory,
/// when this was its last pin).
#[derive(Debug)]
pub struct DiskPin {
    path: PathBuf,
}

impl Drop for DiskPin {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.path);
        if let Some(dir) = self.path.parent() {
            // Only succeeds when no other pin remains; best-effort.
            let _ = fs::remove_dir(dir);
        }
    }
}

/// Register an on-disk pin at `epoch` for the store indexed by
/// `index_path` (canonical spelling). Returns `Ok(None)` on read-only
/// media, where no writer can exist to observe the pin.
///
/// # Errors
/// Any non-degradable I/O failure creating the pin directory or file.
pub fn create(index_path: &Path, epoch: u64) -> io::Result<Option<DiskPin>> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = pins_dir(index_path);
    match fs::create_dir_all(&dir) {
        Ok(()) => {}
        Err(e) if degradable(&e) => return Ok(None),
        Err(e) => return Err(e),
    }
    let pid = std::process::id();
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp_path = dir.join(format!("pin-{pid}-{seq}.tmp"));
    let final_path = dir.join(format!("pin-{pid}-{seq}.pin"));
    let mut body = Vec::with_capacity(PIN_LEN);
    body.extend_from_slice(MAGIC);
    body.extend_from_slice(&epoch.to_le_bytes());
    body.extend_from_slice(&pid.to_le_bytes());
    let crc = crc32c(&body);
    body.extend_from_slice(&crc.to_le_bytes());
    // Write-then-rename: a scan racing this create sees either no pin
    // file or a complete one, never a torn prefix. No fsync — the pin
    // coordinates live processes on one host (page-cache coherent), and
    // a pin lost to a crash is moot: its owner died with it.
    fn write_pin(tmp: &Path, dst: &Path, body: &[u8]) -> io::Result<()> {
        let mut f = fs::File::create(tmp)?;
        f.write_all(body)?;
        fs::rename(tmp, dst)
    }
    match write_pin(&tmp_path, &final_path, &body) {
        Ok(()) => Ok(Some(DiskPin { path: final_path })),
        Err(e) => {
            let _ = fs::remove_file(&tmp_path);
            if degradable(&e) {
                Ok(None)
            } else {
                Err(e)
            }
        }
    }
}

/// Parse one pin file body: `(epoch, owner pid)`, or `None` when the
/// bytes are not a complete, checksummed pin record.
fn parse(body: &[u8]) -> Option<(u64, u32)> {
    if body.len() != PIN_LEN || &body[0..8] != MAGIC {
        return None;
    }
    let crc = u32::from_le_bytes(body[20..24].try_into().unwrap());
    if crc32c(&body[0..20]) != crc {
        return None;
    }
    let epoch = u64::from_le_bytes(body[8..16].try_into().unwrap());
    let pid = u32::from_le_bytes(body[16..20].try_into().unwrap());
    Some((epoch, pid))
}

/// Whether `pid` provably no longer runs. Linux proves it via procfs;
/// other Unixes via a `kill(pid, 0)` probe answering `ESRCH`. Anything
/// short of proof — the probe succeeding, `EPERM` (someone lives at
/// that pid, just not ours to signal), a pid that does not fit the
/// platform's `pid_t`, or a platform with no probe at all (Windows) —
/// presumes the owner alive, which can only delay reclamation, never
/// unprotect a snapshot.
#[cfg(target_os = "linux")]
fn owner_known_dead(pid: u32) -> bool {
    pid != std::process::id() && !Path::new("/proc").join(pid.to_string()).exists()
}

#[cfg(all(unix, not(target_os = "linux")))]
fn owner_known_dead(pid: u32) -> bool {
    // Signal 0 performs existence/permission checking only; nothing is
    // delivered. ESRCH is the one answer that proves the pid is vacant.
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const ESRCH: i32 = 3;
    if pid == 0 || pid == std::process::id() || pid > i32::MAX as u32 {
        return false;
    }
    // SAFETY: kill with signal 0 cannot affect the target process.
    let rc = unsafe { kill(pid as i32, 0) };
    rc != 0 && std::io::Error::last_os_error().raw_os_error() == Some(ESRCH)
}

#[cfg(not(unix))]
fn owner_known_dead(_pid: u32) -> bool {
    false
}

/// The smallest epoch pinned by any live pin file for the store indexed
/// by `index_path`, or `None` when no live pin exists — the on-disk
/// half of the writer's reuse gate. Provably-dead owners' pins are
/// deleted in passing; unreadable or unparseable files count as epoch 0
/// (maximally conservative) because nothing in them says what they
/// protect.
///
/// # Errors
/// Failure listing an existing pin directory. (A missing directory is
/// simply "no pins".)
pub fn scan_min(index_path: &Path) -> io::Result<Option<u64>> {
    let dir = pins_dir(index_path);
    let entries = match fs::read_dir(&dir) {
        Ok(it) => it,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e),
    };
    let mut min: Option<u64> = None;
    for entry in entries {
        let Ok(entry) = entry else { continue };
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) != Some("pin") {
            continue;
        }
        let mut body = Vec::new();
        match fs::File::open(&path).and_then(|mut f| f.read_to_end(&mut body)) {
            Ok(_) => {}
            // The owner dropped its pin between listing and open.
            Err(e) if e.kind() == io::ErrorKind::NotFound => continue,
            Err(_) => {
                min = Some(0);
                continue;
            }
        }
        match parse(&body) {
            Some((_, pid)) if owner_known_dead(pid) => {
                let _ = fs::remove_file(&path);
            }
            Some((epoch, _)) => min = Some(min.map_or(epoch, |m| m.min(epoch))),
            None => min = Some(0),
        }
    }
    Ok(min)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_index_path(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("grouper_pins_test").join(name);
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir.join("data.pstore")
    }

    #[test]
    fn pin_lifecycle_and_minimum() {
        let index = test_index_path("lifecycle");
        assert_eq!(scan_min(&index).unwrap(), None, "no pins yet");
        let p7 = create(&index, 7).unwrap().expect("real fs pins");
        let p3 = create(&index, 3).unwrap().expect("real fs pins");
        assert_eq!(scan_min(&index).unwrap(), Some(3));
        drop(p3);
        assert_eq!(scan_min(&index).unwrap(), Some(7));
        drop(p7);
        assert_eq!(scan_min(&index).unwrap(), None, "all pins dropped");
        assert!(!pins_dir(&index).exists(), "last pin removes the directory");
    }

    #[test]
    fn unparseable_pin_is_maximally_conservative() {
        let index = test_index_path("garbage");
        let _live = create(&index, 9).unwrap().expect("real fs pins");
        fs::write(pins_dir(&index).join("pin-0-0.pin"), b"not a pin").unwrap();
        assert_eq!(
            scan_min(&index).unwrap(),
            Some(0),
            "garbage must block reclamation, not allow it"
        );
    }

    #[test]
    fn non_pin_files_are_ignored() {
        let index = test_index_path("ignored");
        let _live = create(&index, 5).unwrap().expect("real fs pins");
        fs::write(pins_dir(&index).join("pin-1-1.tmp"), b"half-written").unwrap();
        assert_eq!(scan_min(&index).unwrap(), Some(5), "only *.pin files count");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn dead_owner_pins_are_cleaned() {
        let index = test_index_path("dead_owner");
        // Forge a pin whose recorded owner cannot exist (pids are
        // bounded well below u32::MAX on Linux).
        let dir = pins_dir(&index);
        fs::create_dir_all(&dir).unwrap();
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&2u64.to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        let crc = crc32c(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        let dead = dir.join("pin-4294967295-0.pin");
        fs::write(&dead, &body).unwrap();
        assert_eq!(scan_min(&index).unwrap(), None, "dead owner's pin is discounted");
        assert!(!dead.exists(), "and deleted in passing");
    }

    #[test]
    fn own_pins_count_as_live() {
        let index = test_index_path("own_live");
        let _pin = create(&index, 4).unwrap().expect("real fs pins");
        // The scanning process's own pid is trivially alive, so its
        // pins survive the liveness check.
        assert_eq!(scan_min(&index).unwrap(), Some(4));
    }

    /// The liveness probe itself, on every platform: our own pid and a
    /// live-ambiguous pid (pid 1 — init/launchd, alive but not ours to
    /// signal) must never be declared dead. This is the conservative
    /// fallback a replication follower's pin files depend on across the
    /// 3-OS matrix: a pin is swept only on *proof* of death.
    #[test]
    fn ambiguous_owners_are_presumed_alive() {
        assert!(!owner_known_dead(std::process::id()), "own pid is alive by definition");
        assert!(!owner_known_dead(1), "pid 1 exists but is not ours to signal");
        assert!(!owner_known_dead(0), "pid 0 is never a recorded owner; keep its pins");
    }

    /// On any Unix, a spawned-and-reaped child is *provable* death —
    /// procfs on Linux, the `kill(pid, 0)` ESRCH probe elsewhere.
    #[cfg(unix)]
    #[test]
    fn reaped_child_is_provably_dead_on_unix() {
        let mut child = std::process::Command::new("sh")
            .args(["-c", "exit 0"])
            .spawn()
            .expect("spawning a short-lived child");
        let pid = child.id();
        child.wait().expect("reaping the child");
        // The pid is reaped (not a zombie), so the probe must prove it
        // vacant. (A recycled pid in the microseconds since the wait
        // could theoretically flip this; pids recycle slowly enough
        // that the race is not observable in practice.)
        assert!(owner_known_dead(pid), "reaped child pid {pid} should probe as dead");
    }

    /// Platforms with no probe at all must answer "alive" for every
    /// pid — never sweeping is the documented fallback.
    #[cfg(not(unix))]
    #[test]
    fn liveness_is_never_presumed_without_a_probe() {
        assert!(!owner_known_dead(12345));
        assert!(!owner_known_dead(u32::MAX));
    }
}
