//! The virtual filesystem layer: every byte the storage engine reads or
//! writes goes through the [`Vfs`]/[`VfsFile`] trait pair, so the same
//! pager/WAL/B+tree code runs over a real filesystem, over memory, or
//! under deterministic fault injection — SQLite's VFS design (see
//! libsql's `trait Vfs`) transplanted to this engine.
//!
//! Three implementations:
//!
//! * [`StdVfs`] — `std::fs`, the default everywhere. Positional I/O
//!   (`read_exact_at`/`write_all_at` on Unix) lives *here* now, so the
//!   concurrent [`super::shared::SharedPager`] keeps its seek-free fast
//!   path and the seek-emulation fallback for non-Unix platforms is
//!   written once instead of per call site.
//! * [`MemVfs`] — files are in-memory byte vectors. Unit tests and
//!   microbenches become disk-free and fast, and a whole store can be
//!   snapshotted/restored as a `path -> bytes` map.
//! * [`FaultVfs`] — a deterministic wrapper over any inner [`Vfs`] that
//!   can fail the Nth write or sync, tear a write at a byte offset, stop
//!   every later mutation after a chosen operation count ("crash here";
//!   reads pass through unfaulted), and
//!   reconstruct **what would be on disk after a crash**: either every
//!   completed write ([`CrashImage::AllApplied`]), only what was fsynced
//!   ([`CrashImage::SyncedOnly`]), or a seeded-random subset of the
//!   unsynced writes ([`FaultVfs::crash_snapshot_subset`], driven by
//!   [`crate::util::rng::Rng`] so every schedule is replayable).
//!
//! The file API is deliberately **positional and `&self`**: no seek
//! state exists anywhere, so one `Arc<dyn VfsFile>` can serve an
//! exclusive writer and any number of concurrent readers at once. The
//! [`VfsCursor`] adapter layers `Read`/`Write`/`Seek` back on top for
//! stream-shaped consumers (TFRecord framing).
//!
//! Fault model (what [`FaultVfs`] asserts about the engine): a write
//! either fully applies, partially applies (torn), or does not apply; a
//! file's durable image only advances at a successful `sync`; a crash
//! preserves the durable image plus an arbitrary subset of later
//! completed writes. The crash-matrix suite (`rust/tests/crash_matrix.rs`)
//! drives the append → commit → checkpoint cycle through every such
//! point and requires recovery to land on exactly a committed prefix.

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::util::rng::Rng;

/// How a file is opened through a [`Vfs`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpenMode {
    /// Read-only; the file must exist.
    Read,
    /// Read/write; the file must exist (its contents are preserved).
    ReadWrite,
    /// Read/write; created empty when missing, contents preserved when
    /// present.
    Create,
    /// Read/write; created empty, truncating any existing contents.
    CreateTruncate,
}

/// One open file: positional, seek-free, `&self` I/O. `Send + Sync` so a
/// single handle can be shared (behind `Arc`) by a writer and any number
/// of reader threads.
pub trait VfsFile: Send + Sync {
    /// Read up to `buf.len()` bytes at `offset`, returning how many were
    /// read (0 at or past end-of-file).
    ///
    /// # Errors
    /// Any underlying I/O failure.
    fn read_at(&self, buf: &mut [u8], offset: u64) -> io::Result<usize>;

    /// Write all of `buf` at `offset`, extending the file (zero-filling
    /// any gap) when the write lands past the current end.
    ///
    /// # Errors
    /// `PermissionDenied` on a read-only handle; otherwise any
    /// underlying I/O failure — after which the file may hold a torn
    /// prefix of `buf`.
    fn write_all_at(&self, buf: &[u8], offset: u64) -> io::Result<()>;

    /// Truncate (or zero-extend) the file to exactly `len` bytes.
    ///
    /// # Errors
    /// `PermissionDenied` on a read-only handle; otherwise any
    /// underlying I/O failure.
    fn set_len(&self, len: u64) -> io::Result<()>;

    /// Durability point: flush the file's data to stable storage
    /// (`fsync`-equivalent). Nothing written is crash-durable until a
    /// `sync` after it returns `Ok`.
    ///
    /// # Errors
    /// Any underlying I/O failure; on error nothing new is durable.
    fn sync(&self) -> io::Result<()>;

    /// Current file length in bytes.
    ///
    /// # Errors
    /// Any underlying metadata failure.
    fn len(&self) -> io::Result<u64>;

    /// Fill `buf` exactly from `offset`.
    ///
    /// # Errors
    /// `UnexpectedEof` when the file ends first; otherwise any
    /// underlying read failure.
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> io::Result<()> {
        let mut filled = 0usize;
        while filled < buf.len() {
            match self.read_at(&mut buf[filled..], offset + filled as u64)? {
                0 => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "vfs read past end of file",
                    ))
                }
                n => filled += n,
            }
        }
        Ok(())
    }

    /// The raw OS file descriptor behind this handle, when one exists.
    /// Only real-filesystem files ([`StdVfs`]) return `Some`; in-memory
    /// and fault-injected files return `None` — which is what keeps
    /// [`MmapVfs`] from ever mapping around a [`FaultVfs`]'s accounting
    /// or a [`MemVfs`]'s byte store.
    fn os_fd(&self) -> Option<i32> {
        None
    }
}

/// A filesystem: opens files and resolves directories. Implementations
/// must be `Send + Sync`; handles they return are independently
/// shareable.
pub trait Vfs: Send + Sync {
    /// Open `path` in `mode`.
    ///
    /// # Errors
    /// `NotFound` when the file is missing and `mode` does not create;
    /// otherwise any underlying open failure.
    fn open(&self, path: &Path, mode: OpenMode) -> io::Result<Arc<dyn VfsFile>>;

    /// Ensure a directory (and its ancestors) exists.
    ///
    /// # Errors
    /// Any underlying failure ([`MemVfs`] never fails: it has no real
    /// directories).
    fn create_dir_all(&self, path: &Path) -> io::Result<()>;

    /// List the files directly inside `dir` (full paths, unordered).
    ///
    /// # Errors
    /// Any underlying failure; a directory holding no files is `Ok`
    /// with an empty list for [`MemVfs`] but may be `NotFound` for a
    /// [`StdVfs`] directory that does not exist.
    fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>>;

    /// Read a whole file.
    ///
    /// # Errors
    /// `NotFound` when missing; otherwise any read failure.
    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        read_all(self.open(path, OpenMode::Read)?.as_ref())
    }

    /// An identity for this filesystem *instance*, so process-wide state
    /// keyed by file path (the snapshot-pin registry in
    /// [`super::shared`]) can tell two in-memory filesystems holding the
    /// same path apart. The default, 0, means "the one real filesystem":
    /// correct for [`StdVfs`] (all instances see the same disk) and for
    /// any wrapper that forwards to it. [`MemVfs`] assigns each instance
    /// a unique id; wrappers like [`FaultVfs`] delegate to their inner
    /// VFS.
    fn instance_id(&self) -> u64 {
        0
    }

    /// One canonical spelling of `path` for identity-keyed process-wide
    /// state (the snapshot-pin registry): two spellings of the same
    /// on-disk file (relative vs absolute, `./`-prefixed, via symlink)
    /// must map to one key, or a writer consulting the registry under
    /// one spelling would miss a reader pinned under another — and the
    /// epoch gate with it. [`StdVfs`] canonicalizes; [`MemVfs`] keys
    /// files by their verbatim path, so identity is already canonical
    /// there (the default); wrappers delegate to their inner VFS.
    fn registry_key(&self, path: &Path) -> PathBuf {
        path.to_path_buf()
    }
}

/// Read an entire [`VfsFile`] into memory.
///
/// # Errors
/// Any length or read failure.
pub fn read_all(file: &dyn VfsFile) -> io::Result<Vec<u8>> {
    let len = file.len()? as usize;
    let mut buf = vec![0u8; len];
    file.read_exact_at(&mut buf, 0)?;
    Ok(buf)
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

// ---------------------------------------------------------------------------
// StdVfs
// ---------------------------------------------------------------------------

/// The real filesystem (`std::fs`), the default for every store and
/// format constructor. Zero-sized: `&StdVfs` is free to pass around.
#[derive(Clone, Copy, Debug, Default)]
pub struct StdVfs;

struct StdFile {
    file: File,
    writable: bool,
    /// Serializes seek+read/write emulation on platforms without
    /// positional file I/O.
    #[cfg(not(unix))]
    seek_lock: Mutex<()>,
}

impl VfsFile for StdFile {
    fn read_at(&self, buf: &mut [u8], offset: u64) -> io::Result<usize> {
        #[cfg(unix)]
        {
            std::os::unix::fs::FileExt::read_at(&self.file, buf, offset)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let _guard = lock(&self.seek_lock);
            let mut f = &self.file;
            f.seek(SeekFrom::Start(offset))?;
            f.read(buf)
        }
    }

    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> io::Result<()> {
        #[cfg(unix)]
        {
            std::os::unix::fs::FileExt::read_exact_at(&self.file, buf, offset)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let _guard = lock(&self.seek_lock);
            let mut f = &self.file;
            f.seek(SeekFrom::Start(offset))?;
            f.read_exact(buf)
        }
    }

    fn write_all_at(&self, buf: &[u8], offset: u64) -> io::Result<()> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "vfs file opened read-only",
            ));
        }
        #[cfg(unix)]
        {
            std::os::unix::fs::FileExt::write_all_at(&self.file, buf, offset)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Seek, SeekFrom, Write};
            let _guard = lock(&self.seek_lock);
            let mut f = &self.file;
            f.seek(SeekFrom::Start(offset))?;
            f.write_all(buf)
        }
    }

    fn set_len(&self, len: u64) -> io::Result<()> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "vfs file opened read-only",
            ));
        }
        self.file.set_len(len)
    }

    fn sync(&self) -> io::Result<()> {
        self.file.sync_data()
    }

    fn len(&self) -> io::Result<u64> {
        Ok(self.file.metadata()?.len())
    }

    fn os_fd(&self) -> Option<i32> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            Some(self.file.as_raw_fd())
        }
        #[cfg(not(unix))]
        {
            None
        }
    }
}

impl Vfs for StdVfs {
    fn open(&self, path: &Path, mode: OpenMode) -> io::Result<Arc<dyn VfsFile>> {
        let mut opts = OpenOptions::new();
        opts.read(true);
        let writable = match mode {
            OpenMode::Read => false,
            OpenMode::ReadWrite => {
                opts.write(true);
                true
            }
            OpenMode::Create => {
                opts.write(true).create(true);
                true
            }
            OpenMode::CreateTruncate => {
                opts.write(true).create(true).truncate(true);
                true
            }
        };
        Ok(Arc::new(StdFile {
            file: opts.open(path)?,
            writable,
            #[cfg(not(unix))]
            seek_lock: Mutex::new(()),
        }))
    }

    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        std::fs::create_dir_all(path)
    }

    fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            out.push(entry?.path());
        }
        Ok(out)
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        std::fs::read(path)
    }

    fn registry_key(&self, path: &Path) -> PathBuf {
        // An existing file canonicalizes whole — resolving a symlinked
        // `.pstore` to its target, so both spellings share one key.
        if let Ok(canon) = std::fs::canonicalize(path) {
            return canon;
        }
        // The file may not exist yet (a store being created):
        // canonicalize the parent and re-attach the file name; fall back
        // to absolutizing against the current directory so at least
        // relative-vs-absolute spellings converge even for
        // not-yet-created parents.
        let canon_parent = path
            .parent()
            .filter(|p| !p.as_os_str().is_empty())
            .and_then(|p| std::fs::canonicalize(p).ok());
        match (canon_parent, path.file_name()) {
            (Some(dir), Some(name)) => dir.join(name),
            _ if path.is_absolute() => path.to_path_buf(),
            _ => std::env::current_dir()
                .map_or_else(|_| path.to_path_buf(), |cwd| cwd.join(path)),
        }
    }
}

// ---------------------------------------------------------------------------
// MemVfs
// ---------------------------------------------------------------------------

/// An in-memory filesystem: each file is a byte vector keyed by its
/// (verbatim) path. `create_dir_all` is a no-op and `list_dir` filters
/// file keys by parent path, so path spellings must be consistent —
/// which they are for every store/format (all paths come from one
/// `dir.join(name)`).
pub struct MemVfs {
    files: Mutex<HashMap<PathBuf, Arc<Mutex<Vec<u8>>>>>,
    /// Unique per instance (see [`Vfs::instance_id`]): two `MemVfs`
    /// holding the same path are different stores.
    id: u64,
}

impl Default for MemVfs {
    fn default() -> MemVfs {
        MemVfs::new()
    }
}

impl MemVfs {
    /// An empty in-memory filesystem.
    pub fn new() -> MemVfs {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT_MEMVFS_ID: AtomicU64 = AtomicU64::new(1);
        MemVfs {
            files: Mutex::new(HashMap::new()),
            id: NEXT_MEMVFS_ID.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Build a filesystem from a `path -> bytes` snapshot (e.g. a
    /// [`FaultVfs`] crash image).
    pub fn from_map(map: BTreeMap<PathBuf, Vec<u8>>) -> MemVfs {
        let vfs = MemVfs::new();
        for (path, bytes) in map {
            vfs.install(&path, bytes);
        }
        vfs
    }

    /// Create or replace one file's contents.
    pub fn install(&self, path: &Path, bytes: Vec<u8>) {
        lock(&self.files).insert(path.to_path_buf(), Arc::new(Mutex::new(bytes)));
    }

    /// Snapshot every file as a `path -> bytes` map.
    pub fn dump(&self) -> BTreeMap<PathBuf, Vec<u8>> {
        lock(&self.files)
            .iter()
            .map(|(p, b)| (p.clone(), lock(b).clone()))
            .collect()
    }

    /// One file's current bytes, or `None` when it does not exist.
    pub fn file_bytes(&self, path: &Path) -> Option<Vec<u8>> {
        lock(&self.files).get(path).map(|b| lock(b).clone())
    }
}

struct MemFile {
    bytes: Arc<Mutex<Vec<u8>>>,
    writable: bool,
}

impl VfsFile for MemFile {
    fn read_at(&self, buf: &mut [u8], offset: u64) -> io::Result<usize> {
        let bytes = lock(&self.bytes);
        let len = bytes.len() as u64;
        if offset >= len {
            return Ok(0);
        }
        let n = buf.len().min((len - offset) as usize);
        buf[..n].copy_from_slice(&bytes[offset as usize..offset as usize + n]);
        Ok(n)
    }

    fn write_all_at(&self, buf: &[u8], offset: u64) -> io::Result<()> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "vfs file opened read-only",
            ));
        }
        let mut bytes = lock(&self.bytes);
        let end = offset as usize + buf.len();
        if bytes.len() < end {
            bytes.resize(end, 0); // zero-fill any gap, like a sparse write
        }
        bytes[offset as usize..end].copy_from_slice(buf);
        Ok(())
    }

    fn set_len(&self, len: u64) -> io::Result<()> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "vfs file opened read-only",
            ));
        }
        lock(&self.bytes).resize(len as usize, 0);
        Ok(())
    }

    fn sync(&self) -> io::Result<()> {
        Ok(())
    }

    fn len(&self) -> io::Result<u64> {
        Ok(lock(&self.bytes).len() as u64)
    }
}

impl Vfs for MemVfs {
    fn open(&self, path: &Path, mode: OpenMode) -> io::Result<Arc<dyn VfsFile>> {
        let mut files = lock(&self.files);
        let existing = files.get(path).cloned();
        let bytes = match (mode, existing) {
            (OpenMode::Read | OpenMode::ReadWrite, None) => {
                return Err(io::Error::new(
                    io::ErrorKind::NotFound,
                    format!("no such mem file: {}", path.display()),
                ))
            }
            (OpenMode::CreateTruncate, maybe) => {
                if let Some(b) = maybe {
                    lock(&b).clear();
                    b
                } else {
                    let b = Arc::new(Mutex::new(Vec::new()));
                    files.insert(path.to_path_buf(), b.clone());
                    b
                }
            }
            (OpenMode::Create, None) => {
                let b = Arc::new(Mutex::new(Vec::new()));
                files.insert(path.to_path_buf(), b.clone());
                b
            }
            (_, Some(b)) => b,
        };
        Ok(Arc::new(MemFile { bytes, writable: mode != OpenMode::Read }))
    }

    fn create_dir_all(&self, _path: &Path) -> io::Result<()> {
        Ok(())
    }

    fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        Ok(lock(&self.files)
            .keys()
            .filter(|p| p.parent() == Some(dir))
            .cloned()
            .collect())
    }

    fn instance_id(&self) -> u64 {
        self.id
    }
}

// ---------------------------------------------------------------------------
// VfsCursor
// ---------------------------------------------------------------------------

/// `Read`/`Write`/`Seek` over a shared positional [`VfsFile`]: the
/// adapter that lets stream-shaped consumers (TFRecord framing, buffered
/// readers/writers) run over any VFS. Each cursor owns its position, so
/// many cursors can share one file handle without interfering.
pub struct VfsCursor {
    file: Arc<dyn VfsFile>,
    pos: u64,
}

impl VfsCursor {
    /// A cursor at offset 0.
    pub fn new(file: Arc<dyn VfsFile>) -> VfsCursor {
        VfsCursor::at(file, 0)
    }

    /// A cursor at an explicit starting offset.
    pub fn at(file: Arc<dyn VfsFile>, pos: u64) -> VfsCursor {
        VfsCursor { file, pos }
    }

    /// Current byte offset.
    pub fn position(&self) -> u64 {
        self.pos
    }
}

impl io::Read for VfsCursor {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.file.read_at(buf, self.pos)?;
        self.pos += n as u64;
        Ok(n)
    }
}

impl io::Write for VfsCursor {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.file.write_all_at(buf, self.pos)?;
        self.pos += buf.len() as u64;
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

impl io::Seek for VfsCursor {
    fn seek(&mut self, pos: io::SeekFrom) -> io::Result<u64> {
        let next = match pos {
            io::SeekFrom::Start(o) => Some(o),
            io::SeekFrom::Current(d) => self.pos.checked_add_signed(d),
            io::SeekFrom::End(d) => self.file.len()?.checked_add_signed(d),
        };
        match next {
            Some(o) => {
                self.pos = o;
                Ok(o)
            }
            None => Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "vfs cursor seek to a negative offset",
            )),
        }
    }
}

// ---------------------------------------------------------------------------
// MmapVfs
// ---------------------------------------------------------------------------

/// Raw bindings to the two mapping syscalls the read path needs,
/// declared by hand (the crate is dependency-free). The constant values
/// for the flags used here are identical on Linux, macOS and the BSDs.
#[cfg(all(unix, target_pointer_width = "64"))]
mod mmap_sys {
    pub const PROT_READ: i32 = 1;
    pub const MAP_SHARED: i32 = 1;

    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            length: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, length: usize) -> i32;
    }
}

/// A read-only file served from a shared memory mapping: in-bounds
/// `read_at`/`read_exact_at` become a memcpy out of the OS page cache
/// instead of a `pread` syscall (SQLite's `SQLITE_MMAP_SIZE` idea).
/// Reads at or past the mapped prefix fall back to the inner handle, so
/// a file a live writer has grown since the map was taken still reads
/// correctly end to end.
///
/// Safety against truncation: touching mapped bytes beyond the file's
/// *current* length raises SIGBUS. Two facts keep that unreachable
/// here: every mapped access is bound-checked against the mapped length
/// (taken at open, `<=` the file length at that instant), and the
/// storage engine only ever truncates a `.pstore` below that point
/// during tail reclamation — which the snapshot-pin registry gates on
/// no live reader being able to reach the reclaimed pages. Readers are
/// the only holders of mapped handles, and they hold a pin for their
/// whole lifetime.
#[cfg(all(unix, target_pointer_width = "64"))]
struct MmapFile {
    inner: Arc<dyn VfsFile>,
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is PROT_READ over committed bytes the engine
// treats as immutable; concurrent memcpys from it race with nothing.
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Send for MmapFile {}
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Sync for MmapFile {}

#[cfg(all(unix, target_pointer_width = "64"))]
impl MmapFile {
    /// Map `inner` read-only, or `None` when it has no OS descriptor,
    /// is empty (zero-length mappings are invalid), or the kernel
    /// refuses the mapping — all of which mean "serve via `pread`".
    fn try_map(inner: Arc<dyn VfsFile>) -> Option<MmapFile> {
        let fd = inner.os_fd()?;
        let len = inner.len().ok()? as usize;
        if len == 0 {
            return None;
        }
        let ptr = unsafe {
            mmap_sys::mmap(std::ptr::null_mut(), len, mmap_sys::PROT_READ, mmap_sys::MAP_SHARED, fd, 0)
        };
        if ptr as isize == -1 {
            return None; // MAP_FAILED
        }
        Some(MmapFile { inner, ptr, len })
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Drop for MmapFile {
    fn drop(&mut self) {
        // SAFETY: ptr/len are exactly what mmap returned; the fd (and
        // inner handle) outlive the mapping, and nothing reads from the
        // mapping after drop.
        unsafe {
            mmap_sys::munmap(self.ptr, self.len);
        }
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl VfsFile for MmapFile {
    fn read_at(&self, buf: &mut [u8], offset: u64) -> io::Result<usize> {
        if offset >= self.len as u64 {
            // Past the mapped prefix: the file may have grown since the
            // map was taken — the inner handle sees the live length.
            return self.inner.read_at(buf, offset);
        }
        let n = buf.len().min(self.len - offset as usize);
        // SAFETY: offset + n <= self.len, and the mapping stays valid
        // for the life of self (see type docs for why the bytes cannot
        // be truncated out from under a pinned reader).
        unsafe {
            std::ptr::copy_nonoverlapping(self.ptr.add(offset as usize), buf.as_mut_ptr(), n);
        }
        Ok(n)
    }

    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> io::Result<()> {
        if (offset as usize) < self.len && buf.len() <= self.len - offset as usize {
            // SAFETY: wholly in-bounds of the mapping (see read_at).
            unsafe {
                std::ptr::copy_nonoverlapping(
                    self.ptr.add(offset as usize),
                    buf.as_mut_ptr(),
                    buf.len(),
                );
            }
            Ok(())
        } else {
            // Straddles or lies past the mapped prefix: one positional
            // read against the live file.
            self.inner.read_exact_at(buf, offset)
        }
    }

    fn write_all_at(&self, buf: &[u8], offset: u64) -> io::Result<()> {
        self.inner.write_all_at(buf, offset) // read-only handle: rejects
    }

    fn set_len(&self, len: u64) -> io::Result<()> {
        self.inner.set_len(len) // read-only handle: rejects
    }

    fn sync(&self) -> io::Result<()> {
        self.inner.sync()
    }

    fn len(&self) -> io::Result<u64> {
        self.inner.len() // live length, not the mapped prefix
    }

    fn os_fd(&self) -> Option<i32> {
        self.inner.os_fd()
    }
}

/// Try to serve `inner` through a read-only shared memory mapping.
/// `None` — caller keeps the plain handle — when the file exposes no OS
/// descriptor ([`MemVfs`], [`FaultVfs`]), is empty, the platform has no
/// mapping path, or the kernel refuses the map. When `Some`, reads are
/// bit-identical to the plain handle (reads past the mapped prefix fall
/// back to it), only cheaper.
#[cfg(all(unix, target_pointer_width = "64"))]
pub fn map_read_only(inner: &Arc<dyn VfsFile>) -> Option<Arc<dyn VfsFile>> {
    MmapFile::try_map(inner.clone()).map(|f| Arc::new(f) as Arc<dyn VfsFile>)
}

/// No mapping path on this platform: always `None`.
#[cfg(not(all(unix, target_pointer_width = "64")))]
pub fn map_read_only(_inner: &Arc<dyn VfsFile>) -> Option<Arc<dyn VfsFile>> {
    None
}

/// A wrapper [`Vfs`] that serves **read-only** opens from a shared
/// memory mapping whenever the inner file exposes a real OS descriptor
/// (only [`StdVfs`] files do). Everything else — writable opens, files
/// over [`MemVfs`] or [`FaultVfs`], platforms without the mapping path,
/// kernels that refuse the map — passes through to the inner VFS
/// untouched, so enabling mmap can never change behavior, only the
/// syscall count. In particular a [`FaultVfs`] underneath keeps exact
/// fault accounting: its files expose no descriptor, so they are never
/// mapped around.
pub struct MmapVfs {
    inner: Arc<dyn Vfs>,
}

impl MmapVfs {
    /// Wrap `inner`, mapping read-only opens where possible.
    pub fn new(inner: Arc<dyn Vfs>) -> MmapVfs {
        MmapVfs { inner }
    }
}

impl Vfs for MmapVfs {
    fn open(&self, path: &Path, mode: OpenMode) -> io::Result<Arc<dyn VfsFile>> {
        let file = self.inner.open(path, mode)?;
        if mode == OpenMode::Read {
            if let Some(mapped) = map_read_only(&file) {
                return Ok(mapped);
            }
        }
        Ok(file)
    }

    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        self.inner.create_dir_all(path)
    }

    fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        self.inner.list_dir(dir)
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        self.inner.read(path)
    }

    fn instance_id(&self) -> u64 {
        // Mapping does not change which store the files belong to.
        self.inner.instance_id()
    }

    fn registry_key(&self, path: &Path) -> PathBuf {
        self.inner.registry_key(path)
    }
}

// ---------------------------------------------------------------------------
// FaultVfs
// ---------------------------------------------------------------------------

/// A deterministic fault schedule. Write and sync attempts are counted
/// globally (1-based) across all files of the [`FaultVfs`], in the order
/// the engine issues them — single-writer stores issue a deterministic
/// sequence, so "the 7th write" names the same call site on every run.
/// `set_len` and a truncating open ([`OpenMode::CreateTruncate`]) count
/// as writes.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    /// Stop the world after this many mutations (writes + truncates +
    /// syncs) have *completed*: every later mutation fails with a
    /// "simulated crash" error, freezing the disk image for inspection.
    pub crash_after_ops: Option<u64>,
    /// Fail the Nth write attempt cleanly (no bytes applied).
    pub fail_write: Option<u64>,
    /// Tear the Nth write attempt: apply only the first `.1` bytes,
    /// then fail.
    pub torn_write: Option<(u64, usize)>,
    /// Fail the Nth sync attempt (the file's durable image does not
    /// advance).
    pub fail_sync: Option<u64>,
}

/// Which disk image [`FaultVfs::crash_snapshot`] reconstructs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashImage {
    /// Every completed write survives the crash (the kernel flushed its
    /// page cache just in time).
    AllApplied,
    /// Only fsynced state survives (the kernel dropped everything the
    /// engine had not made durable) — the harshest legal image.
    SyncedOnly,
}

#[derive(Clone)]
enum PendingOp {
    Write { offset: u64, bytes: Vec<u8> },
    SetLen(u64),
}

fn apply_op(image: &mut Vec<u8>, op: &PendingOp) {
    match op {
        PendingOp::Write { offset, bytes } => {
            let end = *offset as usize + bytes.len();
            if image.len() < end {
                image.resize(end, 0);
            }
            image[*offset as usize..end].copy_from_slice(bytes);
        }
        PendingOp::SetLen(len) => image.resize(*len as usize, 0),
    }
}

#[derive(Clone, Default)]
struct FileTrack {
    /// The durable image as of the file's last successful sync. `None`
    /// means the file has never been durably synced at all — it was
    /// created this session and a crash may leave it missing entirely,
    /// so the fsynced-only crash image omits it.
    synced: Option<Vec<u8>>,
    /// Completed mutations since then, in order.
    pending: Vec<PendingOp>,
}

#[derive(Default)]
struct FaultState {
    plan: FaultPlan,
    ops_done: u64,
    writes_attempted: u64,
    syncs_attempted: u64,
    files: HashMap<PathBuf, FileTrack>,
}

impl FaultState {
    fn crashed(&self) -> bool {
        matches!(self.plan.crash_after_ops, Some(c) if self.ops_done >= c)
    }

    /// The shared gate for every write-class mutation (byte writes,
    /// truncations, truncating opens): enforces the crash freeze, counts
    /// the attempt, and injects a scheduled clean failure. Returns the
    /// 1-based attempt number so byte-level faults (tearing) can match
    /// against it.
    fn begin_write(&mut self, what: &str) -> io::Result<u64> {
        if self.crashed() {
            return Err(crash_error());
        }
        self.writes_attempted += 1;
        let n = self.writes_attempted;
        if self.plan.fail_write == Some(n) {
            return Err(io::Error::new(
                io::ErrorKind::Other,
                format!("injected failure of write {n} ({what})"),
            ));
        }
        Ok(n)
    }

    /// The gate for sync attempts: crash freeze + scheduled failure.
    fn begin_sync(&mut self) -> io::Result<u64> {
        if self.crashed() {
            return Err(crash_error());
        }
        self.syncs_attempted += 1;
        let n = self.syncs_attempted;
        if self.plan.fail_sync == Some(n) {
            return Err(io::Error::new(
                io::ErrorKind::Other,
                format!("injected failure of sync {n}"),
            ));
        }
        Ok(n)
    }
}

fn crash_error() -> io::Error {
    io::Error::new(io::ErrorKind::Other, "simulated crash: fault schedule stopped I/O")
}

/// Deterministic fault injection over any inner [`Vfs`] (typically
/// [`MemVfs`]). Clone handles share one schedule and one crash image.
#[derive(Clone)]
pub struct FaultVfs {
    inner: Arc<dyn Vfs>,
    state: Arc<Mutex<FaultState>>,
}

impl FaultVfs {
    /// Wrap `inner` with an empty (fault-free) schedule.
    pub fn new(inner: Arc<dyn Vfs>) -> FaultVfs {
        FaultVfs { inner, state: Arc::new(Mutex::new(FaultState::default())) }
    }

    /// Install a fault schedule (counters keep running; plans can be
    /// swapped mid-workload to arm a fault "from here on").
    pub fn set_plan(&self, plan: FaultPlan) {
        lock(&self.state).plan = plan;
    }

    /// Disarm every fault.
    pub fn disarm(&self) {
        self.set_plan(FaultPlan::default());
    }

    /// Mutations (writes + truncates + syncs) completed so far.
    pub fn ops_done(&self) -> u64 {
        lock(&self.state).ops_done
    }

    /// Write attempts so far (including failed/torn ones).
    pub fn writes_attempted(&self) -> u64 {
        lock(&self.state).writes_attempted
    }

    /// Sync attempts so far (including failed ones).
    pub fn syncs_attempted(&self) -> u64 {
        lock(&self.state).syncs_attempted
    }

    /// Reconstruct the post-crash disk: every tracked file's bytes under
    /// the chosen [`CrashImage`]. A file created this session but never
    /// fsynced is absent from the [`CrashImage::SyncedOnly`] image — a
    /// real crash may leave its directory entry unwritten.
    pub fn crash_snapshot(&self, image: CrashImage) -> BTreeMap<PathBuf, Vec<u8>> {
        let st = lock(&self.state);
        st.files
            .iter()
            .filter_map(|(path, track)| {
                let mut bytes = match (&track.synced, image) {
                    (Some(b), _) => b.clone(),
                    (None, CrashImage::AllApplied) => Vec::new(),
                    (None, CrashImage::SyncedOnly) => return None,
                };
                if image == CrashImage::AllApplied {
                    for op in &track.pending {
                        apply_op(&mut bytes, op);
                    }
                }
                Some((path.clone(), bytes))
            })
            .collect()
    }

    /// Reconstruct a post-crash disk where each un-synced mutation —
    /// including the creation of a never-synced file — independently
    /// survived with probability ½: the "kernel flushed some pages, not
    /// others" image. Seeded: the same `rng` state always yields the
    /// same disk.
    pub fn crash_snapshot_subset(&self, rng: &mut Rng) -> BTreeMap<PathBuf, Vec<u8>> {
        let st = lock(&self.state);
        let mut paths: Vec<&PathBuf> = st.files.keys().collect();
        paths.sort(); // HashMap order must not reach the rng stream
        let mut out = BTreeMap::new();
        for path in paths {
            let track = &st.files[path];
            let mut bytes = match &track.synced {
                Some(b) => b.clone(),
                // Creation itself is an un-synced mutation: the file may
                // or may not have made it to the directory.
                None if rng.bernoulli(0.5) => Vec::new(),
                None => continue,
            };
            for op in &track.pending {
                if rng.bernoulli(0.5) {
                    apply_op(&mut bytes, op);
                }
            }
            out.insert(path.clone(), bytes);
        }
        out
    }
}

struct FaultFile {
    path: PathBuf,
    inner: Arc<dyn VfsFile>,
    state: Arc<Mutex<FaultState>>,
}

impl FaultFile {
    fn track<'s>(st: &'s mut FaultState, path: &Path) -> &'s mut FileTrack {
        st.files.entry(path.to_path_buf()).or_default()
    }
}

impl VfsFile for FaultFile {
    fn read_at(&self, buf: &mut [u8], offset: u64) -> io::Result<usize> {
        self.inner.read_at(buf, offset) // reads are never faulted
    }

    fn write_all_at(&self, buf: &[u8], offset: u64) -> io::Result<()> {
        let mut st = lock(&self.state);
        let n = st.begin_write("write")?;
        if let Some((wn, cut)) = st.plan.torn_write {
            if wn == n {
                let torn = &buf[..cut.min(buf.len())];
                self.inner.write_all_at(torn, offset)?;
                Self::track(&mut st, &self.path)
                    .pending
                    .push(PendingOp::Write { offset, bytes: torn.to_vec() });
                return Err(io::Error::new(
                    io::ErrorKind::Other,
                    format!("injected tear of write {n} after {} bytes", torn.len()),
                ));
            }
        }
        self.inner.write_all_at(buf, offset)?;
        Self::track(&mut st, &self.path)
            .pending
            .push(PendingOp::Write { offset, bytes: buf.to_vec() });
        st.ops_done += 1;
        Ok(())
    }

    fn set_len(&self, len: u64) -> io::Result<()> {
        let mut st = lock(&self.state);
        st.begin_write("set_len")?;
        self.inner.set_len(len)?;
        Self::track(&mut st, &self.path).pending.push(PendingOp::SetLen(len));
        st.ops_done += 1;
        Ok(())
    }

    fn sync(&self) -> io::Result<()> {
        let mut st = lock(&self.state);
        st.begin_sync()?;
        self.inner.sync()?;
        let track = Self::track(&mut st, &self.path);
        let mut image = track.synced.take().unwrap_or_default();
        for op in track.pending.drain(..) {
            apply_op(&mut image, &op);
        }
        track.synced = Some(image);
        st.ops_done += 1;
        Ok(())
    }

    fn len(&self) -> io::Result<u64> {
        self.inner.len()
    }
}

impl Vfs for FaultVfs {
    fn open(&self, path: &Path, mode: OpenMode) -> io::Result<Arc<dyn VfsFile>> {
        if mode == OpenMode::CreateTruncate {
            // Creation-truncation is a mutation like any other: it obeys
            // the crash freeze, counts as a write (so the crash matrix
            // enumerates the store-creation window too), and stays
            // *pending* until a sync — a crash right after the truncate
            // can still resurface the old durable bytes.
            lock(&self.state).begin_write("open-truncate")?;
            // Capture the pre-truncation durable image for files this
            // FaultVfs has not seen yet (the truncating open below would
            // destroy it); a file that did not exist has no durable image
            // to fall back to at all. Already-tracked files keep their
            // track, so reading the prior bytes would be wasted work.
            let tracked = lock(&self.state).files.contains_key(path);
            let prior = if tracked {
                None // unused: or_insert_with below will not run
            } else {
                match self.inner.read(path) {
                    Ok(bytes) => Some(bytes),
                    Err(e) if e.kind() == io::ErrorKind::NotFound => None,
                    Err(e) => return Err(e),
                }
            };
            let inner = self.inner.open(path, mode)?;
            let mut st = lock(&self.state);
            st.files
                .entry(path.to_path_buf())
                .or_insert_with(|| FileTrack { synced: prior, pending: Vec::new() })
                .pending
                .push(PendingOp::SetLen(0));
            st.ops_done += 1;
            return Ok(Arc::new(FaultFile {
                path: path.to_path_buf(),
                inner,
                state: self.state.clone(),
            }));
        }
        if mode == OpenMode::Create && !lock(&self.state).files.contains_key(path) {
            // Creating a missing file is a mutation too: gate it, and
            // track it as never-durably-synced (a crash may leave its
            // directory entry unwritten). Opening an existing file with
            // `Create` mutates nothing and passes straight through below.
            let missing = match self.inner.open(path, OpenMode::Read) {
                Ok(_) => false,
                Err(e) if e.kind() == io::ErrorKind::NotFound => true,
                Err(e) => return Err(e),
            };
            if missing {
                lock(&self.state).begin_write("open-create")?;
                let inner = self.inner.open(path, mode)?;
                let mut st = lock(&self.state);
                st.files.insert(path.to_path_buf(), FileTrack::default());
                st.ops_done += 1;
                return Ok(Arc::new(FaultFile {
                    path: path.to_path_buf(),
                    inner,
                    state: self.state.clone(),
                }));
            }
        }
        let inner = self.inner.open(path, mode)?;
        let mut st = lock(&self.state);
        if !st.files.contains_key(path) {
            let synced = Some(read_all(inner.as_ref())?);
            st.files
                .insert(path.to_path_buf(), FileTrack { synced, pending: Vec::new() });
        }
        Ok(Arc::new(FaultFile {
            path: path.to_path_buf(),
            inner,
            state: self.state.clone(),
        }))
    }

    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        self.inner.create_dir_all(path)
    }

    fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        self.inner.list_dir(dir)
    }

    fn instance_id(&self) -> u64 {
        // Faults do not change which store the files belong to.
        self.inner.instance_id()
    }

    fn registry_key(&self, path: &Path) -> PathBuf {
        self.inner.registry_key(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, gen_bytes, prop_assert_eq};
    use std::io::{Read, Seek, SeekFrom, Write};

    fn p(s: &str) -> PathBuf {
        PathBuf::from(s)
    }

    #[test]
    fn mem_modes_and_roundtrip() {
        let vfs = MemVfs::new();
        assert!(vfs.open(&p("/m/a"), OpenMode::Read).is_err(), "missing file");
        assert!(vfs.open(&p("/m/a"), OpenMode::ReadWrite).is_err(), "missing file");
        let f = vfs.open(&p("/m/a"), OpenMode::Create).unwrap();
        f.write_all_at(b"hello", 0).unwrap();
        assert_eq!(f.len().unwrap(), 5);
        // Create preserves; CreateTruncate wipes.
        let g = vfs.open(&p("/m/a"), OpenMode::Create).unwrap();
        assert_eq!(g.len().unwrap(), 5);
        let t = vfs.open(&p("/m/a"), OpenMode::CreateTruncate).unwrap();
        assert_eq!(t.len().unwrap(), 0);
        t.write_all_at(b"xy", 0).unwrap();
        // Read mode reads but rejects mutation.
        let r = vfs.open(&p("/m/a"), OpenMode::Read).unwrap();
        let mut buf = [0u8; 2];
        r.read_exact_at(&mut buf, 0).unwrap();
        assert_eq!(&buf, b"xy");
        assert!(r.write_all_at(b"no", 0).is_err());
        assert!(r.set_len(0).is_err());
    }

    #[test]
    fn mem_gap_write_zero_fills_like_std() {
        let dir = std::env::temp_dir().join("grouper_vfs_gap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let std_path = dir.join("gap.bin");
        let std_vfs = StdVfs;
        let mem_vfs = MemVfs::new();
        let sf = std_vfs.open(&std_path, OpenMode::CreateTruncate).unwrap();
        let mf = mem_vfs.open(&p("/gap.bin"), OpenMode::CreateTruncate).unwrap();
        for f in [&sf, &mf] {
            f.write_all_at(b"ab", 0).unwrap();
            f.write_all_at(b"z", 10).unwrap(); // gap: bytes 2..10 must be zero
            f.set_len(8).unwrap(); // truncate below the far write
            f.set_len(12).unwrap(); // zero-extend back out
        }
        let got_std = read_all(sf.as_ref()).unwrap();
        let got_mem = read_all(mf.as_ref()).unwrap();
        assert_eq!(got_std, got_mem);
        assert_eq!(&got_mem[..2], b"ab");
        assert!(got_mem[2..].iter().all(|b| *b == 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn property_std_and_mem_agree_on_random_op_sequences() {
        let dir = std::env::temp_dir().join("grouper_vfs_prop_test");
        std::fs::create_dir_all(&dir).unwrap();
        check(20, |rng| {
            let std_path = dir.join(format!("f{}.bin", rng.next_u32()));
            let sf = StdVfs.open(&std_path, OpenMode::CreateTruncate).unwrap();
            let mem = MemVfs::new();
            let mf = mem.open(&p("/f.bin"), OpenMode::CreateTruncate).unwrap();
            for _ in 0..12 {
                match rng.gen_range(3) {
                    0 => {
                        let bytes = gen_bytes(rng, 1..=40);
                        let off = rng.gen_range(200);
                        sf.write_all_at(&bytes, off).unwrap();
                        mf.write_all_at(&bytes, off).unwrap();
                    }
                    1 => {
                        let len = rng.gen_range(250);
                        sf.set_len(len).unwrap();
                        mf.set_len(len).unwrap();
                    }
                    _ => {
                        sf.sync().unwrap();
                        mf.sync().unwrap();
                    }
                }
            }
            let a = read_all(sf.as_ref()).unwrap();
            let b = read_all(mf.as_ref()).unwrap();
            std::fs::remove_file(&std_path).ok();
            prop_assert_eq(a, b, "std vs mem file image")
        });
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn list_dir_filters_by_parent() {
        let vfs = MemVfs::new();
        vfs.install(&p("/d/a.bin"), vec![1]);
        vfs.install(&p("/d/b.bin"), vec![2]);
        vfs.install(&p("/d/sub/c.bin"), vec![3]);
        vfs.install(&p("/other/d.bin"), vec![4]);
        let mut got = vfs.list_dir(&p("/d")).unwrap();
        got.sort();
        assert_eq!(got, vec![p("/d/a.bin"), p("/d/b.bin")]);
    }

    #[test]
    fn cursor_read_write_seek() {
        let vfs = MemVfs::new();
        let f = vfs.open(&p("/c.bin"), OpenMode::Create).unwrap();
        let mut w = VfsCursor::new(f.clone());
        w.write_all(b"0123456789").unwrap();
        assert_eq!(w.position(), 10);
        let mut r = VfsCursor::new(f);
        let mut buf = [0u8; 4];
        r.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"0123");
        r.seek(SeekFrom::Start(6)).unwrap();
        r.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"6789");
        assert_eq!(r.read(&mut buf).unwrap(), 0, "clean EOF");
        assert_eq!(r.seek(SeekFrom::End(-2)).unwrap(), 8);
        assert_eq!(r.seek(SeekFrom::Current(1)).unwrap(), 9);
        assert!(r.seek(SeekFrom::Current(-100)).is_err(), "negative offset");
    }

    fn fault_over_mem() -> (FaultVfs, Arc<dyn VfsFile>) {
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let f = fv.open(&p("/f.bin"), OpenMode::Create).unwrap();
        (fv, f)
    }

    #[test]
    fn fault_fails_exactly_the_nth_write() {
        let (fv, f) = fault_over_mem();
        let writes = fv.writes_attempted(); // creating the file counted too
        let ops = fv.ops_done();
        fv.set_plan(FaultPlan { fail_write: Some(writes + 2), ..Default::default() });
        f.write_all_at(b"one", 0).unwrap();
        assert!(f.write_all_at(b"two", 3).is_err(), "2nd write must fail");
        f.write_all_at(b"two", 3).unwrap(); // 3rd attempt passes
        assert_eq!(read_all(f.as_ref()).unwrap(), b"onetwo");
        assert_eq!(fv.writes_attempted(), writes + 3);
        assert_eq!(fv.ops_done(), ops + 2, "the failed write completed nothing");
    }

    #[test]
    fn fault_tears_a_write_at_a_byte_offset() {
        let (fv, f) = fault_over_mem();
        fv.set_plan(FaultPlan {
            torn_write: Some((fv.writes_attempted() + 1, 4)),
            ..Default::default()
        });
        assert!(f.write_all_at(b"0123456789", 0).is_err());
        assert_eq!(read_all(f.as_ref()).unwrap(), b"0123", "only the torn prefix lands");
    }

    #[test]
    fn sync_failure_keeps_the_durable_image_behind() {
        let (fv, f) = fault_over_mem();
        f.write_all_at(b"durable", 0).unwrap();
        f.sync().unwrap();
        f.write_all_at(b"volatile", 7).unwrap();
        fv.set_plan(FaultPlan { fail_sync: Some(2), ..Default::default() });
        assert!(f.sync().is_err(), "2nd sync must fail");
        let synced = fv.crash_snapshot(CrashImage::SyncedOnly);
        assert_eq!(synced[&p("/f.bin")], b"durable".to_vec());
        let all = fv.crash_snapshot(CrashImage::AllApplied);
        assert_eq!(all[&p("/f.bin")], b"durablevolatile".to_vec());
        // A later successful sync advances the durable image.
        fv.disarm();
        f.sync().unwrap();
        let synced = fv.crash_snapshot(CrashImage::SyncedOnly);
        assert_eq!(synced[&p("/f.bin")], b"durablevolatile".to_vec());
    }

    #[test]
    fn crash_after_ops_freezes_the_disk() {
        let (fv, f) = fault_over_mem();
        fv.set_plan(FaultPlan {
            crash_after_ops: Some(fv.ops_done() + 2),
            ..Default::default()
        });
        f.write_all_at(b"a", 0).unwrap();
        f.write_all_at(b"b", 1).unwrap();
        assert!(f.write_all_at(b"c", 2).is_err(), "crashed: writes stop");
        assert!(f.sync().is_err(), "crashed: syncs stop");
        assert!(f.set_len(0).is_err(), "crashed: truncates stop");
        let all = fv.crash_snapshot(CrashImage::AllApplied);
        assert_eq!(all[&p("/f.bin")], b"ab".to_vec());
        // Never synced: a crash may have lost the file entirely, so the
        // fsynced-only image omits it.
        let synced = fv.crash_snapshot(CrashImage::SyncedOnly);
        assert!(!synced.contains_key(&p("/f.bin")));
        // The freeze extends to creating/truncating new files.
        assert!(fv.open(&p("/new.bin"), OpenMode::Create).is_err());
        assert!(fv.open(&p("/new2.bin"), OpenMode::CreateTruncate).is_err());
    }

    #[test]
    fn subset_snapshot_is_seeded_and_deterministic() {
        let build = || {
            let (fv, f) = fault_over_mem();
            f.write_all_at(b"base", 0).unwrap();
            f.sync().unwrap();
            for i in 0..6u8 {
                f.write_all_at(&[b'0' + i], 4 + i as u64).unwrap();
            }
            fv
        };
        let a = build().crash_snapshot_subset(&mut Rng::new(9));
        let b = build().crash_snapshot_subset(&mut Rng::new(9));
        assert_eq!(a, b, "same seed, same crash image");
        let c = build().crash_snapshot_subset(&mut Rng::new(10));
        // The synced prefix always survives regardless of the subset.
        assert!(c[&p("/f.bin")].starts_with(b"base"));
    }

    #[test]
    fn mmap_reads_match_pread_and_track_growth() {
        let dir = std::env::temp_dir().join("grouper_vfs_mmap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mapped.bin");
        let payload: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let w = StdVfs.open(&path, OpenMode::CreateTruncate).unwrap();
        w.write_all_at(&payload, 0).unwrap();

        let mvfs = MmapVfs::new(Arc::new(StdVfs));
        let r = mvfs.open(&path, OpenMode::Read).unwrap();
        assert_eq!(read_all(r.as_ref()).unwrap(), payload);
        let mut mid = [0u8; 64];
        r.read_exact_at(&mut mid, 4321).unwrap();
        assert_eq!(&mid[..], &payload[4321..4321 + 64]);
        assert!(r.write_all_at(b"no", 0).is_err(), "read-only handle");
        assert!(r.set_len(0).is_err(), "read-only handle");

        // A writer grows the file after the map was taken: reads past
        // (and straddling) the mapped prefix must fall back to pread.
        w.write_all_at(b"grown-tail", payload.len() as u64).unwrap();
        assert_eq!(r.len().unwrap(), payload.len() as u64 + 10, "live length");
        let mut tail = [0u8; 10];
        r.read_exact_at(&mut tail, payload.len() as u64).unwrap();
        assert_eq!(&tail, b"grown-tail");
        let mut straddle = [0u8; 14];
        r.read_exact_at(&mut straddle, payload.len() as u64 - 4).unwrap();
        assert_eq!(&straddle[..4], &payload[payload.len() - 4..]);
        assert_eq!(&straddle[4..], b"grown-tail");
        // Whole-file read through the cursor path agrees too.
        let mut all = read_all(r.as_ref()).unwrap();
        assert_eq!(all.split_off(payload.len()), b"grown-tail".to_vec());
        assert_eq!(all, payload);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mmap_over_mem_and_fault_is_an_exact_passthrough() {
        // MemVfs files expose no OS descriptor: MmapVfs must serve them
        // through the inner handle, bit-identically.
        let mem = Arc::new(MemVfs::new());
        mem.install(&p("/m/a.bin"), b"hello mapped world".to_vec());
        let mvfs = MmapVfs::new(mem.clone());
        assert_eq!(mvfs.instance_id(), mem.instance_id(), "same store identity");
        let f = mvfs.open(&p("/m/a.bin"), OpenMode::Read).unwrap();
        assert!(f.os_fd().is_none(), "mem files must never look mappable");
        assert_eq!(read_all(f.as_ref()).unwrap(), b"hello mapped world");

        // FaultVfs under MmapVfs keeps exact fault accounting: a write
        // through a wrapped writable handle still counts, and the Nth
        // write still fails on schedule.
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let wrapped = MmapVfs::new(Arc::new(fv.clone()));
        let f = wrapped.open(&p("/f.bin"), OpenMode::Create).unwrap();
        let writes = fv.writes_attempted();
        fv.set_plan(FaultPlan { fail_write: Some(writes + 2), ..Default::default() });
        f.write_all_at(b"one", 0).unwrap();
        assert!(f.write_all_at(b"two", 3).is_err(), "fault schedule intact through mmap");
        assert_eq!(fv.writes_attempted(), writes + 2);
        // A multi-page-sized vectored read is one read_exact_at to the
        // fault layer — reads are never faulted, never counted.
        let ops = fv.ops_done();
        let mut buf = vec![0u8; 3];
        let r = wrapped.open(&p("/f.bin"), OpenMode::Read).unwrap();
        r.read_exact_at(&mut buf, 0).unwrap();
        assert_eq!(&buf, b"one");
        assert_eq!(fv.ops_done(), ops, "reads must not advance the op counter");
    }

    #[test]
    fn vfs_types_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<StdVfs>();
        assert_send_sync::<MemVfs>();
        assert_send_sync::<FaultVfs>();
        assert_send_sync::<MmapVfs>();
        assert_send_sync::<VfsCursor>();
        assert_send_sync::<Arc<dyn Vfs>>();
        assert_send_sync::<Arc<dyn VfsFile>>();
    }
}
