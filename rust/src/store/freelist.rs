//! The crash-safe page free-list: how the engine reclaims the pages the
//! copy-on-write B+tree supersedes, instead of leaking them forever.
//!
//! # On-disk format: linked trunk pages (SQLite-style)
//!
//! The durable free-list is a singly linked chain of **trunk pages**
//! referenced from the store header. Each trunk (all little-endian):
//!
//! ```text
//! u32  next trunk page id (0 = end of chain; page 0 is the header)
//! u32  entry count in this trunk
//! entry*: u32 free page id | u64 free epoch
//! ```
//!
//! Unlike SQLite's trunk format, every entry carries the checkpoint
//! **epoch at which the page became free** — the key to safe reuse under
//! concurrent snapshot readers (below). A 4 KiB page holds
//! [`TRUNK_CAPACITY`] entries.
//!
//! # Lifecycle: freed → durable → reusable
//!
//! * [`Freelist::free`] records a page as **pending**: it is dead in the
//!   state being built, but still part of the last durable checkpoint —
//!   recovery may need it — so it is not allocatable yet.
//! * At checkpoint, the pager serializes survivors + pending into a
//!   fresh trunk chain ([`super::pager::Pager::write_freelist`]) and the
//!   header swap publishes it atomically with the new tree root. Only
//!   then do pending pages become **reusable**, tagged with the new
//!   epoch. (The previous chain's trunk pages join the pending set at
//!   that point: they are durable state until the swap.)
//! * [`Freelist::allocate`] hands back the lowest reusable id whose free
//!   epoch clears the caller's **reuse gate** — lowest-first, so reuse
//!   also compacts allocation toward the file head.
//!
//! # The epoch-gated reuse invariant
//!
//! A page freed at epoch `F` is absent from every committed tree at
//! epochs `>= F`, but a snapshot reader pinned at an epoch `S < F` can
//! still reach it. Rewriting it under such a reader would hand the
//! reader another epoch's bytes — the one failure the shared read path's
//! "committed pages are immutable" contract cannot tolerate. So reuse
//! (and tail truncation) of an entry with free epoch `F` is allowed only
//! when `F <= min pinned epoch` (the gate; `u64::MAX` when no reader is
//! pinned — see [`super::shared::min_pinned_epoch`]). New readers always
//! pin the *current* header epoch, which is `>= F` for every reusable
//! entry, so the gate check cannot race a concurrent reader open.
//!
//! # Why frees need no WAL record type
//!
//! Frees ride the WAL implicitly: every pending free is a deterministic
//! consequence of replaying the logged appends over the committed tree
//! (a COW supersession frees the same page on replay that it freed in
//! the original run), and compaction's frees are published by its own
//! checkpoints before `compact` returns. A separate free-record type
//! would double-apply during replay; the durable trunk chain written at
//! each checkpoint is the free-list's whole crash-safety story.

use std::collections::{BTreeMap, BTreeSet};
use std::io;

use super::page::{Page, PageId, PAGE_SIZE};

/// Trunk page header bytes: next-trunk id + entry count.
const TRUNK_HDR: usize = 8;
/// Bytes per entry: `u32` page id + `u64` free epoch.
const ENTRY_BYTES: usize = 12;
/// Entries one trunk page holds.
pub const TRUNK_CAPACITY: usize = (PAGE_SIZE - TRUNK_HDR) / ENTRY_BYTES;

fn corrupt(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("freelist: {msg}"))
}

/// In-memory free-list state (the pager owns one; see the module docs
/// for the on-disk trunk chain it serializes to).
#[derive(Debug, Default)]
pub struct Freelist {
    /// Durably free pages available for reuse: id → free epoch. Ordered
    /// so serialization and by-id lookups are cheap.
    reusable: BTreeMap<PageId, u64>,
    /// The allocation index: free ids grouped by free epoch. Lets
    /// [`Freelist::allocate`] consider only the gate-eligible epoch
    /// buckets — a fully gate-blocked list (the long-pinned-reader
    /// case, where the list grows while nothing clears the gate)
    /// answers without touching a single entry, and a partially blocked
    /// one scans eligible buckets, not every blocked entry.
    by_epoch: BTreeMap<u64, BTreeSet<PageId>>,
    /// Pages freed since the last checkpoint: dead in the state being
    /// built, still live in the durable one — not allocatable yet.
    pending: BTreeSet<PageId>,
    /// Trunk pages of the *current durable* chain. They hold committed
    /// metadata until the next header swap, so they are freed (into
    /// `pending`) only when the next chain is written.
    trunks: Vec<PageId>,
}

impl Freelist {
    fn index_add(&mut self, id: PageId, epoch: u64) {
        self.by_epoch.entry(epoch).or_default().insert(id);
    }

    fn index_remove(&mut self, id: PageId, epoch: u64) {
        if let Some(ids) = self.by_epoch.get_mut(&epoch) {
            ids.remove(&id);
            if ids.is_empty() {
                self.by_epoch.remove(&epoch);
            }
        }
    }
}

impl Freelist {
    /// An empty free-list.
    pub fn new() -> Freelist {
        Freelist::default()
    }

    /// Record `id` as freed by the state being built (pending until the
    /// next checkpoint publishes it).
    ///
    /// # Errors
    /// `InvalidData` when `id` is already free (a double free is always
    /// an engine bug, never recoverable state).
    pub fn free(&mut self, id: PageId) -> io::Result<()> {
        if self.reusable.contains_key(&id) || !self.pending.insert(id) {
            return Err(corrupt(&format!("double free of page {id}")));
        }
        Ok(())
    }

    /// Pop the lowest reusable page whose free epoch is `<= gate`
    /// (returning its id and that epoch), or `None` when every entry is
    /// gate-blocked or the list is empty. Cost is the number of
    /// gate-eligible epoch *buckets*, never the number of blocked
    /// entries: each eligible bucket contributes its lowest id and the
    /// minimum wins.
    pub fn allocate(&mut self, gate: u64) -> Option<(PageId, u64)> {
        let (id, epoch) = self
            .by_epoch
            .range(..=gate)
            .filter_map(|(epoch, ids)| ids.first().map(|id| (*id, *epoch)))
            .min()?; // tuples compare by id first: lowest id wins
        self.reusable.remove(&id);
        self.index_remove(id, epoch);
        Some((id, epoch))
    }

    /// Put back an entry popped by [`Freelist::allocate`] (the caller's
    /// follow-up work failed).
    pub fn reinsert(&mut self, id: PageId, epoch: u64) {
        self.reusable.insert(id, epoch);
        self.index_add(id, epoch);
    }

    /// Reusable entry's free epoch, when `id` is reusable.
    pub fn free_epoch(&self, id: PageId) -> Option<u64> {
        self.reusable.get(&id).copied()
    }

    /// Drop a reusable entry (tail reclamation). Returns false when `id`
    /// was not reusable.
    pub fn remove(&mut self, id: PageId) -> bool {
        match self.reusable.remove(&id) {
            Some(epoch) => {
                self.index_remove(id, epoch);
                true
            }
            None => false,
        }
    }

    /// Reusable entries.
    pub fn reusable_len(&self) -> usize {
        self.reusable.len()
    }

    /// Reusable entries whose free epoch clears `gate` — how much the
    /// current readers allow to be reused or reclaimed right now.
    /// Answered from the per-epoch index (O(eligible epoch buckets),
    /// not O(entries)).
    pub fn reusable_under(&self, gate: u64) -> usize {
        self.by_epoch.range(..=gate).map(|(_, ids)| ids.len()).sum()
    }

    /// Pages freed since the last checkpoint.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// All free pages: reusable + pending (the `stat` "free" number).
    pub fn len(&self) -> usize {
        self.reusable.len() + self.pending.len()
    }

    /// True when no page is free.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Trunk pages of the current durable chain.
    pub fn trunks(&self) -> &[PageId] {
        &self.trunks
    }

    /// Forget everything (recovery rewinds to a durable chain via
    /// [`Freelist::absorb_chain`] afterwards).
    pub fn clear(&mut self) {
        self.reusable.clear();
        self.by_epoch.clear();
        self.pending.clear();
        self.trunks.clear();
    }

    /// Begin serializing the next chain: the old chain's trunk pages
    /// become this epoch's frees (they are superseded the moment the new
    /// chain is published). Idempotent once per checkpoint.
    pub fn retire_trunks(&mut self) -> io::Result<()> {
        for id in std::mem::take(&mut self.trunks) {
            self.free(id)?;
        }
        Ok(())
    }

    /// Publish: pending entries become reusable at `free_epoch`, and
    /// `trunks` becomes the new chain.
    pub fn publish(&mut self, free_epoch: u64, trunks: Vec<PageId>) {
        for id in std::mem::take(&mut self.pending) {
            self.reusable.insert(id, free_epoch);
            self.index_add(id, free_epoch);
        }
        self.trunks = trunks;
    }

    /// Snapshot of every entry the next durable chain must carry:
    /// reusable entries keep their epochs, pending ones are tagged
    /// `free_epoch`. Sorted by id.
    pub fn chain_entries(&self, free_epoch: u64) -> Vec<(PageId, u64)> {
        let mut out: Vec<(PageId, u64)> =
            self.reusable.iter().map(|(id, e)| (*id, *e)).collect();
        out.extend(self.pending.iter().map(|id| (*id, free_epoch)));
        out.sort_unstable_by_key(|(id, _)| *id);
        out
    }

    /// Install the decoded entries of one trunk page (used while walking
    /// a durable chain at open). `bound` is the pager's page count: an
    /// entry at or past it means the chain disagrees with the file — a
    /// corrupt image that must not hand out unbacked pages.
    ///
    /// # Errors
    /// `InvalidData` on an out-of-bounds, header (0) or duplicate entry.
    pub fn absorb_chain(
        &mut self,
        trunk: PageId,
        entries: &[(PageId, u64)],
        bound: PageId,
    ) -> io::Result<()> {
        for &(id, epoch) in entries {
            if id == 0 || id >= bound {
                return Err(corrupt(&format!(
                    "chain entry {id} out of bounds (file has {bound} pages)"
                )));
            }
            if self.reusable.insert(id, epoch).is_some() {
                return Err(corrupt(&format!("chain lists page {id} twice")));
            }
            self.index_add(id, epoch);
        }
        self.trunks.push(trunk);
        Ok(())
    }
}

/// Encode one trunk page.
///
/// # Panics
/// Debug-asserts `entries.len() <= TRUNK_CAPACITY`.
pub fn encode_trunk(next: PageId, entries: &[(PageId, u64)]) -> Page {
    debug_assert!(entries.len() <= TRUNK_CAPACITY);
    let mut page = Page::zeroed();
    page.put_u32(0, next);
    page.put_u32(4, entries.len() as u32);
    let mut at = TRUNK_HDR;
    for (id, epoch) in entries {
        page.put_u32(at, *id);
        page.put_u64(at + 4, *epoch);
        at += ENTRY_BYTES;
    }
    page
}

/// Decode one trunk page into `(next trunk id, entries)`.
///
/// # Errors
/// `InvalidData` when the entry count exceeds [`TRUNK_CAPACITY`].
pub fn decode_trunk(page: &Page) -> io::Result<(PageId, Vec<(PageId, u64)>)> {
    let next = page.get_u32(0);
    let count = page.get_u32(4) as usize;
    if count > TRUNK_CAPACITY {
        return Err(corrupt(&format!("trunk claims {count} entries")));
    }
    let mut entries = Vec::with_capacity(count);
    let mut at = TRUNK_HDR;
    for _ in 0..count {
        entries.push((page.get_u32(at), page.get_u64(at + 4)));
        at += ENTRY_BYTES;
    }
    Ok((next, entries))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trunk_roundtrip() {
        let entries: Vec<(PageId, u64)> = (0..TRUNK_CAPACITY as u32)
            .map(|i| (i + 5, u64::from(i) * 7))
            .collect();
        let page = encode_trunk(42, &entries);
        let (next, got) = decode_trunk(&page).unwrap();
        assert_eq!(next, 42);
        assert_eq!(got, entries);
        // Empty trunk.
        let (next, got) = decode_trunk(&encode_trunk(0, &[])).unwrap();
        assert_eq!((next, got.len()), (0, 0));
    }

    #[test]
    fn decode_rejects_oversized_count() {
        let mut page = Page::zeroed();
        page.put_u32(4, (TRUNK_CAPACITY + 1) as u32);
        assert!(decode_trunk(&page).is_err());
    }

    #[test]
    fn pending_is_not_allocatable_until_published() {
        let mut fl = Freelist::new();
        fl.free(7).unwrap();
        fl.free(3).unwrap();
        assert_eq!(fl.allocate(u64::MAX), None, "pending pages are off-limits");
        assert_eq!((fl.pending_len(), fl.len()), (2, 2));
        fl.publish(4, Vec::new());
        assert_eq!(fl.allocate(u64::MAX), Some((3, 4)), "lowest id first");
        assert_eq!(fl.allocate(u64::MAX), Some((7, 4)));
        assert_eq!(fl.allocate(u64::MAX), None);
    }

    #[test]
    fn allocate_respects_the_epoch_gate() {
        let mut fl = Freelist::new();
        fl.free(2).unwrap();
        fl.publish(1, Vec::new());
        fl.free(5).unwrap();
        fl.publish(3, Vec::new());
        // A reader pinned at epoch 2: only the epoch-1 free clears it.
        assert_eq!(fl.allocate(2), Some((2, 1)));
        assert_eq!(fl.allocate(2), None, "epoch-3 free is gate-blocked");
        assert_eq!(fl.allocate(3), Some((5, 3)));
    }

    #[test]
    fn per_epoch_index_stays_consistent() {
        let mut fl = Freelist::new();
        fl.free(2).unwrap();
        fl.free(3).unwrap();
        fl.publish(1, Vec::new());
        fl.free(9).unwrap();
        fl.publish(4, Vec::new());
        assert_eq!(fl.reusable_under(0), 0);
        assert_eq!(fl.reusable_under(1), 2);
        assert_eq!(fl.reusable_under(4), 3);
        assert_eq!(fl.allocate(0), None, "fully blocked answers via the index");
        let (id, epoch) = fl.allocate(1).unwrap();
        assert_eq!((id, epoch), (2, 1));
        assert_eq!(fl.reusable_under(1), 1, "allocation decrements the index");
        fl.reinsert(id, epoch);
        assert_eq!(fl.reusable_under(1), 2, "reinsert restores it");
        assert!(fl.remove(9));
        assert_eq!(fl.reusable_under(u64::MAX), 2, "removal decrements it");
        fl.clear();
        assert_eq!(fl.reusable_under(u64::MAX), 0);
    }

    #[test]
    fn double_free_is_rejected() {
        let mut fl = Freelist::new();
        fl.free(9).unwrap();
        assert!(fl.free(9).is_err(), "pending double free");
        fl.publish(1, Vec::new());
        assert!(fl.free(9).is_err(), "reusable double free");
    }

    #[test]
    fn retire_trunks_frees_the_old_chain() {
        let mut fl = Freelist::new();
        fl.free(4).unwrap();
        fl.publish(1, vec![10, 11]);
        fl.retire_trunks().unwrap();
        assert_eq!(fl.pending_len(), 2, "old trunks are pending frees");
        assert!(fl.trunks().is_empty());
        // They are chain entries at the next epoch…
        let entries = fl.chain_entries(2);
        assert_eq!(entries, vec![(4, 1), (10, 2), (11, 2)]);
        // …and only allocatable once published.
        assert_eq!(fl.allocate(u64::MAX), Some((4, 1)));
        fl.publish(2, vec![12]);
        assert_eq!(fl.allocate(u64::MAX), Some((10, 2)));
    }

    #[test]
    fn absorb_chain_validates_bounds_and_duplicates() {
        let mut fl = Freelist::new();
        fl.absorb_chain(9, &[(3, 1), (4, 2)], 10).unwrap();
        assert_eq!(fl.reusable_len(), 2);
        assert_eq!(fl.trunks(), &[9]);
        let mut oob = Freelist::new();
        assert!(oob.absorb_chain(9, &[(10, 1)], 10).is_err(), "id == bound");
        assert!(oob.absorb_chain(9, &[(0, 1)], 10).is_err(), "header id");
        let mut dup = Freelist::new();
        dup.absorb_chain(8, &[(3, 1)], 10).unwrap();
        assert!(dup.absorb_chain(9, &[(3, 2)], 10).is_err(), "duplicate id");
    }
}
