//! The pager: page allocation, read-through-cache access and ordered
//! flush over one paged file.
//!
//! All page I/O for a file goes through one `Pager`, so the LRU cache is
//! the single knob governing how much index state stays hot — the
//! tunable the hardcoded root-only caching of the original
//! `btree_index` could not offer.
//!
//! Durability contract: nothing is guaranteed on disk until
//! [`Pager::flush`], which writes every dirty page in ascending id order
//! and then fsyncs. Callers building crash-safe structures pair this
//! with the WAL ([`super::wal`]): log logically first, flush pages at
//! checkpoint, swap the header page last.
//!
//! Space reclamation: the pager owns a [`super::freelist::Freelist`].
//! [`Pager::free`] records a page as pending-free; [`Pager::allocate`]
//! prefers reusing a durably-free page (lowest id first, subject to the
//! epoch [`Pager::set_reuse_gate`]) over growing the file; and
//! [`Pager::write_freelist`]/[`Pager::load_freelist`] serialize the list
//! as the linked trunk chain the store header points at.
//! [`Pager::reclaim_tail`] gives freed tail pages back to the
//! filesystem. Pages allocated since the last [`Pager::mark_committed`]
//! are *fresh* ([`Pager::is_fresh`]): the B+tree mutates them in place
//! even when their id sits below its copy-on-write watermark, which is
//! what keeps reused low-id pages from being pointlessly re-copied.
//!
//! All file I/O goes through the [`super::vfs`] layer: the `*_with`
//! constructors take any [`Vfs`], the plain ones default to
//! [`StdVfs`] — which is how the fault-injection suite drives a pager
//! over [`super::vfs::FaultVfs`] without the pager knowing.

use std::collections::HashSet;
use std::io;
use std::path::Path;
use std::sync::Arc;

use super::cache::{CacheStats, PageCache};
use super::freelist::{decode_trunk, encode_trunk, Freelist, TRUNK_CAPACITY};
use super::page::{Page, PageId, PAGE_SIZE};
use super::vfs::{OpenMode, StdVfs, Vfs, VfsFile};

/// Uniform page-read access for tree walkers: implemented by the
/// exclusive [`Pager`] (the write path) and by the concurrent
/// [`super::shared::SnapshotReader`] (the shared read path), so readers
/// like [`super::btree::BTree::scan_from`] are agnostic to which one
/// serves them.
pub trait PageRead {
    /// Read one page, returning an owned copy.
    ///
    /// # Errors
    /// Fails when `id` is out of bounds for the implementor's view of
    /// the file, or on an underlying I/O error.
    fn read_page(&mut self, id: PageId) -> io::Result<Page>;

    /// Advisory, best-effort hint that the caller is about to read
    /// `ids`: implementors with a batched read path (the shared pager's
    /// vectored group scans) coalesce runs of adjacent ids into one
    /// positional read. The default does nothing, so single-page
    /// implementors (the exclusive [`Pager`], test doubles) are
    /// unaffected. Must never change what `read_page` returns.
    fn prefetch(&mut self, _ids: &[PageId]) {}
}

/// The exclusive pager: one owner, `&mut self` access, a single LRU
/// cache. This is the write path; for concurrent `Send + Sync` reads
/// over a committed file, see [`super::shared::SharedPager`].
pub struct Pager {
    file: Arc<dyn VfsFile>,
    cache: PageCache,
    num_pages: u32,
    writable: bool,
    disk_reads: u64,
    disk_writes: u64,
    freelist: Freelist,
    /// Pages allocated since the last [`Pager::mark_committed`]: they
    /// belong to no committed state, so callers (the COW B+tree) may
    /// mutate them in place regardless of their id.
    fresh: HashSet<PageId>,
    /// Free entries with a free epoch above this value are not
    /// reusable/reclaimable (a snapshot reader pinned at an older epoch
    /// could still reach them). `u64::MAX` = no reader pinned.
    reuse_gate: u64,
}

fn base_pager(file: Arc<dyn VfsFile>, cache_pages: usize, num_pages: u32, writable: bool) -> Pager {
    Pager {
        file,
        cache: PageCache::new(cache_pages),
        num_pages,
        writable,
        disk_reads: 0,
        disk_writes: 0,
        freelist: Freelist::new(),
        fresh: HashSet::new(),
        reuse_gate: u64::MAX,
    }
}

impl Pager {
    /// Create (or truncate) a paged file on the real filesystem
    /// (equivalent to [`Pager::create_with`] over [`StdVfs`]).
    ///
    /// # Errors
    /// Fails when the parent directory cannot be created or the file
    /// cannot be opened for writing.
    ///
    /// # Panics
    /// Panics when `cache_pages` is 0 (the cache needs one frame).
    pub fn create(path: &Path, cache_pages: usize) -> io::Result<Pager> {
        Pager::create_with(&StdVfs, path, cache_pages)
    }

    /// Create (or truncate) a paged file on `vfs`.
    ///
    /// # Errors
    /// Fails when the parent directory cannot be created or the file
    /// cannot be opened for writing.
    ///
    /// # Panics
    /// Panics when `cache_pages` is 0 (the cache needs one frame).
    pub fn create_with(vfs: &dyn Vfs, path: &Path, cache_pages: usize) -> io::Result<Pager> {
        if let Some(d) = path.parent() {
            vfs.create_dir_all(d)?;
        }
        let file = vfs.open(path, OpenMode::CreateTruncate)?;
        Ok(base_pager(file, cache_pages, 0, true))
    }

    /// Open an existing paged file read/write on the real filesystem
    /// (equivalent to [`Pager::open_with`] over [`StdVfs`]). A torn
    /// trailing partial page (crash mid-extend) is ignored, not an
    /// error.
    ///
    /// # Errors
    /// Fails when the file does not exist or cannot be opened
    /// read/write.
    pub fn open(path: &Path, cache_pages: usize) -> io::Result<Pager> {
        Pager::open_with(&StdVfs, path, cache_pages)
    }

    /// Open an existing paged file read/write on `vfs`.
    ///
    /// # Errors
    /// Fails when the file does not exist or cannot be opened
    /// read/write.
    pub fn open_with(vfs: &dyn Vfs, path: &Path, cache_pages: usize) -> io::Result<Pager> {
        let file = vfs.open(path, OpenMode::ReadWrite)?;
        let num_pages = (file.len()? / PAGE_SIZE as u64) as u32;
        Ok(base_pager(file, cache_pages, num_pages, true))
    }

    /// Open read-only (readers over immutable/committed files) on the
    /// real filesystem.
    ///
    /// # Errors
    /// Fails when the file does not exist or cannot be opened.
    pub fn open_read(path: &Path, cache_pages: usize) -> io::Result<Pager> {
        Pager::open_read_with(&StdVfs, path, cache_pages)
    }

    /// Open read-only on `vfs`.
    ///
    /// # Errors
    /// Fails when the file does not exist or cannot be opened.
    pub fn open_read_with(vfs: &dyn Vfs, path: &Path, cache_pages: usize) -> io::Result<Pager> {
        let file = vfs.open(path, OpenMode::Read)?;
        let num_pages = (file.len()? / PAGE_SIZE as u64) as u32;
        Ok(base_pager(file, cache_pages, num_pages, false))
    }

    /// Pages allocated in the file (committed or not).
    pub fn num_pages(&self) -> u32 {
        self.num_pages
    }

    /// False for pagers opened via [`Pager::open_read`].
    pub fn is_writable(&self) -> bool {
        self.writable
    }

    fn read_from_disk(&mut self, id: PageId) -> io::Result<Page> {
        let mut buf = vec![0u8; PAGE_SIZE];
        self.file.read_exact_at(&mut buf, id as u64 * PAGE_SIZE as u64)?;
        self.disk_reads += 1;
        Page::from_vec(buf)
    }

    fn write_to_disk(&mut self, id: PageId, page: &Page) -> io::Result<()> {
        self.file
            .write_all_at(page.as_slice(), id as u64 * PAGE_SIZE as u64)?;
        self.disk_writes += 1;
        Ok(())
    }

    /// Insert into the cache, writing back the dirty eviction victim
    /// FIRST: if that write fails, the cache is untouched (the victim
    /// stays resident and dirty, the new page was never inserted), so
    /// no page image is ever lost to an I/O error.
    fn cache_insert(&mut self, id: PageId, page: Page, dirty: bool) -> io::Result<()> {
        let victim: Option<(PageId, Page)> =
            self.cache.pending_writeback(id).map(|(vid, p)| (vid, p.clone()));
        if let Some((vid, vpage)) = victim {
            self.write_to_disk(vid, &vpage)?;
            self.cache.mark_clean(vid);
        }
        if let Some((vid, vpage)) = self.cache.insert(id, page, dirty)? {
            // Unreachable in practice (the victim was just cleaned), but
            // never drop a dirty page silently.
            self.write_to_disk(vid, &vpage)?;
        }
        Ok(())
    }

    /// Allocate a zeroed page: the lowest reusable free page whose free
    /// epoch clears the reuse gate, or — when the free-list has nothing
    /// eligible — a fresh page at the end of the file. Either way the
    /// page lives in the cache (dirty) until eviction or flush writes it
    /// out, and counts as *fresh* (see [`Pager::is_fresh`]) until the
    /// next [`Pager::mark_committed`].
    ///
    /// # Errors
    /// `PermissionDenied` on a read-only pager; also fails when the
    /// 32-bit page id space is exhausted or an eviction write-back
    /// fails.
    pub fn allocate(&mut self) -> io::Result<PageId> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "pager is read-only",
            ));
        }
        if let Some((id, epoch)) = self.freelist.allocate(self.reuse_gate) {
            debug_assert!(id > 0 && id < self.num_pages, "free-list entry out of bounds");
            if let Err(e) = self.cache_insert(id, Page::zeroed(), true) {
                self.freelist.reinsert(id, epoch);
                return Err(e);
            }
            self.fresh.insert(id);
            return Ok(id);
        }
        let id = self.num_pages;
        self.num_pages = self
            .num_pages
            .checked_add(1)
            .ok_or_else(|| io::Error::new(io::ErrorKind::Other, "page id space exhausted"))?;
        self.cache_insert(id, Page::zeroed(), true)?;
        self.fresh.insert(id);
        Ok(id)
    }

    /// Record `id` as freed by the state being built. The page stays
    /// intact (it may belong to the last durable checkpoint, which
    /// recovery falls back to) and becomes reusable only after
    /// [`Pager::write_freelist`] + the caller's header swap publish the
    /// free durably.
    ///
    /// # Errors
    /// `PermissionDenied` on a read-only pager; `InvalidData` for the
    /// header page, an out-of-bounds id, or a double free.
    pub fn free(&mut self, id: PageId) -> io::Result<()> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "pager is read-only",
            ));
        }
        if id == 0 || id >= self.num_pages {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("free of page {id} out of bounds (file has {})", self.num_pages),
            ));
        }
        debug_assert!(
            !self.fresh.contains(&id),
            "freeing fresh page {id}: fresh pages are mutated in place, never superseded"
        );
        self.freelist.free(id)
    }

    /// Set the reuse gate: the minimum epoch pinned by any live snapshot
    /// reader ([`super::shared::min_pinned_epoch`]), or `u64::MAX` when
    /// none is pinned. Free entries newer than the gate are neither
    /// reused nor truncated, so a pinned snapshot can never observe a
    /// page it can reach being rewritten.
    pub fn set_reuse_gate(&mut self, gate: u64) {
        self.reuse_gate = gate;
    }

    /// Current reuse gate (see [`Pager::set_reuse_gate`]).
    pub fn reuse_gate(&self) -> u64 {
        self.reuse_gate
    }

    /// True when `id` was allocated since the last
    /// [`Pager::mark_committed`] — it belongs to no committed state, so
    /// in-place mutation is always safe.
    pub fn is_fresh(&self, id: PageId) -> bool {
        self.fresh.contains(&id)
    }

    /// A checkpoint's header swap just published every current page:
    /// nothing is fresh any more.
    pub fn mark_committed(&mut self) {
        self.fresh.clear();
    }

    /// All free pages (reusable + pending) — the `stat` "free" count.
    pub fn free_page_count(&self) -> u32 {
        self.freelist.len() as u32
    }

    /// Durably free pages currently available for reuse (ignoring the
    /// gate).
    pub fn reusable_page_count(&self) -> u32 {
        self.freelist.reusable_len() as u32
    }

    /// Free pages the current reuse gate actually permits touching —
    /// zero means reuse, relocation and truncation are all blocked by a
    /// pinned reader (or there is nothing free).
    pub fn reusable_under_gate(&self) -> u32 {
        self.freelist.reusable_under(self.reuse_gate) as u32
    }

    /// Load the durable free-list by walking the trunk chain starting at
    /// `head` (0 = empty list). Replaces any in-memory free-list state.
    ///
    /// # Errors
    /// `InvalidData` on an out-of-bounds trunk or entry, a duplicate
    /// entry, or a cycle in the chain; otherwise any page-read failure.
    pub fn load_freelist(&mut self, head: PageId) -> io::Result<()> {
        self.freelist.clear();
        let mut next = head;
        let mut walked = 0u32;
        while next != 0 {
            if next >= self.num_pages {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("freelist trunk {next} out of bounds ({})", self.num_pages),
                ));
            }
            walked += 1;
            if walked > self.num_pages {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "freelist trunk chain does not terminate",
                ));
            }
            let page = self.read_copy(next)?;
            let (nxt, entries) = decode_trunk(&page)?;
            self.freelist.absorb_chain(next, &entries, self.num_pages)?;
            next = nxt;
        }
        Ok(())
    }

    /// Serialize the free-list as a fresh trunk chain: the previous
    /// chain's trunks become this epoch's frees, new trunk pages are
    /// allocated (free-list first, like any allocation), the chain is
    /// written through the cache, and pending frees are published as
    /// reusable at `free_epoch`. Returns `(head page id, total free
    /// entries)` for the caller's header.
    ///
    /// The caller must [`Pager::flush`] before swapping the header, and
    /// must treat a *later* failure as fatal for this handle: the
    /// in-memory list is already the new chain's state, so continuing to
    /// allocate against it without the header swap would hand out pages
    /// the durable (previous) state still owns.
    ///
    /// # Errors
    /// Any allocation or page-write failure.
    pub fn write_freelist(&mut self, free_epoch: u64) -> io::Result<(PageId, u32)> {
        self.freelist.retire_trunks()?;
        // Allocating a trunk can consume a reusable entry (shrinking the
        // list) or grow the file (leaving it unchanged), so loop until
        // the trunks on hand cover the entries that remain. Accepting
        // `trunks >= needed` (an overshoot leaves one near-empty trunk)
        // guarantees termination.
        let mut trunks: Vec<PageId> = Vec::new();
        loop {
            let entries = self.freelist.len();
            let needed = entries.div_ceil(TRUNK_CAPACITY);
            if trunks.len() >= needed {
                break;
            }
            trunks.push(self.allocate()?);
        }
        let entries = self.freelist.chain_entries(free_epoch);
        let mut chunks = entries.chunks(TRUNK_CAPACITY);
        for (i, &trunk) in trunks.iter().enumerate() {
            let next = trunks.get(i + 1).copied().unwrap_or(0);
            // An overshoot trunk holds zero entries but still links
            // cleanly.
            let chunk = chunks.next().unwrap_or(&[]);
            self.put(trunk, encode_trunk(next, chunk))?;
        }
        let head = trunks.first().copied().unwrap_or(0);
        let count = entries.len() as u32;
        self.freelist.publish(free_epoch, trunks);
        Ok((head, count))
    }

    /// Drop the longest run of gate-eligible free pages at the end of
    /// the file from the page count (and the free-list, and the cache).
    /// Returns how many pages were reclaimed. The *file* is not
    /// truncated here — the caller first publishes the smaller committed
    /// page count via its header swap, then calls
    /// [`Pager::sync_file_len`]; a crash in between leaves a stale tail
    /// that the next open ignores.
    pub fn reclaim_tail(&mut self) -> u32 {
        debug_assert_eq!(self.freelist.pending_len(), 0, "reclaim before publishing frees");
        let mut cutoff = self.num_pages;
        while cutoff > 1 {
            match self.freelist.free_epoch(cutoff - 1) {
                Some(epoch) if epoch <= self.reuse_gate => cutoff -= 1,
                _ => break,
            }
        }
        let reclaimed = self.num_pages - cutoff;
        for id in cutoff..self.num_pages {
            self.freelist.remove(id);
            self.cache.remove(id);
            self.fresh.remove(&id);
        }
        self.num_pages = cutoff;
        reclaimed
    }

    /// Truncate the backing file to the current page count and fsync —
    /// the final step of tail reclamation, run only after a header
    /// committing the smaller count is durable.
    ///
    /// # Errors
    /// Any truncation or fsync failure (retryable; the logical state is
    /// already consistent).
    pub fn sync_file_len(&mut self) -> io::Result<()> {
        self.file.set_len(u64::from(self.num_pages) * PAGE_SIZE as u64)?;
        self.file.sync()
    }

    /// Read a page through the cache.
    ///
    /// # Errors
    /// `InvalidData` when `id` is past the allocated page count;
    /// otherwise any I/O error from the read or the eviction
    /// write-back.
    pub fn read(&mut self, id: PageId) -> io::Result<&Page> {
        if id >= self.num_pages {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("page {id} out of bounds (file has {})", self.num_pages),
            ));
        }
        if self.cache.lookup(id).is_none() {
            let page = self.read_from_disk(id)?;
            self.cache_insert(id, page, false)?;
        }
        Ok(self.cache.peek(id).expect("page resident after read-through"))
    }

    /// Owned copy of a page.
    ///
    /// # Errors
    /// Same conditions as [`Pager::read`].
    pub fn read_copy(&mut self, id: PageId) -> io::Result<Page> {
        Ok(self.read(id)?.clone())
    }

    /// Mutate a page in place through the cache and mark it dirty.
    ///
    /// # Errors
    /// `PermissionDenied` on a read-only pager; otherwise the same
    /// conditions as [`Pager::read`].
    pub fn update<R>(&mut self, id: PageId, f: impl FnOnce(&mut Page) -> R) -> io::Result<R> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "pager is read-only",
            ));
        }
        self.read(id)?;
        let page = self.cache.peek_mut(id).expect("page resident after read-through");
        let out = f(page);
        self.cache.mark_dirty(id);
        Ok(out)
    }

    /// Replace a whole page.
    ///
    /// # Errors
    /// `PermissionDenied` on a read-only pager, `InvalidData` when `id`
    /// is out of bounds, or any eviction write-back failure.
    pub fn put(&mut self, id: PageId, page: Page) -> io::Result<()> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "pager is read-only",
            ));
        }
        if id >= self.num_pages {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("put: page {id} out of bounds ({})", self.num_pages),
            ));
        }
        self.cache_insert(id, page, true)
    }

    /// Pin a page so the cache never evicts it (it must be resident; read
    /// it first). Returns false when not resident.
    pub fn pin(&mut self, id: PageId) -> bool {
        self.cache.pin(id)
    }

    /// Release one pin on `id`. Returns false when not resident.
    pub fn unpin(&mut self, id: PageId) -> bool {
        self.cache.unpin(id)
    }

    /// Ordered flush: every dirty page, ascending id, then fsync. On any
    /// failure the not-yet-durable pages are re-marked dirty (they are
    /// still resident — `take_dirty` leaves pages cached), so a retry
    /// after e.g. ENOSPC rewrites everything instead of silently
    /// committing a header over never-written pages.
    ///
    /// # Errors
    /// Any write or fsync failure; the failed pages stay dirty for a
    /// retry.
    pub fn flush(&mut self) -> io::Result<()> {
        let dirty = self.cache.take_dirty();
        for (i, (id, page)) in dirty.iter().enumerate() {
            if let Err(e) = self.write_to_disk(*id, page) {
                for (rid, _) in &dirty[i..] {
                    self.cache.mark_dirty(*rid);
                }
                return Err(e);
            }
        }
        if let Err(e) = self.file.sync() {
            for (rid, _) in &dirty {
                self.cache.mark_dirty(*rid);
            }
            return Err(e);
        }
        Ok(())
    }

    /// Recovery: drop all cached (possibly dirty, uncommitted) pages and
    /// clamp the allocated count to `pages` — the committed watermark from
    /// a header. Stale tail pages in the file are simply overwritten by
    /// future allocations.
    ///
    /// The in-memory free-list (and the fresh-page set) is rewound too:
    /// it may describe a newer, never-committed state whose entries lie
    /// beyond the truncated length — a post-crash store must never hand
    /// those out. The caller reloads the durable chain with
    /// [`Pager::load_freelist`] afterwards.
    ///
    /// # Errors
    /// `InvalidData` when `pages` exceeds the file's allocated count (a
    /// header claiming more pages than exist is corruption).
    pub fn reset_to(&mut self, pages: u32) -> io::Result<()> {
        if pages > self.num_pages {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "header claims {pages} committed pages but file has {}",
                    self.num_pages
                ),
            ));
        }
        self.cache.clear();
        self.freelist.clear();
        self.fresh.clear();
        self.num_pages = pages;
        Ok(())
    }

    /// Hit/miss/eviction counters of the LRU cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Pages fetched from disk so far (cache misses).
    pub fn disk_reads(&self) -> u64 {
        self.disk_reads
    }

    /// Pages written to disk so far (evictions + flushes).
    pub fn disk_writes(&self) -> u64 {
        self.disk_writes
    }
}

impl PageRead for Pager {
    fn read_page(&mut self, id: PageId) -> io::Result<Page> {
        self.read_copy(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grouper_pager_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn allocate_update_flush_reopen() {
        let path = tmp("basic.pages");
        {
            let mut p = Pager::create(&path, 4).unwrap();
            for i in 0..10u32 {
                let id = p.allocate().unwrap();
                assert_eq!(id, i);
                p.update(id, |pg| pg.put_u32(0, 1000 + i)).unwrap();
            }
            p.flush().unwrap();
        }
        let mut p = Pager::open(&path, 4).unwrap();
        assert_eq!(p.num_pages(), 10);
        for i in 0..10u32 {
            assert_eq!(p.read(i).unwrap().get_u32(0), 1000 + i);
        }
    }

    #[test]
    fn tiny_cache_evicts_and_writes_back_correctly() {
        let path = tmp("evict.pages");
        let mut p = Pager::create(&path, 2).unwrap();
        // Far more pages than frames: every page must survive eviction
        // write-back even before any explicit flush.
        for i in 0..32u32 {
            let id = p.allocate().unwrap();
            p.update(id, |pg| pg.put_u64(8, 7 * i as u64)).unwrap();
        }
        for i in 0..32u32 {
            assert_eq!(p.read(i).unwrap().get_u64(8), 7 * i as u64, "page {i}");
        }
        assert!(p.disk_writes() > 0, "evictions must have written back");
        assert!(p.cache_stats().evictions > 0);
        p.flush().unwrap();
        let mut q = Pager::open_read(&path, 2).unwrap();
        for i in 0..32u32 {
            assert_eq!(q.read(i).unwrap().get_u64(8), 7 * i as u64);
        }
    }

    #[test]
    fn read_through_counts_hits_and_misses() {
        let path = tmp("stats.pages");
        let mut p = Pager::create(&path, 8).unwrap();
        for _ in 0..4 {
            p.allocate().unwrap();
        }
        p.flush().unwrap();
        let mut r = Pager::open_read(&path, 8).unwrap();
        r.read(0).unwrap();
        r.read(0).unwrap();
        r.read(1).unwrap();
        let s = r.cache_stats();
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(r.disk_reads(), 2);
    }

    #[test]
    fn bounds_and_readonly_are_enforced() {
        let path = tmp("bounds.pages");
        let mut p = Pager::create(&path, 2).unwrap();
        p.allocate().unwrap();
        assert!(p.read(5).is_err());
        p.flush().unwrap();
        let mut r = Pager::open_read(&path, 2).unwrap();
        assert!(r.allocate().is_err());
        assert!(r.update(0, |_| ()).is_err());
        assert!(r.put(0, Page::zeroed()).is_err());
    }

    #[test]
    fn flush_write_failure_remarks_dirty_and_a_retry_succeeds() {
        use crate::store::vfs::{CrashImage, FaultPlan, FaultVfs, MemVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let path = std::path::Path::new("/fault/write.pages");
        let mut p = Pager::create_with(&fv, path, 8).unwrap();
        for i in 0..3u32 {
            let id = p.allocate().unwrap();
            p.update(id, |pg| pg.put_u32(0, 100 + i)).unwrap();
        }
        // Fail the middle page write of the flush: pages 1..2 (the failed
        // write and everything after it) are re-marked dirty; page 0 was
        // written and only awaits the retry's fsync.
        fv.set_plan(FaultPlan { fail_write: Some(fv.writes_attempted() + 2), ..Default::default() });
        assert!(p.flush().is_err(), "injected write failure must surface");
        fv.disarm();
        let writes_before_retry = p.disk_writes();
        p.flush().unwrap();
        assert_eq!(
            p.disk_writes(),
            writes_before_retry + 2,
            "the failed write and every page after it must be rewritten on retry"
        );
        // The retried flush is durable: the synced-only crash image holds
        // every page.
        let img = fv.crash_snapshot(CrashImage::SyncedOnly);
        let mem2 = MemVfs::from_map(img);
        let mut q = Pager::open_read_with(&mem2, path, 8).unwrap();
        for i in 0..3u32 {
            assert_eq!(q.read(i).unwrap().get_u32(0), 100 + i);
        }
    }

    #[test]
    fn flush_sync_failure_remarks_dirty_and_a_retry_succeeds() {
        use crate::store::vfs::{CrashImage, FaultPlan, FaultVfs, MemVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let path = std::path::Path::new("/fault/sync.pages");
        let mut p = Pager::create_with(&fv, path, 8).unwrap();
        for i in 0..4u32 {
            let id = p.allocate().unwrap();
            p.update(id, |pg| pg.put_u32(0, i)).unwrap();
        }
        fv.set_plan(FaultPlan { fail_sync: Some(fv.syncs_attempted() + 1), ..Default::default() });
        assert!(p.flush().is_err(), "injected fsync failure must surface");
        // Nothing is durable: the never-synced file is absent from (or at
        // most empty in) the fsynced-only crash image.
        let img = fv.crash_snapshot(CrashImage::SyncedOnly);
        assert!(
            img.get(std::path::Path::new("/fault/sync.pages"))
                .map_or(true, |b| b.is_empty()),
            "a failed fsync must leave nothing durable"
        );
        fv.disarm();
        let writes_before_retry = p.disk_writes();
        p.flush().unwrap();
        assert_eq!(p.disk_writes(), writes_before_retry + 4, "all pages rewritten");
        let img = fv.crash_snapshot(CrashImage::SyncedOnly);
        assert_eq!(img[std::path::Path::new("/fault/sync.pages")].len(), 4 * PAGE_SIZE);
    }

    #[test]
    fn memvfs_pager_roundtrips_like_disk() {
        use crate::store::vfs::MemVfs;
        let mem = MemVfs::new();
        let path = std::path::Path::new("/mem/basic.pages");
        {
            let mut p = Pager::create_with(&mem, path, 4).unwrap();
            for i in 0..10u32 {
                let id = p.allocate().unwrap();
                p.update(id, |pg| pg.put_u32(0, 1000 + i)).unwrap();
            }
            p.flush().unwrap();
        }
        let mut p = Pager::open_with(&mem, path, 4).unwrap();
        assert_eq!(p.num_pages(), 10);
        for i in 0..10u32 {
            assert_eq!(p.read(i).unwrap().get_u32(0), 1000 + i);
        }
    }

    #[test]
    fn free_then_publish_then_reuse_lowest_first() {
        use crate::store::vfs::MemVfs;
        let mem = MemVfs::new();
        let path = std::path::Path::new("/mem/freelist.pages");
        let mut p = Pager::create_with(&mem, path, 8).unwrap();
        for _ in 0..6u32 {
            p.allocate().unwrap();
        }
        p.mark_committed();
        p.free(4).unwrap();
        p.free(2).unwrap();
        // Pending frees are not reusable: allocation still grows the file.
        assert_eq!(p.allocate().unwrap(), 6);
        assert_eq!(p.free_page_count(), 2);
        // Publish (checkpoint): the chain is written, frees become
        // reusable at epoch 1.
        let (head, count) = p.write_freelist(1).unwrap();
        assert_eq!(count, 2);
        assert!(head != 0, "two frees need a trunk page");
        p.flush().unwrap();
        p.mark_committed();
        // Reuse prefers the lowest free id over growing the file.
        let pages_before = p.num_pages();
        assert_eq!(p.allocate().unwrap(), 2);
        assert_eq!(p.allocate().unwrap(), 4);
        assert_eq!(p.num_pages(), pages_before, "reuse must not grow the file");
        // List exhausted: back to growing.
        assert_eq!(p.allocate().unwrap(), pages_before);
    }

    #[test]
    fn reuse_gate_blocks_epochs_a_reader_still_pins() {
        use crate::store::vfs::MemVfs;
        let mem = MemVfs::new();
        let path = std::path::Path::new("/mem/gate.pages");
        let mut p = Pager::create_with(&mem, path, 8).unwrap();
        for _ in 0..5u32 {
            p.allocate().unwrap();
        }
        p.mark_committed();
        p.free(3).unwrap();
        p.write_freelist(2).unwrap();
        p.mark_committed();
        // A reader pinned at epoch 1 blocks the epoch-2 free: the file
        // grows instead of reusing page 3.
        p.set_reuse_gate(1);
        assert_eq!(p.allocate().unwrap(), p.num_pages() - 1, "gate-blocked: file grows");
        // Gate lifted (reader dropped): the free is reusable again.
        p.set_reuse_gate(2);
        assert_eq!(p.allocate().unwrap(), 3);
    }

    #[test]
    fn freelist_chain_survives_reopen() {
        use crate::store::vfs::MemVfs;
        let mem = MemVfs::new();
        let path = std::path::Path::new("/mem/chain.pages");
        let head;
        {
            let mut p = Pager::create_with(&mem, path, 8).unwrap();
            for _ in 0..8u32 {
                p.allocate().unwrap();
            }
            p.mark_committed();
            for id in [2u32, 5, 6] {
                p.free(id).unwrap();
            }
            let (h, count) = p.write_freelist(3).unwrap();
            assert_eq!(count, 3);
            head = h;
            p.flush().unwrap();
        }
        let mut q = Pager::open_with(&mem, path, 8).unwrap();
        q.load_freelist(head).unwrap();
        assert_eq!(q.free_page_count(), 3);
        assert_eq!(q.allocate().unwrap(), 2);
        assert_eq!(q.allocate().unwrap(), 5);
        assert_eq!(q.allocate().unwrap(), 6);
    }

    #[test]
    fn multi_trunk_chain_roundtrips() {
        use crate::store::freelist::TRUNK_CAPACITY;
        use crate::store::vfs::MemVfs;
        let mem = MemVfs::new();
        let path = std::path::Path::new("/mem/bigchain.pages");
        let n = (TRUNK_CAPACITY + 40) as u32; // forces a 2-trunk chain
        let mut p = Pager::create_with(&mem, path, 8).unwrap();
        for _ in 0..(n + 10) {
            p.allocate().unwrap();
        }
        p.mark_committed();
        for id in 1..=n {
            p.free(id).unwrap();
        }
        let (head, count) = p.write_freelist(1).unwrap();
        assert_eq!(count, n);
        p.flush().unwrap();
        drop(p);
        let mut q = Pager::open_with(&mem, path, 8).unwrap();
        q.load_freelist(head).unwrap();
        assert_eq!(q.free_page_count(), n);
        assert_eq!(q.allocate().unwrap(), 1, "lowest entry survives the chain walk");
    }

    #[test]
    fn reclaim_tail_then_sync_len_shrinks_the_file() {
        use crate::store::vfs::MemVfs;
        let mem = MemVfs::new();
        let path = std::path::Path::new("/mem/reclaim.pages");
        let mut p = Pager::create_with(&mem, path, 8).unwrap();
        for _ in 0..10u32 {
            p.allocate().unwrap();
        }
        p.flush().unwrap();
        p.mark_committed();
        // Free a tail run [6..10) and an interior page (3).
        for id in [3u32, 6, 7, 8, 9] {
            p.free(id).unwrap();
        }
        // First publish: the frees are pending, so the trunk is a fresh
        // tail page (10) — it pins the tail, and that is correct: it is
        // durable chain metadata.
        p.write_freelist(1).unwrap();
        p.flush().unwrap();
        p.mark_committed();
        assert_eq!(p.reclaim_tail(), 0, "the durable trunk pins the tail");
        // Second publish: the trunk relocates to the lowest free slot
        // (3), the old trunk (10) joins the list, and the whole tail run
        // [6..11) becomes reclaimable.
        p.write_freelist(2).unwrap();
        p.flush().unwrap();
        p.mark_committed();
        assert_eq!(p.reclaim_tail(), 5);
        assert_eq!(p.num_pages(), 6);
        assert!(p.read(6).is_err(), "reclaimed page is out of bounds");
        p.flush().unwrap();
        p.sync_file_len().unwrap();
        let q = Pager::open_with(&mem, path, 8).unwrap();
        assert_eq!(q.num_pages(), 6, "file truncated to the reclaimed length");
        assert_eq!(p.free_page_count(), 0, "every free was either reused or reclaimed");
    }

    #[test]
    fn reset_to_rewinds_the_freelist_too() {
        // Regression (post-crash recovery): a free-list describing a
        // newer, never-committed state must not survive reset_to — it
        // could hand out pages beyond the truncated length.
        use crate::store::vfs::MemVfs;
        let mem = MemVfs::new();
        let path = std::path::Path::new("/mem/resetfl.pages");
        let mut p = Pager::create_with(&mem, path, 8).unwrap();
        for _ in 0..8u32 {
            p.allocate().unwrap();
        }
        p.flush().unwrap();
        p.mark_committed();
        // Uncommitted epoch: free two pages (one beyond the rewind
        // point) and publish them in memory only.
        p.free(6).unwrap();
        p.free(2).unwrap();
        p.write_freelist(1).unwrap();
        // Crash-recover to a 4-page committed state.
        p.reset_to(4).unwrap();
        assert_eq!(p.free_page_count(), 0, "free-list must be rewound");
        let id = p.allocate().unwrap();
        assert_eq!(id, 4, "allocation grows from the rewind point, not from stale frees");
        // And a stale chain whose entries lie beyond the rewind point is
        // rejected rather than trusted.
        let mut q = Pager::create_with(&mem, std::path::Path::new("/mem/resetfl2.pages"), 8)
            .unwrap();
        for _ in 0..8u32 {
            q.allocate().unwrap();
        }
        q.mark_committed();
        q.free(6).unwrap();
        let (head, _) = q.write_freelist(1).unwrap();
        q.flush().unwrap();
        q.reset_to(5).unwrap();
        assert!(
            q.load_freelist(head).is_err(),
            "a chain reaching past the rewound length must be rejected, not trusted"
        );
    }

    #[test]
    fn reset_to_discards_uncommitted_tail() {
        let path = tmp("reset.pages");
        let mut p = Pager::create(&path, 8).unwrap();
        for i in 0..3u32 {
            let id = p.allocate().unwrap();
            p.update(id, |pg| pg.put_u32(0, i)).unwrap();
        }
        p.flush().unwrap();
        // Uncommitted tail: allocated + modified but never flushed.
        let id = p.allocate().unwrap();
        p.update(id, |pg| pg.put_u32(0, 999)).unwrap();
        p.update(0, |pg| pg.put_u32(100, 123)).unwrap();
        p.reset_to(3).unwrap();
        assert_eq!(p.num_pages(), 3);
        // The dirty in-cache change to page 0 is gone; disk state rules.
        assert_eq!(p.read(0).unwrap().get_u32(100), 0);
        assert!(p.read(3).is_err());
        // Reallocation reuses the id.
        assert_eq!(p.allocate().unwrap(), 3);
        assert!(p.reset_to(10).is_err(), "cannot reset above file size");
    }
}
