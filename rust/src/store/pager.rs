//! The pager: page allocation, read-through-cache access and ordered
//! flush over one paged file.
//!
//! All page I/O for a file goes through one `Pager`, so the LRU cache is
//! the single knob governing how much index state stays hot — the
//! tunable the hardcoded root-only caching of the original
//! `btree_index` could not offer.
//!
//! Durability contract: nothing is guaranteed on disk until
//! [`Pager::flush`], which writes every dirty page in ascending id order
//! and then fsyncs. Callers building crash-safe structures pair this
//! with the WAL ([`super::wal`]): log logically first, flush pages at
//! checkpoint, swap the header page last.
//!
//! All file I/O goes through the [`super::vfs`] layer: the `*_with`
//! constructors take any [`Vfs`], the plain ones default to
//! [`StdVfs`] — which is how the fault-injection suite drives a pager
//! over [`super::vfs::FaultVfs`] without the pager knowing.

use std::io;
use std::path::Path;
use std::sync::Arc;

use super::cache::{CacheStats, PageCache};
use super::page::{Page, PageId, PAGE_SIZE};
use super::vfs::{OpenMode, StdVfs, Vfs, VfsFile};

/// Uniform page-read access for tree walkers: implemented by the
/// exclusive [`Pager`] (the write path) and by the concurrent
/// [`super::shared::SnapshotReader`] (the shared read path), so readers
/// like [`super::btree::BTree::scan_from`] are agnostic to which one
/// serves them.
pub trait PageRead {
    /// Read one page, returning an owned copy.
    ///
    /// # Errors
    /// Fails when `id` is out of bounds for the implementor's view of
    /// the file, or on an underlying I/O error.
    fn read_page(&mut self, id: PageId) -> io::Result<Page>;
}

/// The exclusive pager: one owner, `&mut self` access, a single LRU
/// cache. This is the write path; for concurrent `Send + Sync` reads
/// over a committed file, see [`super::shared::SharedPager`].
pub struct Pager {
    file: Arc<dyn VfsFile>,
    cache: PageCache,
    num_pages: u32,
    writable: bool,
    disk_reads: u64,
    disk_writes: u64,
}

impl Pager {
    /// Create (or truncate) a paged file on the real filesystem
    /// (equivalent to [`Pager::create_with`] over [`StdVfs`]).
    ///
    /// # Errors
    /// Fails when the parent directory cannot be created or the file
    /// cannot be opened for writing.
    ///
    /// # Panics
    /// Panics when `cache_pages` is 0 (the cache needs one frame).
    pub fn create(path: &Path, cache_pages: usize) -> io::Result<Pager> {
        Pager::create_with(&StdVfs, path, cache_pages)
    }

    /// Create (or truncate) a paged file on `vfs`.
    ///
    /// # Errors
    /// Fails when the parent directory cannot be created or the file
    /// cannot be opened for writing.
    ///
    /// # Panics
    /// Panics when `cache_pages` is 0 (the cache needs one frame).
    pub fn create_with(vfs: &dyn Vfs, path: &Path, cache_pages: usize) -> io::Result<Pager> {
        if let Some(d) = path.parent() {
            vfs.create_dir_all(d)?;
        }
        let file = vfs.open(path, OpenMode::CreateTruncate)?;
        Ok(Pager {
            file,
            cache: PageCache::new(cache_pages),
            num_pages: 0,
            writable: true,
            disk_reads: 0,
            disk_writes: 0,
        })
    }

    /// Open an existing paged file read/write on the real filesystem
    /// (equivalent to [`Pager::open_with`] over [`StdVfs`]). A torn
    /// trailing partial page (crash mid-extend) is ignored, not an
    /// error.
    ///
    /// # Errors
    /// Fails when the file does not exist or cannot be opened
    /// read/write.
    pub fn open(path: &Path, cache_pages: usize) -> io::Result<Pager> {
        Pager::open_with(&StdVfs, path, cache_pages)
    }

    /// Open an existing paged file read/write on `vfs`.
    ///
    /// # Errors
    /// Fails when the file does not exist or cannot be opened
    /// read/write.
    pub fn open_with(vfs: &dyn Vfs, path: &Path, cache_pages: usize) -> io::Result<Pager> {
        let file = vfs.open(path, OpenMode::ReadWrite)?;
        let num_pages = (file.len()? / PAGE_SIZE as u64) as u32;
        Ok(Pager {
            file,
            cache: PageCache::new(cache_pages),
            num_pages,
            writable: true,
            disk_reads: 0,
            disk_writes: 0,
        })
    }

    /// Open read-only (readers over immutable/committed files) on the
    /// real filesystem.
    ///
    /// # Errors
    /// Fails when the file does not exist or cannot be opened.
    pub fn open_read(path: &Path, cache_pages: usize) -> io::Result<Pager> {
        Pager::open_read_with(&StdVfs, path, cache_pages)
    }

    /// Open read-only on `vfs`.
    ///
    /// # Errors
    /// Fails when the file does not exist or cannot be opened.
    pub fn open_read_with(vfs: &dyn Vfs, path: &Path, cache_pages: usize) -> io::Result<Pager> {
        let file = vfs.open(path, OpenMode::Read)?;
        let num_pages = (file.len()? / PAGE_SIZE as u64) as u32;
        Ok(Pager {
            file,
            cache: PageCache::new(cache_pages),
            num_pages,
            writable: false,
            disk_reads: 0,
            disk_writes: 0,
        })
    }

    /// Pages allocated in the file (committed or not).
    pub fn num_pages(&self) -> u32 {
        self.num_pages
    }

    /// False for pagers opened via [`Pager::open_read`].
    pub fn is_writable(&self) -> bool {
        self.writable
    }

    fn read_from_disk(&mut self, id: PageId) -> io::Result<Page> {
        let mut buf = vec![0u8; PAGE_SIZE];
        self.file.read_exact_at(&mut buf, id as u64 * PAGE_SIZE as u64)?;
        self.disk_reads += 1;
        Page::from_vec(buf)
    }

    fn write_to_disk(&mut self, id: PageId, page: &Page) -> io::Result<()> {
        self.file
            .write_all_at(page.as_slice(), id as u64 * PAGE_SIZE as u64)?;
        self.disk_writes += 1;
        Ok(())
    }

    /// Insert into the cache, writing back the dirty eviction victim
    /// FIRST: if that write fails, the cache is untouched (the victim
    /// stays resident and dirty, the new page was never inserted), so
    /// no page image is ever lost to an I/O error.
    fn cache_insert(&mut self, id: PageId, page: Page, dirty: bool) -> io::Result<()> {
        let victim: Option<(PageId, Page)> =
            self.cache.pending_writeback(id).map(|(vid, p)| (vid, p.clone()));
        if let Some((vid, vpage)) = victim {
            self.write_to_disk(vid, &vpage)?;
            self.cache.mark_clean(vid);
        }
        if let Some((vid, vpage)) = self.cache.insert(id, page, dirty)? {
            // Unreachable in practice (the victim was just cleaned), but
            // never drop a dirty page silently.
            self.write_to_disk(vid, &vpage)?;
        }
        Ok(())
    }

    /// Allocate a fresh zeroed page at the end of the file. The page lives
    /// in the cache (dirty) until eviction or flush writes it out.
    ///
    /// # Errors
    /// `PermissionDenied` on a read-only pager; also fails when the
    /// 32-bit page id space is exhausted or an eviction write-back
    /// fails.
    pub fn allocate(&mut self) -> io::Result<PageId> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "pager is read-only",
            ));
        }
        let id = self.num_pages;
        self.num_pages = self
            .num_pages
            .checked_add(1)
            .ok_or_else(|| io::Error::new(io::ErrorKind::Other, "page id space exhausted"))?;
        self.cache_insert(id, Page::zeroed(), true)?;
        Ok(id)
    }

    /// Read a page through the cache.
    ///
    /// # Errors
    /// `InvalidData` when `id` is past the allocated page count;
    /// otherwise any I/O error from the read or the eviction
    /// write-back.
    pub fn read(&mut self, id: PageId) -> io::Result<&Page> {
        if id >= self.num_pages {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("page {id} out of bounds (file has {})", self.num_pages),
            ));
        }
        if self.cache.lookup(id).is_none() {
            let page = self.read_from_disk(id)?;
            self.cache_insert(id, page, false)?;
        }
        Ok(self.cache.peek(id).expect("page resident after read-through"))
    }

    /// Owned copy of a page.
    ///
    /// # Errors
    /// Same conditions as [`Pager::read`].
    pub fn read_copy(&mut self, id: PageId) -> io::Result<Page> {
        Ok(self.read(id)?.clone())
    }

    /// Mutate a page in place through the cache and mark it dirty.
    ///
    /// # Errors
    /// `PermissionDenied` on a read-only pager; otherwise the same
    /// conditions as [`Pager::read`].
    pub fn update<R>(&mut self, id: PageId, f: impl FnOnce(&mut Page) -> R) -> io::Result<R> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "pager is read-only",
            ));
        }
        self.read(id)?;
        let page = self.cache.peek_mut(id).expect("page resident after read-through");
        let out = f(page);
        self.cache.mark_dirty(id);
        Ok(out)
    }

    /// Replace a whole page.
    ///
    /// # Errors
    /// `PermissionDenied` on a read-only pager, `InvalidData` when `id`
    /// is out of bounds, or any eviction write-back failure.
    pub fn put(&mut self, id: PageId, page: Page) -> io::Result<()> {
        if !self.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "pager is read-only",
            ));
        }
        if id >= self.num_pages {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("put: page {id} out of bounds ({})", self.num_pages),
            ));
        }
        self.cache_insert(id, page, true)
    }

    /// Pin a page so the cache never evicts it (it must be resident; read
    /// it first). Returns false when not resident.
    pub fn pin(&mut self, id: PageId) -> bool {
        self.cache.pin(id)
    }

    /// Release one pin on `id`. Returns false when not resident.
    pub fn unpin(&mut self, id: PageId) -> bool {
        self.cache.unpin(id)
    }

    /// Ordered flush: every dirty page, ascending id, then fsync. On any
    /// failure the not-yet-durable pages are re-marked dirty (they are
    /// still resident — `take_dirty` leaves pages cached), so a retry
    /// after e.g. ENOSPC rewrites everything instead of silently
    /// committing a header over never-written pages.
    ///
    /// # Errors
    /// Any write or fsync failure; the failed pages stay dirty for a
    /// retry.
    pub fn flush(&mut self) -> io::Result<()> {
        let dirty = self.cache.take_dirty();
        for (i, (id, page)) in dirty.iter().enumerate() {
            if let Err(e) = self.write_to_disk(*id, page) {
                for (rid, _) in &dirty[i..] {
                    self.cache.mark_dirty(*rid);
                }
                return Err(e);
            }
        }
        if let Err(e) = self.file.sync() {
            for (rid, _) in &dirty {
                self.cache.mark_dirty(*rid);
            }
            return Err(e);
        }
        Ok(())
    }

    /// Recovery: drop all cached (possibly dirty, uncommitted) pages and
    /// clamp the allocated count to `pages` — the committed watermark from
    /// a header. Stale tail pages in the file are simply overwritten by
    /// future allocations.
    ///
    /// # Errors
    /// `InvalidData` when `pages` exceeds the file's allocated count (a
    /// header claiming more pages than exist is corruption).
    pub fn reset_to(&mut self, pages: u32) -> io::Result<()> {
        if pages > self.num_pages {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "header claims {pages} committed pages but file has {}",
                    self.num_pages
                ),
            ));
        }
        self.cache.clear();
        self.num_pages = pages;
        Ok(())
    }

    /// Hit/miss/eviction counters of the LRU cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Pages fetched from disk so far (cache misses).
    pub fn disk_reads(&self) -> u64 {
        self.disk_reads
    }

    /// Pages written to disk so far (evictions + flushes).
    pub fn disk_writes(&self) -> u64 {
        self.disk_writes
    }
}

impl PageRead for Pager {
    fn read_page(&mut self, id: PageId) -> io::Result<Page> {
        self.read_copy(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grouper_pager_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn allocate_update_flush_reopen() {
        let path = tmp("basic.pages");
        {
            let mut p = Pager::create(&path, 4).unwrap();
            for i in 0..10u32 {
                let id = p.allocate().unwrap();
                assert_eq!(id, i);
                p.update(id, |pg| pg.put_u32(0, 1000 + i)).unwrap();
            }
            p.flush().unwrap();
        }
        let mut p = Pager::open(&path, 4).unwrap();
        assert_eq!(p.num_pages(), 10);
        for i in 0..10u32 {
            assert_eq!(p.read(i).unwrap().get_u32(0), 1000 + i);
        }
    }

    #[test]
    fn tiny_cache_evicts_and_writes_back_correctly() {
        let path = tmp("evict.pages");
        let mut p = Pager::create(&path, 2).unwrap();
        // Far more pages than frames: every page must survive eviction
        // write-back even before any explicit flush.
        for i in 0..32u32 {
            let id = p.allocate().unwrap();
            p.update(id, |pg| pg.put_u64(8, 7 * i as u64)).unwrap();
        }
        for i in 0..32u32 {
            assert_eq!(p.read(i).unwrap().get_u64(8), 7 * i as u64, "page {i}");
        }
        assert!(p.disk_writes() > 0, "evictions must have written back");
        assert!(p.cache_stats().evictions > 0);
        p.flush().unwrap();
        let mut q = Pager::open_read(&path, 2).unwrap();
        for i in 0..32u32 {
            assert_eq!(q.read(i).unwrap().get_u64(8), 7 * i as u64);
        }
    }

    #[test]
    fn read_through_counts_hits_and_misses() {
        let path = tmp("stats.pages");
        let mut p = Pager::create(&path, 8).unwrap();
        for _ in 0..4 {
            p.allocate().unwrap();
        }
        p.flush().unwrap();
        let mut r = Pager::open_read(&path, 8).unwrap();
        r.read(0).unwrap();
        r.read(0).unwrap();
        r.read(1).unwrap();
        let s = r.cache_stats();
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(r.disk_reads(), 2);
    }

    #[test]
    fn bounds_and_readonly_are_enforced() {
        let path = tmp("bounds.pages");
        let mut p = Pager::create(&path, 2).unwrap();
        p.allocate().unwrap();
        assert!(p.read(5).is_err());
        p.flush().unwrap();
        let mut r = Pager::open_read(&path, 2).unwrap();
        assert!(r.allocate().is_err());
        assert!(r.update(0, |_| ()).is_err());
        assert!(r.put(0, Page::zeroed()).is_err());
    }

    #[test]
    fn flush_write_failure_remarks_dirty_and_a_retry_succeeds() {
        use crate::store::vfs::{CrashImage, FaultPlan, FaultVfs, MemVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let path = std::path::Path::new("/fault/write.pages");
        let mut p = Pager::create_with(&fv, path, 8).unwrap();
        for i in 0..3u32 {
            let id = p.allocate().unwrap();
            p.update(id, |pg| pg.put_u32(0, 100 + i)).unwrap();
        }
        // Fail the middle page write of the flush: pages 1..2 (the failed
        // write and everything after it) are re-marked dirty; page 0 was
        // written and only awaits the retry's fsync.
        fv.set_plan(FaultPlan { fail_write: Some(fv.writes_attempted() + 2), ..Default::default() });
        assert!(p.flush().is_err(), "injected write failure must surface");
        fv.disarm();
        let writes_before_retry = p.disk_writes();
        p.flush().unwrap();
        assert_eq!(
            p.disk_writes(),
            writes_before_retry + 2,
            "the failed write and every page after it must be rewritten on retry"
        );
        // The retried flush is durable: the synced-only crash image holds
        // every page.
        let img = fv.crash_snapshot(CrashImage::SyncedOnly);
        let mem2 = MemVfs::from_map(img);
        let mut q = Pager::open_read_with(&mem2, path, 8).unwrap();
        for i in 0..3u32 {
            assert_eq!(q.read(i).unwrap().get_u32(0), 100 + i);
        }
    }

    #[test]
    fn flush_sync_failure_remarks_dirty_and_a_retry_succeeds() {
        use crate::store::vfs::{CrashImage, FaultPlan, FaultVfs, MemVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let path = std::path::Path::new("/fault/sync.pages");
        let mut p = Pager::create_with(&fv, path, 8).unwrap();
        for i in 0..4u32 {
            let id = p.allocate().unwrap();
            p.update(id, |pg| pg.put_u32(0, i)).unwrap();
        }
        fv.set_plan(FaultPlan { fail_sync: Some(fv.syncs_attempted() + 1), ..Default::default() });
        assert!(p.flush().is_err(), "injected fsync failure must surface");
        // Nothing is durable: the never-synced file is absent from (or at
        // most empty in) the fsynced-only crash image.
        let img = fv.crash_snapshot(CrashImage::SyncedOnly);
        assert!(
            img.get(std::path::Path::new("/fault/sync.pages"))
                .map_or(true, |b| b.is_empty()),
            "a failed fsync must leave nothing durable"
        );
        fv.disarm();
        let writes_before_retry = p.disk_writes();
        p.flush().unwrap();
        assert_eq!(p.disk_writes(), writes_before_retry + 4, "all pages rewritten");
        let img = fv.crash_snapshot(CrashImage::SyncedOnly);
        assert_eq!(img[std::path::Path::new("/fault/sync.pages")].len(), 4 * PAGE_SIZE);
    }

    #[test]
    fn memvfs_pager_roundtrips_like_disk() {
        use crate::store::vfs::MemVfs;
        let mem = MemVfs::new();
        let path = std::path::Path::new("/mem/basic.pages");
        {
            let mut p = Pager::create_with(&mem, path, 4).unwrap();
            for i in 0..10u32 {
                let id = p.allocate().unwrap();
                p.update(id, |pg| pg.put_u32(0, 1000 + i)).unwrap();
            }
            p.flush().unwrap();
        }
        let mut p = Pager::open_with(&mem, path, 4).unwrap();
        assert_eq!(p.num_pages(), 10);
        for i in 0..10u32 {
            assert_eq!(p.read(i).unwrap().get_u32(0), 1000 + i);
        }
    }

    #[test]
    fn reset_to_discards_uncommitted_tail() {
        let path = tmp("reset.pages");
        let mut p = Pager::create(&path, 8).unwrap();
        for i in 0..3u32 {
            let id = p.allocate().unwrap();
            p.update(id, |pg| pg.put_u32(0, i)).unwrap();
        }
        p.flush().unwrap();
        // Uncommitted tail: allocated + modified but never flushed.
        let id = p.allocate().unwrap();
        p.update(id, |pg| pg.put_u32(0, 999)).unwrap();
        p.update(0, |pg| pg.put_u32(100, 123)).unwrap();
        p.reset_to(3).unwrap();
        assert_eq!(p.num_pages(), 3);
        // The dirty in-cache change to page 0 is gone; disk state rules.
        assert_eq!(p.read(0).unwrap().get_u32(100), 0);
        assert!(p.read(3).is_err());
        // Reallocation reuses the id.
        assert_eq!(p.allocate().unwrap(), 3);
        assert!(p.reset_to(10).is_err(), "cannot reset above file size");
    }
}
