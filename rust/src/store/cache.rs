//! LRU page cache with pin/dirty tracking and hit/miss counters.
//!
//! Recency is a monotonically increasing tick stamped on every tracked
//! access; eviction picks the unpinned frame with the smallest stamp —
//! exact LRU, O(capacity) per eviction, which is trivial at the cache
//! sizes a group store uses (tens to a few thousand 4 KiB frames).
//!
//! The cache never does I/O. [`PageCache::insert`] hands a dirty victim
//! back to the caller (the pager) for write-back; [`PageCache::take_dirty`]
//! surfaces all dirty pages in ascending id order for the pager's ordered
//! flush.

use std::collections::HashMap;
use std::io;

use super::page::{Page, PageId};

/// Hit/miss/eviction counters (cost introspection for benches and the
/// Table 3 paged column).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Tracked lookups that found the page resident.
    pub hits: u64,
    /// Tracked lookups that missed.
    pub misses: u64,
    /// Frames evicted to make room.
    pub evictions: u64,
}

impl CacheStats {
    /// Hits as a fraction of tracked lookups (0.0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Frame {
    page: Page,
    dirty: bool,
    pins: u32,
    last_used: u64,
}

/// A bounded pool of pages keyed by [`PageId`].
pub struct PageCache {
    capacity: usize,
    frames: HashMap<PageId, Frame>,
    tick: u64,
    stats: CacheStats,
}

impl PageCache {
    /// An empty cache with room for `capacity` frames.
    ///
    /// # Panics
    /// Panics when `capacity` is 0.
    pub fn new(capacity: usize) -> PageCache {
        assert!(capacity >= 1, "page cache needs at least one frame");
        PageCache {
            capacity,
            frames: HashMap::with_capacity(capacity.min(1024)),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// Maximum resident frames.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Currently resident frames.
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    /// True when no frame is resident.
    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// True when `id` is resident (untracked; no stats or recency bump).
    pub fn contains(&self, id: PageId) -> bool {
        self.frames.contains_key(&id)
    }

    /// Tracked lookup: bumps recency and counts a hit or a miss.
    pub fn lookup(&mut self, id: PageId) -> Option<&mut Page> {
        self.tick += 1;
        match self.frames.get_mut(&id) {
            Some(f) => {
                f.last_used = self.tick;
                self.stats.hits += 1;
                Some(&mut f.page)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Untracked read: no stats, no recency bump.
    pub fn peek(&self, id: PageId) -> Option<&Page> {
        self.frames.get(&id).map(|f| &f.page)
    }

    /// Untracked mutable access: no stats, no recency bump, and the caller
    /// is responsible for [`PageCache::mark_dirty`].
    pub fn peek_mut(&mut self, id: PageId) -> Option<&mut Page> {
        self.frames.get_mut(&id).map(|f| &mut f.page)
    }

    /// Insert (or overwrite) a page. When full, the least-recently-used
    /// unpinned frame is evicted first; if it was dirty it is returned for
    /// write-back. Errors only when every frame is pinned.
    pub fn insert(
        &mut self,
        id: PageId,
        page: Page,
        dirty: bool,
    ) -> io::Result<Option<(PageId, Page)>> {
        self.tick += 1;
        if let Some(f) = self.frames.get_mut(&id) {
            f.page = page;
            f.dirty = f.dirty || dirty;
            f.last_used = self.tick;
            return Ok(None);
        }
        let mut writeback = None;
        if self.frames.len() >= self.capacity {
            let victim = self
                .frames
                .iter()
                .filter(|(_, f)| f.pins == 0)
                .min_by_key(|(_, f)| f.last_used)
                .map(|(vid, _)| *vid);
            match victim {
                None => {
                    return Err(io::Error::new(
                        io::ErrorKind::Other,
                        "page cache full and every frame pinned",
                    ))
                }
                Some(vid) => {
                    let f = self.frames.remove(&vid).unwrap();
                    self.stats.evictions += 1;
                    if f.dirty {
                        writeback = Some((vid, f.page));
                    }
                }
            }
        }
        self.frames
            .insert(id, Frame { page, dirty, pins: 0, last_used: self.tick });
        Ok(writeback)
    }

    /// The dirty frame that [`PageCache::insert`] of `incoming` would
    /// evict right now — the caller (pager) writes it back *before* the
    /// insert, so a failed write-back leaves the cache state fully
    /// intact (page still resident and dirty) instead of dropping the
    /// newest image on the floor. Ticks are unique, so the victim choice
    /// here and in `insert` is identical.
    pub fn pending_writeback(&self, incoming: PageId) -> Option<(PageId, &Page)> {
        if self.frames.contains_key(&incoming) || self.frames.len() < self.capacity {
            return None;
        }
        self.frames
            .iter()
            .filter(|(_, f)| f.pins == 0)
            .min_by_key(|(_, f)| f.last_used)
            .filter(|(_, f)| f.dirty)
            .map(|(vid, f)| (*vid, &f.page))
    }

    /// Clear a resident frame's dirty bit (after a successful write-back).
    pub fn mark_clean(&mut self, id: PageId) -> bool {
        match self.frames.get_mut(&id) {
            Some(f) => {
                f.dirty = false;
                true
            }
            None => false,
        }
    }

    /// Returns false when the page is not resident.
    pub fn mark_dirty(&mut self, id: PageId) -> bool {
        match self.frames.get_mut(&id) {
            Some(f) => {
                f.dirty = true;
                true
            }
            None => false,
        }
    }

    /// Pin a resident page (pinned pages are never evicted).
    pub fn pin(&mut self, id: PageId) -> bool {
        match self.frames.get_mut(&id) {
            Some(f) => {
                f.pins += 1;
                true
            }
            None => false,
        }
    }

    /// Release one pin. Returns false when the page is not resident.
    pub fn unpin(&mut self, id: PageId) -> bool {
        match self.frames.get_mut(&id) {
            Some(f) => {
                f.pins = f.pins.saturating_sub(1);
                true
            }
            None => false,
        }
    }

    /// Copies of all dirty pages in ascending id order, clearing their
    /// dirty bits (the pages stay resident, now clean).
    pub fn take_dirty(&mut self) -> Vec<(PageId, Page)> {
        let mut out: Vec<(PageId, Page)> = self
            .frames
            .iter_mut()
            .filter(|(_, f)| f.dirty)
            .map(|(id, f)| {
                f.dirty = false;
                (*id, f.page.clone())
            })
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out
    }

    /// Drop every frame (recovery discards uncommitted cached state).
    /// Dirty pages are deliberately lost — that is the point.
    pub fn clear(&mut self) {
        self.frames.clear();
    }

    /// Drop one frame unconditionally (tail reclamation removes pages
    /// from the file, so any cached image — even a dirty one — is
    /// garbage). Returns false when the page was not resident.
    pub fn remove(&mut self, id: PageId) -> bool {
        self.frames.remove(&id).is_some()
    }

    /// Hit/miss/eviction counters since construction.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, prop_assert, prop_assert_eq};

    fn page_tagged(tag: u8) -> Page {
        let mut p = Page::zeroed();
        p.put_u8(0, tag);
        p
    }

    #[test]
    fn hits_misses_and_recency() {
        let mut c = PageCache::new(2);
        assert!(c.lookup(1).is_none());
        c.insert(1, page_tagged(1), false).unwrap();
        assert!(c.lookup(1).is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = PageCache::new(2);
        c.insert(1, page_tagged(1), false).unwrap();
        c.insert(2, page_tagged(2), false).unwrap();
        // Touch 1 so 2 becomes LRU.
        assert!(c.lookup(1).is_some());
        c.insert(3, page_tagged(3), false).unwrap();
        assert!(c.contains(1));
        assert!(!c.contains(2), "page 2 was LRU and must be evicted");
        assert!(c.contains(3));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn dirty_eviction_returns_writeback() {
        let mut c = PageCache::new(1);
        c.insert(5, page_tagged(5), true).unwrap();
        let evicted = c.insert(6, page_tagged(6), false).unwrap();
        let (id, page) = evicted.expect("dirty victim must be handed back");
        assert_eq!(id, 5);
        assert_eq!(page.get_u8(0), 5);
        // Clean eviction returns nothing.
        assert!(c.insert(7, page_tagged(7), false).unwrap().is_none());
    }

    #[test]
    fn pinned_pages_survive_eviction() {
        let mut c = PageCache::new(2);
        c.insert(1, page_tagged(1), false).unwrap();
        c.insert(2, page_tagged(2), false).unwrap();
        assert!(c.pin(1));
        // 1 is LRU but pinned: 2 must go instead.
        c.insert(3, page_tagged(3), false).unwrap();
        assert!(c.contains(1));
        assert!(!c.contains(2));
        // All pinned -> insert errors.
        let mut tiny = PageCache::new(1);
        tiny.insert(9, page_tagged(9), false).unwrap();
        tiny.pin(9);
        assert!(tiny.insert(10, page_tagged(10), false).is_err());
        tiny.unpin(9);
        assert!(tiny.insert(10, page_tagged(10), false).is_ok());
    }

    #[test]
    fn take_dirty_is_ordered_and_clears() {
        let mut c = PageCache::new(8);
        c.insert(3, page_tagged(3), true).unwrap();
        c.insert(1, page_tagged(1), true).unwrap();
        c.insert(2, page_tagged(2), false).unwrap();
        let dirty = c.take_dirty();
        let ids: Vec<PageId> = dirty.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![1, 3]);
        assert!(c.take_dirty().is_empty(), "dirty bits must clear");
        assert!(c.contains(1) && c.contains(3), "pages stay resident");
    }

    /// Property: eviction matches a reference LRU (a recency-ordered Vec).
    #[test]
    fn property_matches_reference_lru() {
        check(30, |rng| {
            let cap = 2 + rng.gen_range_usize(6);
            let mut cache = PageCache::new(cap);
            // Reference: most-recently-used last.
            let mut reference: Vec<PageId> = Vec::new();
            for _ in 0..200 {
                let id = 1 + rng.gen_range(12) as PageId;
                if rng.bernoulli(0.5) {
                    // Tracked lookup.
                    let hit = cache.lookup(id).is_some();
                    let ref_hit = reference.contains(&id);
                    prop_assert_eq(hit, ref_hit, "hit status diverged")?;
                    if ref_hit {
                        reference.retain(|x| *x != id);
                        reference.push(id);
                    }
                } else {
                    cache.insert(id, Page::zeroed(), false).unwrap();
                    if reference.contains(&id) {
                        reference.retain(|x| *x != id);
                    } else if reference.len() >= cap {
                        reference.remove(0); // evict LRU
                    }
                    reference.push(id);
                }
                prop_assert_eq(cache.len(), reference.len(), "size diverged")?;
                for id in &reference {
                    prop_assert(cache.contains(*id), "reference page missing from cache")?;
                }
            }
            Ok(())
        });
    }
}
