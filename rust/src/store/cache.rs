//! Page cache with pin/dirty tracking, hit/miss counters, and two
//! replacement policies.
//!
//! [`CachePolicy::Lru`] (the default) is exact LRU: recency is a
//! monotonically increasing tick stamped on every tracked access;
//! eviction picks the unpinned frame with the smallest stamp —
//! O(capacity) per eviction, which is trivial at the cache sizes a group
//! store uses (tens to a few thousand 4 KiB frames).
//!
//! [`CachePolicy::TwoQ`] is a scan-resistant two-queue policy (2Q-lite,
//! after the classic 2Q family): a frame enters **cold** (probationary)
//! and is promoted to **hot** (protected) only on a second tracked
//! access. Eviction drains unpinned cold frames first, so a sequential
//! scan longer than the cache — whose pages are touched exactly once —
//! churns through the cold queue and never displaces the hot set (B+tree
//! root and internal pages, hot groups). The hot set is capped at 3/4 of
//! capacity; a promotion past the cap demotes the least-recently-used
//! hot frame back to cold so the hot set can still turn over.
//!
//! A [`FrameBudget`] lets several caches (the
//! [`super::shared::SharedPager`] shards) share one global frame
//! allowance instead of fixed per-shard capacities: each cache prepays
//! `reserved` frames and must win a budget token to grow past them, so a
//! hot shard can borrow frames idle shards never claimed while the
//! cross-shard total stays bounded.
//!
//! The cache never does I/O. [`PageCache::insert`] hands a dirty victim
//! back to the caller (the pager) for write-back; [`PageCache::take_dirty`]
//! surfaces all dirty pages in ascending id order for the pager's ordered
//! flush.

use std::collections::HashMap;
use std::io;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::page::{Page, PageId};

/// Hit/miss/eviction counters (cost introspection for benches and the
/// Table 3 paged column).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Tracked lookups that found the page resident.
    pub hits: u64,
    /// Tracked lookups that missed.
    pub misses: u64,
    /// Frames evicted to make room.
    pub evictions: u64,
}

impl CacheStats {
    /// Hits as a fraction of tracked lookups (0.0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Replacement policy for a [`PageCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CachePolicy {
    /// Exact least-recently-used: matches a recency-ordered reference
    /// list exactly. The default, and the policy the exclusive write-path
    /// pager always uses.
    #[default]
    Lru,
    /// Scan-resistant two-queue: pages enter cold, are promoted to hot
    /// on re-access, and cold frames are evicted first.
    TwoQ,
}

impl CachePolicy {
    /// Parse a CLI spelling (`lru` or `2q`).
    pub fn parse(s: &str) -> Option<CachePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "lru" => Some(CachePolicy::Lru),
            "2q" | "twoq" | "two-q" => Some(CachePolicy::TwoQ),
            _ => None,
        }
    }
}

impl std::fmt::Display for CachePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CachePolicy::Lru => write!(f, "lru"),
            CachePolicy::TwoQ => write!(f, "2q"),
        }
    }
}

/// A shared allowance of cache frames, split dynamically between the
/// caches that hold an `Arc` to it. Tokens are claimed on growth and
/// returned when frames are dropped, so the cross-cache resident total
/// never exceeds `sum(reserved) + total`.
#[derive(Debug)]
pub struct FrameBudget {
    avail: AtomicUsize,
    total: usize,
}

impl FrameBudget {
    /// A pool of `total` loanable frames.
    pub fn new(total: usize) -> FrameBudget {
        FrameBudget { avail: AtomicUsize::new(total), total }
    }

    /// Pool size at construction.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Tokens currently unclaimed.
    pub fn available(&self) -> usize {
        self.avail.load(Ordering::Relaxed)
    }

    /// Claim one frame; false when the pool is empty.
    fn try_acquire(&self) -> bool {
        self.avail
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| n.checked_sub(1))
            .is_ok()
    }

    /// Return `n` frames to the pool.
    fn release(&self, n: usize) {
        if n > 0 {
            self.avail.fetch_add(n, Ordering::Relaxed);
        }
    }
}

struct Frame {
    page: Page,
    dirty: bool,
    pins: u32,
    /// TwoQ protected bit; always false under [`CachePolicy::Lru`].
    hot: bool,
    /// Inserted by a batched prefetch: the next tracked hit is the
    /// page's *first* real access, so it consumes this flag instead of
    /// promoting the frame (a prefetched-then-scanned page must look
    /// exactly like a demand-missed one to the TwoQ policy).
    arrived: bool,
    last_used: u64,
}

/// A bounded pool of pages keyed by [`PageId`].
pub struct PageCache {
    capacity: usize,
    policy: CachePolicy,
    /// Frames this cache may hold without consulting the shared budget
    /// (equals `capacity` when there is no budget).
    reserved: usize,
    budget: Option<Arc<FrameBudget>>,
    frames: HashMap<PageId, Frame>,
    /// Resident frames with the hot bit set.
    hot: usize,
    tick: u64,
    stats: CacheStats,
}

impl PageCache {
    /// An empty LRU cache with room for `capacity` frames.
    ///
    /// # Panics
    /// Panics when `capacity` is 0 (use [`PageCache::with_policy`] for a
    /// stats-only zero-capacity cache).
    pub fn new(capacity: usize) -> PageCache {
        assert!(capacity >= 1, "page cache needs at least one frame");
        PageCache::with_policy(capacity, CachePolicy::Lru)
    }

    /// An empty cache under `policy`. Unlike [`PageCache::new`],
    /// `capacity` 0 is allowed: the cache then stores nothing but still
    /// counts tracked lookups, so the miss/disk-read identity holds even
    /// for an uncached store.
    pub fn with_policy(capacity: usize, policy: CachePolicy) -> PageCache {
        PageCache {
            capacity,
            policy,
            reserved: capacity,
            budget: None,
            frames: HashMap::with_capacity(capacity.min(1024)),
            hot: 0,
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// A cache that owns `reserved` frames outright and draws any growth
    /// beyond them (up to `capacity`) from a shared [`FrameBudget`].
    ///
    /// # Panics
    /// Panics when `reserved > capacity`.
    pub fn with_budget(
        capacity: usize,
        policy: CachePolicy,
        reserved: usize,
        budget: Arc<FrameBudget>,
    ) -> PageCache {
        assert!(reserved <= capacity, "reserved frames exceed capacity");
        PageCache {
            capacity,
            policy,
            reserved,
            budget: Some(budget),
            frames: HashMap::with_capacity(reserved.clamp(16, 1024)),
            hot: 0,
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// Maximum resident frames (local cap; a shared budget may stop
    /// growth earlier).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The replacement policy.
    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    /// Currently resident frames.
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    /// Resident frames currently in the protected (hot) set. Always 0
    /// under [`CachePolicy::Lru`].
    pub fn hot_len(&self) -> usize {
        self.hot
    }

    /// True when no frame is resident.
    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// True when `id` is resident (untracked; no stats or recency bump).
    pub fn contains(&self, id: PageId) -> bool {
        self.frames.contains_key(&id)
    }

    fn hot_cap(&self) -> usize {
        (self.capacity * 3 / 4).max(1)
    }

    /// Promote `id` into the hot set, demoting the LRU hot frame when
    /// the cap is exceeded (never the frame just promoted: it carries
    /// the newest tick, and when it is the only hot frame the cap — at
    /// least 1 — is not exceeded).
    fn promote(&mut self, id: PageId) {
        if let Some(f) = self.frames.get_mut(&id) {
            if !f.hot {
                f.hot = true;
                self.hot += 1;
            }
        }
        if self.hot > self.hot_cap() {
            let demote = self
                .frames
                .iter()
                .filter(|(_, f)| f.hot)
                .min_by_key(|(_, f)| f.last_used)
                .map(|(vid, _)| *vid);
            if let Some(vid) = demote {
                if let Some(f) = self.frames.get_mut(&vid) {
                    f.hot = false;
                    self.hot -= 1;
                }
            }
        }
    }

    /// Tracked lookup: bumps recency and counts a hit or a miss. Under
    /// [`CachePolicy::TwoQ`] a hit on a cold frame promotes it.
    pub fn lookup(&mut self, id: PageId) -> Option<&mut Page> {
        self.tick += 1;
        let promote = match self.frames.get_mut(&id) {
            Some(f) => {
                f.last_used = self.tick;
                self.stats.hits += 1;
                if f.arrived {
                    // First access to a prefetched frame: it stays cold,
                    // exactly as a demand miss would have left it.
                    f.arrived = false;
                    false
                } else {
                    self.policy == CachePolicy::TwoQ && !f.hot
                }
            }
            None => {
                self.stats.misses += 1;
                return None;
            }
        };
        if promote {
            self.promote(id);
        }
        self.frames.get_mut(&id).map(|f| &mut f.page)
    }

    /// Untracked read: no stats, no recency bump.
    pub fn peek(&self, id: PageId) -> Option<&Page> {
        self.frames.get(&id).map(|f| &f.page)
    }

    /// Untracked mutable access: no stats, no recency bump, and the caller
    /// is responsible for [`PageCache::mark_dirty`].
    pub fn peek_mut(&mut self, id: PageId) -> Option<&mut Page> {
        self.frames.get_mut(&id).map(|f| &mut f.page)
    }

    /// Whether a new frame may be added without evicting: room under the
    /// local capacity and (past the reserved prepay) a token won from
    /// the shared budget. Consumes a token on success past the prepay.
    fn try_grow(&mut self) -> bool {
        if self.frames.len() >= self.capacity {
            return false;
        }
        if self.frames.len() < self.reserved {
            return true;
        }
        match &self.budget {
            None => true,
            Some(b) => b.try_acquire(),
        }
    }

    /// Non-consuming twin of `try_grow`: may be optimistic under
    /// cross-cache budget races, but only the write-path pager — which
    /// never has a budget — relies on its answer for correctness.
    fn would_grow(&self) -> bool {
        if self.frames.len() >= self.capacity {
            return false;
        }
        if self.frames.len() < self.reserved {
            return true;
        }
        self.budget.as_ref().map_or(true, |b| b.available() > 0)
    }

    /// The frame an eviction would remove right now: under LRU the
    /// unpinned frame with the smallest tick; under TwoQ the coldest
    /// unpinned cold frame, falling back to the coldest unpinned hot
    /// frame when no cold frame is evictable.
    fn victim(&self) -> Option<PageId> {
        let pick = |want_hot: Option<bool>| {
            self.frames
                .iter()
                .filter(|(_, f)| f.pins == 0 && want_hot.map_or(true, |h| f.hot == h))
                .min_by_key(|(_, f)| f.last_used)
                .map(|(vid, _)| *vid)
        };
        match self.policy {
            CachePolicy::Lru => pick(None),
            CachePolicy::TwoQ => pick(Some(false)).or_else(|| pick(Some(true))),
        }
    }

    /// Insert (or overwrite) a page. New frames enter cold; when the
    /// cache cannot grow (capacity reached, or the shared budget is
    /// exhausted) a victim is evicted first — if it was dirty it is
    /// returned for write-back. A zero-capacity cache stores nothing and
    /// returns `Ok(None)`. Errors only when an eviction is needed and
    /// every frame is pinned.
    pub fn insert(
        &mut self,
        id: PageId,
        page: Page,
        dirty: bool,
    ) -> io::Result<Option<(PageId, Page)>> {
        self.tick += 1;
        if let Some(f) = self.frames.get_mut(&id) {
            f.page = page;
            f.dirty = f.dirty || dirty;
            f.arrived = false; // a demand insert is a real access
            f.last_used = self.tick;
            return Ok(None);
        }
        if self.capacity == 0 {
            return Ok(None);
        }
        let mut writeback = None;
        if !self.try_grow() {
            let victim = self.victim();
            match victim {
                None => {
                    return Err(io::Error::new(
                        io::ErrorKind::Other,
                        "page cache full and every frame pinned",
                    ))
                }
                Some(vid) => {
                    let f = self.frames.remove(&vid).unwrap();
                    if f.hot {
                        self.hot -= 1;
                    }
                    self.stats.evictions += 1;
                    if f.dirty {
                        writeback = Some((vid, f.page));
                    }
                }
            }
        }
        self.frames.insert(
            id,
            Frame { page, dirty, pins: 0, hot: false, arrived: false, last_used: self.tick },
        );
        Ok(writeback)
    }

    /// Insert a clean page fetched by a batched prefetch. Identical to
    /// [`PageCache::insert`] except that the frame is marked as having
    /// *arrived ahead of its first access*: the next tracked hit leaves
    /// it cold instead of promoting it, so a vectored sequential scan is
    /// still scan-resistant under [`CachePolicy::TwoQ`]. A page that is
    /// already resident is left untouched (the bytes are identical —
    /// committed pages are immutable).
    ///
    /// # Errors
    /// Same as [`PageCache::insert`].
    pub fn insert_prefetched(&mut self, id: PageId, page: Page) -> io::Result<()> {
        if self.frames.contains_key(&id) || self.capacity == 0 {
            return Ok(());
        }
        self.tick += 1;
        if !self.try_grow() {
            match self.victim() {
                None => {
                    return Err(io::Error::new(
                        io::ErrorKind::Other,
                        "page cache full and every frame pinned",
                    ))
                }
                Some(vid) => {
                    let f = self.frames.remove(&vid).unwrap();
                    if f.hot {
                        self.hot -= 1;
                    }
                    self.stats.evictions += 1;
                    debug_assert!(!f.dirty, "prefetch only runs on read-only caches");
                }
            }
        }
        self.frames.insert(
            id,
            Frame { page, dirty: false, pins: 0, hot: false, arrived: true, last_used: self.tick },
        );
        Ok(())
    }

    /// Count `n` tracked misses without a lookup. The shared pager's
    /// batched prefetch probes residency under the shard lock and then
    /// fetches every absent page itself, so it records the misses here —
    /// keeping the stats identity (misses == non-header disk reads)
    /// intact on the vectored path.
    pub fn count_prefetch_misses(&mut self, n: u64) {
        self.stats.misses += n;
    }

    /// The dirty frame that [`PageCache::insert`] of `incoming` would
    /// evict right now — the caller (pager) writes it back *before* the
    /// insert, so a failed write-back leaves the cache state fully
    /// intact (page still resident and dirty) instead of dropping the
    /// newest image on the floor. Ticks are unique, so the victim choice
    /// here and in `insert` is identical.
    pub fn pending_writeback(&self, incoming: PageId) -> Option<(PageId, &Page)> {
        if self.capacity == 0 || self.frames.contains_key(&incoming) || self.would_grow() {
            return None;
        }
        let vid = self.victim()?;
        let f = &self.frames[&vid];
        if f.dirty {
            Some((vid, &f.page))
        } else {
            None
        }
    }

    /// Clear a resident frame's dirty bit (after a successful write-back).
    pub fn mark_clean(&mut self, id: PageId) -> bool {
        match self.frames.get_mut(&id) {
            Some(f) => {
                f.dirty = false;
                true
            }
            None => false,
        }
    }

    /// Returns false when the page is not resident.
    pub fn mark_dirty(&mut self, id: PageId) -> bool {
        match self.frames.get_mut(&id) {
            Some(f) => {
                f.dirty = true;
                true
            }
            None => false,
        }
    }

    /// Pin a resident page (pinned pages are never evicted).
    pub fn pin(&mut self, id: PageId) -> bool {
        match self.frames.get_mut(&id) {
            Some(f) => {
                f.pins += 1;
                true
            }
            None => false,
        }
    }

    /// Release one pin. Returns false when the page is not resident.
    pub fn unpin(&mut self, id: PageId) -> bool {
        match self.frames.get_mut(&id) {
            Some(f) => {
                f.pins = f.pins.saturating_sub(1);
                true
            }
            None => false,
        }
    }

    /// Copies of all dirty pages in ascending id order, clearing their
    /// dirty bits (the pages stay resident, now clean).
    pub fn take_dirty(&mut self) -> Vec<(PageId, Page)> {
        let mut out: Vec<(PageId, Page)> = self
            .frames
            .iter_mut()
            .filter(|(_, f)| f.dirty)
            .map(|(id, f)| {
                f.dirty = false;
                (*id, f.page.clone())
            })
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out
    }

    /// Drop every frame (recovery discards uncommitted cached state).
    /// Dirty pages are deliberately lost — that is the point. Budget
    /// tokens held beyond the reserved prepay return to the pool.
    pub fn clear(&mut self) {
        if let Some(b) = &self.budget {
            b.release(self.frames.len().saturating_sub(self.reserved));
        }
        self.frames.clear();
        self.hot = 0;
    }

    /// Drop one frame unconditionally (tail reclamation removes pages
    /// from the file, so any cached image — even a dirty one — is
    /// garbage). Returns false when the page was not resident.
    pub fn remove(&mut self, id: PageId) -> bool {
        match self.frames.remove(&id) {
            Some(f) => {
                if f.hot {
                    self.hot -= 1;
                }
                // The frame count just dropped from len+1 to len; the
                // removed frame was budget-funded iff len+1 > reserved.
                if self.frames.len() >= self.reserved {
                    if let Some(b) = &self.budget {
                        b.release(1);
                    }
                }
                true
            }
            None => false,
        }
    }

    /// Hit/miss/eviction counters since construction.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, prop_assert, prop_assert_eq};

    fn page_tagged(tag: u8) -> Page {
        let mut p = Page::zeroed();
        p.put_u8(0, tag);
        p
    }

    #[test]
    fn hits_misses_and_recency() {
        let mut c = PageCache::new(2);
        assert!(c.lookup(1).is_none());
        c.insert(1, page_tagged(1), false).unwrap();
        assert!(c.lookup(1).is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = PageCache::new(2);
        c.insert(1, page_tagged(1), false).unwrap();
        c.insert(2, page_tagged(2), false).unwrap();
        // Touch 1 so 2 becomes LRU.
        assert!(c.lookup(1).is_some());
        c.insert(3, page_tagged(3), false).unwrap();
        assert!(c.contains(1));
        assert!(!c.contains(2), "page 2 was LRU and must be evicted");
        assert!(c.contains(3));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn dirty_eviction_returns_writeback() {
        let mut c = PageCache::new(1);
        c.insert(5, page_tagged(5), true).unwrap();
        let evicted = c.insert(6, page_tagged(6), false).unwrap();
        let (id, page) = evicted.expect("dirty victim must be handed back");
        assert_eq!(id, 5);
        assert_eq!(page.get_u8(0), 5);
        // Clean eviction returns nothing.
        assert!(c.insert(7, page_tagged(7), false).unwrap().is_none());
    }

    #[test]
    fn pinned_pages_survive_eviction() {
        let mut c = PageCache::new(2);
        c.insert(1, page_tagged(1), false).unwrap();
        c.insert(2, page_tagged(2), false).unwrap();
        assert!(c.pin(1));
        // 1 is LRU but pinned: 2 must go instead.
        c.insert(3, page_tagged(3), false).unwrap();
        assert!(c.contains(1));
        assert!(!c.contains(2));
        // All pinned -> insert errors.
        let mut tiny = PageCache::new(1);
        tiny.insert(9, page_tagged(9), false).unwrap();
        tiny.pin(9);
        assert!(tiny.insert(10, page_tagged(10), false).is_err());
        tiny.unpin(9);
        assert!(tiny.insert(10, page_tagged(10), false).is_ok());
    }

    #[test]
    fn take_dirty_is_ordered_and_clears() {
        let mut c = PageCache::new(8);
        c.insert(3, page_tagged(3), true).unwrap();
        c.insert(1, page_tagged(1), true).unwrap();
        c.insert(2, page_tagged(2), false).unwrap();
        let dirty = c.take_dirty();
        let ids: Vec<PageId> = dirty.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![1, 3]);
        assert!(c.take_dirty().is_empty(), "dirty bits must clear");
        assert!(c.contains(1) && c.contains(3), "pages stay resident");
    }

    /// Property: eviction matches a reference LRU (a recency-ordered Vec).
    #[test]
    fn property_matches_reference_lru() {
        check(30, |rng| {
            let cap = 2 + rng.gen_range_usize(6);
            let mut cache = PageCache::new(cap);
            // Reference: most-recently-used last.
            let mut reference: Vec<PageId> = Vec::new();
            for _ in 0..200 {
                let id = 1 + rng.gen_range(12) as PageId;
                if rng.bernoulli(0.5) {
                    // Tracked lookup.
                    let hit = cache.lookup(id).is_some();
                    let ref_hit = reference.contains(&id);
                    prop_assert_eq(hit, ref_hit, "hit status diverged")?;
                    if ref_hit {
                        reference.retain(|x| *x != id);
                        reference.push(id);
                    }
                } else {
                    cache.insert(id, Page::zeroed(), false).unwrap();
                    if reference.contains(&id) {
                        reference.retain(|x| *x != id);
                    } else if reference.len() >= cap {
                        reference.remove(0); // evict LRU
                    }
                    reference.push(id);
                }
                prop_assert_eq(cache.len(), reference.len(), "size diverged")?;
                for id in &reference {
                    prop_assert(cache.contains(*id), "reference page missing from cache")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cache_policy_parses_cli_spellings() {
        assert_eq!(CachePolicy::parse("lru"), Some(CachePolicy::Lru));
        assert_eq!(CachePolicy::parse("LRU"), Some(CachePolicy::Lru));
        assert_eq!(CachePolicy::parse("2q"), Some(CachePolicy::TwoQ));
        assert_eq!(CachePolicy::parse("TwoQ"), Some(CachePolicy::TwoQ));
        assert_eq!(CachePolicy::parse("arc"), None);
        assert_eq!(CachePolicy::TwoQ.to_string(), "2q");
    }

    #[test]
    fn zero_capacity_cache_counts_but_stores_nothing() {
        let mut c = PageCache::with_policy(0, CachePolicy::Lru);
        assert!(c.lookup(1).is_none());
        assert!(c.insert(1, page_tagged(1), false).unwrap().is_none());
        assert!(c.lookup(1).is_none(), "nothing may become resident");
        assert_eq!(c.len(), 0);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (0, 2, 0));
        assert!(c.pending_writeback(2).is_none());
    }

    /// Scan resistance: a one-touch scan longer than capacity must not
    /// displace the re-accessed (hot) working set.
    #[test]
    fn two_q_scan_leaves_hot_set_resident() {
        let mut c = PageCache::with_policy(8, CachePolicy::TwoQ);
        for id in 1..=4 {
            c.insert(id, page_tagged(id as u8), false).unwrap();
        }
        for id in 1..=4 {
            assert!(c.lookup(id).is_some(), "promote {id} to hot");
        }
        assert_eq!(c.hot_len(), 4);
        // A scan of 3x capacity, every page touched exactly once.
        for id in 100..124 {
            c.insert(id, Page::zeroed(), false).unwrap();
        }
        for id in 1..=4 {
            assert!(c.contains(id), "hot page {id} evicted by a cold scan");
        }
        assert_eq!(c.len(), 8, "cache stayed full");
        // Under strict LRU the same trace evicts the whole hot set.
        let mut lru = PageCache::new(8);
        for id in 1..=4 {
            lru.insert(id, page_tagged(id as u8), false).unwrap();
            lru.lookup(id);
        }
        for id in 100..124 {
            lru.insert(id, Page::zeroed(), false).unwrap();
        }
        for id in 1..=4 {
            assert!(!lru.contains(id), "LRU keeps no hot page through the scan");
        }
    }

    #[test]
    fn two_q_hot_cap_demotes_lru_hot_frame() {
        // capacity 4 -> hot cap 3: promoting a 4th page demotes the
        // least-recently-used hot frame back to cold.
        let mut c = PageCache::with_policy(4, CachePolicy::TwoQ);
        for id in 1..=4 {
            c.insert(id, page_tagged(id as u8), false).unwrap();
        }
        for id in 1..=4 {
            c.lookup(id);
        }
        assert_eq!(c.hot_len(), 3, "hot cap must bound the protected set");
        // Page 1 was the LRU hot frame when 4 was promoted, so it is the
        // cold one — the next one-touch insert evicts it.
        c.insert(9, Page::zeroed(), false).unwrap();
        assert!(!c.contains(1));
        assert!(c.contains(2) && c.contains(3) && c.contains(4));
    }

    /// Property: TwoQ matches a reference model — one global recency
    /// order plus a hot set; victims are the coldest unpinned cold
    /// frame, else the coldest hot frame; promotion past the hot cap
    /// demotes the coldest hot frame.
    #[test]
    fn property_matches_reference_two_q() {
        check(30, |rng| {
            let cap = 2 + rng.gen_range_usize(6);
            let hot_cap = (cap * 3 / 4).max(1);
            let mut cache = PageCache::with_policy(cap, CachePolicy::TwoQ);
            let mut recency: Vec<PageId> = Vec::new(); // MRU last
            let mut hot: Vec<PageId> = Vec::new();
            for _ in 0..200 {
                let id = 1 + rng.gen_range(12) as PageId;
                if rng.bernoulli(0.5) {
                    let hit = cache.lookup(id).is_some();
                    let ref_hit = recency.contains(&id);
                    prop_assert_eq(hit, ref_hit, "hit status diverged")?;
                    if ref_hit {
                        recency.retain(|x| *x != id);
                        recency.push(id);
                        if !hot.contains(&id) {
                            hot.push(id);
                            if hot.len() > hot_cap {
                                // Demote the coldest hot frame.
                                let demote = *recency
                                    .iter()
                                    .find(|x| hot.contains(x))
                                    .expect("hot set is non-empty");
                                hot.retain(|x| *x != demote);
                            }
                        }
                    }
                } else {
                    cache.insert(id, Page::zeroed(), false).unwrap();
                    if recency.contains(&id) {
                        recency.retain(|x| *x != id);
                    } else if recency.len() >= cap {
                        // Evict coldest cold, else coldest overall.
                        let victim = recency
                            .iter()
                            .find(|x| !hot.contains(x))
                            .copied()
                            .unwrap_or(recency[0]);
                        recency.retain(|x| *x != victim);
                        hot.retain(|x| *x != victim);
                    }
                    recency.push(id); // new frames enter cold
                }
                prop_assert_eq(cache.len(), recency.len(), "size diverged")?;
                prop_assert_eq(cache.hot_len(), hot.len(), "hot count diverged")?;
                for id in &recency {
                    prop_assert(cache.contains(*id), "reference page missing from cache")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prefetched_frames_need_two_real_accesses_to_go_hot() {
        let mut c = PageCache::with_policy(4, CachePolicy::TwoQ);
        c.insert_prefetched(1, page_tagged(1)).unwrap();
        assert!(c.lookup(1).is_some());
        assert_eq!(c.hot_len(), 0, "first access after prefetch stays cold");
        assert!(c.lookup(1).is_some());
        assert_eq!(c.hot_len(), 1, "second access promotes");
        // Prefetch of a resident page is a no-op (same immutable bytes).
        c.insert_prefetched(1, Page::zeroed()).unwrap();
        assert!(c.lookup(1).is_some());
        assert_eq!(c.hot_len(), 1);
        assert_eq!(c.stats().misses, 0, "prefetch probes count no lookup");
    }

    #[test]
    fn frame_budget_is_shared_and_conserved() {
        let budget = Arc::new(FrameBudget::new(4));
        let mut a = PageCache::with_budget(64, CachePolicy::TwoQ, 1, budget.clone());
        let mut b = PageCache::with_budget(64, CachePolicy::TwoQ, 1, budget.clone());
        // A grows through its prepaid frame plus the whole pool.
        for id in 0..8 {
            a.insert(id, Page::zeroed(), false).unwrap();
        }
        assert_eq!(a.len(), 5, "1 reserved + 4 pooled frames");
        assert_eq!(budget.available(), 0);
        assert_eq!(a.stats().evictions, 3, "later inserts evict instead of growing");
        // B is squeezed down to its prepaid frame.
        for id in 100..104 {
            b.insert(id, Page::zeroed(), false).unwrap();
        }
        assert_eq!(b.len(), 1);
        assert!(a.len() + b.len() <= 2 + budget.total(), "cross-cache total bounded");
        // Dropping A's frames returns tokens B can then claim.
        a.clear();
        assert_eq!(budget.available(), 4);
        for id in 200..208 {
            b.insert(id, Page::zeroed(), false).unwrap();
        }
        assert_eq!(b.len(), 5);
        assert_eq!(budget.available(), 0);
        // remove() releases one token per budget-funded frame.
        let resident: Vec<PageId> = (200..208).filter(|id| b.contains(*id)).collect();
        for id in &resident[1..] {
            b.remove(*id);
        }
        assert_eq!(b.len(), 1);
        assert_eq!(budget.available(), 4, "all pooled tokens returned");
    }
}
