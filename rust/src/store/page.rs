//! The fixed-size page: the unit of I/O, caching and logging for the whole
//! storage engine. Shared by the pager, the LRU page cache, the WAL
//! checkpointer and both B-trees (the immutable bulk-loaded
//! [`crate::formats::btree_index`] and the mutable [`super::btree`]).

use std::io;

/// Fixed page size. [`crate::formats::btree_index`] re-exports this so the
/// immutable index and the mutable engine share one on-disk granularity.
pub const PAGE_SIZE: usize = 4096;

/// A page's 0-based position in its backing file.
pub type PageId = u32;

/// Sentinel meaning "no page". Page 0 is always a file header in the
/// formats built on the pager, so it can never be a valid root/child.
pub const NO_PAGE: PageId = 0;

/// One fixed-size page of bytes with little-endian scalar accessors.
#[derive(Clone)]
pub struct Page {
    buf: Box<[u8]>,
}

impl Page {
    /// A fresh all-zero page.
    pub fn zeroed() -> Page {
        Page { buf: vec![0u8; PAGE_SIZE].into_boxed_slice() }
    }

    /// Wrap an exactly-`PAGE_SIZE` buffer.
    ///
    /// # Errors
    /// `InvalidData` when `v` is not exactly [`PAGE_SIZE`] bytes.
    pub fn from_vec(v: Vec<u8>) -> io::Result<Page> {
        if v.len() != PAGE_SIZE {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("page must be {PAGE_SIZE} bytes, got {}", v.len()),
            ));
        }
        Ok(Page { buf: v.into_boxed_slice() })
    }

    /// The whole page as bytes.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    /// The whole page as mutable bytes.
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        &mut self.buf
    }

    /// Read the byte at `at`.
    ///
    /// # Panics
    /// All scalar accessors panic when the access runs past
    /// [`PAGE_SIZE`] — offsets are internal layout constants, never
    /// external input.
    pub fn get_u8(&self, at: usize) -> u8 {
        self.buf[at]
    }

    /// Write the byte at `at` (see [`Page::get_u8`] for panics).
    pub fn put_u8(&mut self, at: usize, v: u8) {
        self.buf[at] = v;
    }

    /// Read a little-endian u16 at `at` (see [`Page::get_u8`] for panics).
    pub fn get_u16(&self, at: usize) -> u16 {
        u16::from_le_bytes(self.buf[at..at + 2].try_into().unwrap())
    }

    /// Write a little-endian u16 at `at` (see [`Page::get_u8`] for panics).
    pub fn put_u16(&mut self, at: usize, v: u16) {
        self.buf[at..at + 2].copy_from_slice(&v.to_le_bytes());
    }

    /// Read a little-endian u32 at `at` (see [`Page::get_u8`] for panics).
    pub fn get_u32(&self, at: usize) -> u32 {
        u32::from_le_bytes(self.buf[at..at + 4].try_into().unwrap())
    }

    /// Write a little-endian u32 at `at` (see [`Page::get_u8`] for panics).
    pub fn put_u32(&mut self, at: usize, v: u32) {
        self.buf[at..at + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// Read a little-endian u64 at `at` (see [`Page::get_u8`] for panics).
    pub fn get_u64(&self, at: usize) -> u64 {
        u64::from_le_bytes(self.buf[at..at + 8].try_into().unwrap())
    }

    /// Write a little-endian u64 at `at` (see [`Page::get_u8`] for panics).
    pub fn put_u64(&mut self, at: usize, v: u64) {
        self.buf[at..at + 8].copy_from_slice(&v.to_le_bytes());
    }

    /// Borrow `len` bytes at `at` (see [`Page::get_u8`] for panics).
    pub fn get_bytes(&self, at: usize, len: usize) -> &[u8] {
        &self.buf[at..at + len]
    }

    /// Copy `v` into the page at `at` (see [`Page::get_u8`] for panics).
    pub fn put_bytes(&mut self, at: usize, v: &[u8]) {
        self.buf[at..at + v.len()].copy_from_slice(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_accessors_roundtrip() {
        let mut p = Page::zeroed();
        p.put_u8(0, 0xAB);
        p.put_u16(1, 0x1234);
        p.put_u32(3, 0xDEAD_BEEF);
        p.put_u64(7, 0x0102_0304_0506_0708);
        p.put_bytes(100, b"hello");
        assert_eq!(p.get_u8(0), 0xAB);
        assert_eq!(p.get_u16(1), 0x1234);
        assert_eq!(p.get_u32(3), 0xDEAD_BEEF);
        assert_eq!(p.get_u64(7), 0x0102_0304_0506_0708);
        assert_eq!(p.get_bytes(100, 5), b"hello");
    }

    #[test]
    fn from_vec_enforces_size() {
        assert!(Page::from_vec(vec![0u8; PAGE_SIZE]).is_ok());
        assert!(Page::from_vec(vec![0u8; PAGE_SIZE - 1]).is_err());
        assert!(Page::from_vec(vec![0u8; PAGE_SIZE + 1]).is_err());
    }
}
