//! The multi-threaded partition runner: map -> spill -> external
//! group-by-key -> contiguous shards + group index.
//!
//! Memory discipline is the point (paper §3.1-3.2): no phase holds more
//! than `spill_chunk_bytes` of example payload in RAM, regardless of how
//! many examples a single group accumulates — grouping is a disk-backed
//! external sort (sorted runs + k-way merge), exactly how a Beam/MapReduce
//! shuffle scales past memory.
//!
//! Two sinks share the map/spill/merge machinery:
//!
//! * [`run_partition`] — the classic streaming output: contiguous
//!   TFRecord shards plus a `.gindex`;
//! * [`run_partition_paged`] — **direct-to-paged** materialization: each
//!   group-by-key bucket appends its merged stream straight into its own
//!   shard's `PagedStore` (one WAL per shard, all buckets concurrently),
//!   producing a `.pset` sharded paged set with no intermediate TFRecord
//!   pass. Bucket placement is [`crate::formats::paged_sharded::shard_of_key`]
//!   for both sinks, so the bucket a group sorts in *is* the shard it
//!   lives on.

use std::collections::BinaryHeap;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::index::{GroupIndex, GroupIndexEntry};
use super::partition::Partitioner;
use crate::corpus::{word_count, BaseDataset};
use crate::formats::paged::{
    PagedStat, PagedStore, BUILD_CHECKPOINT_WAL_BYTES, DEFAULT_CACHE_PAGES,
};
use crate::formats::paged_sharded::{
    invalidate_overlapping_manifest, restore_manifest_if_intact, shard_of_key,
    stale_shard_stores, truncate_shard_stores, PagedSetManifest, PagedShardSet,
};
use crate::records::sharded::shard_name;
use crate::records::tfrecord::{framed_len, RecordReader, RecordWriter};
use crate::store::vfs::{StdVfs, Vfs};
use crate::util::threadpool::{parallel_for_each_mut, ThreadPool};
use crate::util::timer::Timer;

/// Tuning knobs for a partition run.
///
/// Superseded by [`PartitionRequest`] (which carries these knobs plus a
/// [`SinkOptions`]); kept as a direct parameter of [`run_partition`]
/// for one more release.
#[derive(Debug, Clone)]
pub struct PartitionOptions {
    /// Map workers (also the number of dataset splits requested).
    pub num_workers: usize,
    /// Output shards == group-by-key buckets.
    pub num_shards: usize,
    /// Max example payload bytes held in RAM while grouping one bucket.
    pub spill_chunk_bytes: usize,
    /// Count whitespace words of the `text` feature into the index
    /// (Tables 1/6/7 read these; disable for binary datasets).
    pub count_words: bool,
}

impl Default for PartitionOptions {
    fn default() -> Self {
        PartitionOptions {
            num_workers: ThreadPool::default_workers(),
            num_shards: 8,
            spill_chunk_bytes: 64 << 20,
            count_words: true,
        }
    }
}

/// Summary of a completed run (printed by the CLI, asserted by tests).
#[derive(Debug, Clone)]
pub struct PartitionReport {
    pub num_examples: u64,
    pub num_groups: u64,
    pub total_payload_bytes: u64,
    pub total_words: u64,
    pub map_secs: f64,
    pub group_secs: f64,
    pub wall_secs: f64,
    pub index_path: PathBuf,
}

// ---------------------------------------------------------------------------
// Spill record codec: key_len u32 | key | split u32 | seq u64 | words u32 | example
// ---------------------------------------------------------------------------

fn encode_spill(key: &[u8], split: u32, seq: u64, words: u32, example: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(20 + key.len() + example.len());
    out.extend_from_slice(&(key.len() as u32).to_le_bytes());
    out.extend_from_slice(key);
    out.extend_from_slice(&split.to_le_bytes());
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&words.to_le_bytes());
    out.extend_from_slice(example);
    out
}

/// Decoded spill record view (owned; used during sort/merge).
#[derive(Debug, Clone, PartialEq, Eq)]
struct SpillRec {
    key: Vec<u8>,
    split: u32,
    seq: u64,
    words: u32,
    example: Vec<u8>,
}

impl SpillRec {
    fn decode(b: &[u8]) -> io::Result<SpillRec> {
        if b.len() < 4 {
            return Err(bad("spill: short"));
        }
        let klen = u32::from_le_bytes(b[..4].try_into().unwrap()) as usize;
        let need = 4 + klen + 4 + 8 + 4;
        if b.len() < need {
            return Err(bad("spill: truncated"));
        }
        let key = b[4..4 + klen].to_vec();
        let mut p = 4 + klen;
        let split = u32::from_le_bytes(b[p..p + 4].try_into().unwrap());
        p += 4;
        let seq = u64::from_le_bytes(b[p..p + 8].try_into().unwrap());
        p += 8;
        let words = u32::from_le_bytes(b[p..p + 4].try_into().unwrap());
        p += 4;
        Ok(SpillRec { key, split, seq, words, example: b[p..].to_vec() })
    }

    fn encode(&self) -> Vec<u8> {
        encode_spill(&self.key, self.split, self.seq, self.words, &self.example)
    }

    fn order_key(&self) -> (&[u8], u32, u64) {
        (&self.key, self.split, self.seq)
    }

    fn payload_bytes(&self) -> usize {
        self.key.len() + self.example.len() + 16
    }
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

// ---------------------------------------------------------------------------
// Phase A: map + spill
// ---------------------------------------------------------------------------

struct MapStats {
    examples: AtomicU64,
    payload_bytes: AtomicU64,
}

fn map_phase(
    dataset: &dyn BaseDataset,
    partitioner: &dyn Partitioner,
    spill_dir: &Path,
    opts: &PartitionOptions,
    hash_seed: u64,
) -> Result<(u64, u64)> {
    std::fs::create_dir_all(spill_dir)?;
    let splits = dataset.splits(opts.num_workers);
    let stats = MapStats { examples: AtomicU64::new(0), payload_bytes: AtomicU64::new(0) };
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        for (split_id, split) in splits.into_iter().enumerate() {
            let stats = &stats;
            let errors = &errors;
            let spill_dir = spill_dir.to_path_buf();
            let num_shards = opts.num_shards;
            let count_words = opts.count_words;
            scope.spawn(move || {
                let run = || -> Result<()> {
                    let mut writers: Vec<Option<RecordWriter<io::BufWriter<std::fs::File>>>> =
                        (0..num_shards).map(|_| None).collect();
                    let mut seq: u64 = 0;
                    for example in split {
                        let key = partitioner.key(&example);
                        let bucket = shard_of_key(&key, hash_seed, num_shards);
                        let words = if count_words {
                            example.get_str("text").map(word_count).unwrap_or(0) as u32
                        } else {
                            0
                        };
                        let enc = example.encode();
                        stats.examples.fetch_add(1, Ordering::Relaxed);
                        stats
                            .payload_bytes
                            .fetch_add(enc.len() as u64, Ordering::Relaxed);
                        let w = match &mut writers[bucket] {
                            Some(w) => w,
                            slot => {
                                let path = spill_dir
                                    .join(format!("map-{split_id:04}-bucket-{bucket:05}.spill"));
                                *slot = Some(RecordWriter::create(path)?);
                                slot.as_mut().unwrap()
                            }
                        };
                        w.write_record(&encode_spill(&key, split_id as u32, seq, words, &enc))?;
                        seq += 1;
                    }
                    for w in writers.iter_mut().flatten() {
                        w.flush()?;
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    errors.lock().unwrap().push(format!("split {split_id}: {e:#}"));
                }
            });
        }
    });

    let errs = errors.into_inner().unwrap();
    if !errs.is_empty() {
        anyhow::bail!("map phase failed: {}", errs.join("; "));
    }
    Ok((
        stats.examples.load(Ordering::Relaxed),
        stats.payload_bytes.load(Ordering::Relaxed),
    ))
}

// ---------------------------------------------------------------------------
// Phase B: per-bucket external group-by-key
// ---------------------------------------------------------------------------

/// Cursor over a sorted run file for the k-way merge.
struct RunCursor {
    reader: RecordReader<io::BufReader<std::fs::File>>,
    current: SpillRec,
}

impl RunCursor {
    fn open(path: &Path) -> Result<Option<RunCursor>> {
        let mut reader = RecordReader::open(path)?;
        match reader.next_record()? {
            None => Ok(None),
            Some(b) => Ok(Some(RunCursor { reader, current: SpillRec::decode(&b)? })),
        }
    }

    fn advance(&mut self) -> Result<Option<SpillRec>> {
        let next = match self.reader.next_record()? {
            None => None,
            Some(b) => Some(std::mem::replace(&mut self.current, SpillRec::decode(&b)?)),
        };
        Ok(next)
    }
}

// BinaryHeap is a max-heap; reverse the ordering for a min-merge.
struct HeapItem {
    rec: SpillRec,
    run: usize,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.rec.order_key() == other.rec.order_key()
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.rec.order_key().cmp(&self.rec.order_key())
    }
}

struct BucketOutput {
    entries: Vec<GroupIndexEntry>,
}

/// Stream bucket `bucket`'s spill records into `emit` in
/// `(key, split, seq)` order, holding at most `chunk_bytes` of payload
/// in RAM — the disk-backed external group-by-key both sinks (TFRecord
/// shards and paged shard stores) are built on. Sorted runs are written
/// next to the spills and removed before returning.
fn merge_bucket(
    bucket: usize,
    spill_dir: &Path,
    chunk_bytes: usize,
    emit: &mut dyn FnMut(SpillRec) -> Result<()>,
) -> Result<()> {
    // 1. Collect this bucket's spill files.
    let mut spill_files: Vec<PathBuf> = Vec::new();
    for entry in std::fs::read_dir(spill_dir)? {
        let p = entry?.path();
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        if name.starts_with("map-") && name.ends_with(&format!("-bucket-{bucket:05}.spill")) {
            spill_files.push(p);
        }
    }
    spill_files.sort();

    // 2. Sorted runs under the chunk budget.
    let mut runs: Vec<PathBuf> = Vec::new();
    let mut chunk: Vec<SpillRec> = Vec::new();
    let mut chunk_size = 0usize;
    let flush_chunk = |chunk: &mut Vec<SpillRec>, runs: &mut Vec<PathBuf>| -> Result<()> {
        if chunk.is_empty() {
            return Ok(());
        }
        chunk.sort_by(|a, b| a.order_key().cmp(&b.order_key()));
        let run_path = spill_dir.join(format!("run-{bucket:05}-{:04}.spill", runs.len()));
        let mut w = RecordWriter::create(&run_path)?;
        for r in chunk.iter() {
            w.write_record(&r.encode())?;
        }
        w.flush()?;
        runs.push(run_path);
        chunk.clear();
        Ok(())
    };

    let mut buf = Vec::new();
    for f in &spill_files {
        let mut reader = RecordReader::open(f)?;
        while reader.read_into(&mut buf)? {
            let rec = SpillRec::decode(&buf)?;
            chunk_size += rec.payload_bytes();
            chunk.push(rec);
            if chunk_size >= chunk_bytes {
                flush_chunk(&mut chunk, &mut runs)?;
                chunk_size = 0;
            }
        }
    }

    if runs.is_empty() {
        // Everything fit in one chunk: sort in memory and stream out.
        chunk.sort_by(|a, b| a.order_key().cmp(&b.order_key()));
        for rec in chunk.drain(..) {
            emit(rec)?;
        }
    } else {
        // Flush the tail chunk, then k-way merge all runs.
        flush_chunk(&mut chunk, &mut runs)?;
        let mut heap: BinaryHeap<HeapItem> = BinaryHeap::new();
        let mut cursors: Vec<Option<RunCursor>> = Vec::new();
        for p in &runs {
            let c = RunCursor::open(p)?;
            if let Some(c) = c {
                heap.push(HeapItem { rec: c.current.clone(), run: cursors.len() });
                cursors.push(Some(c));
            }
        }
        while let Some(HeapItem { run, .. }) = heap.pop() {
            let cur = cursors[run].as_mut().unwrap();
            match cur.advance()? {
                Some(prev) => {
                    heap.push(HeapItem { rec: cur.current.clone(), run });
                    emit(prev)?;
                }
                None => {
                    let last = cursors[run].take().unwrap().current;
                    emit(last)?;
                }
            }
        }
    }

    for p in runs {
        std::fs::remove_file(p).ok();
    }
    Ok(())
}

fn group_bucket(
    bucket: usize,
    spill_dir: &Path,
    out_dir: &Path,
    prefix: &str,
    num_shards: usize,
    chunk_bytes: usize,
) -> Result<BucketOutput> {
    // Output shard writer (always created so the shard set is complete).
    let shard_path = out_dir.join(shard_name(prefix, bucket, num_shards));
    let mut out = RecordWriter::create(&shard_path)?;
    let mut entries: Vec<GroupIndexEntry> = Vec::new();

    struct GroupAcc {
        key: Vec<u8>,
        offset: u64,
        count: u64,
        bytes: u64,
        words: u64,
    }
    let mut acc: Option<GroupAcc> = None;
    merge_bucket(bucket, spill_dir, chunk_bytes, &mut |rec| {
        let start = out.bytes_written();
        match &mut acc {
            Some(a) if a.key == rec.key => {
                a.count += 1;
                a.bytes += framed_len(rec.example.len());
                a.words += rec.words as u64;
            }
            _ => {
                if let Some(a) = acc.take() {
                    entries.push(GroupIndexEntry {
                        key: a.key,
                        shard: bucket as u32,
                        offset: a.offset,
                        num_examples: a.count,
                        bytes: a.bytes,
                        words: a.words,
                    });
                }
                acc = Some(GroupAcc {
                    key: rec.key.clone(),
                    offset: start,
                    count: 1,
                    bytes: framed_len(rec.example.len()),
                    words: rec.words as u64,
                });
            }
        }
        out.write_record(&rec.example)?;
        Ok(())
    })?;

    if let Some(a) = acc.take() {
        entries.push(GroupIndexEntry {
            key: a.key,
            shard: bucket as u32,
            offset: a.offset,
            num_examples: a.count,
            bytes: a.bytes,
            words: a.words,
        });
    }
    out.flush()?;
    Ok(BucketOutput { entries })
}

/// Bucket sink for the direct-to-paged path: append the merged stream
/// straight into this bucket's shard store (already-encoded bytes, no
/// decode/re-encode), checkpointing whenever the WAL passes the same
/// budget [`PagedStore::build`] uses so recovery cost stays bounded.
/// Ends with commit + checkpoint, leaving the shard cold (WAL empty).
fn paged_bucket(
    bucket: usize,
    spill_dir: &Path,
    store: &mut PagedStore,
    chunk_bytes: usize,
) -> Result<u64> {
    let mut appended = 0u64;
    merge_bucket(bucket, spill_dir, chunk_bytes, &mut |rec| {
        store.append_encoded(&rec.key, &rec.example)?;
        appended += 1;
        if store.wal_len_bytes() >= BUILD_CHECKPOINT_WAL_BYTES {
            store.checkpoint()?;
        }
        Ok(())
    })?;
    store.commit()?;
    store.checkpoint()?;
    Ok(appended)
}

// ---------------------------------------------------------------------------
// Entry point
// ---------------------------------------------------------------------------

/// Partition `dataset` with `partitioner` into
/// `out_dir/<prefix>-*.tfrecord` + `out_dir/<prefix>.gindex`.
pub fn run_partition(
    dataset: &dyn BaseDataset,
    partitioner: &dyn Partitioner,
    out_dir: &Path,
    prefix: &str,
    opts: &PartitionOptions,
) -> Result<PartitionReport> {
    assert!(opts.num_shards > 0 && opts.num_workers > 0);
    let wall = Timer::start();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let spill_dir = out_dir.join(format!(".spill-{prefix}"));
    if spill_dir.exists() {
        std::fs::remove_dir_all(&spill_dir)?;
    }

    let map_t = Timer::start();
    let (num_examples, payload_bytes) = map_phase(dataset, partitioner, &spill_dir, opts, 0)?;
    let map_secs = map_t.elapsed_secs();

    let group_t = Timer::start();
    let pool = ThreadPool::new(opts.num_workers.min(opts.num_shards));
    let results: Vec<Result<BucketOutput>> = {
        let spill_dir = spill_dir.clone();
        let out_dir = out_dir.to_path_buf();
        let prefix = prefix.to_string();
        let num_shards = opts.num_shards;
        let chunk = opts.spill_chunk_bytes;
        pool.map((0..opts.num_shards).collect(), move |b| {
            group_bucket(b, &spill_dir, &out_dir, &prefix, num_shards, chunk)
        })
    };
    let group_secs = group_t.elapsed_secs();

    let mut index = GroupIndex::default();
    for r in results {
        index.entries.extend(r?.entries);
    }
    index.sort_physical();
    let index_path = out_dir.join(format!("{prefix}.gindex"));
    index.write(&index_path)?;

    std::fs::remove_dir_all(&spill_dir).ok();

    Ok(PartitionReport {
        num_examples,
        num_groups: index.num_groups() as u64,
        total_payload_bytes: payload_bytes,
        total_words: index.total_words(),
        map_secs,
        group_secs,
        wall_secs: wall.elapsed_secs(),
        index_path,
    })
}

/// Knobs specific to `--format paged` materialization.
///
/// Superseded by [`SinkOptions::Paged`] inside a [`PartitionRequest`];
/// kept as a direct parameter of [`run_partition_paged`] for one more
/// release.
#[derive(Debug, Clone)]
pub struct PagedPartitionOptions {
    /// Shard stores to hash groups across (1 = the classic single
    /// store, byte-identical to [`PagedStore::build`]).
    pub shards: usize,
    /// LRU frames **per shard store** while building.
    pub cache_pages: usize,
    /// Placement seed for [`shard_of_key`] (0 = plain FNV-1a).
    pub hash_seed: u64,
}

impl Default for PagedPartitionOptions {
    fn default() -> Self {
        PagedPartitionOptions { shards: 1, cache_pages: DEFAULT_CACHE_PAGES, hash_seed: 0 }
    }
}

/// Summary of a completed [`run_partition_paged`] run.
#[derive(Debug, Clone)]
pub struct PagedPartitionReport {
    pub num_examples: u64,
    pub num_groups: u64,
    pub shards: usize,
    /// Map+spill seconds (0 on the single-shard path, which appends in
    /// arrival order with no spill at all).
    pub map_secs: f64,
    /// Group-by-key + shard-append seconds.
    pub group_secs: f64,
    pub wall_secs: f64,
    /// The `.pset` manifest describing the materialized set.
    pub manifest_path: PathBuf,
    /// Final page accounting per shard, in shard order — saves callers
    /// (the CLI's `--auto-compact-threshold` check) a full set reopen
    /// just to read numbers the build already had in hand.
    pub shard_stats: Vec<PagedStat>,
}

/// Materialize `dataset` as a **sharded paged set**: hash-shard group
/// keys across `paged.shards` independent `PagedStore`s, written
/// concurrently by the group-by-key bucket writers — when the output
/// format is paged there is no intermediate TFRecord pass, the merged
/// bucket streams append straight into the shard WALs.
///
/// With `paged.shards == 1` this delegates to [`PagedStore::build`]
/// (arrival-order appends, no spill), so the produced `<prefix>.pstore`
/// is byte-identical to the unsharded path — plus a one-shard `.pset`
/// manifest so the same [`crate::formats::ShardedPagedReader`] opens
/// either layout. Per-group contents are identical at every shard count:
/// the merge orders a group's examples by `(split, seq)`, which is
/// arrival order (dataset splits are contiguous, in order).
///
/// # Errors
/// Any map/spill/merge I/O failure, any shard store append/checkpoint
/// failure, or a mapped-vs-stored example count mismatch (which would
/// mean a bucket writer silently lost data).
pub fn run_partition_paged(
    dataset: &dyn BaseDataset,
    partitioner: &dyn Partitioner,
    out_dir: &Path,
    prefix: &str,
    opts: &PartitionOptions,
    paged: &PagedPartitionOptions,
) -> Result<PagedPartitionReport> {
    assert!(paged.shards > 0 && opts.num_workers > 0);
    let wall = Timer::start();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let manifest_path = PagedSetManifest::path(out_dir, prefix);

    if paged.shards == 1 {
        // The compatibility path: exactly PagedStore::build, so the
        // store bytes (and every crash-matrix invariant over them) are
        // those of an unsharded materialization. A previous multi-shard
        // set in the same dir/prefix still gets its stale stores
        // reclaimed — captured before the manifest overwrite, truncated
        // only after the new store and manifest are durable (a crash in
        // between leaks the old bytes rather than losing them).
        let keep = [prefix.to_string()];
        let stale = stale_shard_stores(&StdVfs, out_dir, prefix, &keep);
        // Building in place destroys any same-named previous store:
        // refuse while a live reader pins its snapshot, and unpublish an
        // old manifest naming it first — a crash mid-build must not
        // leave a manifest pointing at wreckage.
        let pstore = out_dir.join(format!("{prefix}.pstore"));
        if crate::store::shared::pin_count(
            StdVfs.instance_id(),
            &StdVfs.registry_key(&pstore),
        ) > 0
        {
            bail!(
                "cannot rebuild paged store {prefix}: a live reader still pins a snapshot \
                 of the store being overwritten"
            );
        }
        let unpublished = invalidate_overlapping_manifest(&StdVfs, out_dir, prefix, &keep)?;
        let group_t = Timer::start();
        let store =
            match PagedStore::build(dataset, partitioner, out_dir, prefix, paged.cache_pages) {
                Ok(store) => store,
                Err(e) => {
                    // Failed before destroying the old store? Republish
                    // its manifest so the old set stays discoverable.
                    if let Some(old) = &unpublished {
                        restore_manifest_if_intact(&StdVfs, out_dir, prefix, old);
                    }
                    return Err(e);
                }
            };
        let manifest = PagedSetManifest {
            hash_seed: paged.hash_seed,
            shard_prefixes: vec![prefix.to_string()],
            epochs: vec![store.epoch()],
        };
        manifest.write_with(&StdVfs, out_dir, prefix)?;
        // Still-pinned stale stores (a live reader of the previous
        // layout) are left for that reader's lifetime; this process
        // exit (or a later re-run) is the retry.
        let _still_pinned = truncate_shard_stores(&StdVfs, out_dir, &stale);
        return Ok(PagedPartitionReport {
            num_examples: store.num_examples(),
            num_groups: store.num_groups() as u64,
            shards: 1,
            map_secs: 0.0,
            group_secs: group_t.elapsed_secs(),
            wall_secs: wall.elapsed_secs(),
            manifest_path,
            shard_stats: vec![store.stat()],
        });
    }

    let spill_dir = out_dir.join(format!(".spill-{prefix}"));
    if spill_dir.exists() {
        std::fs::remove_dir_all(&spill_dir)?;
    }

    // Phase A: map + spill, bucketed by the *shard* placement hash, so a
    // bucket's merged stream is exactly one shard's contents. The paged
    // index keeps no word counts, so never pay the per-example text
    // scan here (the single-shard build path doesn't either).
    let map_opts =
        PartitionOptions { num_shards: paged.shards, count_words: false, ..opts.clone() };
    let map_t = Timer::start();
    let (num_examples, _payload_bytes) =
        match map_phase(dataset, partitioner, &spill_dir, &map_opts, paged.hash_seed) {
            Ok(mapped) => mapped,
            Err(e) => {
                std::fs::remove_dir_all(&spill_dir).ok();
                return Err(e);
            }
        };
    let map_secs = map_t.elapsed_secs();

    let group_t = Timer::start();
    let phase_b = paged_group_phase(out_dir, prefix, &spill_dir, opts, paged, num_examples);
    // The spill can hold roughly the whole dataset: clean it up on the
    // failure paths too, not just on success.
    std::fs::remove_dir_all(&spill_dir).ok();
    let (num_groups, shard_stats) = phase_b?;
    let group_secs = group_t.elapsed_secs();

    Ok(PagedPartitionReport {
        num_examples,
        num_groups,
        shards: paged.shards,
        map_secs,
        group_secs,
        wall_secs: wall.elapsed_secs(),
        manifest_path,
        shard_stats,
    })
}

/// Phase B of [`run_partition_paged`]: per-bucket external group-by-key,
/// appending straight into that bucket's shard store — S concurrent
/// writers, one WAL each (the single-live-writer contract holds per
/// shard). `num_workers` long-lived threads pop buckets from a shared
/// counter, so a skewed (heavy) bucket never barriers the rest: each
/// store sits behind its own mutex that is locked exactly once, by
/// whichever worker pops that bucket — `&mut`-per-shard exclusivity
/// without waves. Returns the distinct-group count across shards plus
/// the final per-shard page accounting.
fn paged_group_phase(
    out_dir: &Path,
    prefix: &str,
    spill_dir: &Path,
    opts: &PartitionOptions,
    paged: &PagedPartitionOptions,
    num_examples: u64,
) -> Result<(u64, Vec<PagedStat>)> {
    let mut set =
        PagedShardSet::create(out_dir, prefix, paged.shards, paged.cache_pages, paged.hash_seed)?;
    let chunk_bytes = opts.spill_chunk_bytes;
    let results: Vec<Result<u64>> =
        parallel_for_each_mut(set.shards_mut(), opts.num_workers, |bucket, store| {
            paged_bucket(bucket, spill_dir, store, chunk_bytes)
        });
    let errs: Vec<String> = results
        .iter()
        .enumerate()
        .filter_map(|(bucket, r)| r.as_ref().err().map(|e| format!("shard {bucket}: {e:#}")))
        .collect();
    if !errs.is_empty() {
        bail!("sharded paged materialization failed: {}", errs.join("; "));
    }
    // Integrity gate BEFORE publication: a set that lost examples must
    // never become discoverable, and must never cost the previous
    // layout its (still intact) data.
    if set.num_examples() != num_examples {
        bail!(
            "sharded materialization stored {} of {num_examples} mapped examples",
            set.num_examples()
        );
    }
    // Publish the per-shard epochs in the manifest — the set's first
    // (and only) publication on this path; only then is it durable
    // enough to reclaim a previous layout's stores.
    set.sync_manifest()?;
    set.reclaim_stale();
    Ok((set.num_groups() as u64, set.shard_stats()))
}

// ---------------------------------------------------------------------------
// Unified request surface
// ---------------------------------------------------------------------------

/// Where a partition run materializes to.
///
/// This is the sink half of [`PartitionRequest`], which unifies the
/// [`run_partition`] / [`run_partition_paged`] call pair behind one
/// surface: the map/group tuning knobs are shared, only the sink
/// differs.
#[derive(Debug, Clone)]
pub enum SinkOptions {
    /// Sharded TFRecords + a `.gindex` (the classic streaming layout).
    Streaming {
        /// Output shards == group-by-key buckets.
        num_shards: usize,
    },
    /// A sharded paged set (`.pstore` shards + a `.pset` manifest).
    Paged { shards: usize, cache_pages: usize, hash_seed: u64 },
}

/// One request describing a full partition run: shared map/group tuning
/// plus a [`SinkOptions`] choosing the output layout. Supersedes the
/// `(PartitionOptions, PagedPartitionOptions)` pair; those remain as the
/// internal tuning carrier and for callers not yet migrated, for one
/// release.
#[derive(Debug, Clone)]
pub struct PartitionRequest {
    /// Map workers (also the number of dataset splits requested).
    pub num_workers: usize,
    /// Max example payload bytes held in RAM while grouping one bucket.
    pub spill_chunk_bytes: usize,
    /// Count whitespace words of the `text` feature into the index
    /// (streaming sink only; the paged index keeps no word counts).
    pub count_words: bool,
    pub sink: SinkOptions,
}

impl Default for PartitionRequest {
    fn default() -> Self {
        let base = PartitionOptions::default();
        PartitionRequest {
            num_workers: base.num_workers,
            spill_chunk_bytes: base.spill_chunk_bytes,
            count_words: base.count_words,
            sink: SinkOptions::Streaming { num_shards: base.num_shards },
        }
    }
}

impl PartitionRequest {
    /// A request for the streaming TFRecord sink with `num_shards` shards.
    pub fn streaming(num_shards: usize) -> Self {
        PartitionRequest { sink: SinkOptions::Streaming { num_shards }, ..Default::default() }
    }

    /// A request for the paged sink with `shards` shard stores.
    pub fn paged(shards: usize, cache_pages: usize) -> Self {
        PartitionRequest {
            sink: SinkOptions::Paged { shards, cache_pages, hash_seed: 0 },
            ..Default::default()
        }
    }

    fn base_options(&self) -> PartitionOptions {
        PartitionOptions {
            num_workers: self.num_workers,
            num_shards: match self.sink {
                SinkOptions::Streaming { num_shards } => num_shards,
                // The paged path re-buckets by shard placement itself.
                SinkOptions::Paged { .. } => PartitionOptions::default().num_shards,
            },
            spill_chunk_bytes: self.spill_chunk_bytes,
            count_words: self.count_words,
        }
    }
}

/// Sink-specific half of a [`PartitionSummary`].
#[derive(Debug, Clone)]
pub enum SinkReport {
    Streaming { index_path: PathBuf, total_payload_bytes: u64, total_words: u64 },
    Paged { manifest_path: PathBuf, shards: usize, shard_stats: Vec<PagedStat> },
}

/// Summary of a completed [`run_partition_request`] run: the counters
/// every sink shares, plus the sink-specific artifacts.
#[derive(Debug, Clone)]
pub struct PartitionSummary {
    pub num_examples: u64,
    pub num_groups: u64,
    pub map_secs: f64,
    pub group_secs: f64,
    pub wall_secs: f64,
    pub sink: SinkReport,
}

/// Partition `dataset` with `partitioner` into `out_dir` under
/// `prefix`, through whichever sink `req.sink` selects. Delegates to
/// [`run_partition`] / [`run_partition_paged`], so behavior (including
/// crash-safety and byte-identical layouts) is exactly theirs.
pub fn run_partition_request(
    dataset: &dyn BaseDataset,
    partitioner: &dyn Partitioner,
    out_dir: &Path,
    prefix: &str,
    req: &PartitionRequest,
) -> Result<PartitionSummary> {
    let opts = req.base_options();
    match req.sink {
        SinkOptions::Streaming { .. } => {
            let r = run_partition(dataset, partitioner, out_dir, prefix, &opts)?;
            Ok(PartitionSummary {
                num_examples: r.num_examples,
                num_groups: r.num_groups,
                map_secs: r.map_secs,
                group_secs: r.group_secs,
                wall_secs: r.wall_secs,
                sink: SinkReport::Streaming {
                    index_path: r.index_path,
                    total_payload_bytes: r.total_payload_bytes,
                    total_words: r.total_words,
                },
            })
        }
        SinkOptions::Paged { shards, cache_pages, hash_seed } => {
            let paged = PagedPartitionOptions { shards, cache_pages, hash_seed };
            let r = run_partition_paged(dataset, partitioner, out_dir, prefix, &opts, &paged)?;
            Ok(PartitionSummary {
                num_examples: r.num_examples,
                num_groups: r.num_groups,
                map_secs: r.map_secs,
                group_secs: r.group_secs,
                wall_secs: r.wall_secs,
                sink: SinkReport::Paged {
                    manifest_path: r.manifest_path,
                    shards: r.shards,
                    shard_stats: r.shard_stats,
                },
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, GroupedCifarLike, SyntheticTextDataset};
    use crate::pipeline::partition::{FeatureKey, RandomPartitioner};
    use crate::records::Example;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("grouper_runner_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn small_text() -> SyntheticTextDataset {
        let mut spec = DatasetSpec::fedccnews_mini(30, 5);
        spec.max_group_words = 2000;
        SyntheticTextDataset::new(spec)
    }

    fn opts(shards: usize) -> PartitionOptions {
        PartitionOptions { num_workers: 4, num_shards: shards, ..Default::default() }
    }

    /// Oracle: group examples in memory with the same partitioner.
    fn oracle_groups(
        ds: &dyn crate::corpus::BaseDataset,
        p: &dyn Partitioner,
    ) -> std::collections::HashMap<Vec<u8>, Vec<Vec<u8>>> {
        let mut m: std::collections::HashMap<Vec<u8>, Vec<Vec<u8>>> = Default::default();
        for ex in ds.examples() {
            m.entry(p.key(&ex)).or_default().push(ex.encode());
        }
        m
    }

    fn read_materialized(
        dir: &Path,
        prefix: &str,
    ) -> std::collections::HashMap<Vec<u8>, Vec<Vec<u8>>> {
        let index = GroupIndex::read(dir.join(format!("{prefix}.gindex"))).unwrap();
        let mut m = std::collections::HashMap::new();
        for e in &index.entries {
            let shard = dir.join(shard_name(prefix, e.shard as usize, {
                // total shards from the shard files present
                std::fs::read_dir(dir)
                    .unwrap()
                    .filter(|f| {
                        f.as_ref()
                            .unwrap()
                            .file_name()
                            .to_string_lossy()
                            .ends_with(".tfrecord")
                    })
                    .count()
            }));
            let mut r = RecordReader::open(&shard).unwrap();
            r.seek_to(e.offset).unwrap();
            let mut examples = Vec::new();
            for _ in 0..e.num_examples {
                examples.push(r.next_record().unwrap().unwrap());
            }
            m.insert(e.key.clone(), examples);
        }
        m
    }

    #[test]
    fn partition_matches_in_memory_oracle() {
        let ds = small_text();
        let p = FeatureKey::new("domain");
        let dir = tmp("oracle");
        let report = run_partition(&ds, &p, &dir, "data", &opts(4)).unwrap();
        assert_eq!(report.num_examples as usize, ds.len());

        let oracle = oracle_groups(&ds, &p);
        let got = read_materialized(&dir, "data");
        assert_eq!(got.len(), oracle.len());
        for (k, want) in &oracle {
            let have = got.get(k).unwrap_or_else(|| panic!("missing group"));
            // Same multiset; within-group order is (split, seq), and with
            // group-range splits each group comes from one split, so the
            // order is exactly generation order.
            assert_eq!(have, want);
        }
    }

    #[test]
    fn paged_sharded_partition_matches_oracle() {
        let ds = small_text();
        let p = FeatureKey::new("domain");
        let dir = tmp("paged_sharded");
        let paged = PagedPartitionOptions { shards: 4, cache_pages: 32, hash_seed: 0 };
        let report = run_partition_paged(&ds, &p, &dir, "data", &opts(4), &paged).unwrap();
        assert_eq!(report.num_examples as usize, ds.len());
        assert_eq!(report.shards, 4);
        let r = crate::formats::ShardedPagedReader::open(&dir, "data", 32).unwrap();
        assert_eq!(r.num_examples() as usize, ds.len());
        let oracle = oracle_groups(&ds, &p);
        assert_eq!(r.num_groups(), oracle.len());
        for (k, want) in &oracle {
            let mut got = Vec::new();
            assert!(r.visit_group(k, |ex| got.push(ex.encode())).unwrap());
            assert_eq!(&got, want, "group {k:?}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn every_example_lands_in_exactly_one_group() {
        let ds = small_text();
        let p = RandomPartitioner::new(17, 3);
        let dir = tmp("coverage");
        let report = run_partition(&ds, &p, &dir, "data", &opts(3)).unwrap();
        let index = GroupIndex::read(&report.index_path).unwrap();
        assert_eq!(index.total_examples(), report.num_examples);
        assert_eq!(report.num_examples as usize, ds.len());
    }

    #[test]
    fn tiny_chunk_forces_external_sort_same_result() {
        let ds = small_text();
        let p = FeatureKey::new("domain");
        let dir_big = tmp("chunk_big");
        let dir_small = tmp("chunk_small");
        run_partition(&ds, &p, &dir_big, "data", &opts(2)).unwrap();
        let mut small = opts(2);
        small.spill_chunk_bytes = 1024; // forces many runs + merge
        run_partition(&ds, &p, &dir_small, "data", &small).unwrap();
        assert_eq!(
            read_materialized(&dir_big, "data"),
            read_materialized(&dir_small, "data")
        );
    }

    #[test]
    fn deterministic_across_runs_and_worker_counts() {
        let ds = small_text();
        let p = FeatureKey::new("domain");
        let dir1 = tmp("det1");
        let dir2 = tmp("det2");
        run_partition(&ds, &p, &dir1, "data", &opts(4)).unwrap();
        let mut o2 = opts(4);
        o2.num_workers = 1;
        run_partition(&ds, &p, &dir2, "data", &o2).unwrap();
        assert_eq!(read_materialized(&dir1, "data"), read_materialized(&dir2, "data"));
    }

    #[test]
    fn word_counts_match_dataset() {
        let ds = small_text();
        let p = FeatureKey::new("domain");
        let dir = tmp("words");
        let report = run_partition(&ds, &p, &dir, "data", &opts(2)).unwrap();
        let expected: u64 = (0..ds.spec.num_groups)
            .map(|g| ds.spec.group_words(g) as u64)
            .sum();
        assert_eq!(report.total_words, expected);
    }

    #[test]
    fn groups_are_contiguous_extents() {
        let ds = small_text();
        let p = FeatureKey::new("domain");
        let dir = tmp("contig");
        let report = run_partition(&ds, &p, &dir, "data", &opts(2)).unwrap();
        let mut index = GroupIndex::read(&report.index_path).unwrap();
        index.sort_physical();
        let mut next_offset: std::collections::HashMap<u32, u64> = Default::default();
        for e in &index.entries {
            let off = next_offset.entry(e.shard).or_insert(0);
            assert_eq!(e.offset, *off, "gap before group in shard {}", e.shard);
            *off += e.bytes;
        }
    }

    #[test]
    fn cifar_partition_by_label() {
        let ds = GroupedCifarLike { num_groups: 10, examples_per_group: 8, height: 8, width: 8, channels: 1, seed: 1 };
        let p = FeatureKey::new("label");
        let dir = tmp("cifar");
        let mut o = opts(4);
        o.count_words = false;
        let report = run_partition(&ds, &p, &dir, "data", &o).unwrap();
        assert_eq!(report.num_groups, 10);
        assert_eq!(report.num_examples, 80);
        assert_eq!(report.total_words, 0);
        let got = read_materialized(&dir, "data");
        for (_k, v) in got {
            assert_eq!(v.len(), 8);
        }
    }

    #[test]
    fn empty_dataset_produces_empty_index_and_full_shard_set() {
        struct Empty;
        impl crate::corpus::BaseDataset for Empty {
            fn name(&self) -> &str {
                "empty"
            }
            fn examples(&self) -> Box<dyn Iterator<Item = Example> + Send> {
                Box::new(std::iter::empty())
            }
            fn len(&self) -> usize {
                0
            }
        }
        let dir = tmp("empty");
        let report = run_partition(&Empty, &FeatureKey::new("x"), &dir, "data", &opts(3)).unwrap();
        assert_eq!(report.num_groups, 0);
        let shards = crate::records::sharded::discover_shards(&dir, "data").unwrap();
        assert_eq!(shards.len(), 3);
    }

    #[test]
    fn unified_request_matches_legacy_paths() {
        let ds = small_text();
        let p = FeatureKey::new("domain");

        // Streaming sink == run_partition.
        let dir_old = tmp("req_stream_old");
        let dir_new = tmp("req_stream_new");
        let old = run_partition(&ds, &p, &dir_old, "data", &opts(4)).unwrap();
        let req = PartitionRequest {
            num_workers: 4,
            sink: SinkOptions::Streaming { num_shards: 4 },
            ..Default::default()
        };
        let new = run_partition_request(&ds, &p, &dir_new, "data", &req).unwrap();
        assert_eq!(new.num_examples, old.num_examples);
        assert_eq!(new.num_groups, old.num_groups);
        match &new.sink {
            SinkReport::Streaming { total_words, total_payload_bytes, .. } => {
                assert_eq!(*total_words, old.total_words);
                assert_eq!(*total_payload_bytes, old.total_payload_bytes);
            }
            other => panic!("expected streaming report, got {other:?}"),
        }
        assert_eq!(read_materialized(&dir_old, "data"), read_materialized(&dir_new, "data"));

        // Paged sink == run_partition_paged (same groups via the reader).
        let dir_paged = tmp("req_paged");
        let mut req = PartitionRequest::paged(2, 32);
        req.num_workers = 4;
        let summary = run_partition_request(&ds, &p, &dir_paged, "data", &req).unwrap();
        assert_eq!(summary.num_examples as usize, ds.len());
        match &summary.sink {
            SinkReport::Paged { shards, .. } => assert_eq!(*shards, 2),
            other => panic!("expected paged report, got {other:?}"),
        }
        let r = crate::formats::ShardedPagedReader::open(&dir_paged, "data", 32).unwrap();
        let oracle = oracle_groups(&ds, &p);
        assert_eq!(r.num_groups(), oracle.len());
        for (k, want) in &oracle {
            let mut got = Vec::new();
            assert!(r.visit_group(k, |ex| got.push(ex.encode())).unwrap());
            assert_eq!(&got, want);
        }
    }

    #[test]
    fn spill_rec_roundtrip() {
        let r = SpillRec {
            key: b"key".to_vec(),
            split: 7,
            seq: 99,
            words: 12,
            example: b"payload".to_vec(),
        };
        assert_eq!(SpillRec::decode(&r.encode()).unwrap(), r);
        assert!(SpillRec::decode(b"\x01").is_err());
        assert!(SpillRec::decode(&[5, 0, 0, 0, b'a']).is_err());
    }
}
