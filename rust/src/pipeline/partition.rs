//! User-defined partitioners: the `get_key_fn(example) -> group_id`
//! contract of the paper (Appendix A.1), plus the three canonical
//! implementations the paper ships as example scripts:
//!
//! * [`FeatureKey`] — partition by a feature's value (FedC4/FedCCnews use
//!   the URL's domain; Listing 1 uses the MNIST label);
//! * [`RandomPartitioner`] — uniform random assignment (the IID control);
//! * [`DirichletPartitioner`] — heterogeneous assignment via a truncated
//!   stick-breaking Dirichlet process, the embarrassingly-parallel
//!   version of the LDA-style partitioner popular in FL literature [71].
//!
//! All partitioners are stateless per example — the formal trade-off the
//! paper makes for scalability (§3.2): assignment of example `x` may not
//! depend on the assignment of example `y`.
//!
//! Beyond the three canonical implementations, this module carries the
//! scenario-suite partitioners (see `pipeline/scenario.rs`):
//!
//! * [`PathologicalPartitioner`] — the classic pathological non-IID
//!   split: each group sees only `classes_per_group` of the label space;
//! * [`TemporalPartitioner`] — one group per window of an integer
//!   time/sequence feature;
//! * [`ModmPartitioner`] — Mixtures of Dirichlet-Multinomials (Scott &
//!   Cahill, arXiv 2406.02416): [`ModmModel::fit`] fits mixture weights
//!   to an observed group-size/label histogram with deterministic EM,
//!   and the partitioner samples a synthetic population from the model,
//!   keeping only O(groups) state so millions-of-groups populations fit
//!   in memory.
//!
//! Construction goes through [`PartitionerSpec`]: `parse` (the CLI
//! `--by` grammar) → `validate` (typed [`SpecError`]s, never panics) →
//! `build() -> Box<dyn Partitioner>`.

use std::fmt;

use crate::records::{Example, Feature};
use crate::util::rng::{fnv1a, Rng};
use crate::util::special::ln_gamma;

/// An embarrassingly parallel partition function.
pub trait Partitioner: Send + Sync {
    /// The group key for one example. Must be a pure function of the
    /// example (and the partitioner's own immutable config).
    fn key(&self, example: &Example) -> Vec<u8>;

    /// Diagnostic name for reports.
    fn name(&self) -> String;
}

/// Partition by a feature's (first) value: domains, article ids, labels.
pub struct FeatureKey {
    pub feature: String,
}

impl FeatureKey {
    pub fn new(feature: &str) -> Self {
        FeatureKey { feature: feature.to_string() }
    }
}

impl Partitioner for FeatureKey {
    fn key(&self, example: &Example) -> Vec<u8> {
        match example.features.get(&self.feature) {
            Some(crate::records::Feature::Bytes(v)) if !v.is_empty() => v[0].clone(),
            Some(crate::records::Feature::Ints(v)) if !v.is_empty() => {
                format!("{}", v[0]).into_bytes()
            }
            Some(crate::records::Feature::Floats(v)) if !v.is_empty() => {
                format!("{}", v[0]).into_bytes()
            }
            _ => b"<missing>".to_vec(),
        }
    }

    fn name(&self) -> String {
        format!("feature:{}", self.feature)
    }
}

/// Uniform random assignment to `num_groups` groups, keyed off a stable
/// hash of the example content (so re-running the pipeline reproduces the
/// identical partition, and parallel workers agree without coordination).
pub struct RandomPartitioner {
    pub num_groups: usize,
    pub seed: u64,
}

impl RandomPartitioner {
    pub fn new(num_groups: usize, seed: u64) -> Self {
        assert!(num_groups > 0);
        RandomPartitioner { num_groups, seed }
    }
}

impl Partitioner for RandomPartitioner {
    fn key(&self, example: &Example) -> Vec<u8> {
        // content_hash64() is fnv1a over the canonical encoding, computed
        // incrementally — same digest as fnv1a(&example.encode()) (pinned
        // by a test below, so existing partitions never move) without
        // re-serializing the whole example just to hash it.
        let h = example.content_hash64() ^ self.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // SplitMix finalizer decorrelates the xor.
        let mut r = Rng::new(h);
        let g = r.gen_range(self.num_groups as u64);
        format!("rand-{g:06}").into_bytes()
    }

    fn name(&self) -> String {
        format!("random:{}", self.num_groups)
    }
}

/// Truncated stick-breaking Dirichlet process: group probabilities
/// `p_k = beta_k * prod_{j<k} (1 - beta_j)`, `beta ~ Beta(1, alpha)`,
/// truncated at `max_groups`. Each example samples its group from the
/// *fixed* categorical using its own content hash — stateless, parallel,
/// heavy-tailed like the sequential CRP.
pub struct DirichletPartitioner {
    cdf: Vec<f64>,
    pub alpha: f64,
    pub seed: u64,
}

impl DirichletPartitioner {
    /// Panicking convenience over [`DirichletPartitioner::try_new`] for
    /// call sites with statically-known-good parameters (tests, benches).
    /// Anything handling user input goes through [`PartitionerSpec`],
    /// which surfaces the typed error instead.
    pub fn new(alpha: f64, max_groups: usize, seed: u64) -> Self {
        Self::try_new(alpha, max_groups, seed).expect("invalid DirichletPartitioner parameters")
    }

    /// Validating constructor: rejects non-finite or non-positive
    /// `alpha` (NaN used to panic through an assert; zero/negative
    /// alpha would degenerate the stick-breaking draws) and a zero
    /// truncation with a typed [`SpecError`].
    pub fn try_new(alpha: f64, max_groups: usize, seed: u64) -> Result<Self, SpecError> {
        if !alpha.is_finite() || alpha <= 0.0 {
            return Err(SpecError::Invalid {
                field: "dirichlet.alpha",
                reason: format!("must be a finite positive number, got {alpha}"),
            });
        }
        if max_groups == 0 {
            return Err(SpecError::Invalid {
                field: "dirichlet.max_groups",
                reason: "must be at least 1".to_string(),
            });
        }
        let mut rng = Rng::new(seed ^ 0xD112_1C43);
        let mut remaining = 1.0f64;
        let mut cdf = Vec::with_capacity(max_groups);
        let mut acc = 0.0;
        for k in 0..max_groups {
            // Beta(1, alpha) sample: 1 - U^(1/alpha).
            let beta = if k + 1 == max_groups {
                1.0 // close the stick
            } else {
                1.0 - rng.next_f64().powf(1.0 / alpha)
            };
            let p = beta * remaining;
            remaining -= p;
            acc += p;
            cdf.push(acc);
        }
        Ok(DirichletPartitioner { cdf, alpha, seed })
    }

    pub fn max_groups(&self) -> usize {
        self.cdf.len()
    }
}

impl Partitioner for DirichletPartitioner {
    fn key(&self, example: &Example) -> Vec<u8> {
        // Incremental hash, same digest as fnv1a(&example.encode()) —
        // see RandomPartitioner::key.
        let h = example.content_hash64() ^ self.seed.rotate_left(17);
        let u = Rng::new(h).next_f64();
        let g = match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        };
        format!("dp-{g:06}").into_bytes()
    }

    fn name(&self) -> String {
        format!("dirichlet:alpha={}", self.alpha)
    }
}

/// The CLI default truncation for `dirichlet:ALPHA` specs that don't
/// spell out a max group count (formerly a magic number buried in
/// `main.rs`'s string parser).
pub const DEFAULT_DIRICHLET_MAX_GROUPS: usize = 10_000;

/// The default seed [`PartitionerSpec`]'s `FromStr` uses — the same
/// default the CLI `--seed` flag documents.
pub const DEFAULT_SEED: u64 = 42;

/// A typed error from parsing, validating, or building a partitioner
/// spec. Malformed spec strings and out-of-domain parameters surface
/// here instead of panicking mid-pipeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpecError {
    /// The spec string does not match the `--by` grammar.
    Malformed { spec: String, reason: String },
    /// The spec parsed, but a parameter is out of its valid domain.
    Invalid { field: &'static str, reason: String },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::Malformed { spec, reason } => {
                write!(f, "malformed partitioner spec {spec:?}: {reason}")
            }
            SpecError::Invalid { field, reason } => {
                write!(f, "invalid partitioner spec: {field} {reason}")
            }
        }
    }
}

impl std::error::Error for SpecError {}

/// A typed, validated description of a partitioner — the one way every
/// caller (CLI `--by`, scenario registry, benches, tests) constructs
/// partitioners. `parse` → [`validate`](Self::validate) →
/// [`build`](Self::build).
///
/// The `--by` grammar (also accepted by `FromStr`):
///
/// ```text
/// feature[:NAME]                      partition by a feature's value
/// random:N                            uniform over N groups (IID control)
/// dirichlet:ALPHA[:MAX_GROUPS]        stick-breaking DP (default trunc 10000)
/// pathological:GROUPS:CLASSES[:LABELS] each group sees CLASSES of LABELS
/// temporal:PERIOD[:FEATURE]           one group per window of an int feature
/// ```
///
/// MoDM specs carry a full mixture model and come from the scenario
/// registry (TOML or [`ModmModel::fit`]), not from the inline grammar.
#[derive(Debug, Clone, PartialEq)]
pub enum PartitionerSpec {
    /// [`FeatureKey`]: group by a feature's first value.
    Feature { feature: String },
    /// [`RandomPartitioner`]: uniform over `num_groups`.
    Random { num_groups: usize, seed: u64 },
    /// [`DirichletPartitioner`]: truncated stick-breaking DP.
    Dirichlet { alpha: f64, max_groups: usize, seed: u64 },
    /// [`PathologicalPartitioner`]: label-restricted non-IID groups.
    Pathological {
        num_groups: usize,
        classes_per_group: usize,
        num_labels: usize,
        label_feature: String,
        seed: u64,
    },
    /// [`TemporalPartitioner`]: windows of an integer time feature.
    Temporal { feature: String, period: u64 },
    /// [`ModmPartitioner`]: a fitted/declared Dirichlet-multinomial
    /// mixture sampled into a synthetic population.
    Modm(ModmSpec),
}

impl PartitionerSpec {
    /// Parse the `--by` grammar. `default_feature` fills the bare
    /// `feature` form (the dataset's key feature); `default_seed` seeds
    /// the stochastic partitioners.
    pub fn parse(
        spec: &str,
        default_feature: &str,
        default_seed: u64,
    ) -> Result<Self, SpecError> {
        let malformed = |reason: String| SpecError::Malformed { spec: spec.to_string(), reason };
        let parts: Vec<&str> = spec.split(':').collect();
        match parts.as_slice() {
            ["feature"] => {
                if default_feature.is_empty() {
                    return Err(malformed(
                        "bare `feature` needs a dataset key feature; spell it feature:NAME"
                            .to_string(),
                    ));
                }
                Ok(PartitionerSpec::Feature { feature: default_feature.to_string() })
            }
            ["feature", name] if !name.is_empty() => {
                Ok(PartitionerSpec::Feature { feature: name.to_string() })
            }
            ["random", n] => Ok(PartitionerSpec::Random {
                num_groups: parse_field(spec, "group count", n)?,
                seed: default_seed,
            }),
            ["dirichlet", a] => Ok(PartitionerSpec::Dirichlet {
                alpha: parse_field(spec, "alpha", a)?,
                max_groups: DEFAULT_DIRICHLET_MAX_GROUPS,
                seed: default_seed,
            }),
            ["dirichlet", a, g] => Ok(PartitionerSpec::Dirichlet {
                alpha: parse_field(spec, "alpha", a)?,
                max_groups: parse_field(spec, "max group count", g)?,
                seed: default_seed,
            }),
            ["pathological", g, k] => Ok(PartitionerSpec::Pathological {
                num_groups: parse_field(spec, "group count", g)?,
                classes_per_group: parse_field(spec, "classes per group", k)?,
                num_labels: 10,
                label_feature: "label".to_string(),
                seed: default_seed,
            }),
            ["pathological", g, k, l] => Ok(PartitionerSpec::Pathological {
                num_groups: parse_field(spec, "group count", g)?,
                classes_per_group: parse_field(spec, "classes per group", k)?,
                num_labels: parse_field(spec, "label count", l)?,
                label_feature: "label".to_string(),
                seed: default_seed,
            }),
            ["temporal", p] => Ok(PartitionerSpec::Temporal {
                feature: "example_index".to_string(),
                period: parse_field(spec, "period", p)?,
            }),
            ["temporal", p, feat] if !feat.is_empty() => Ok(PartitionerSpec::Temporal {
                feature: feat.to_string(),
                period: parse_field(spec, "period", p)?,
            }),
            _ => Err(malformed(format!(
                "unknown form {:?}; expected feature[:NAME] | random:N | \
                 dirichlet:ALPHA[:MAX_GROUPS] | pathological:GROUPS:CLASSES[:LABELS] | \
                 temporal:PERIOD[:FEATURE]",
                parts[0]
            ))),
        }
    }

    /// Check every parameter's domain. [`build`](Self::build) calls this,
    /// so malformed requests fail with a typed error before any work.
    pub fn validate(&self) -> Result<(), SpecError> {
        fn invalid(field: &'static str, reason: String) -> Result<(), SpecError> {
            Err(SpecError::Invalid { field, reason })
        }
        match self {
            PartitionerSpec::Feature { feature } => {
                if feature.is_empty() {
                    return invalid("feature", "name must be non-empty".to_string());
                }
            }
            PartitionerSpec::Random { num_groups, .. } => {
                if *num_groups == 0 {
                    return invalid("random.num_groups", "must be at least 1".to_string());
                }
            }
            PartitionerSpec::Dirichlet { alpha, max_groups, .. } => {
                if !alpha.is_finite() || *alpha <= 0.0 {
                    return invalid(
                        "dirichlet.alpha",
                        format!("must be a finite positive number, got {alpha}"),
                    );
                }
                if *max_groups == 0 {
                    return invalid("dirichlet.max_groups", "must be at least 1".to_string());
                }
            }
            PartitionerSpec::Pathological {
                num_groups,
                classes_per_group,
                num_labels,
                label_feature,
                ..
            } => {
                if *num_groups == 0 {
                    return invalid("pathological.num_groups", "must be at least 1".to_string());
                }
                if *num_labels == 0 {
                    return invalid("pathological.num_labels", "must be at least 1".to_string());
                }
                if *classes_per_group == 0 || classes_per_group > num_labels {
                    return invalid(
                        "pathological.classes_per_group",
                        format!("must be in 1..={num_labels}, got {classes_per_group}"),
                    );
                }
                if label_feature.is_empty() {
                    return invalid(
                        "pathological.label_feature",
                        "name must be non-empty".to_string(),
                    );
                }
            }
            PartitionerSpec::Temporal { feature, period } => {
                if feature.is_empty() {
                    return invalid("temporal.feature", "name must be non-empty".to_string());
                }
                if *period == 0 {
                    return invalid("temporal.period", "must be at least 1".to_string());
                }
            }
            PartitionerSpec::Modm(spec) => spec.validate()?,
        }
        Ok(())
    }

    /// Validate, then construct the partitioner.
    pub fn build(&self) -> Result<Box<dyn Partitioner>, SpecError> {
        self.validate()?;
        Ok(match self {
            PartitionerSpec::Feature { feature } => Box::new(FeatureKey::new(feature)),
            PartitionerSpec::Random { num_groups, seed } => {
                Box::new(RandomPartitioner::new(*num_groups, *seed))
            }
            PartitionerSpec::Dirichlet { alpha, max_groups, seed } => {
                Box::new(DirichletPartitioner::try_new(*alpha, *max_groups, *seed)?)
            }
            PartitionerSpec::Pathological {
                num_groups,
                classes_per_group,
                num_labels,
                label_feature,
                seed,
            } => Box::new(PathologicalPartitioner::new(
                *num_groups,
                *classes_per_group,
                *num_labels,
                label_feature,
                *seed,
            )?),
            PartitionerSpec::Temporal { feature, period } => {
                Box::new(TemporalPartitioner::new(feature, *period))
            }
            PartitionerSpec::Modm(spec) => Box::new(ModmPartitioner::from_spec(spec)?),
        })
    }

    /// The label feature + class count this spec's heterogeneity should
    /// be characterized against, when it models labels at all.
    pub fn label_feature(&self) -> Option<(&str, usize)> {
        match self {
            PartitionerSpec::Pathological { label_feature, num_labels, .. } => {
                Some((label_feature.as_str(), *num_labels))
            }
            PartitionerSpec::Modm(spec) if spec.model.num_labels() > 0 => {
                spec.label_feature.as_deref().map(|f| (f, spec.model.num_labels()))
            }
            _ => None,
        }
    }
}

impl fmt::Display for PartitionerSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PartitionerSpec::Feature { feature } => write!(f, "feature:{feature}"),
            PartitionerSpec::Random { num_groups, .. } => write!(f, "random:{num_groups}"),
            PartitionerSpec::Dirichlet { alpha, max_groups, .. } => {
                write!(f, "dirichlet:{alpha}:{max_groups}")
            }
            PartitionerSpec::Pathological {
                num_groups, classes_per_group, num_labels, ..
            } => write!(f, "pathological:{num_groups}:{classes_per_group}:{num_labels}"),
            PartitionerSpec::Temporal { feature, period } => {
                write!(f, "temporal:{period}:{feature}")
            }
            PartitionerSpec::Modm(spec) => {
                write!(f, "modm:{}g/{}c", spec.num_groups, spec.model.components.len())
            }
        }
    }
}

impl std::str::FromStr for PartitionerSpec {
    type Err = SpecError;

    /// The thin CLI-facing entry: the `--by` grammar with no dataset
    /// context (bare `feature` is malformed here) and the documented
    /// default seed.
    fn from_str(s: &str) -> Result<Self, SpecError> {
        Self::parse(s, "", DEFAULT_SEED)
    }
}

fn parse_field<T: std::str::FromStr>(
    spec: &str,
    what: &str,
    value: &str,
) -> Result<T, SpecError> {
    value.parse().map_err(|_| SpecError::Malformed {
        spec: spec.to_string(),
        reason: format!("{what} {value:?} is not a number"),
    })
}

/// An example's label class in `[0, num_labels)`: the first value of
/// `feature`, reduced mod `num_labels` (int values directly; byte/float
/// values through a stable hash). Examples without the feature get a
/// deterministic pseudo-label from the content hash, so label-driven
/// scenarios stay runnable on unlabeled corpora — documented in the
/// scenario docs rather than silently collapsing to one class.
pub fn label_of(example: &Example, feature: &str, num_labels: usize) -> usize {
    assert!(num_labels > 0, "label_of with zero classes");
    let n = num_labels as u64;
    match example.features.get(feature) {
        Some(Feature::Ints(v)) if !v.is_empty() => v[0].rem_euclid(num_labels as i64) as usize,
        Some(Feature::Bytes(v)) if !v.is_empty() => (fnv1a(&v[0]) % n) as usize,
        Some(Feature::Floats(v)) if !v.is_empty() => {
            (fnv1a(format!("{}", v[0]).as_bytes()) % n) as usize
        }
        _ => (example.content_hash64() % n) as usize,
    }
}

/// Binary-search a cumulative distribution for `u` (same convention as
/// the Dirichlet partitioner's stick CDF).
fn search_cdf(cdf: &[f64], u: f64) -> usize {
    match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
        Ok(i) => i,
        Err(i) => i.min(cdf.len() - 1),
    }
}

const PATH_SALT: u64 = 0x7061_7468_6F67_656E; // "pathogen"

/// Pathological non-IID assignment (the McMahan et al. FedAvg split,
/// LEAF's "pathological" scenario): each of `num_groups` groups is
/// assigned `classes_per_group` of the `num_labels` label classes at
/// construction, and every example routes — via its content hash — to a
/// uniformly random group among those that carry its label.
pub struct PathologicalPartitioner {
    num_groups: usize,
    classes_per_group: usize,
    num_labels: usize,
    label_feature: String,
    seed: u64,
    /// label class -> groups carrying it (never empty: classes no group
    /// drew are backfilled deterministically so every label routes).
    label_groups: Vec<Vec<u32>>,
}

impl PathologicalPartitioner {
    pub fn new(
        num_groups: usize,
        classes_per_group: usize,
        num_labels: usize,
        label_feature: &str,
        seed: u64,
    ) -> Result<Self, SpecError> {
        let spec = PartitionerSpec::Pathological {
            num_groups,
            classes_per_group,
            num_labels,
            label_feature: label_feature.to_string(),
            seed,
        };
        spec.validate()?;
        let mut label_groups = vec![Vec::new(); num_labels];
        let mut root = Rng::new(seed ^ PATH_SALT);
        for g in 0..num_groups {
            let mut rng = root.fork(g as u64);
            for l in rng.sample_indices(num_labels, classes_per_group) {
                label_groups[l].push(g as u32);
            }
        }
        for (l, groups) in label_groups.iter_mut().enumerate() {
            if groups.is_empty() {
                groups.push((l % num_groups) as u32);
            }
        }
        Ok(PathologicalPartitioner {
            num_groups,
            classes_per_group,
            num_labels,
            label_feature: label_feature.to_string(),
            seed,
            label_groups,
        })
    }
}

impl Partitioner for PathologicalPartitioner {
    fn key(&self, example: &Example) -> Vec<u8> {
        let l = label_of(example, &self.label_feature, self.num_labels);
        let h = example.content_hash64() ^ self.seed.wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
        let bucket = &self.label_groups[l];
        let g = bucket[Rng::new(h).gen_range(bucket.len() as u64) as usize];
        format!("path-{g:06}").into_bytes()
    }

    fn name(&self) -> String {
        format!("pathological:{}x{}", self.num_groups, self.classes_per_group)
    }
}

/// Temporal split: one group per `period`-sized window of an integer
/// time/sequence feature (`example_index` for the synthetic corpora).
/// Negative timestamps clamp to window zero; examples without the
/// feature share the `<missing>` group, same as [`FeatureKey`].
pub struct TemporalPartitioner {
    pub feature: String,
    pub period: u64,
}

impl TemporalPartitioner {
    pub fn new(feature: &str, period: u64) -> Self {
        assert!(period > 0, "temporal period must be positive");
        TemporalPartitioner { feature: feature.to_string(), period }
    }
}

impl Partitioner for TemporalPartitioner {
    fn key(&self, example: &Example) -> Vec<u8> {
        match example.features.get(&self.feature) {
            Some(Feature::Ints(v)) if !v.is_empty() => {
                let t = v[0].max(0) as u64;
                format!("time-{:06}", t / self.period).into_bytes()
            }
            _ => b"<missing>".to_vec(),
        }
    }

    fn name(&self) -> String {
        format!("temporal:{}/{}", self.feature, self.period)
    }
}

const MODM_POP_SALT: u64 = 0x6D6F_646D_5F70_6F70; // "modm_pop"
const MODM_GEN_SALT: u64 = 0x6D6F_646D_5F67_656E; // "modm_gen"
const MODM_FIT_SALT: u64 = 0x6D6F_646D_5F66_6974; // "modm_fit"

/// One mixture component of a [`ModmModel`]: a log-normal over group
/// sizes (the paper's Figure 3 size model) plus, optionally, a
/// Dirichlet concentration over label classes.
#[derive(Debug, Clone, PartialEq)]
pub struct ModmComponent {
    /// Mixing proportion (normalized against the other components).
    pub weight: f64,
    /// Mean of ln(group size).
    pub size_mu: f64,
    /// Std-dev of ln(group size); 0 pins the component's size.
    pub size_sigma: f64,
    /// Dirichlet concentration over label classes; empty = size-only.
    pub label_alpha: Vec<f64>,
}

/// A mixture of Dirichlet-multinomials over (group size, label
/// histogram) observations — Scott & Cahill, arXiv 2406.02416. Either
/// declared directly (scenario TOML) or fitted to an observed
/// population with [`ModmModel::fit`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ModmModel {
    pub components: Vec<ModmComponent>,
}

/// One observed group: its example count and (optionally empty) label
/// histogram. What [`ModmModel::fit`] consumes — derivable from a
/// `GroupIndex` (sizes) or a labeled read pass (sizes + labels).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupObservation {
    pub size: u64,
    pub label_counts: Vec<u64>,
}

/// Knobs for [`ModmModel::fit`]. Defaults: 2 components, 40 EM
/// iterations, seed 0 (the seed only jitters the initial
/// responsibilities; the fit is deterministic given (obs, opts)).
#[derive(Debug, Clone)]
pub struct ModmFitOptions {
    pub components: usize,
    pub iterations: usize,
    pub seed: u64,
}

impl Default for ModmFitOptions {
    fn default() -> Self {
        ModmFitOptions { components: 2, iterations: 40, seed: 0 }
    }
}

impl ModmModel {
    /// Label-class count (all components agree; 0 = size-only model).
    pub fn num_labels(&self) -> usize {
        self.components.first().map(|c| c.label_alpha.len()).unwrap_or(0)
    }

    pub fn validate(&self) -> Result<(), SpecError> {
        fn invalid(field: &'static str, reason: String) -> Result<(), SpecError> {
            Err(SpecError::Invalid { field, reason })
        }
        if self.components.is_empty() {
            return invalid("modm.components", "need at least one component".to_string());
        }
        let labels = self.components[0].label_alpha.len();
        for (i, c) in self.components.iter().enumerate() {
            if !c.weight.is_finite() || c.weight <= 0.0 {
                return invalid(
                    "modm.weight",
                    format!("component {i}: must be finite positive, got {}", c.weight),
                );
            }
            if !c.size_mu.is_finite() {
                return invalid(
                    "modm.size_mu",
                    format!("component {i}: must be finite, got {}", c.size_mu),
                );
            }
            if !c.size_sigma.is_finite() || c.size_sigma < 0.0 {
                return invalid(
                    "modm.size_sigma",
                    format!("component {i}: must be finite non-negative, got {}", c.size_sigma),
                );
            }
            if c.label_alpha.len() != labels {
                return invalid(
                    "modm.label_alpha",
                    format!(
                        "component {i} has {} label classes, component 0 has {labels}",
                        c.label_alpha.len()
                    ),
                );
            }
            for &a in &c.label_alpha {
                if !a.is_finite() || a <= 0.0 {
                    return invalid(
                        "modm.label_alpha",
                        format!("component {i}: alphas must be finite positive, got {a}"),
                    );
                }
            }
        }
        Ok(())
    }

    /// Fit a `opts.components`-component model to observed groups with
    /// EM. Deterministic: same (observations, options) → bit-identical
    /// model, on every platform (the only special function involved,
    /// `ln_gamma`, is in-repo).
    ///
    /// E-step: exact posterior responsibilities under ln-size Gaussian ×
    /// Dirichlet-multinomial likelihood. M-step: weighted Gaussian
    /// moments for (mu, sigma), and *moment-matched* Dirichlet alphas
    /// (mean proportions scaled by a variance-implied precision) — the
    /// standard closed-form approximation to the alpha MLE; the DM
    /// likelihood in the E-step is what drives component separation.
    pub fn fit(obs: &[GroupObservation], opts: &ModmFitOptions) -> Result<ModmModel, SpecError> {
        fn invalid(reason: String) -> SpecError {
            SpecError::Invalid { field: "modm.fit", reason }
        }
        let n = obs.len();
        let m_count = opts.components;
        if m_count == 0 {
            return Err(invalid("need at least one component".to_string()));
        }
        if n < m_count {
            return Err(invalid(format!(
                "{n} observation(s) cannot support {m_count} components"
            )));
        }
        let l_count = obs[0].label_counts.len();
        if obs.iter().any(|o| o.label_counts.len() != l_count) {
            return Err(invalid(
                "observations disagree on the number of label classes".to_string(),
            ));
        }
        let xs: Vec<f64> = obs.iter().map(|o| (o.size.max(1) as f64).ln()).collect();
        // Init: hard-assign size quantile slices, softened by a seeded
        // jitter so EM can move mass across the slice boundaries.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]).then(a.cmp(&b)));
        let mut resp = vec![vec![0.0f64; m_count]; n];
        let mut rng = Rng::new(opts.seed ^ MODM_FIT_SALT);
        for (rank, &g) in order.iter().enumerate() {
            let slice = (rank * m_count / n).min(m_count - 1);
            resp[g][slice] = 1.0;
            let mut total = 0.0;
            for r in resp[g].iter_mut() {
                *r += 0.25 * rng.next_f64();
                total += *r;
            }
            for r in resp[g].iter_mut() {
                *r /= total;
            }
        }
        let mut model = modm_m_step(obs, &xs, &resp, l_count);
        for _ in 1..opts.iterations.max(1) {
            modm_e_step(obs, &xs, &model, &mut resp);
            model = modm_m_step(obs, &xs, &resp, l_count);
        }
        model.validate()?;
        Ok(model)
    }

    /// Sample `num_groups` (size, label histogram) observations from the
    /// model — the generative direction, used by round-trip tests and to
    /// preview a fitted model.
    pub fn sample_observations(&self, num_groups: usize, seed: u64) -> Vec<GroupObservation> {
        let l_count = self.num_labels();
        let pick = weight_cdf(&self.components);
        let mut root = Rng::new(seed ^ MODM_GEN_SALT);
        let mut out = Vec::with_capacity(num_groups);
        for g in 0..num_groups {
            let mut rng = root.fork(g as u64);
            let c = &self.components[search_cdf(&pick, rng.next_f64())];
            let size = rng.log_normal(c.size_mu, c.size_sigma).round().max(1.0) as u64;
            let label_counts = if l_count > 0 {
                let p = rng.dirichlet(&c.label_alpha);
                rng.multinomial(size, &p)
            } else {
                Vec::new()
            };
            out.push(GroupObservation { size, label_counts });
        }
        out
    }
}

/// Normalized cumulative mixing weights.
fn weight_cdf(components: &[ModmComponent]) -> Vec<f64> {
    let total: f64 = components.iter().map(|c| c.weight).sum();
    let mut cdf = Vec::with_capacity(components.len());
    let mut acc = 0.0;
    for c in components {
        acc += c.weight / total;
        cdf.push(acc);
    }
    cdf
}

fn modm_m_step(
    obs: &[GroupObservation],
    xs: &[f64],
    resp: &[Vec<f64>],
    l_count: usize,
) -> ModmModel {
    let n = obs.len();
    let m_count = resp[0].len();
    let global_mu = xs.iter().sum::<f64>() / n as f64;
    let mut components = Vec::with_capacity(m_count);
    for m in 0..m_count {
        let w_m: f64 = resp.iter().map(|r| r[m]).sum();
        if w_m < 1e-9 {
            // A component EM emptied out: park it at the global size
            // center with negligible weight instead of dividing by ~0.
            components.push(ModmComponent {
                weight: 1e-6,
                size_mu: global_mu,
                size_sigma: 1.0,
                label_alpha: vec![1.0; l_count],
            });
            continue;
        }
        let mu = resp.iter().zip(xs).map(|(r, &x)| r[m] * x).sum::<f64>() / w_m;
        let var = resp.iter().zip(xs).map(|(r, &x)| r[m] * (x - mu) * (x - mu)).sum::<f64>()
            / w_m;
        let sigma = var.max(0.0).sqrt().max(0.05);
        let label_alpha = if l_count == 0 {
            Vec::new()
        } else {
            modm_alpha_moment_match(obs, resp, m, l_count)
        };
        components.push(ModmComponent {
            weight: (w_m / n as f64).max(1e-6),
            size_mu: mu,
            size_sigma: sigma,
            label_alpha,
        });
    }
    // Canonical order (ascending size center): the fit's output order
    // is part of its determinism contract.
    components.sort_by(|a, b| a.size_mu.total_cmp(&b.size_mu));
    ModmModel { components }
}

/// Moment-matched Dirichlet concentration for component `m`: mean label
/// proportions under the responsibilities, scaled by the precision the
/// observed proportion variance implies (`s = (m1 - m2) / (m2 - m1²)`
/// per class, averaged over well-conditioned classes).
fn modm_alpha_moment_match(
    obs: &[GroupObservation],
    resp: &[Vec<f64>],
    m: usize,
    l_count: usize,
) -> Vec<f64> {
    let mut m1 = vec![0.0f64; l_count];
    let mut m2 = vec![0.0f64; l_count];
    let mut w_lab = 0.0f64;
    for (g, o) in obs.iter().enumerate() {
        let tot: u64 = o.label_counts.iter().sum();
        if tot == 0 {
            continue;
        }
        let r = resp[g][m];
        w_lab += r;
        for (l, &c) in o.label_counts.iter().enumerate() {
            let p = c as f64 / tot as f64;
            m1[l] += r * p;
            m2[l] += r * p * p;
        }
    }
    if w_lab < 1e-9 {
        return vec![1.0; l_count];
    }
    for v in m1.iter_mut() {
        *v /= w_lab;
    }
    for v in m2.iter_mut() {
        *v /= w_lab;
    }
    let mut s_sum = 0.0f64;
    let mut s_n = 0usize;
    for l in 0..l_count {
        let var_l = m2[l] - m1[l] * m1[l];
        let num = m1[l] - m2[l];
        if var_l > 1e-12 && num > 0.0 {
            s_sum += num / var_l;
            s_n += 1;
        }
    }
    // No class with usable variance (e.g. every group one-hot on the
    // same class): fall back to a moderately concentrated prior.
    let s = if s_n == 0 { 100.0 } else { (s_sum / s_n as f64).clamp(0.01, 1e4) };
    m1.iter().map(|&p| (s * p).max(1e-3)).collect()
}

fn modm_e_step(obs: &[GroupObservation], xs: &[f64], model: &ModmModel, resp: &mut [Vec<f64>]) {
    let comps = &model.components;
    let a_sums: Vec<f64> = comps.iter().map(|c| c.label_alpha.iter().sum()).collect();
    let l_count = model.num_labels();
    let mut lls = vec![0.0f64; comps.len()];
    for (g, o) in obs.iter().enumerate() {
        let tot: u64 = if l_count > 0 { o.label_counts.iter().sum() } else { 0 };
        for (m, c) in comps.iter().enumerate() {
            let mut ll = c.weight.max(1e-300).ln();
            let z = (xs[g] - c.size_mu) / c.size_sigma;
            ll += -c.size_sigma.ln() - 0.5 * z * z;
            if tot > 0 {
                // Dirichlet-multinomial log-likelihood, multinomial
                // coefficient dropped (constant across components).
                ll += ln_gamma(a_sums[m]) - ln_gamma(tot as f64 + a_sums[m]);
                for (l, &cnt) in o.label_counts.iter().enumerate() {
                    if cnt > 0 {
                        let al = c.label_alpha[l];
                        ll += ln_gamma(cnt as f64 + al) - ln_gamma(al);
                    }
                }
            }
            lls[m] = ll;
        }
        let max = lls.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut total = 0.0;
        for (m, &ll) in lls.iter().enumerate() {
            let e = (ll - max).exp();
            resp[g][m] = e;
            total += e;
        }
        for r in resp[g].iter_mut() {
            *r /= total;
        }
    }
}

/// A full MoDM partitioner description: a model plus how to sample it
/// into a synthetic population. Comes from the scenario registry (TOML
/// declaration or an index-fitted model), not the inline `--by` grammar.
#[derive(Debug, Clone, PartialEq)]
pub struct ModmSpec {
    /// Synthetic population size: groups to sample from the model.
    pub num_groups: usize,
    /// Feature carrying the label class; required when the model has
    /// label alphas (see [`label_of`] for the missing-feature fallback).
    pub label_feature: Option<String>,
    pub seed: u64,
    pub model: ModmModel,
}

impl ModmSpec {
    pub fn validate(&self) -> Result<(), SpecError> {
        if self.num_groups == 0 {
            return Err(SpecError::Invalid {
                field: "modm.num_groups",
                reason: "must be at least 1".to_string(),
            });
        }
        if self.num_groups > u32::MAX as usize {
            return Err(SpecError::Invalid {
                field: "modm.num_groups",
                reason: format!("must fit in u32, got {}", self.num_groups),
            });
        }
        self.model.validate()?;
        if self.model.num_labels() > 0 && self.label_feature.is_none() {
            return Err(SpecError::Invalid {
                field: "modm.label_feature",
                reason: "required when components carry label alphas".to_string(),
            });
        }
        Ok(())
    }
}

/// Mixtures-of-Dirichlet-Multinomials partitioner: at construction it
/// samples a synthetic population of `num_groups` groups from the model
/// (each group: a component, then a target size weight from that
/// component's log-normal) and keeps only O(groups) state — per-group
/// (component, weight) collapsed into per-component CDFs. Per example,
/// [`key`](Partitioner::key) draws — from the example's own content
/// hash, so the assignment stays a pure function — a component (biased
/// by the example's label class through the component label means
/// `theta = alpha / sum(alpha)`, when the model has labels), then a
/// group inside it proportional to target size.
///
/// Scalability trade-off, documented in ARCHITECTURE.md: label bias is
/// applied at *component* granularity (the per-group Dirichlet draw is
/// integrated out at assignment time); per-group label overdispersion
/// is what the DM likelihood captures during *fitting*. This keeps the
/// population O(groups) and assignment stateless per §3.2.
pub struct ModmPartitioner {
    seed: u64,
    label_feature: Option<String>,
    num_labels: usize,
    num_groups: usize,
    /// Global group ids per component.
    group_ids: Vec<Vec<u32>>,
    /// Per-component cumulative normalized target-size CDF (parallel to
    /// `group_ids`).
    group_cdf: Vec<Vec<f64>>,
    /// Component CDF without label context: P(m) ∝ S_m (total target
    /// size mass).
    comp_cdf: Vec<f64>,
    /// Component CDF per label class: P(m | l) ∝ S_m · theta_m[l].
    comp_cdf_by_label: Vec<Vec<f64>>,
    /// Normalized target size share per global group id (diagnostics;
    /// the round-trip tests compare realized histograms against this).
    weights: Vec<f64>,
}

impl ModmPartitioner {
    pub fn from_spec(spec: &ModmSpec) -> Result<Self, SpecError> {
        spec.validate()?;
        let comps = &spec.model.components;
        let m_count = comps.len();
        let l_count = spec.model.num_labels();
        let pick = weight_cdf(comps);
        let mut root = Rng::new(spec.seed ^ MODM_POP_SALT);
        let mut group_ids = vec![Vec::new(); m_count];
        let mut group_w = vec![Vec::new(); m_count];
        let mut weights = vec![0.0f64; spec.num_groups];
        for g in 0..spec.num_groups {
            let mut rng = root.fork(g as u64);
            let m = search_cdf(&pick, rng.next_f64());
            let w = rng.log_normal(comps[m].size_mu, comps[m].size_sigma);
            group_ids[m].push(g as u32);
            group_w[m].push(w);
            weights[g] = w;
        }
        let total_w: f64 = weights.iter().sum();
        for w in weights.iter_mut() {
            *w /= total_w;
        }
        let mass: Vec<f64> = group_w.iter().map(|ws| ws.iter().sum()).collect();
        let group_cdf: Vec<Vec<f64>> = group_w
            .iter()
            .zip(&mass)
            .map(|(ws, &s)| {
                let mut acc = 0.0;
                ws.iter().map(|w| {
                    acc += w / s;
                    acc
                })
                .collect()
            })
            .collect();
        let comp_cdf = mass_cdf(&mass);
        let comp_cdf_by_label = (0..l_count)
            .map(|l| {
                let biased: Vec<f64> = comps
                    .iter()
                    .zip(&mass)
                    .map(|(c, &s)| {
                        let a_sum: f64 = c.label_alpha.iter().sum();
                        s * c.label_alpha[l] / a_sum
                    })
                    .collect();
                mass_cdf(&biased)
            })
            .collect();
        Ok(ModmPartitioner {
            seed: spec.seed,
            label_feature: spec.label_feature.clone(),
            num_labels: l_count,
            num_groups: spec.num_groups,
            group_ids,
            group_cdf,
            comp_cdf,
            comp_cdf_by_label,
            weights,
        })
    }

    /// Target (normalized) size share per global group id — what the
    /// realized partition's group-size histogram converges to.
    pub fn group_weights(&self) -> &[f64] {
        &self.weights
    }

    pub fn num_groups(&self) -> usize {
        self.num_groups
    }
}

/// Cumulative distribution over possibly-zero masses (empty components
/// contribute zero width and are skipped by the empty-bucket walk in
/// `key`).
fn mass_cdf(mass: &[f64]) -> Vec<f64> {
    let total: f64 = mass.iter().sum();
    let mut acc = 0.0;
    mass.iter()
        .map(|&m| {
            acc += m / total;
            acc
        })
        .collect()
}

impl Partitioner for ModmPartitioner {
    fn key(&self, example: &Example) -> Vec<u8> {
        let h = example.content_hash64() ^ self.seed.wrapping_mul(0xA076_1D64_78BD_642F);
        let mut r = Rng::new(h);
        let cdf = match (&self.label_feature, self.num_labels) {
            (Some(f), l) if l > 0 => &self.comp_cdf_by_label[label_of(example, f, l)],
            _ => &self.comp_cdf,
        };
        let mut m = search_cdf(cdf, r.next_f64());
        // A boundary draw can land on a zero-mass (group-less)
        // component; walk to the next populated one deterministically.
        while self.group_ids[m].is_empty() {
            m = (m + 1) % self.group_ids.len();
        }
        let gi = search_cdf(&self.group_cdf[m], r.next_f64());
        let g = self.group_ids[m][gi];
        format!("modm-{g:08}").into_bytes()
    }

    fn name(&self) -> String {
        format!("modm:{}g/{}c", self.num_groups, self.group_ids.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::Feature;
    use crate::util::proptest_lite::{check, gen_word, prop_assert, prop_assert_eq};

    fn ex(text: &str, domain: &str) -> Example {
        Example::text(text).with("domain", Feature::bytes_one(domain.as_bytes().to_vec()))
    }

    #[test]
    fn feature_key_extracts_domain() {
        let p = FeatureKey::new("domain");
        assert_eq!(p.key(&ex("hi", "nytimes.com")), b"nytimes.com");
        assert_eq!(p.key(&Example::text("orphan")), b"<missing>");
    }

    #[test]
    fn feature_key_int_and_float() {
        let p = FeatureKey::new("label");
        let e = Example::new().with("label", Feature::ints(vec![9]));
        assert_eq!(p.key(&e), b"9");
        let p2 = FeatureKey::new("score");
        let e2 = Example::new().with("score", Feature::Floats(vec![1.5]));
        assert_eq!(p2.key(&e2), b"1.5");
    }

    #[test]
    fn partitioners_are_pure_functions() {
        let rand = RandomPartitioner::new(50, 3);
        let dir = DirichletPartitioner::new(2.0, 100, 3);
        check(100, |rng| {
            let e = ex(&gen_word(rng, 1..=30), &gen_word(rng, 3..=10));
            prop_assert_eq(rand.key(&e), rand.key(&e), "random purity")?;
            prop_assert_eq(dir.key(&e), dir.key(&e), "dirichlet purity")
        });
    }

    #[test]
    fn incremental_hash_leaves_the_partition_unchanged() {
        use crate::util::rng::fnv1a;
        // The partitioners used to hash fnv1a(&example.encode()); they now
        // hash incrementally. Re-derive the old formulas here verbatim and
        // require key-for-key agreement, so the produced partition for any
        // seed (including the CLI default, 42) can never silently move.
        let rand = RandomPartitioner::new(37, 42);
        let dir = DirichletPartitioner::new(2.5, 500, 42);
        check(200, |rng| {
            let e = ex(&gen_word(rng, 1..=40), &gen_word(rng, 3..=12));
            let old_rand = {
                let h = fnv1a(&e.encode()) ^ 42u64.wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let g = Rng::new(h).gen_range(37);
                format!("rand-{g:06}").into_bytes()
            };
            prop_assert_eq(rand.key(&e), old_rand, "random key unchanged")?;
            let old_dir = {
                let h = fnv1a(&e.encode()) ^ 42u64.rotate_left(17);
                let u = Rng::new(h).next_f64();
                let g = match dir.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
                    Ok(i) => i,
                    Err(i) => i.min(dir.cdf.len() - 1),
                };
                format!("dp-{g:06}").into_bytes()
            };
            prop_assert_eq(dir.key(&e), old_dir, "dirichlet key unchanged")
        });
    }

    #[test]
    fn random_partition_covers_groups_roughly_uniformly() {
        let p = RandomPartitioner::new(10, 7);
        let mut counts = std::collections::HashMap::new();
        for i in 0..5000 {
            let e = ex(&format!("example {i}"), "d");
            *counts.entry(p.key(&e)).or_insert(0u64) += 1;
        }
        assert_eq!(counts.len(), 10);
        for (_, c) in counts {
            assert!((300..=700).contains(&c), "non-uniform: {c}");
        }
    }

    #[test]
    fn different_seeds_give_different_partitions() {
        let p1 = RandomPartitioner::new(100, 1);
        let p2 = RandomPartitioner::new(100, 2);
        let diffs = (0..200)
            .filter(|i| {
                let e = ex(&format!("x{i}"), "d");
                p1.key(&e) != p2.key(&e)
            })
            .count();
        assert!(diffs > 150, "seeds too correlated: {diffs}");
    }

    #[test]
    fn dirichlet_is_heavy_tailed() {
        let p = DirichletPartitioner::new(5.0, 1000, 11);
        let mut counts = std::collections::HashMap::new();
        for i in 0..10_000 {
            let e = ex(&format!("doc {i}"), "d");
            *counts.entry(p.key(&e)).or_insert(0u64) += 1;
        }
        let n_groups = counts.len();
        assert!(n_groups > 5, "{n_groups}");
        let max = *counts.values().max().unwrap();
        let mean = 10_000 / n_groups as u64;
        assert!(max > mean * 3, "max {max} mean {mean}: not heavy tailed");
    }

    #[test]
    fn dirichlet_alpha_controls_group_count() {
        let count_groups = |alpha: f64| {
            let p = DirichletPartitioner::new(alpha, 2000, 5);
            let mut set = std::collections::HashSet::new();
            for i in 0..5000 {
                set.insert(p.key(&ex(&format!("e{i}"), "d")));
            }
            set.len()
        };
        let low = count_groups(1.0);
        let high = count_groups(100.0);
        assert!(high > low * 2, "alpha effect missing: {low} vs {high}");
    }

    #[test]
    fn dirichlet_cdf_is_proper() {
        let p = DirichletPartitioner::new(3.0, 64, 9);
        check(200, |rng| {
            let e = ex(&gen_word(rng, 1..=20), "d");
            let k = p.key(&e);
            prop_assert(k.starts_with(b"dp-"), "key prefix")
        });
        assert!((p.cdf.last().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dirichlet_try_new_rejects_bad_alpha() {
        // The bugfix: degenerate alphas are typed errors, not panics or
        // silent degenerate draws.
        for bad in [f64::NAN, 0.0, -1.0, f64::INFINITY, f64::NEG_INFINITY] {
            let err = DirichletPartitioner::try_new(bad, 10, 1).unwrap_err();
            assert!(matches!(err, SpecError::Invalid { field: "dirichlet.alpha", .. }), "{bad}");
        }
        assert!(matches!(
            DirichletPartitioner::try_new(1.0, 0, 1),
            Err(SpecError::Invalid { field: "dirichlet.max_groups", .. })
        ));
        assert!(DirichletPartitioner::try_new(1.0, 10, 1).is_ok());
    }

    #[test]
    fn spec_parse_covers_the_grammar() {
        let p = |s: &str| PartitionerSpec::parse(s, "domain", 7).unwrap();
        assert_eq!(p("feature"), PartitionerSpec::Feature { feature: "domain".into() });
        assert_eq!(p("feature:label"), PartitionerSpec::Feature { feature: "label".into() });
        assert_eq!(p("random:50"), PartitionerSpec::Random { num_groups: 50, seed: 7 });
        assert_eq!(
            p("dirichlet:2.5"),
            PartitionerSpec::Dirichlet {
                alpha: 2.5,
                max_groups: DEFAULT_DIRICHLET_MAX_GROUPS,
                seed: 7
            }
        );
        assert_eq!(
            p("dirichlet:2.5:600"),
            PartitionerSpec::Dirichlet { alpha: 2.5, max_groups: 600, seed: 7 }
        );
        assert_eq!(
            p("pathological:40:2"),
            PartitionerSpec::Pathological {
                num_groups: 40,
                classes_per_group: 2,
                num_labels: 10,
                label_feature: "label".into(),
                seed: 7
            }
        );
        assert_eq!(
            p("temporal:16"),
            PartitionerSpec::Temporal { feature: "example_index".into(), period: 16 }
        );
        assert_eq!(
            p("temporal:16:ts"),
            PartitionerSpec::Temporal { feature: "ts".into(), period: 16 }
        );
    }

    #[test]
    fn spec_parse_and_validate_yield_typed_errors() {
        let parse = |s: &str| PartitionerSpec::parse(s, "domain", 7);
        // Malformed strings (the old parser panicked on `dirichlet:x`).
        for bad in ["", "bogus:1", "dirichlet:x", "random:", "random:1:2", "feature:"] {
            assert!(
                matches!(parse(bad), Err(SpecError::Malformed { .. })),
                "{bad:?} should be malformed"
            );
        }
        // Bare `feature` without a dataset context (the FromStr path).
        assert!(matches!(
            "feature".parse::<PartitionerSpec>(),
            Err(SpecError::Malformed { .. })
        ));
        assert_eq!(
            "random:9".parse::<PartitionerSpec>().unwrap(),
            PartitionerSpec::Random { num_groups: 9, seed: DEFAULT_SEED }
        );
        // Parsed-but-invalid parameters ("NaN" parses as f64).
        for bad in ["random:0", "dirichlet:NaN", "dirichlet:-2", "dirichlet:1:0",
            "pathological:10:0", "pathological:10:11", "temporal:0"]
        {
            let spec = parse(bad).unwrap();
            assert!(
                matches!(spec.build(), Err(SpecError::Invalid { .. })),
                "{bad:?} should be invalid"
            );
        }
    }

    #[test]
    fn spec_build_matches_direct_construction() {
        // The typed API must reproduce the exact keys of the pinned
        // constructors — existing partitions never move.
        let rand_spec = PartitionerSpec::parse("random:37", "domain", 42).unwrap().build().unwrap();
        let dir_spec =
            PartitionerSpec::parse("dirichlet:2.5:500", "domain", 42).unwrap().build().unwrap();
        let rand = RandomPartitioner::new(37, 42);
        let dir = DirichletPartitioner::new(2.5, 500, 42);
        check(100, |rng| {
            let e = ex(&gen_word(rng, 1..=30), &gen_word(rng, 3..=10));
            prop_assert_eq(rand_spec.key(&e), rand.key(&e), "random via spec")?;
            prop_assert_eq(dir_spec.key(&e), dir.key(&e), "dirichlet via spec")
        });
    }

    #[test]
    fn label_of_extracts_and_falls_back() {
        let labeled = Example::new().with("label", Feature::ints(vec![13]));
        assert_eq!(label_of(&labeled, "label", 10), 3);
        let negative = Example::new().with("label", Feature::ints(vec![-1]));
        assert_eq!(label_of(&negative, "label", 10), 9);
        // Missing feature: deterministic pseudo-label.
        let plain = Example::text("no label here");
        let l = label_of(&plain, "label", 10);
        assert!(l < 10);
        assert_eq!(l, label_of(&plain, "label", 10));
    }

    #[test]
    fn pathological_groups_see_few_classes() {
        let p = PathologicalPartitioner::new(30, 2, 10, "label", 5).unwrap();
        let mut classes_per_group: std::collections::HashMap<Vec<u8>, _> =
            std::collections::HashMap::new();
        for i in 0..3000i64 {
            let e = Example::text(&format!("x{i}")).with("label", Feature::ints(vec![i % 10]));
            classes_per_group
                .entry(p.key(&e))
                .or_insert_with(std::collections::HashSet::new)
                .insert(i % 10);
        }
        assert!(classes_per_group.len() > 5, "{}", classes_per_group.len());
        for (g, classes) in &classes_per_group {
            assert!(
                classes.len() <= 2,
                "group {:?} saw {} classes",
                String::from_utf8_lossy(g),
                classes.len()
            );
        }
    }

    #[test]
    fn temporal_windows_by_period() {
        let p = TemporalPartitioner::new("example_index", 16);
        let at = |t: i64| {
            p.key(&Example::text("x").with("example_index", Feature::ints(vec![t])))
        };
        assert_eq!(at(0), b"time-000000");
        assert_eq!(at(15), b"time-000000");
        assert_eq!(at(16), b"time-000001");
        assert_eq!(at(-5), b"time-000000");
        assert_eq!(p.key(&Example::text("x")), b"<missing>");
    }

    #[test]
    fn modm_partitioner_tracks_target_weights() {
        let spec = ModmSpec {
            num_groups: 100,
            label_feature: None,
            seed: 9,
            model: ModmModel {
                components: vec![
                    ModmComponent {
                        weight: 0.8,
                        size_mu: 3.0,
                        size_sigma: 0.5,
                        label_alpha: vec![],
                    },
                    ModmComponent {
                        weight: 0.2,
                        size_mu: 5.0,
                        size_sigma: 0.5,
                        label_alpha: vec![],
                    },
                ],
            },
        };
        let p = ModmPartitioner::from_spec(&spec).unwrap();
        assert_eq!(p.group_weights().len(), 100);
        assert!((p.group_weights().iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // Realized assignment frequencies track the target size shares.
        let n = 20_000usize;
        let mut counts: std::collections::HashMap<Vec<u8>, u64> = std::collections::HashMap::new();
        for i in 0..n {
            let e = Example::text(&format!("doc {i}"));
            *counts.entry(p.key(&e)).or_insert(0) += 1;
        }
        let mut l1 = 0.0;
        for (g, &w) in p.group_weights().iter().enumerate() {
            let key = format!("modm-{g:08}").into_bytes();
            let realized = *counts.get(&key).unwrap_or(&0) as f64 / n as f64;
            l1 += (realized - w).abs();
        }
        assert!(l1 < 0.15, "realized vs target L1 distance {l1}");
    }

    #[test]
    fn modm_fit_is_deterministic() {
        let truth = ModmModel {
            components: vec![
                ModmComponent { weight: 0.6, size_mu: 2.5, size_sigma: 0.4, label_alpha: vec![] },
                ModmComponent { weight: 0.4, size_mu: 5.5, size_sigma: 0.5, label_alpha: vec![] },
            ],
        };
        let obs = truth.sample_observations(400, 11);
        let opts = ModmFitOptions { components: 2, iterations: 25, seed: 3 };
        let a = ModmModel::fit(&obs, &opts).unwrap();
        let b = ModmModel::fit(&obs, &opts).unwrap();
        assert_eq!(a, b, "same observations + options must refit bit-identically");
        assert!(a.components[0].size_mu < a.components[1].size_mu);
    }

    #[test]
    fn modm_fit_rejects_degenerate_requests() {
        let obs = vec![GroupObservation { size: 5, label_counts: vec![] }];
        assert!(matches!(
            ModmModel::fit(&obs, &ModmFitOptions { components: 2, ..Default::default() }),
            Err(SpecError::Invalid { .. })
        ));
        assert!(matches!(
            ModmModel::fit(&obs, &ModmFitOptions { components: 0, ..Default::default() }),
            Err(SpecError::Invalid { .. })
        ));
        let ragged = vec![
            GroupObservation { size: 5, label_counts: vec![1, 2] },
            GroupObservation { size: 5, label_counts: vec![1, 2, 3] },
        ];
        assert!(matches!(
            ModmModel::fit(&ragged, &ModmFitOptions { components: 1, ..Default::default() }),
            Err(SpecError::Invalid { .. })
        ));
    }
}
