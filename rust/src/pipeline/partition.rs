//! User-defined partitioners: the `get_key_fn(example) -> group_id`
//! contract of the paper (Appendix A.1), plus the three canonical
//! implementations the paper ships as example scripts:
//!
//! * [`FeatureKey`] — partition by a feature's value (FedC4/FedCCnews use
//!   the URL's domain; Listing 1 uses the MNIST label);
//! * [`RandomPartitioner`] — uniform random assignment (the IID control);
//! * [`DirichletPartitioner`] — heterogeneous assignment via a truncated
//!   stick-breaking Dirichlet process, the embarrassingly-parallel
//!   version of the LDA-style partitioner popular in FL literature [71].
//!
//! All partitioners are stateless per example — the formal trade-off the
//! paper makes for scalability (§3.2): assignment of example `x` may not
//! depend on the assignment of example `y`.

use crate::records::Example;
use crate::util::rng::Rng;

/// An embarrassingly parallel partition function.
pub trait Partitioner: Send + Sync {
    /// The group key for one example. Must be a pure function of the
    /// example (and the partitioner's own immutable config).
    fn key(&self, example: &Example) -> Vec<u8>;

    /// Diagnostic name for reports.
    fn name(&self) -> String;
}

/// Partition by a feature's (first) value: domains, article ids, labels.
pub struct FeatureKey {
    pub feature: String,
}

impl FeatureKey {
    pub fn new(feature: &str) -> Self {
        FeatureKey { feature: feature.to_string() }
    }
}

impl Partitioner for FeatureKey {
    fn key(&self, example: &Example) -> Vec<u8> {
        match example.features.get(&self.feature) {
            Some(crate::records::Feature::Bytes(v)) if !v.is_empty() => v[0].clone(),
            Some(crate::records::Feature::Ints(v)) if !v.is_empty() => {
                format!("{}", v[0]).into_bytes()
            }
            Some(crate::records::Feature::Floats(v)) if !v.is_empty() => {
                format!("{}", v[0]).into_bytes()
            }
            _ => b"<missing>".to_vec(),
        }
    }

    fn name(&self) -> String {
        format!("feature:{}", self.feature)
    }
}

/// Uniform random assignment to `num_groups` groups, keyed off a stable
/// hash of the example content (so re-running the pipeline reproduces the
/// identical partition, and parallel workers agree without coordination).
pub struct RandomPartitioner {
    pub num_groups: usize,
    pub seed: u64,
}

impl RandomPartitioner {
    pub fn new(num_groups: usize, seed: u64) -> Self {
        assert!(num_groups > 0);
        RandomPartitioner { num_groups, seed }
    }
}

impl Partitioner for RandomPartitioner {
    fn key(&self, example: &Example) -> Vec<u8> {
        // content_hash64() is fnv1a over the canonical encoding, computed
        // incrementally — same digest as fnv1a(&example.encode()) (pinned
        // by a test below, so existing partitions never move) without
        // re-serializing the whole example just to hash it.
        let h = example.content_hash64() ^ self.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // SplitMix finalizer decorrelates the xor.
        let mut r = Rng::new(h);
        let g = r.gen_range(self.num_groups as u64);
        format!("rand-{g:06}").into_bytes()
    }

    fn name(&self) -> String {
        format!("random:{}", self.num_groups)
    }
}

/// Truncated stick-breaking Dirichlet process: group probabilities
/// `p_k = beta_k * prod_{j<k} (1 - beta_j)`, `beta ~ Beta(1, alpha)`,
/// truncated at `max_groups`. Each example samples its group from the
/// *fixed* categorical using its own content hash — stateless, parallel,
/// heavy-tailed like the sequential CRP.
pub struct DirichletPartitioner {
    cdf: Vec<f64>,
    pub alpha: f64,
    pub seed: u64,
}

impl DirichletPartitioner {
    pub fn new(alpha: f64, max_groups: usize, seed: u64) -> Self {
        assert!(alpha > 0.0 && max_groups > 0);
        let mut rng = Rng::new(seed ^ 0xD112_1C43);
        let mut remaining = 1.0f64;
        let mut cdf = Vec::with_capacity(max_groups);
        let mut acc = 0.0;
        for k in 0..max_groups {
            // Beta(1, alpha) sample: 1 - U^(1/alpha).
            let beta = if k + 1 == max_groups {
                1.0 // close the stick
            } else {
                1.0 - rng.next_f64().powf(1.0 / alpha)
            };
            let p = beta * remaining;
            remaining -= p;
            acc += p;
            cdf.push(acc);
        }
        DirichletPartitioner { cdf, alpha, seed }
    }

    pub fn max_groups(&self) -> usize {
        self.cdf.len()
    }
}

impl Partitioner for DirichletPartitioner {
    fn key(&self, example: &Example) -> Vec<u8> {
        // Incremental hash, same digest as fnv1a(&example.encode()) —
        // see RandomPartitioner::key.
        let h = example.content_hash64() ^ self.seed.rotate_left(17);
        let u = Rng::new(h).next_f64();
        let g = match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        };
        format!("dp-{g:06}").into_bytes()
    }

    fn name(&self) -> String {
        format!("dirichlet:alpha={}", self.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::Feature;
    use crate::util::proptest_lite::{check, gen_word, prop_assert, prop_assert_eq};

    fn ex(text: &str, domain: &str) -> Example {
        Example::text(text).with("domain", Feature::bytes_one(domain.as_bytes().to_vec()))
    }

    #[test]
    fn feature_key_extracts_domain() {
        let p = FeatureKey::new("domain");
        assert_eq!(p.key(&ex("hi", "nytimes.com")), b"nytimes.com");
        assert_eq!(p.key(&Example::text("orphan")), b"<missing>");
    }

    #[test]
    fn feature_key_int_and_float() {
        let p = FeatureKey::new("label");
        let e = Example::new().with("label", Feature::ints(vec![9]));
        assert_eq!(p.key(&e), b"9");
        let p2 = FeatureKey::new("score");
        let e2 = Example::new().with("score", Feature::Floats(vec![1.5]));
        assert_eq!(p2.key(&e2), b"1.5");
    }

    #[test]
    fn partitioners_are_pure_functions() {
        let rand = RandomPartitioner::new(50, 3);
        let dir = DirichletPartitioner::new(2.0, 100, 3);
        check(100, |rng| {
            let e = ex(&gen_word(rng, 1..=30), &gen_word(rng, 3..=10));
            prop_assert_eq(rand.key(&e), rand.key(&e), "random purity")?;
            prop_assert_eq(dir.key(&e), dir.key(&e), "dirichlet purity")
        });
    }

    #[test]
    fn incremental_hash_leaves_the_partition_unchanged() {
        use crate::util::rng::fnv1a;
        // The partitioners used to hash fnv1a(&example.encode()); they now
        // hash incrementally. Re-derive the old formulas here verbatim and
        // require key-for-key agreement, so the produced partition for any
        // seed (including the CLI default, 42) can never silently move.
        let rand = RandomPartitioner::new(37, 42);
        let dir = DirichletPartitioner::new(2.5, 500, 42);
        check(200, |rng| {
            let e = ex(&gen_word(rng, 1..=40), &gen_word(rng, 3..=12));
            let old_rand = {
                let h = fnv1a(&e.encode()) ^ 42u64.wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let g = Rng::new(h).gen_range(37);
                format!("rand-{g:06}").into_bytes()
            };
            prop_assert_eq(rand.key(&e), old_rand, "random key unchanged")?;
            let old_dir = {
                let h = fnv1a(&e.encode()) ^ 42u64.rotate_left(17);
                let u = Rng::new(h).next_f64();
                let g = match dir.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
                    Ok(i) => i,
                    Err(i) => i.min(dir.cdf.len() - 1),
                };
                format!("dp-{g:06}").into_bytes()
            };
            prop_assert_eq(dir.key(&e), old_dir, "dirichlet key unchanged")
        });
    }

    #[test]
    fn random_partition_covers_groups_roughly_uniformly() {
        let p = RandomPartitioner::new(10, 7);
        let mut counts = std::collections::HashMap::new();
        for i in 0..5000 {
            let e = ex(&format!("example {i}"), "d");
            *counts.entry(p.key(&e)).or_insert(0u64) += 1;
        }
        assert_eq!(counts.len(), 10);
        for (_, c) in counts {
            assert!((300..=700).contains(&c), "non-uniform: {c}");
        }
    }

    #[test]
    fn different_seeds_give_different_partitions() {
        let p1 = RandomPartitioner::new(100, 1);
        let p2 = RandomPartitioner::new(100, 2);
        let diffs = (0..200)
            .filter(|i| {
                let e = ex(&format!("x{i}"), "d");
                p1.key(&e) != p2.key(&e)
            })
            .count();
        assert!(diffs > 150, "seeds too correlated: {diffs}");
    }

    #[test]
    fn dirichlet_is_heavy_tailed() {
        let p = DirichletPartitioner::new(5.0, 1000, 11);
        let mut counts = std::collections::HashMap::new();
        for i in 0..10_000 {
            let e = ex(&format!("doc {i}"), "d");
            *counts.entry(p.key(&e)).or_insert(0u64) += 1;
        }
        let n_groups = counts.len();
        assert!(n_groups > 5, "{n_groups}");
        let max = *counts.values().max().unwrap();
        let mean = 10_000 / n_groups as u64;
        assert!(max > mean * 3, "max {max} mean {mean}: not heavy tailed");
    }

    #[test]
    fn dirichlet_alpha_controls_group_count() {
        let count_groups = |alpha: f64| {
            let p = DirichletPartitioner::new(alpha, 2000, 5);
            let mut set = std::collections::HashSet::new();
            for i in 0..5000 {
                set.insert(p.key(&ex(&format!("e{i}"), "d")));
            }
            set.len()
        };
        let low = count_groups(1.0);
        let high = count_groups(100.0);
        assert!(high > low * 2, "alpha effect missing: {low} vs {high}");
    }

    #[test]
    fn dirichlet_cdf_is_proper() {
        let p = DirichletPartitioner::new(3.0, 64, 9);
        check(200, |rng| {
            let e = ex(&gen_word(rng, 1..=20), "d");
            let k = p.key(&e);
            prop_assert(k.starts_with(b"dp-"), "key prefix")
        });
        assert!((p.cdf.last().unwrap() - 1.0).abs() < 1e-9);
    }
}
