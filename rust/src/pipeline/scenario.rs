//! The scenario registry: named, config-driven partition scenarios.
//!
//! The paper's promise is "group-structured versions of existing
//! datasets based on user-specified partitions"; a *scenario* is the
//! unit of specification — a name, a human description, and a
//! [`PartitionerSpec`]. The LEAF-style built-in suite
//! ([`builtin_scenarios`]) covers the heterogeneity axes the FL
//! literature benchmarks: natural feature grouping, the IID control,
//! Dirichlet skew, pathological label restriction, MoDM quantity skew,
//! MoDM label skew, and temporal splits. Custom scenarios load from
//! TOML files ([`load_scenario`]) with unknown-key refusal — a typo'd
//! knob is an error, never a silently ignored default.
//!
//! Every scenario materializes through the normal sinks
//! (`run_partition_request`), and [`HeterogeneityReport`] characterizes
//! what came out: group-size quantiles, a p90/p10 quantity-skew ratio,
//! a Gini coefficient, and (for label-aware scenarios) the
//! example-weighted Jensen–Shannon divergence between per-group label
//! histograms and the global one. These are the Table 1b/10b rows.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::toml_lite::{parse as parse_toml, TomlDoc, TomlValue};
use crate::formats::ShardedPagedReader;
use crate::metrics::Summary;
use crate::pipeline::index::GroupIndex;
use crate::pipeline::partition::{
    label_of, GroupObservation, ModmComponent, ModmFitOptions, ModmModel, ModmSpec,
    PartitionerSpec, DEFAULT_DIRICHLET_MAX_GROUPS,
};

/// A named partition scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    pub spec: PartitionerSpec,
}

/// Peaked Dirichlet concentration: `hot` on classes `[lo, hi)`, a cold
/// floor elsewhere — the label-skew building block.
fn peaked_alpha(labels: usize, lo: usize, hi: usize, hot: f64, cold: f64) -> Vec<f64> {
    (0..labels).map(|l| if l >= lo && l < hi { hot } else { cold }).collect()
}

/// The built-in suite. `key_feature` is the dataset's natural grouping
/// feature (fills the `by-feature` scenario); `seed` seeds every
/// stochastic partitioner, so one `--seed` reproduces the whole suite.
pub fn builtin_scenarios(key_feature: &str, seed: u64) -> Vec<Scenario> {
    let scenario = |name: &str, description: &str, spec: PartitionerSpec| Scenario {
        name: name.to_string(),
        description: description.to_string(),
        spec,
    };
    vec![
        scenario(
            "by-feature",
            "natural groups: partition by the dataset's key feature",
            PartitionerSpec::Feature { feature: key_feature.to_string() },
        ),
        scenario(
            "iid",
            "IID control: uniform random assignment over 500 groups",
            PartitionerSpec::Random { num_groups: 500, seed },
        ),
        scenario(
            "dirichlet",
            "stick-breaking Dirichlet-process skew (alpha = 5)",
            PartitionerSpec::Dirichlet {
                alpha: 5.0,
                max_groups: DEFAULT_DIRICHLET_MAX_GROUPS,
                seed,
            },
        ),
        scenario(
            "pathological",
            "pathological non-IID: 100 groups, each seeing 2 of 10 label classes",
            PartitionerSpec::Pathological {
                num_groups: 100,
                classes_per_group: 2,
                num_labels: 10,
                label_feature: "label".to_string(),
                seed,
            },
        ),
        scenario(
            "quantity-skew",
            "MoDM size mixture: many small groups plus a heavy tail of large ones",
            PartitionerSpec::Modm(ModmSpec {
                num_groups: 400,
                label_feature: None,
                seed,
                model: ModmModel {
                    components: vec![
                        ModmComponent {
                            weight: 0.85,
                            size_mu: 3.0,
                            size_sigma: 0.6,
                            label_alpha: vec![],
                        },
                        ModmComponent {
                            weight: 0.15,
                            size_mu: 5.5,
                            size_sigma: 0.9,
                            label_alpha: vec![],
                        },
                    ],
                },
            }),
        ),
        scenario(
            "label-skew",
            "MoDM label mixture: 3 components peaked on disjoint label ranges",
            PartitionerSpec::Modm(ModmSpec {
                num_groups: 300,
                label_feature: Some("label".to_string()),
                seed,
                model: ModmModel {
                    components: vec![
                        ModmComponent {
                            weight: 0.4,
                            size_mu: 3.6,
                            size_sigma: 0.5,
                            label_alpha: peaked_alpha(10, 0, 3, 4.0, 0.2),
                        },
                        ModmComponent {
                            weight: 0.3,
                            size_mu: 3.6,
                            size_sigma: 0.5,
                            label_alpha: peaked_alpha(10, 3, 6, 4.0, 0.2),
                        },
                        ModmComponent {
                            weight: 0.3,
                            size_mu: 3.6,
                            size_sigma: 0.5,
                            label_alpha: peaked_alpha(10, 6, 10, 4.0, 0.2),
                        },
                    ],
                },
            }),
        ),
        scenario(
            "temporal",
            "temporal split: one group per window of 16 sequence indices",
            PartitionerSpec::Temporal { feature: "example_index".to_string(), period: 16 },
        ),
    ]
}

/// Look up a built-in by name.
pub fn find_builtin(name: &str, key_feature: &str, seed: u64) -> Option<Scenario> {
    builtin_scenarios(key_feature, seed).into_iter().find(|s| s.name == name)
}

/// Resolve a `--scenario` argument: a built-in name, else a path to a
/// scenario TOML file.
pub fn resolve_scenario(arg: &str, key_feature: &str, seed: u64) -> Result<Scenario> {
    if let Some(s) = find_builtin(arg, key_feature, seed) {
        return Ok(s);
    }
    let path = Path::new(arg);
    if arg.ends_with(".toml") || path.exists() {
        return load_scenario(path);
    }
    let names: Vec<String> =
        builtin_scenarios(key_feature, seed).into_iter().map(|s| s.name).collect();
    bail!(
        "unknown scenario {arg:?}; built-ins: {}, or pass a path to a scenario .toml",
        names.join(", ")
    )
}

/// Load a scenario from a TOML file. `fit_index` paths inside the file
/// resolve relative to the process working directory.
pub fn load_scenario(path: &Path) -> Result<Scenario> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading scenario file {}", path.display()))?;
    scenario_from_toml_str(&text)
        .with_context(|| format!("in scenario file {}", path.display()))
}

/// Parse a scenario TOML document:
///
/// ```toml
/// name = "my-skew"
/// description = "optional prose"
///
/// [partitioner]
/// kind = "dirichlet"       # feature|random|dirichlet|pathological|temporal|modm
/// alpha = 5.0
/// max_groups = 10000       # optional (default 10000)
/// seed = 42                # optional (default 42)
/// ```
///
/// MoDM declares its mixture as parallel per-component arrays (the
/// TOML subset has no array-of-tables) plus one `alpha_<i>` array per
/// labeled component:
///
/// ```toml
/// [partitioner]
/// kind = "modm"
/// groups = 300
/// label_feature = "label"
/// weights = [0.6, 0.4]
/// size_mu = [3.0, 5.5]
/// size_sigma = [0.5, 0.8]
/// alpha_0 = [4.0, 0.2]
/// alpha_1 = [0.2, 4.0]
/// ```
///
/// — or asks for a fit against an existing materialization's group
/// sizes: `fit_index = "work/part/data.gindex"` with optional
/// `fit_components` / `fit_iterations`. Unknown keys are refused.
pub fn scenario_from_toml_str(text: &str) -> Result<Scenario> {
    let doc = parse_toml(text)?;
    let name = match doc.get("name").map(|v| require_str("name", v)) {
        Some(n) => n?,
        None => bail!("scenario is missing the top-level `name` key"),
    };
    let description =
        match doc.get("description") {
            Some(v) => require_str("description", v)?,
            None => String::new(),
        };
    let Some(kind_v) = doc.get("partitioner.kind") else {
        bail!("scenario is missing `kind` under [partitioner]");
    };
    let kind = require_str("partitioner.kind", kind_v)?;
    let seed = get_u64(&doc, "partitioner.seed")?.unwrap_or(42);
    let spec = match kind.as_str() {
        "feature" => PartitionerSpec::Feature {
            feature: get_str(&doc, "partitioner.feature")?
                .context("feature scenarios need `feature`")?,
        },
        "random" => PartitionerSpec::Random {
            num_groups: get_usize(&doc, "partitioner.groups")?
                .context("random scenarios need `groups`")?,
            seed,
        },
        "dirichlet" => PartitionerSpec::Dirichlet {
            alpha: get_f64(&doc, "partitioner.alpha")?
                .context("dirichlet scenarios need `alpha`")?,
            max_groups: get_usize(&doc, "partitioner.max_groups")?
                .unwrap_or(DEFAULT_DIRICHLET_MAX_GROUPS),
            seed,
        },
        "pathological" => PartitionerSpec::Pathological {
            num_groups: get_usize(&doc, "partitioner.groups")?
                .context("pathological scenarios need `groups`")?,
            classes_per_group: get_usize(&doc, "partitioner.classes_per_group")?
                .context("pathological scenarios need `classes_per_group`")?,
            num_labels: get_usize(&doc, "partitioner.labels")?.unwrap_or(10),
            label_feature: get_str(&doc, "partitioner.label_feature")?
                .unwrap_or_else(|| "label".to_string()),
            seed,
        },
        "temporal" => PartitionerSpec::Temporal {
            feature: get_str(&doc, "partitioner.feature")?
                .unwrap_or_else(|| "example_index".to_string()),
            period: get_u64(&doc, "partitioner.period")?
                .context("temporal scenarios need `period`")?,
        },
        "modm" => PartitionerSpec::Modm(modm_from_doc(&doc, seed)?),
        other => bail!(
            "unknown partitioner kind {other:?}; expected feature | random | dirichlet | \
             pathological | temporal | modm"
        ),
    };
    refuse_unknown_keys(&doc, &spec)?;
    spec.validate().map_err(anyhow::Error::from)?;
    Ok(Scenario { name, description, spec })
}

fn modm_from_doc(doc: &TomlDoc, seed: u64) -> Result<ModmSpec> {
    let num_groups =
        get_usize(doc, "partitioner.groups")?.context("modm scenarios need `groups`")?;
    let label_feature = get_str(doc, "partitioner.label_feature")?;
    let declared = doc.contains_key("partitioner.weights");
    let fitted = doc.contains_key("partitioner.fit_index");
    let model = match (declared, fitted) {
        (true, true) => {
            bail!("modm scenarios declare components (`weights`/...) or `fit_index`, not both")
        }
        (false, false) => {
            bail!("modm scenarios need declared components (`weights`/`size_mu`/`size_sigma`) \
                   or `fit_index`")
        }
        (true, false) => {
            let weights = get_f64_array(doc, "partitioner.weights")?;
            let size_mu = get_f64_array(doc, "partitioner.size_mu")?;
            let size_sigma = get_f64_array(doc, "partitioner.size_sigma")?;
            if weights.is_empty() {
                bail!("`weights` must name at least one component");
            }
            if size_mu.len() != weights.len() || size_sigma.len() != weights.len() {
                bail!(
                    "component arrays disagree: {} weights, {} size_mu, {} size_sigma",
                    weights.len(),
                    size_mu.len(),
                    size_sigma.len()
                );
            }
            let mut components = Vec::with_capacity(weights.len());
            let has_alphas = doc.contains_key("partitioner.alpha_0");
            for (i, &w) in weights.iter().enumerate() {
                let label_alpha = if has_alphas {
                    get_f64_array(doc, &format!("partitioner.alpha_{i}")).with_context(|| {
                        format!("labeled modm components each need an `alpha_{i}` array")
                    })?
                } else {
                    Vec::new()
                };
                components.push(ModmComponent {
                    weight: w,
                    size_mu: size_mu[i],
                    size_sigma: size_sigma[i],
                    label_alpha,
                });
            }
            ModmModel { components }
        }
        (false, true) => {
            let index_path = get_str(doc, "partitioner.fit_index")?.unwrap();
            let index = GroupIndex::read(Path::new(&index_path))
                .with_context(|| format!("reading fit_index {index_path}"))?;
            let opts = ModmFitOptions {
                components: get_usize(doc, "partitioner.fit_components")?.unwrap_or(2),
                iterations: get_usize(doc, "partitioner.fit_iterations")?.unwrap_or(40),
                seed,
            };
            ModmModel::fit(&observations_from_index(&index), &opts)
                .map_err(anyhow::Error::from)?
        }
    };
    Ok(ModmSpec { num_groups, label_feature, seed, model })
}

/// Refuse any key the chosen kind does not consume — a typo'd knob must
/// fail loudly, not silently fall back to a default.
fn refuse_unknown_keys(doc: &TomlDoc, spec: &PartitionerSpec) -> Result<()> {
    let allowed: &[&str] = match spec {
        PartitionerSpec::Feature { .. } => &["kind", "feature"],
        PartitionerSpec::Random { .. } => &["kind", "groups", "seed"],
        PartitionerSpec::Dirichlet { .. } => &["kind", "alpha", "max_groups", "seed"],
        PartitionerSpec::Pathological { .. } => {
            &["kind", "groups", "classes_per_group", "labels", "label_feature", "seed"]
        }
        PartitionerSpec::Temporal { .. } => &["kind", "feature", "period"],
        PartitionerSpec::Modm(_) => &[
            "kind",
            "groups",
            "seed",
            "label_feature",
            "weights",
            "size_mu",
            "size_sigma",
            "fit_index",
            "fit_components",
            "fit_iterations",
        ],
    };
    let components = match spec {
        PartitionerSpec::Modm(m) => m.model.components.len(),
        _ => 0,
    };
    for key in doc.keys() {
        let ok = if let Some(sub) = key.strip_prefix("partitioner.") {
            allowed.contains(&sub)
                || sub
                    .strip_prefix("alpha_")
                    .and_then(|i| i.parse::<usize>().ok())
                    .is_some_and(|i| matches!(spec, PartitionerSpec::Modm(_)) && i < components)
        } else {
            key == "name" || key == "description"
        };
        if !ok {
            bail!("unknown scenario key {key:?} (for kind \"{}\")", kind_name(spec));
        }
    }
    Ok(())
}

fn kind_name(spec: &PartitionerSpec) -> &'static str {
    match spec {
        PartitionerSpec::Feature { .. } => "feature",
        PartitionerSpec::Random { .. } => "random",
        PartitionerSpec::Dirichlet { .. } => "dirichlet",
        PartitionerSpec::Pathological { .. } => "pathological",
        PartitionerSpec::Temporal { .. } => "temporal",
        PartitionerSpec::Modm(_) => "modm",
    }
}

/// Serialize a scenario back to the TOML grammar [`load_scenario`]
/// accepts (fitted MoDM models serialize as declared components, so a
/// fit can be frozen into a file). Round-trip: `scenario_from_toml_str
/// (scenario_to_toml(s))` reproduces `s.spec` exactly.
pub fn scenario_to_toml(s: &Scenario) -> String {
    let mut out = String::new();
    out.push_str(&format!("name = \"{}\"\n", s.name));
    if !s.description.is_empty() {
        out.push_str(&format!("description = \"{}\"\n", s.description));
    }
    out.push_str("\n[partitioner]\n");
    out.push_str(&format!("kind = \"{}\"\n", kind_name(&s.spec)));
    let push_f64 = |out: &mut String, key: &str, v: f64| {
        // `{:?}` prints a round-trippable float (always with a decimal
        // point, so it re-parses as Float, though Int coercion would be
        // fine too).
        out.push_str(&format!("{key} = {v:?}\n"));
    };
    match &s.spec {
        PartitionerSpec::Feature { feature } => {
            out.push_str(&format!("feature = \"{feature}\"\n"));
        }
        PartitionerSpec::Random { num_groups, seed } => {
            out.push_str(&format!("groups = {num_groups}\nseed = {seed}\n"));
        }
        PartitionerSpec::Dirichlet { alpha, max_groups, seed } => {
            push_f64(&mut out, "alpha", *alpha);
            out.push_str(&format!("max_groups = {max_groups}\nseed = {seed}\n"));
        }
        PartitionerSpec::Pathological {
            num_groups,
            classes_per_group,
            num_labels,
            label_feature,
            seed,
        } => {
            out.push_str(&format!(
                "groups = {num_groups}\nclasses_per_group = {classes_per_group}\n\
                 labels = {num_labels}\nlabel_feature = \"{label_feature}\"\nseed = {seed}\n"
            ));
        }
        PartitionerSpec::Temporal { feature, period } => {
            out.push_str(&format!("feature = \"{feature}\"\nperiod = {period}\n"));
        }
        PartitionerSpec::Modm(m) => {
            out.push_str(&format!("groups = {}\nseed = {}\n", m.num_groups, m.seed));
            if let Some(f) = &m.label_feature {
                out.push_str(&format!("label_feature = \"{f}\"\n"));
            }
            let join = |xs: &[f64]| {
                xs.iter().map(|x| format!("{x:?}")).collect::<Vec<_>>().join(", ")
            };
            let comps = &m.model.components;
            out.push_str(&format!(
                "weights = [{}]\n",
                join(&comps.iter().map(|c| c.weight).collect::<Vec<_>>())
            ));
            out.push_str(&format!(
                "size_mu = [{}]\n",
                join(&comps.iter().map(|c| c.size_mu).collect::<Vec<_>>())
            ));
            out.push_str(&format!(
                "size_sigma = [{}]\n",
                join(&comps.iter().map(|c| c.size_sigma).collect::<Vec<_>>())
            ));
            if m.model.num_labels() > 0 {
                for (i, c) in comps.iter().enumerate() {
                    out.push_str(&format!("alpha_{i} = [{}]\n", join(&c.label_alpha)));
                }
            }
        }
    }
    out
}

// ---- TOML getters (typed, with the key in every error) ----

fn require_str(key: &str, v: &TomlValue) -> Result<String> {
    v.as_str().map(|s| s.to_string()).with_context(|| format!("`{key}` must be a string"))
}

fn get_str(doc: &TomlDoc, key: &str) -> Result<Option<String>> {
    doc.get(key).map(|v| require_str(key, v)).transpose()
}

fn get_u64(doc: &TomlDoc, key: &str) -> Result<Option<u64>> {
    doc.get(key)
        .map(|v| {
            let i = v.as_int().with_context(|| format!("`{key}` must be an integer"))?;
            u64::try_from(i).with_context(|| format!("`{key}` must be non-negative"))
        })
        .transpose()
}

fn get_usize(doc: &TomlDoc, key: &str) -> Result<Option<usize>> {
    Ok(get_u64(doc, key)?.map(|v| v as usize))
}

fn get_f64(doc: &TomlDoc, key: &str) -> Result<Option<f64>> {
    doc.get(key)
        .map(|v| v.as_float().with_context(|| format!("`{key}` must be a number")))
        .transpose()
}

fn get_f64_array(doc: &TomlDoc, key: &str) -> Result<Vec<f64>> {
    let Some(v) = doc.get(key) else {
        bail!("`{key}` array is missing");
    };
    let TomlValue::Array(items) = v else {
        bail!("`{key}` must be an array of numbers");
    };
    items
        .iter()
        .map(|item| {
            item.as_float().with_context(|| format!("`{key}` must contain only numbers"))
        })
        .collect()
}

// ---- Heterogeneity characterization (Table 1b/10b) ----

/// What a materialized scenario looks like: size spread and (for
/// label-aware scenarios) label skew.
#[derive(Debug, Clone)]
pub struct HeterogeneityReport {
    pub num_groups: usize,
    pub num_examples: u64,
    /// Distribution summary of per-group example counts.
    pub sizes: Summary,
    /// p90 / max(p10, 1) of group sizes — the quantity-skew headline.
    pub size_ratio: f64,
    /// Gini coefficient of group sizes, in [0, 1).
    pub size_gini: f64,
    /// Example-weighted mean Jensen–Shannon divergence (nats, so
    /// bounded by ln 2) between each group's label histogram and the
    /// global one; `None` when the scenario has no label model.
    pub label_divergence: Option<f64>,
}

/// Characterize a population from its per-group sizes and (optionally)
/// per-group label histograms (parallel to `sizes`).
pub fn heterogeneity(sizes: &[u64], label_hists: Option<&[Vec<u64>]>) -> HeterogeneityReport {
    if sizes.is_empty() {
        return HeterogeneityReport {
            num_groups: 0,
            num_examples: 0,
            sizes: Summary::of(&[0.0]),
            size_ratio: 1.0,
            size_gini: 0.0,
            label_divergence: None,
        };
    }
    let fs: Vec<f64> = sizes.iter().map(|&s| s as f64).collect();
    let summary = Summary::of(&fs);
    let num_examples: u64 = sizes.iter().sum();
    let label_divergence = label_hists.map(|hists| {
        assert_eq!(hists.len(), sizes.len(), "label histograms must parallel sizes");
        mean_label_js_divergence(hists)
    });
    HeterogeneityReport {
        num_groups: sizes.len(),
        num_examples,
        size_ratio: summary.p90 / summary.p10.max(1.0),
        size_gini: gini(sizes),
        sizes: summary,
        label_divergence,
    }
}

/// Characterize an already-materialized streaming partition from its
/// group index (sizes only — the index does not store labels).
pub fn heterogeneity_of_index(index: &GroupIndex) -> HeterogeneityReport {
    let sizes: Vec<u64> = index.entries.iter().map(|e| e.num_examples).collect();
    heterogeneity(&sizes, None)
}

/// Characterize a materialized paged/sharded set by visiting every
/// group. `label` = (feature, class count) turns on label-skew
/// measurement, costing one decode pass over the set.
pub fn characterize_paged(
    dir: &Path,
    prefix: &str,
    cache_pages: usize,
    label: Option<(&str, usize)>,
) -> Result<HeterogeneityReport> {
    let reader = ShardedPagedReader::open(dir, prefix, cache_pages)?;
    let mut sizes = Vec::with_capacity(reader.num_groups());
    let mut hists: Vec<Vec<u64>> = Vec::new();
    for key in reader.keys().to_vec() {
        let mut n = 0u64;
        let mut hist = label.map(|(_, l)| vec![0u64; l]);
        reader.visit_group(&key, |ex| {
            n += 1;
            if let (Some(hist), Some((feature, l))) = (hist.as_mut(), label) {
                hist[label_of(&ex, feature, l)] += 1;
            }
        })?;
        sizes.push(n);
        if let Some(hist) = hist {
            hists.push(hist);
        }
    }
    Ok(heterogeneity(&sizes, label.map(|_| hists.as_slice())))
}

/// Size-only fit observations from a streaming partition's group index.
pub fn observations_from_index(index: &GroupIndex) -> Vec<GroupObservation> {
    index
        .entries
        .iter()
        .map(|e| GroupObservation { size: e.num_examples, label_counts: Vec::new() })
        .collect()
}

/// Gini coefficient of a size distribution (0 = perfectly even).
pub fn gini(sizes: &[u64]) -> f64 {
    let n = sizes.len();
    let total: u64 = sizes.iter().sum();
    if n == 0 || total == 0 {
        return 0.0;
    }
    let mut sorted: Vec<u64> = sizes.to_vec();
    sorted.sort_unstable();
    let weighted: f64 =
        sorted.iter().enumerate().map(|(i, &x)| (i as f64 + 1.0) * x as f64).sum();
    (2.0 * weighted) / (n as f64 * total as f64) - (n as f64 + 1.0) / n as f64
}

/// Example-weighted mean Jensen–Shannon divergence (nats) between each
/// group's label distribution and the population's.
fn mean_label_js_divergence(hists: &[Vec<u64>]) -> f64 {
    let l = hists.first().map(|h| h.len()).unwrap_or(0);
    if l == 0 {
        return 0.0;
    }
    let mut global = vec![0u64; l];
    let mut total = 0u64;
    for h in hists {
        for (g, &c) in global.iter_mut().zip(h) {
            *g += c;
        }
        total += h.iter().sum::<u64>();
    }
    if total == 0 {
        return 0.0;
    }
    let q: Vec<f64> = global.iter().map(|&c| c as f64 / total as f64).collect();
    let mut acc = 0.0;
    for h in hists {
        let n: u64 = h.iter().sum();
        if n == 0 {
            continue;
        }
        let p: Vec<f64> = h.iter().map(|&c| c as f64 / n as f64).collect();
        acc += n as f64 / total as f64 * js_divergence(&p, &q);
    }
    acc
}

/// Jensen–Shannon divergence in nats (`0 ln 0 = 0` convention).
fn js_divergence(p: &[f64], q: &[f64]) -> f64 {
    let mut d = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        let m = 0.5 * (pi + qi);
        if pi > 0.0 {
            d += 0.5 * pi * (pi / m).ln();
        }
        if qi > 0.0 {
            d += 0.5 * qi * (qi / m).ln();
        }
    }
    d.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_suite_is_well_formed() {
        let suite = builtin_scenarios("domain", 42);
        assert_eq!(suite.len(), 7);
        let names: std::collections::HashSet<&str> =
            suite.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names.len(), suite.len(), "duplicate scenario names");
        for s in &suite {
            s.spec.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
            assert!(!s.description.is_empty(), "{} has no description", s.name);
            assert!(find_builtin(&s.name, "domain", 42).is_some());
        }
    }

    #[test]
    fn builtin_toml_round_trips() {
        for s in builtin_scenarios("domain", 7) {
            let toml = scenario_to_toml(&s);
            let back = scenario_from_toml_str(&toml)
                .unwrap_or_else(|e| panic!("{} failed to re-parse: {e:#}\n{toml}", s.name));
            assert_eq!(back.spec, s.spec, "{} spec drifted through TOML:\n{toml}", s.name);
            assert_eq!(back.name, s.name);
        }
    }

    #[test]
    fn unknown_keys_are_refused() {
        let base = "name = \"x\"\n[partitioner]\nkind = \"random\"\ngroups = 10\n";
        assert!(scenario_from_toml_str(base).is_ok());
        let typo = format!("{base}grups = 5\n");
        let err = scenario_from_toml_str(&typo).unwrap_err();
        assert!(format!("{err:#}").contains("grups"), "{err:#}");
        // Keys of *other* kinds are just as unknown.
        let wrong_kind = format!("{base}alpha = 2.0\n");
        assert!(scenario_from_toml_str(&wrong_kind).is_err());
        // Top-level strangers too.
        let top = format!("surprise = 1\n{base}");
        assert!(scenario_from_toml_str(&top).is_err());
        // Out-of-range alpha_<i> for a 1-component modm.
        let modm = "name = \"m\"\n[partitioner]\nkind = \"modm\"\ngroups = 5\n\
                    weights = [1.0]\nsize_mu = [3.0]\nsize_sigma = [0.5]\nalpha_1 = [1.0]\n";
        assert!(scenario_from_toml_str(modm).is_err());
    }

    #[test]
    fn malformed_scenarios_fail_with_context() {
        // No kind.
        assert!(scenario_from_toml_str("name = \"x\"\n").is_err());
        // No name.
        assert!(scenario_from_toml_str("[partitioner]\nkind = \"random\"\ngroups = 1\n")
            .is_err());
        // Component arrays disagree.
        let ragged = "name = \"m\"\n[partitioner]\nkind = \"modm\"\ngroups = 5\n\
                      weights = [0.5, 0.5]\nsize_mu = [3.0]\nsize_sigma = [0.5, 0.5]\n";
        assert!(scenario_from_toml_str(ragged).is_err());
        // Declared + fitted at once.
        let both = "name = \"m\"\n[partitioner]\nkind = \"modm\"\ngroups = 5\n\
                    weights = [1.0]\nsize_mu = [3.0]\nsize_sigma = [0.5]\n\
                    fit_index = \"nope.gindex\"\n";
        assert!(scenario_from_toml_str(both).is_err());
        // Invalid domain surfaces the typed SpecError.
        let bad = "name = \"d\"\n[partitioner]\nkind = \"dirichlet\"\nalpha = -1.0\n";
        let err = scenario_from_toml_str(bad).unwrap_err();
        assert!(format!("{err:#}").contains("alpha"), "{err:#}");
    }

    #[test]
    fn gini_and_js_basics() {
        assert_eq!(gini(&[5, 5, 5, 5]), 0.0);
        assert!(gini(&[0, 0, 0, 100]) > 0.7);
        assert_eq!(gini(&[]), 0.0);
        let uniform = vec![vec![10u64, 10, 10], vec![10, 10, 10]];
        assert!(mean_label_js_divergence(&uniform) < 1e-12);
        // Each group is a point mass, the global is uniform over 3:
        // JSD = (ln 1.5 + ln 2 / 3) / 2 ≈ 0.3183 nats, equal weights.
        let skewed = vec![vec![30u64, 0, 0], vec![0, 30, 0], vec![0, 0, 30]];
        let d = mean_label_js_divergence(&skewed);
        assert!((d - 0.3182).abs() < 1e-3 && d <= std::f64::consts::LN_2 + 1e-9, "{d}");
    }

    #[test]
    fn heterogeneity_report_shapes() {
        let r = heterogeneity(&[1, 1, 1, 1, 100], None);
        assert_eq!(r.num_groups, 5);
        assert_eq!(r.num_examples, 104);
        assert!(r.size_ratio > 1.0);
        assert!(r.size_gini > 0.5);
        assert!(r.label_divergence.is_none());
        let empty = heterogeneity(&[], None);
        assert_eq!(empty.num_groups, 0);
        assert_eq!(empty.num_examples, 0);
    }
}
