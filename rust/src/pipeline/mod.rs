//! The Dataset Grouper partitioning pipeline ("beam-lite").
//!
//! This is the paper's §3.2 contribution: create a group-structured
//! materialization of a base dataset from a user-specified,
//! **embarrassingly parallel** partition function `example -> group_key`
//! (sequential partition rules are rejected by construction — the
//! [`partition::Partitioner`] trait only sees one example at a time,
//! exactly the `get_key_fn` contract of the paper's Listing 1).
//!
//! Dataflow (mirrors a Beam shuffle):
//!
//! ```text
//!  BaseDataset ──split──> W map workers:  key = get_key_fn(example)
//!          (key, seq, example) ──hash(key) % S──> per-(worker,bucket) spill runs
//!  per bucket (parallel):  external sort by (key, split, seq)   [disk-backed]
//!          ──merge──> contiguous groups appended to shard b  + index entries
//!  merged index: group -> (shard, offset, count, bytes)
//! ```
//!
//! The external sort is what lets a *single group* exceed memory: grouping
//! never holds more than `spill_chunk_bytes` of examples in RAM
//! (`runner::PartitionOptions`), no matter how large a group gets.
//!
//! Output layout (consumed by [`crate::formats`]):
//! * `<prefix>-SSSSS-of-TTTTT.tfrecord` — encoded [`crate::records::Example`]s,
//!   group-contiguous within a shard;
//! * `<prefix>.gindex` — the group index ([`index`]).
//!
//! When the output format is **paged** ([`run_partition_paged`]), the
//! group-by-key buckets skip the TFRecord sink entirely: each bucket's
//! merged stream appends concurrently into its own shard's `PagedStore`
//! (one WAL per shard), producing `<prefix>.pset` +
//! `<prefix>-sSSSSS-of-TTTTT.{pstore,pdata,pwal}` — see
//! [`crate::formats::paged_sharded`].
//!
//! Partitioners are constructed from a typed [`partition::PartitionerSpec`]
//! (parse → validate → build), and named bundles of spec + provenance live
//! in the [`scenario`] registry — `grouper partition --scenario label-skew`
//! end to end.

pub mod index;
pub mod partition;
pub mod runner;
pub mod scenario;

pub use index::{GroupIndex, GroupIndexEntry};
pub use partition::{
    label_of, DirichletPartitioner, FeatureKey, GroupObservation, ModmComponent,
    ModmFitOptions, ModmModel, ModmPartitioner, ModmSpec, Partitioner, PartitionerSpec,
    PathologicalPartitioner, RandomPartitioner, SpecError, TemporalPartitioner,
    DEFAULT_DIRICHLET_MAX_GROUPS,
};
pub use runner::{
    run_partition, run_partition_paged, run_partition_request, PagedPartitionOptions,
    PagedPartitionReport, PartitionOptions, PartitionReport, PartitionRequest,
    PartitionSummary, SinkOptions, SinkReport,
};
pub use scenario::{
    builtin_scenarios, characterize_paged, heterogeneity, heterogeneity_of_index,
    load_scenario, observations_from_index, resolve_scenario, HeterogeneityReport, Scenario,
};
