//! The group index: `group_key -> (shard, byte offset, example count,
//! framed byte length, word count)`.
//!
//! This sidecar is what distinguishes the three formats' access patterns:
//! the *hierarchical* format loads the index into memory and seeks per
//! group; the *streaming* format walks each shard's entries in offset
//! order; the statistics module aggregates over entries without touching
//! the data shards at all.
//!
//! On-disk encoding: a magic header, then one length-prefixed entry per
//! group (LE fixed-width fields). Entries are sorted by (shard, offset) —
//! i.e. physical layout order — which both access patterns want.

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"GRPIDX01";

/// One group's placement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupIndexEntry {
    pub key: Vec<u8>,
    pub shard: u32,
    pub offset: u64,
    pub num_examples: u64,
    /// Total framed bytes of the group's records (offset..offset+bytes is
    /// the group's contiguous extent in the shard).
    pub bytes: u64,
    /// Whitespace words summed over the group's `text` features (0 for
    /// non-text datasets) — powers Table 1/6/7 without re-reading data.
    pub words: u64,
}

/// The full index of a materialized partitioned dataset.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GroupIndex {
    pub entries: Vec<GroupIndexEntry>,
}

impl GroupIndex {
    pub fn num_groups(&self) -> usize {
        self.entries.len()
    }

    pub fn total_examples(&self) -> u64 {
        self.entries.iter().map(|e| e.num_examples).sum()
    }

    pub fn total_words(&self) -> u64 {
        self.entries.iter().map(|e| e.words).sum()
    }

    /// Sort into physical layout order (shard, then offset).
    pub fn sort_physical(&mut self) {
        self.entries.sort_by(|a, b| (a.shard, a.offset).cmp(&(b.shard, b.offset)));
    }

    pub fn write<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.entries.len() as u64).to_le_bytes())?;
        for e in &self.entries {
            w.write_all(&(e.key.len() as u32).to_le_bytes())?;
            w.write_all(&e.key)?;
            w.write_all(&e.shard.to_le_bytes())?;
            w.write_all(&e.offset.to_le_bytes())?;
            w.write_all(&e.num_examples.to_le_bytes())?;
            w.write_all(&e.bytes.to_le_bytes())?;
            w.write_all(&e.words.to_le_bytes())?;
        }
        w.flush()
    }

    /// Read an index from the real filesystem.
    pub fn read<P: AsRef<Path>>(path: P) -> io::Result<GroupIndex> {
        Self::read_with(&crate::store::vfs::StdVfs, path.as_ref())
    }

    /// [`GroupIndex::read`] over an explicit [`crate::store::vfs::Vfs`]
    /// (so VFS-portable formats can resolve the sidecar from the same
    /// backend as their shards).
    pub fn read_with(vfs: &dyn crate::store::vfs::Vfs, path: &Path) -> io::Result<GroupIndex> {
        let mut r = BufReader::new(crate::store::vfs::VfsCursor::new(
            vfs.open(path, crate::store::vfs::OpenMode::Read)?,
        ));
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bad index magic in {}", path.display()),
            ));
        }
        let mut n8 = [0u8; 8];
        r.read_exact(&mut n8)?;
        let n = u64::from_le_bytes(n8) as usize;
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            let mut l4 = [0u8; 4];
            r.read_exact(&mut l4)?;
            let klen = u32::from_le_bytes(l4) as usize;
            let mut key = vec![0u8; klen];
            r.read_exact(&mut key)?;
            let mut f4 = [0u8; 4];
            let mut f8 = [0u8; 8];
            r.read_exact(&mut f4)?;
            let shard = u32::from_le_bytes(f4);
            r.read_exact(&mut f8)?;
            let offset = u64::from_le_bytes(f8);
            r.read_exact(&mut f8)?;
            let num_examples = u64::from_le_bytes(f8);
            r.read_exact(&mut f8)?;
            let bytes = u64::from_le_bytes(f8);
            r.read_exact(&mut f8)?;
            let words = u64::from_le_bytes(f8);
            entries.push(GroupIndexEntry { key, shard, offset, num_examples, bytes, words });
        }
        Ok(GroupIndex { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, gen_bytes, gen_vec, prop_assert_eq};

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grouper_index_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_property() {
        check(50, |rng| {
            let entries = gen_vec(rng, 0..=30, |r| GroupIndexEntry {
                key: gen_bytes(r, 0..=40),
                shard: r.next_u32() % 64,
                offset: r.next_u64() % (1 << 40),
                num_examples: r.next_u64() % 1000,
                bytes: r.next_u64() % (1 << 40),
                words: r.next_u64() % (1 << 30),
            });
            let idx = GroupIndex { entries };
            let p = tmpfile(&format!("i{}.gindex", rng.next_u32()));
            idx.write(&p).unwrap();
            let back = GroupIndex::read(&p).unwrap();
            std::fs::remove_file(&p).ok();
            prop_assert_eq(back, idx, "index roundtrip")
        });
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmpfile("bad.gindex");
        std::fs::write(&p, b"NOTMAGIC\0\0\0\0\0\0\0\0").unwrap();
        assert!(GroupIndex::read(&p).is_err());
    }

    #[test]
    fn aggregates() {
        let idx = GroupIndex {
            entries: vec![
                GroupIndexEntry { key: b"a".to_vec(), shard: 1, offset: 100, num_examples: 2, bytes: 50, words: 10 },
                GroupIndexEntry { key: b"b".to_vec(), shard: 0, offset: 0, num_examples: 3, bytes: 70, words: 20 },
            ],
        };
        assert_eq!(idx.num_groups(), 2);
        assert_eq!(idx.total_examples(), 5);
        assert_eq!(idx.total_words(), 30);
        let mut sorted = idx.clone();
        sorted.sort_physical();
        assert_eq!(sorted.entries[0].key, b"b");
    }
}
