//! Deterministic pseudo-random generation and the distribution samplers the
//! synthetic corpora need.
//!
//! The offline crate registry has no `rand`, so this module provides a
//! small, well-tested substitute: [`Rng`] is SplitMix64 (Steele et al.,
//! "Fast Splittable Pseudorandom Number Generators") — a 64-bit
//! counter-based generator with excellent statistical quality for
//! simulation purposes and, crucially for reproducibility, *stable output
//! across platforms and releases*. Every dataset/partition/experiment in
//! this repo is a pure function of its seed.
//!
//! Distribution samplers implemented on top: uniform ranges, Bernoulli,
//! Gaussian (Box–Muller), log-normal (the paper's Figure 3 fits per-group
//! sizes as log-normal), Zipf (bounded, via rejection-inversion — text
//! token frequencies, per the paper's §4 discussion of heavy tails),
//! Poisson, gamma (Marsaglia–Tsang) with Dirichlet and multinomial
//! composites (the MoDM scenario sampler), Dirichlet-process partition
//! sampling (Appendix A.1's heterogeneous partitioner), and
//! Fisher–Yates shuffling.

/// SplitMix64: deterministic, seedable, platform-stable.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream for a sub-task (e.g. per group, per
    /// shard) without correlating with the parent stream.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut r = Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407));
        r.next_u64();
        r
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// to avoid modulo bias.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn gen_range_usize(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: `exp(N(mu, sigma))`. The paper's per-group size model
    /// (Figure 3: Q-Q of log sizes vs Gaussian is near-linear).
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Poisson via Knuth (small lambda) / normal approximation (large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_with(lambda, lambda.sqrt()).round();
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang squeeze (2000), the standard
    /// rejection sampler; shapes below 1 use the boost
    /// `Gamma(a) = Gamma(a+1) · U^(1/a)`.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0 && shape.is_finite(), "gamma shape {shape}");
        if shape < 1.0 {
            let boost = self.next_f64().max(1e-300).powf(1.0 / shape);
            return self.gamma(shape + 1.0) * boost;
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * (x * x) * (x * x) {
                return d * v;
            }
            if u > 1e-300 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alphas) draw: normalized independent gamma variates.
    pub fn dirichlet(&mut self, alphas: &[f64]) -> Vec<f64> {
        assert!(!alphas.is_empty());
        let draws: Vec<f64> = alphas.iter().map(|&a| self.gamma(a)).collect();
        let total: f64 = draws.iter().sum();
        if total <= 0.0 {
            // All gammas underflowed (pathologically tiny alphas): fall
            // back to a deterministic one-hot on the largest alpha.
            let mut out = vec![0.0; alphas.len()];
            let argmax = alphas
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            out[argmax] = 1.0;
            return out;
        }
        draws.iter().map(|&d| d / total).collect()
    }

    /// Multinomial(n, probs) draw by sequential binomial-free sampling:
    /// `n` categorical draws against the probability CDF. O(n log k) —
    /// fine for the group sizes the synthetic populations use.
    pub fn multinomial(&mut self, n: u64, probs: &[f64]) -> Vec<u64> {
        assert!(!probs.is_empty());
        let mut cdf = Vec::with_capacity(probs.len());
        let mut acc = 0.0;
        for &p in probs {
            acc += p.max(0.0);
            cdf.push(acc);
        }
        let total = acc.max(1e-300);
        let mut counts = vec![0u64; probs.len()];
        for _ in 0..n {
            let u = self.next_f64() * total;
            let i = match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
                Ok(i) => i,
                Err(i) => i.min(probs.len() - 1),
            };
            counts[i] += 1;
        }
        counts
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.gen_range_usize(n);
            if seen.insert(i) {
                out.push(i);
            }
        }
        out
    }
}

/// Bounded Zipf(s) sampler over `{0, .., n-1}` using precomputed inverse
/// CDF tables — O(log n) per sample. Token frequencies in natural text are
/// Zipfian (paper §4, refs [75, 76]).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for i in 0..n {
            total += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(total);
        }
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }
}

/// Chinese-restaurant-process sampler: the embarrassingly-parallel
/// Dirichlet-process partitioner of Appendix A.1 assigns example `i` to a
/// group drawn from CRP(alpha) — here made parallel-safe by hashing the
/// example id into a per-example stream.
pub struct CrpSampler {
    pub alpha: f64,
    counts: Vec<u64>,
    total: u64,
}

impl CrpSampler {
    pub fn new(alpha: f64) -> Self {
        CrpSampler { alpha, counts: Vec::new(), total: 0 }
    }

    /// Sequential CRP draw (used per-partition; the parallel pipeline runs
    /// one CRP per hash bucket which preserves the marginal heavy tail).
    pub fn sample(&mut self, rng: &mut Rng) -> usize {
        let u = rng.next_f64() * (self.total as f64 + self.alpha);
        if u >= self.total as f64 || self.counts.is_empty() {
            self.counts.push(1);
            self.total += 1;
            return self.counts.len() - 1;
        }
        let mut acc = 0.0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c as f64;
            if u < acc {
                self.counts[i] += 1;
                self.total += 1;
                return i;
            }
        }
        let last = self.counts.len() - 1;
        self.counts[last] += 1;
        self.total += 1;
        last
    }

    pub fn num_groups(&self) -> usize {
        self.counts.len()
    }
}

/// 64-bit FNV-1a — stable hashing for partition keys (std's SipHash is
/// seeded per-process, which would make partitions non-reproducible).
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

/// Streaming FNV-1a: feed bytes in any number of chunks and get exactly
/// the digest [`fnv1a`] would produce over their concatenation. Lets
/// callers hash a structured value (e.g. an [`crate::records::Example`]'s
/// canonical encoding) field by field without materializing the encoded
/// buffer first — the partitioners hash every example once per pipeline
/// run, so the avoided allocation is a hot-path win.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a {
    state: u64,
}

impl Fnv1a {
    /// Start a digest at the FNV-1a offset basis.
    #[inline]
    pub fn new() -> Fnv1a {
        Fnv1a { state: 0xcbf2_9ce4_8422_2325 }
    }

    /// Absorb one chunk. Chunk boundaries never affect the digest:
    /// `update(a); update(b)` equals `update(a ++ b)`.
    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        self.state = h;
    }

    /// The digest of everything absorbed so far (non-consuming: more
    /// `update` calls may follow).
    #[inline]
    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn log_normal_median() {
        let mut r = Rng::new(6);
        let mu = 3.0;
        let mut xs: Vec<f64> = (0..20_001).map(|_| r.log_normal(mu, 1.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[10_000];
        // Median of log-normal is exp(mu).
        assert!((median.ln() - mu).abs() < 0.05, "median {median}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut r = Rng::new(7);
        let z = Zipf::new(1000, 1.1);
        let mut counts = vec![0u64; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Rank 0 must dominate rank 99 by roughly (100)^1.1.
        assert!(counts[0] > counts[99] * 20, "{} vs {}", counts[0], counts[99]);
        assert!(counts[0] > 0 && counts[999] < counts[0]);
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(8);
        for &lambda in &[2.0, 50.0] {
            let n = 20_000;
            let s: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = s as f64 / n as f64;
            assert!((mean - lambda).abs() < lambda * 0.05, "{mean} vs {lambda}");
        }
    }

    #[test]
    fn gamma_moments() {
        // Gamma(a, 1) has mean a and variance a — check both regimes of
        // the sampler (shape < 1 boost path and the Marsaglia–Tsang core).
        let mut r = Rng::new(21);
        for &shape in &[0.5, 2.0, 9.0] {
            let n = 40_000;
            let xs: Vec<f64> = (0..n).map(|_| r.gamma(shape)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < shape * 0.05, "shape {shape}: mean {mean}");
            assert!((var - shape).abs() < shape * 0.15, "shape {shape}: var {var}");
            assert!(xs.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn dirichlet_sums_to_one_with_alpha_proportional_means() {
        let mut r = Rng::new(22);
        let alphas = [2.0, 5.0, 1.0];
        let n = 20_000;
        let mut means = [0.0f64; 3];
        for _ in 0..n {
            let p = r.dirichlet(&alphas);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            for (m, &pi) in means.iter_mut().zip(&p) {
                *m += pi;
            }
        }
        let total: f64 = alphas.iter().sum();
        for (i, m) in means.iter().enumerate() {
            let got = m / n as f64;
            let want = alphas[i] / total;
            assert!((got - want).abs() < 0.01, "component {i}: {got} vs {want}");
        }
    }

    #[test]
    fn multinomial_counts_sum_and_track_probs() {
        let mut r = Rng::new(23);
        let probs = [0.7, 0.2, 0.1];
        let counts = r.multinomial(50_000, &probs);
        assert_eq!(counts.iter().sum::<u64>(), 50_000);
        for (i, &c) in counts.iter().enumerate() {
            let got = c as f64 / 50_000.0;
            assert!((got - probs[i]).abs() < 0.01, "cat {i}: {got} vs {}", probs[i]);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(10);
        for &(n, k) in &[(100usize, 10usize), (10, 10), (50, 40)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn crp_generates_heavy_tail() {
        let mut r = Rng::new(11);
        let mut crp = CrpSampler::new(5.0);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..5000 {
            *counts.entry(crp.sample(&mut r)).or_insert(0u64) += 1;
        }
        assert!(crp.num_groups() > 10, "too few groups: {}", crp.num_groups());
        let max = counts.values().max().unwrap();
        let min = counts.values().min().unwrap();
        assert!(max > &(min * 10), "not heavy-tailed: {max} {min}");
    }

    #[test]
    fn fnv1a_stable_values() {
        // Pinned digest values: partition layouts must never change
        // silently across releases.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"dataset-grouper"), fnv1a(b"dataset-grouper"));
        assert_ne!(fnv1a(b"nytimes.com"), fnv1a(b"bbc.co.uk"));
    }

    #[test]
    fn streaming_fnv1a_is_chunking_invariant() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let whole = fnv1a(data);
        for split in 0..=data.len() {
            let mut h = Fnv1a::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finish(), whole, "split at {split}");
        }
        let mut bytewise = Fnv1a::new();
        for b in data {
            bytewise.update(std::slice::from_ref(b));
        }
        assert_eq!(bytewise.finish(), whole);
        assert_eq!(Fnv1a::new().finish(), fnv1a(b""));
    }
}
