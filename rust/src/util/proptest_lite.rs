//! proptest-lite: a minimal property-based testing helper.
//!
//! The offline registry lacks `proptest`, so this provides the core of what
//! the test suite needs: run a property over many seeded-random cases and,
//! on failure, report the case number and seed so the exact input can be
//! replayed deterministically. Generators are plain closures over
//! [`crate::util::rng::Rng`] — no macro DSL, no shrinking, but fully
//! reproducible.
//!
//! ```ignore
//! check(100, |rng| {
//!     let xs = gen_vec(rng, 0..=50, |r| r.gen_range(1000) as i64);
//!     let mut sorted = xs.clone();
//!     sorted.sort();
//!     prop_assert(sorted.len() == xs.len(), "sort changed length")
//! });
//! ```

use crate::util::rng::Rng;

/// Result type for properties: `Err(msg)` fails the case with context.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert equality with debug formatting.
pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, msg: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{msg}: {a:?} != {b:?}"))
    }
}

/// Run `cases` random cases of `prop`. Panics (failing the enclosing
/// `#[test]`) with the case index and seed on the first failure.
pub fn check<F: FnMut(&mut Rng) -> PropResult>(cases: usize, mut prop: F) {
    check_seeded(0xDA7A_5E7_u64, cases, &mut prop);
}

/// Same, with an explicit base seed (use to replay a reported failure).
pub fn check_seeded<F: FnMut(&mut Rng) -> PropResult>(base_seed: u64, cases: usize, prop: &mut F) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} (replay: check_seeded({base_seed:#x}) case {case}): {msg}"
            );
        }
    }
}

/// Generate a Vec whose length is uniform in `len_range`.
pub fn gen_vec<T>(
    rng: &mut Rng,
    len_range: std::ops::RangeInclusive<usize>,
    mut gen: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let (lo, hi) = (*len_range.start(), *len_range.end());
    let len = lo + rng.gen_range_usize(hi - lo + 1);
    (0..len).map(|_| gen(rng)).collect()
}

/// Generate ASCII-ish byte strings (useful for record payload fuzzing).
pub fn gen_bytes(rng: &mut Rng, len_range: std::ops::RangeInclusive<usize>) -> Vec<u8> {
    gen_vec(rng, len_range, |r| r.gen_range(256) as u8)
}

/// Generate lowercase words.
pub fn gen_word(rng: &mut Rng, len_range: std::ops::RangeInclusive<usize>) -> String {
    gen_vec(rng, len_range, |r| (b'a' + r.gen_range(26) as u8) as char)
        .into_iter()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, |rng| {
            let x = rng.gen_range(100);
            prop_assert(x < 100, "range bound")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        check(50, |rng| {
            let x = rng.gen_range(100);
            prop_assert(x < 50, "upper half must appear within 50 cases")
        });
    }

    #[test]
    fn gen_vec_len_in_range() {
        check(100, |rng| {
            let v = gen_vec(rng, 2..=5, |r| r.next_u32());
            prop_assert((2..=5).contains(&v.len()), "len out of range")
        });
    }

    #[test]
    fn gen_word_is_lowercase() {
        check(100, |rng| {
            let w = gen_word(rng, 1..=10);
            prop_assert(w.chars().all(|c| c.is_ascii_lowercase()), "non-lowercase")
        });
    }

    #[test]
    fn cases_are_reproducible() {
        let mut first: Vec<u64> = Vec::new();
        check(10, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check(10, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
