//! Wall-clock timing helpers for the benchmark harness (the offline
//! registry has no criterion; Tables 3/4 need mean ± std over trials).

use std::time::{Duration, Instant};

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Mean and (population) standard deviation of a set of trial timings —
/// the "avg ± std over 5 trials" the paper reports in Table 3.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeanStd {
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

impl MeanStd {
    pub fn of(xs: &[f64]) -> MeanStd {
        let n = xs.len();
        if n == 0 {
            return MeanStd { mean: f64::NAN, std: f64::NAN, n: 0 };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        MeanStd { mean, std: var.sqrt(), n }
    }
}

impl std::fmt::Display for MeanStd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.4} ± {:.4}", self.mean, self.std)
    }
}

/// Run `trials` timed repetitions of `f` (with a `setup` run before each,
/// untimed) and return the timing summary in seconds.
pub fn time_trials<F: FnMut()>(trials: usize, mut f: F) -> MeanStd {
    let mut times = Vec::with_capacity(trials);
    for _ in 0..trials {
        let t = Timer::start();
        f();
        times.push(t.elapsed_secs());
    }
    MeanStd::of(&times)
}

/// Time a single call and return (result, seconds).
pub fn timed<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let s = MeanStd::of(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn mean_std_empty_is_nan() {
        let s = MeanStd::of(&[]);
        assert!(s.mean.is_nan());
        assert_eq!(s.n, 0);
    }

    #[test]
    fn mean_std_constant_zero_std() {
        let s = MeanStd::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn timed_returns_result() {
        let (x, secs) = timed(|| 41 + 1);
        assert_eq!(x, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn time_trials_counts() {
        let mut calls = 0;
        let s = time_trials(4, || calls += 1);
        assert_eq!(calls, 4);
        assert_eq!(s.n, 4);
    }
}
