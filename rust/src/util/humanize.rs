//! Human-friendly formatting of counts, byte sizes, and durations, matching
//! the paper's table conventions ("132B", "15.6M", "11K", "> 7200").

/// Format a count the way the paper's Table 1 does: 132B / 15.6M / 11K.
pub fn count(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        trim(x / 1e9, "B")
    } else if ax >= 1e6 {
        trim(x / 1e6, "M")
    } else if ax >= 1e3 {
        trim(x / 1e3, "K")
    } else if x == x.trunc() {
        format!("{}", x as i64)
    } else {
        format!("{x:.1}")
    }
}

fn trim(v: f64, suffix: &str) -> String {
    if v >= 100.0 {
        format!("{v:.0}{suffix}")
    } else if v >= 10.0 {
        let s = format!("{v:.1}");
        format!("{}{suffix}", s.strip_suffix(".0").unwrap_or(&s))
    } else {
        let s = format!("{v:.2}");
        let s = s.trim_end_matches('0').trim_end_matches('.');
        format!("{s}{suffix}")
    }
}

/// Bytes -> "1.2 GiB" style.
pub fn bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Seconds -> compact duration.
pub fn secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_paper_style() {
        assert_eq!(count(132e9), "132B");
        assert_eq!(count(15.6e6), "15.6M");
        assert_eq!(count(11_000.0), "11K");
        assert_eq!(count(815.0), "815");
        assert_eq!(count(0.36e9), "360M");
        assert_eq!(count(42.0), "42");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.00 KiB");
        assert_eq!(bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn secs_ranges() {
        assert_eq!(secs(0.0000005), "0.5 µs");
        assert_eq!(secs(0.25), "250.00 ms");
        assert_eq!(secs(3.5), "3.50 s");
        assert_eq!(secs(180.0), "3.0 min");
    }
}
