//! Markdown/aligned-text table rendering for the bench harness and CLI —
//! every reproduced paper table is printed through this, and also written
//! to `results/*.csv` for downstream plotting.

use std::io::Write;
use std::path::Path;

/// A simple column-aligned table with a title.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }

    /// Write a CSV copy (for plotting / EXPERIMENTS.md provenance).
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", csv_line(&self.headers))?;
        for row in &self.rows {
            writeln!(f, "{}", csv_line(row))?;
        }
        Ok(())
    }
}

fn csv_line(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Series export: named (x, y[, extra…]) columns — used for the paper's
/// figures (loss curves, histograms, Q-Q series).
pub fn write_series_csv<P: AsRef<Path>>(
    path: P,
    headers: &[&str],
    rows: &[Vec<f64>],
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", headers.join(","))?;
    for r in rows {
        let line: Vec<String> = r.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a "));
        assert!(md.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        assert_eq!(csv_line(&["a,b".into(), "c\"d".into()]), "\"a,b\",\"c\"\"d\"");
    }

    #[test]
    fn csv_roundtrip_files() {
        let dir = std::env::temp_dir().join("grouper_table_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut t = Table::new("t", &["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "x,y\n1,2\n");
        write_series_csv(dir.join("s.csv"), &["a"], &[vec![1.5]]).unwrap();
        assert_eq!(std::fs::read_to_string(dir.join("s.csv")).unwrap(), "a\n1.5\n");
    }
}
