//! Shared utilities: deterministic RNG + distribution samplers, timers,
//! markdown tables, a byte-counting global allocator (Table 12's peak-memory
//! instrumentation), a scoped thread pool, and a small property-testing
//! helper (the offline registry has no `rand`/`proptest`/`criterion`, so
//! these are in-repo — see DESIGN.md §2).

pub mod alloc;
pub mod humanize;
pub mod proptest_lite;
pub mod rng;
pub mod special;
pub mod table;
pub mod threadpool;
pub mod timer;
