//! Special functions the statistical fitting code needs.
//!
//! Stable Rust's `f64` has no `ln_gamma`, and the offline crate registry
//! has no `libm` / `statrs` — so the one special function the
//! Dirichlet-multinomial likelihood needs lives here: [`ln_gamma`] via
//! the Lanczos approximation (g = 7, 9 coefficients), accurate to ~15
//! significant digits over the fitting code's domain and, unlike a
//! platform `lgamma`, bit-stable across OSes — the MoDM fit must produce
//! the same model on every CI leg.

use std::f64::consts::PI;

/// Lanczos coefficients for g = 7 (Godfrey's tabulation, the same set
/// used by Boost and numpy's published references).
const LANCZOS_G: f64 = 7.0;
const LANCZOS: [f64; 9] = [
    0.999_999_999_999_809_93,
    676.520_368_121_885_1,
    -1_259.139_216_722_402_8,
    771.323_428_777_653_13,
    -176.615_029_162_140_59,
    12.507_343_278_686_905,
    -0.138_571_095_265_720_12,
    9.984_369_578_019_571_6e-6,
    1.505_632_735_149_311_6e-7,
];

/// Natural log of the gamma function, `ln Γ(x)`, for `x > 0`.
///
/// Negative and zero inputs return `f64::NAN` (the fitting code never
/// produces them; a NaN surfacing downstream is a bug signal, not a
/// value to silently clamp). Uses the reflection formula below 0.5 so
/// the Lanczos series only ever evaluates in its well-conditioned range.
pub fn ln_gamma(x: f64) -> f64 {
    if x.is_nan() || x <= 0.0 {
        return f64::NAN;
    }
    if x < 0.5 {
        // Reflection: Γ(x) Γ(1-x) = π / sin(πx).
        return (PI / (PI * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = LANCZOS[0];
    for (i, c) in LANCZOS.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + LANCZOS_G + 0.5;
    0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

/// `ln B(a) = Σ ln Γ(a_i) − ln Γ(Σ a_i)` — the log multivariate beta,
/// the Dirichlet normalizer the DM likelihood is built from.
pub fn ln_multivariate_beta(alphas: &[f64]) -> f64 {
    let sum: f64 = alphas.iter().sum();
    alphas.iter().map(|&a| ln_gamma(a)).sum::<f64>() - ln_gamma(sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_factorials() {
        // Γ(n) = (n-1)! — exact anchors.
        let mut fact = 1.0f64;
        for n in 1..15u32 {
            if n > 1 {
                fact *= (n - 1) as f64;
            }
            let got = ln_gamma(n as f64);
            assert!((got - fact.ln()).abs() < 1e-10, "n={n}: {got} vs {}", fact.ln());
        }
    }

    #[test]
    fn half_integer_values() {
        // Γ(1/2) = sqrt(π), Γ(3/2) = sqrt(π)/2.
        assert!((ln_gamma(0.5) - PI.sqrt().ln()).abs() < 1e-12);
        assert!((ln_gamma(1.5) - (PI.sqrt() / 2.0).ln()).abs() < 1e-12);
    }

    #[test]
    fn recurrence_holds() {
        // ln Γ(x+1) = ln Γ(x) + ln x across magnitudes.
        for &x in &[0.1, 0.7, 1.3, 4.5, 20.0, 333.25, 1e6] {
            let lhs = ln_gamma(x + 1.0);
            let rhs = ln_gamma(x) + x.ln();
            assert!((lhs - rhs).abs() < 1e-8 * lhs.abs().max(1.0), "x={x}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn invalid_domain_is_nan() {
        assert!(ln_gamma(0.0).is_nan());
        assert!(ln_gamma(-3.2).is_nan());
        assert!(ln_gamma(f64::NAN).is_nan());
    }

    #[test]
    fn multivariate_beta_reduces_to_beta() {
        // B(a, b) = Γ(a)Γ(b)/Γ(a+b); B(2, 3) = 1/12.
        let got = ln_multivariate_beta(&[2.0, 3.0]);
        assert!((got - (1.0f64 / 12.0).ln()).abs() < 1e-12, "{got}");
    }
}
