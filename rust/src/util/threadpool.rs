//! A small fixed-size thread pool over std threads + channels.
//!
//! This is the execution substrate for the beam-lite pipeline runner
//! (`pipeline::runner`): the offline registry has neither tokio nor rayon,
//! and the pipeline's needs are simple — fan a queue of work items across
//! N workers, collect results, propagate panics.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size pool; jobs are executed FIFO by whichever worker is free.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("grouper-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(tx) }
    }

    /// Default parallelism: available cores, capped.
    pub fn default_workers() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, U)>();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let out = f(item);
                // Receiver may be gone if the caller panicked; ignore.
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<U>> = (0..n).map(|_| None).collect();
        for (i, u) in rx {
            slots[i] = Some(u);
        }
        slots
            .into_iter()
            .map(|s| s.expect("worker panicked before producing a result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.sender.take(); // close the channel; workers exit their loops
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<i32>>());
    }

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn map_with_uneven_work() {
        let pool = ThreadPool::new(4);
        let out = pool.map(vec![30u64, 1, 20, 2, 10, 3], |x| {
            std::thread::sleep(std::time::Duration::from_millis(x / 10));
            x + 1
        });
        assert_eq!(out, vec![31, 2, 21, 3, 11, 4]);
    }

    #[test]
    fn single_worker_is_serial_and_correct() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }
}
