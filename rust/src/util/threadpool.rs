//! A small fixed-size thread pool over std threads + channels.
//!
//! This is the execution substrate for the beam-lite pipeline runner
//! (`pipeline::runner`) and the trainer's parallel cohort fetch: the
//! offline registry has neither tokio nor rayon, and the needs are
//! simple — fan a queue of work items across N workers, collect results,
//! and surface job panics as values ([`ThreadPool::try_map`]) so a
//! crashed job fails its caller loudly instead of stalling a barrier.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A parallel-map job panicked (or its worker died before reporting).
///
/// Surfaced as a value instead of a deferred join-time panic so callers
/// like the federated trainer can fail their round loudly — a crashed
/// parallel client fetch must never leave the cohort barrier waiting on
/// a result that will not come.
#[derive(Debug)]
pub struct JobPanic {
    /// Index of the input item whose job failed.
    pub index: usize,
    /// The panic payload rendered to a string (or a note that the
    /// worker vanished without one).
    pub message: String,
}

impl fmt::Display for JobPanic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parallel job {} panicked: {}", self.index, self.message)
    }
}

impl std::error::Error for JobPanic {}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Fixed-size pool; jobs are executed FIFO by whichever worker is free.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("grouper-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(tx) }
    }

    /// Default parallelism: available cores, capped.
    pub fn default_workers() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Map `f` over `items` in parallel, preserving order.
    ///
    /// # Panics
    /// Panics (in the caller) when any job panicked; use
    /// [`ThreadPool::try_map`] to receive the failure as a value.
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
    {
        self.try_map(items, f).unwrap_or_else(|p| panic!("{p}"))
    }

    /// Map `f` over `items` in parallel, preserving order, surfacing the
    /// first job panic as an error instead of unwinding the caller.
    /// Panics are caught inside the worker, so the pool's workers all
    /// survive a crashing job and the pool stays usable.
    ///
    /// # Errors
    /// [`JobPanic`] when any job panicked (the first by completion
    /// order), or when a worker died before reporting a result.
    pub fn try_map<T, U, F>(&self, items: Vec<T>, f: F) -> Result<Vec<U>, JobPanic>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, thread::Result<U>)>();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                // AssertUnwindSafe: `item` is consumed and `f` is only
                // observed again through further whole calls, so a
                // half-completed call leaks no broken state.
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                // Receiver may be gone if the caller bailed; ignore.
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<U>> = (0..n).map(|_| None).collect();
        let mut failure: Option<JobPanic> = None;
        for (i, result) in rx {
            match result {
                Ok(u) => slots[i] = Some(u),
                Err(payload) => {
                    failure.get_or_insert(JobPanic { index: i, message: panic_message(payload) });
                }
            }
        }
        if let Some(p) = failure {
            return Err(p);
        }
        let mut out = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(u) => out.push(u),
                None => {
                    return Err(JobPanic {
                        index: i,
                        message: "worker terminated without reporting a result".to_string(),
                    })
                }
            }
        }
        Ok(out)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.sender.take(); // close the channel; workers exit their loops
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(index, &mut item)` once per item over at most `workers` scoped
/// threads, returning the results in item order.
///
/// The borrow-friendly sibling of [`ThreadPool::try_map`] for callers
/// whose items (or closures) are **not** `'static` — e.g. the sharded
/// paged writers, where each worker needs `&mut` on one shard store
/// owned by the caller. Workers pop indices from a shared counter, so a
/// skewed (slow) item never barriers the rest; each item sits behind its
/// own mutex that is locked exactly once, by whichever worker pops it —
/// exclusive `&mut`-per-item access without waves or unsafe. A panic in
/// `f` propagates at scope exit (std scoped-thread semantics), so
/// callers who need panics-as-values should catch inside `f`.
pub fn parallel_for_each_mut<T, R, F>(items: &mut [T], workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let workers = workers.max(1).min(items.len());
    let slots: Vec<Mutex<(&mut T, Option<R>)>> =
        items.iter_mut().map(|item| Mutex::new((item, None))).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let slots = &slots;
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= slots.len() {
                    break;
                }
                let mut slot = slots[i].lock().unwrap();
                let out = f(i, &mut *slot.0);
                slot.1 = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            // Every index is popped exactly once and filled before its
            // worker moves on; a panicking worker re-raised at scope
            // exit, so reaching this drain means every slot completed.
            slot.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .1
                .expect("scope joined: every popped slot holds a result")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<i32>>());
    }

    #[test]
    fn parallel_for_each_mut_visits_every_item_once_in_order() {
        let mut items: Vec<u64> = (0..37).collect();
        let results = parallel_for_each_mut(&mut items, 4, |i, item| {
            *item += 100;
            (i as u64, *item)
        });
        assert_eq!(results.len(), 37);
        for (i, (idx, val)) in results.iter().enumerate() {
            assert_eq!(*idx, i as u64, "results must come back in item order");
            assert_eq!(*val, i as u64 + 100);
        }
        assert_eq!(items, (100..137).collect::<Vec<u64>>());
        // Degenerate shapes: empty slice, more workers than items.
        let empty: Vec<u64> = parallel_for_each_mut(&mut [], 8, |_, item: &mut u64| *item);
        assert!(empty.is_empty());
        let mut one = [7u64];
        assert_eq!(parallel_for_each_mut(&mut one, 16, |_, item| *item * 2), vec![14]);
    }

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn map_with_uneven_work() {
        let pool = ThreadPool::new(4);
        let out = pool.map(vec![30u64, 1, 20, 2, 10, 3], |x| {
            std::thread::sleep(std::time::Duration::from_millis(x / 10));
            x + 1
        });
        assert_eq!(out, vec![31, 2, 21, 3, 11, 4]);
    }

    #[test]
    fn single_worker_is_serial_and_correct() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }

    #[test]
    fn try_map_surfaces_worker_panics_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let err = pool
            .try_map(vec![1u32, 2, 3, 4], |x| {
                if x == 3 {
                    panic!("boom on {x}");
                }
                x * 10
            })
            .unwrap_err();
        assert_eq!(err.index, 2, "failure must name the item");
        assert!(err.message.contains("boom"), "payload lost: {}", err.message);
        // The panic was caught inside the worker: the pool is intact and
        // every worker still alive.
        assert_eq!(pool.try_map(vec![5u32, 6, 7], |x| x + 1).unwrap(), vec![6, 7, 8]);
        assert_eq!(pool.map(vec![1u32, 2], |x| x), vec![1, 2]);
    }

    #[test]
    fn try_map_ok_on_clean_jobs() {
        let pool = ThreadPool::new(4);
        let out = pool.try_map((0..50).collect::<Vec<i64>>(), |x| x * 3).unwrap();
        assert_eq!(out, (0..50).map(|x| x * 3).collect::<Vec<i64>>());
    }
}
