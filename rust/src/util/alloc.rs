//! Byte-counting global allocator — the instrumentation behind the
//! Table 12 reproduction (peak memory per dataset format).
//!
//! The paper measures peak memory while iterating each format on a single
//! CPU (Appendix E). We reproduce that with a wrapping allocator that
//! tracks live and peak heap bytes; bench binaries opt in with:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: grouper::util::alloc::CountingAlloc = grouper::util::alloc::CountingAlloc;
//! ```
//!
//! Counters are process-global atomics; `reset_peak()` re-bases the peak to
//! the current live size so successive measurement regions are independent.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

/// Wraps the system allocator with live/peak accounting.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                let live =
                    LIVE.fetch_add(new_size - layout.size(), Ordering::Relaxed)
                        + (new_size - layout.size());
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

/// Bytes currently allocated.
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// High-water mark since the last [`reset_peak`].
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Re-base the peak to the current live size (start of a measurement region).
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Peak bytes *above* the live baseline at region start; convenience for
/// "how much extra memory did this block need".
pub fn measure_peak<T, F: FnOnce() -> T>(f: F) -> (T, usize) {
    let base = live_bytes();
    reset_peak();
    let out = f();
    let peak = peak_bytes();
    (out, peak.saturating_sub(base))
}

#[cfg(test)]
mod tests {
    // NOTE: the counting allocator is only installed in bench binaries,
    // so in unit tests the counters stay zero; we test the arithmetic
    // surface, not the wiring.
    use super::*;

    #[test]
    fn counters_monotone_sane() {
        let live = live_bytes();
        let peak = peak_bytes();
        assert!(peak >= 0usize.min(live)); // no underflow panics
        reset_peak();
        assert!(peak_bytes() >= live_bytes().saturating_sub(1));
    }

    #[test]
    fn measure_peak_returns_value() {
        let (v, extra) = measure_peak(|| vec![0u8; 1024].len());
        assert_eq!(v, 1024);
        // Without the allocator installed, extra is 0; with it, >= 1024.
        let _ = extra;
    }
}
