//! WordPiece tokenization (§5.1 uses a WordPiece tokenizer [79] with a
//! BERT vocabulary; no pretrained vocab ships offline, so [`VocabBuilder`]
//! trains one from the corpus with the same greedy longest-match-first
//! decoding and `##` continuation convention).

pub mod vocab_builder;
pub mod wordpiece;

pub use vocab_builder::VocabBuilder;
pub use wordpiece::{WordPiece, BOS_ID, PAD_ID, UNK_ID};
