//! Greedy longest-match-first WordPiece encoding (Wu et al. [79]),
//! matching the BERT convention: the first piece of a word is a vocabulary
//! entry, subsequent pieces carry a `##` prefix; words with no possible
//! decomposition become `[UNK]`.

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::Path;

pub const PAD_ID: u32 = 0;
pub const UNK_ID: u32 = 1;
pub const BOS_ID: u32 = 2;
pub const SPECIALS: [&str; 3] = ["[PAD]", "[UNK]", "[BOS]"];

/// An immutable WordPiece vocabulary + encoder.
#[derive(Debug, Clone)]
pub struct WordPiece {
    tokens: Vec<String>,
    ids: HashMap<String, u32>,
    max_piece_len: usize,
}

impl WordPiece {
    /// Build from a token list; the first three entries must be the
    /// specials (the vocab builder guarantees this).
    pub fn new(tokens: Vec<String>) -> Self {
        assert!(tokens.len() >= SPECIALS.len(), "vocab too small");
        for (i, s) in SPECIALS.iter().enumerate() {
            assert_eq!(tokens[i], *s, "special token order");
        }
        let ids: HashMap<String, u32> =
            tokens.iter().enumerate().map(|(i, t)| (t.clone(), i as u32)).collect();
        assert_eq!(ids.len(), tokens.len(), "duplicate vocab tokens");
        let max_piece_len = tokens.iter().map(|t| t.trim_start_matches("##").len()).max().unwrap();
        WordPiece { tokens, ids, max_piece_len }
    }

    pub fn vocab_size(&self) -> usize {
        self.tokens.len()
    }

    pub fn token(&self, id: u32) -> &str {
        &self.tokens[id as usize]
    }

    pub fn id(&self, token: &str) -> Option<u32> {
        self.ids.get(token).copied()
    }

    /// Encode one word into piece ids (greedy longest-match-first).
    pub fn encode_word(&self, word: &str, out: &mut Vec<u32>) {
        if word.is_empty() {
            return;
        }
        let start_len = out.len();
        let bytes = word.as_bytes();
        let mut pos = 0;
        let mut first = true;
        while pos < bytes.len() {
            let max_end = (pos + self.max_piece_len + 2).min(bytes.len());
            let mut matched = None;
            let mut end = max_end;
            while end > pos {
                // Our corpora are ASCII; guard for UTF-8 anyway.
                if !word.is_char_boundary(end) {
                    end -= 1;
                    continue;
                }
                let piece = &word[pos..end];
                let lookup = if first {
                    self.ids.get(piece)
                } else {
                    // avoid allocation for the common single-char case via
                    // a small stack buffer
                    let mut s = String::with_capacity(piece.len() + 2);
                    s.push_str("##");
                    s.push_str(piece);
                    self.ids.get(&s)
                };
                if let Some(&id) = lookup {
                    matched = Some((id, end));
                    break;
                }
                end -= 1;
            }
            match matched {
                Some((id, next)) => {
                    out.push(id);
                    pos = next;
                    first = false;
                }
                None => {
                    // No decomposition: the whole word becomes [UNK].
                    out.truncate(start_len);
                    out.push(UNK_ID);
                    return;
                }
            }
        }
    }

    /// Encode whitespace-separated text.
    pub fn encode(&self, text: &str, out: &mut Vec<u32>) {
        for word in text.split_whitespace() {
            self.encode_word(word, out);
        }
    }

    pub fn encode_to_vec(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::new();
        self.encode(text, &mut out);
        out
    }

    /// Decode ids back to text (## pieces merge into the previous word).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut out = String::new();
        for &id in ids {
            let t = &self.tokens[id as usize];
            if let Some(cont) = t.strip_prefix("##") {
                out.push_str(cont);
            } else {
                if !out.is_empty() {
                    out.push(' ');
                }
                out.push_str(t);
            }
        }
        out
    }

    /// Persist as one token per line.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(d) = path.parent() {
            std::fs::create_dir_all(d)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for t in &self.tokens {
            writeln!(f, "{t}")?;
        }
        f.flush()
    }

    pub fn load(path: &Path) -> std::io::Result<Self> {
        let f = std::io::BufReader::new(std::fs::File::open(path)?);
        let tokens: Vec<String> = f.lines().collect::<Result<_, _>>()?;
        Ok(WordPiece::new(tokens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_vocab() -> WordPiece {
        let mut tokens: Vec<String> = SPECIALS.iter().map(|s| s.to_string()).collect();
        for t in ["a", "b", "c", "ab", "abc", "##a", "##b", "##c", "##bc", "hello"] {
            tokens.push(t.to_string());
        }
        WordPiece::new(tokens)
    }

    #[test]
    fn greedy_longest_match() {
        let wp = toy_vocab();
        // "abc" matches the whole-word piece, not a+##bc.
        assert_eq!(wp.decode(&wp.encode_to_vec("abc")), "abc");
        assert_eq!(wp.encode_to_vec("abc").len(), 1);
        // "abca" -> abc + ##a
        let ids = wp.encode_to_vec("abca");
        assert_eq!(ids.len(), 2);
        assert_eq!(wp.decode(&ids), "abca");
        // "ab" whole piece
        assert_eq!(wp.encode_to_vec("ab").len(), 1);
    }

    #[test]
    fn unk_for_unknown_chars() {
        let wp = toy_vocab();
        assert_eq!(wp.encode_to_vec("xyz"), vec![UNK_ID]);
        // A word that starts decomposable but hits an unknown char is UNK
        // as a whole (BERT behavior).
        assert_eq!(wp.encode_to_vec("abx"), vec![UNK_ID]);
    }

    #[test]
    fn multi_word_encoding() {
        let wp = toy_vocab();
        let ids = wp.encode_to_vec("hello abc  hello");
        assert_eq!(wp.decode(&ids), "hello abc hello");
    }

    #[test]
    fn empty_and_whitespace() {
        let wp = toy_vocab();
        assert!(wp.encode_to_vec("").is_empty());
        assert!(wp.encode_to_vec("   \t\n").is_empty());
    }

    #[test]
    fn specials_have_fixed_ids() {
        let wp = toy_vocab();
        assert_eq!(wp.id("[PAD]"), Some(PAD_ID));
        assert_eq!(wp.id("[UNK]"), Some(UNK_ID));
        assert_eq!(wp.id("[BOS]"), Some(BOS_ID));
    }

    #[test]
    #[should_panic(expected = "special token order")]
    fn rejects_wrong_special_order() {
        WordPiece::new(vec!["[UNK]".into(), "[PAD]".into(), "[BOS]".into(), "a".into()]);
    }

    #[test]
    fn save_load_roundtrip() {
        let wp = toy_vocab();
        let p = std::env::temp_dir().join("grouper_wp_test").join("vocab.txt");
        wp.save(&p).unwrap();
        let wp2 = WordPiece::load(&p).unwrap();
        assert_eq!(wp2.vocab_size(), wp.vocab_size());
        assert_eq!(wp2.encode_to_vec("abca"), wp.encode_to_vec("abca"));
    }
}
