//! Frequency-based WordPiece vocabulary training.
//!
//! Simplified from the BPE-style likelihood training of [79] to a
//! frequency scheme that preserves the properties the experiments need:
//! full coverage (every ASCII-lowercase word is encodable: all single
//! chars and their `##` forms are always included), high-frequency words
//! as single tokens (Zipf head), and sub-word sharing for the tail
//! (frequent prefixes/suffix pieces).

use std::collections::HashMap;

use super::wordpiece::{WordPiece, SPECIALS};

/// Accumulates word counts from text, then emits a [`WordPiece`] vocab.
#[derive(Debug, Default)]
pub struct VocabBuilder {
    word_counts: HashMap<String, u64>,
    total_words: u64,
}

impl VocabBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn feed(&mut self, text: &str) {
        for w in text.split_whitespace() {
            *self.word_counts.entry(w.to_string()).or_insert(0) += 1;
            self.total_words += 1;
        }
    }

    pub fn distinct_words(&self) -> usize {
        self.word_counts.len()
    }

    pub fn total_words(&self) -> u64 {
        self.total_words
    }

    /// Build a vocabulary of exactly `vocab_size` tokens (>= specials +
    /// observed alphabet; panics otherwise).
    pub fn build(&self, vocab_size: usize) -> WordPiece {
        // 1. Specials.
        let mut tokens: Vec<String> = SPECIALS.iter().map(|s| s.to_string()).collect();
        let mut have: std::collections::HashSet<String> =
            tokens.iter().cloned().collect();

        // 2. Alphabet (chars + ## forms) for total coverage.
        let mut chars: Vec<char> = self
            .word_counts
            .keys()
            .flat_map(|w| w.chars())
            .collect::<std::collections::HashSet<char>>()
            .into_iter()
            .collect();
        chars.sort();
        for c in &chars {
            for t in [c.to_string(), format!("##{c}")] {
                if have.insert(t.clone()) {
                    tokens.push(t);
                }
            }
        }
        assert!(
            tokens.len() <= vocab_size,
            "vocab_size {vocab_size} smaller than specials+alphabet ({})",
            tokens.len()
        );

        // 3. Candidate scoring: whole words by count; word prefixes (len>=2)
        //    and suffix pieces (##s, len>=2) by the count mass they touch.
        let mut scores: HashMap<String, u64> = HashMap::new();
        for (w, &c) in &self.word_counts {
            let n = w.len();
            *scores.entry(w.clone()).or_insert(0) += c * 4; // whole words favored
            let max_aff = n.min(8);
            for l in 2..max_aff {
                if w.is_char_boundary(l) {
                    *scores.entry(w[..l].to_string()).or_insert(0) += c;
                }
                if w.is_char_boundary(n - l) {
                    *scores.entry(format!("##{}", &w[n - l..])).or_insert(0) += c;
                }
            }
        }
        let mut candidates: Vec<(String, u64)> = scores.into_iter().collect();
        // Deterministic order: score desc, then lexicographic.
        candidates.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));

        for (tok, _) in candidates {
            if tokens.len() == vocab_size {
                break;
            }
            if have.insert(tok.clone()) {
                tokens.push(tok);
            }
        }
        // 4. Pad with reserved tokens if the corpus was too small to fill
        //    the budget (keeps the model's vocab_size contract).
        let mut i = 0;
        while tokens.len() < vocab_size {
            let t = format!("[RES{i}]");
            if have.insert(t.clone()) {
                tokens.push(t);
            }
            i += 1;
        }
        WordPiece::new(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::text::TextModel;
    use crate::tokenizer::wordpiece::UNK_ID;
    use crate::util::rng::Rng;

    fn corpus_builder(words: usize) -> (VocabBuilder, String) {
        let model = TextModel::new(2000, 1.2);
        let mut rng = Rng::new(17);
        let text = model.generate(&mut rng, words, 0, 0.2);
        let mut b = VocabBuilder::new();
        b.feed(&text);
        (b, text)
    }

    #[test]
    fn exact_vocab_size() {
        let (b, _) = corpus_builder(20_000);
        for &v in &[256usize, 1024] {
            let wp = b.build(v);
            assert_eq!(wp.vocab_size(), v);
        }
    }

    #[test]
    fn full_coverage_no_unk_on_training_corpus() {
        let (b, text) = corpus_builder(10_000);
        let wp = b.build(512);
        let ids = wp.encode_to_vec(&text);
        assert!(!ids.is_empty());
        assert!(
            !ids.contains(&UNK_ID),
            "alphabet coverage must prevent UNK on in-domain text"
        );
    }

    #[test]
    fn frequent_words_become_single_tokens() {
        let (b, _) = corpus_builder(30_000);
        let wp = b.build(1024);
        // The Zipf head word appears thousands of times -> one token.
        let head = TextModel::new(2000, 1.2).word(0).to_string();
        assert_eq!(wp.encode_to_vec(&head).len(), 1, "head word split: {head}");
    }

    #[test]
    fn rare_words_split_into_multiple_pieces() {
        let (b, _) = corpus_builder(30_000);
        let wp = b.build(320);
        let model = TextModel::new(2000, 1.2);
        // Deep-tail words should need >= 2 pieces at a small vocab size.
        let mut split = 0;
        for r in 1900..1950 {
            if wp.encode_to_vec(model.word(r)).len() >= 2 {
                split += 1;
            }
        }
        assert!(split > 25, "tail words unexpectedly whole: {split}/50");
    }

    #[test]
    fn compression_better_than_chars() {
        let (b, text) = corpus_builder(5_000);
        let wp = b.build(1024);
        let ids = wp.encode_to_vec(&text);
        let chars: usize = text.split_whitespace().map(|w| w.len()).sum();
        assert!(
            ids.len() * 2 < chars,
            "tokenization barely compresses: {} ids vs {} chars",
            ids.len(),
            chars
        );
    }

    #[test]
    fn small_corpus_pads_with_reserved() {
        let mut b = VocabBuilder::new();
        b.feed("aa bb aa");
        let wp = b.build(64);
        assert_eq!(wp.vocab_size(), 64);
        assert!(wp.id("[RES0]").is_some());
        assert!(!wp.encode_to_vec("aa bb").contains(&UNK_ID));
    }

    #[test]
    #[should_panic(expected = "smaller than")]
    fn rejects_impossible_budget() {
        let (b, _) = corpus_builder(1000);
        b.build(10);
    }

    #[test]
    fn deterministic() {
        let (b, _) = corpus_builder(5000);
        let a = b.build(256);
        let c = b.build(256);
        for i in 0..256 {
            assert_eq!(a.token(i), c.token(i));
        }
    }
}
