//! [`ModelRuntime`]: the typed facade over the compiled AOT artifacts.
//!
//! Executes `eval_loss` / `grad` / `sgd_step` / fused `local_train` with
//! flattened host parameters ([`super::Params`]), converting to/from
//! `xla::Literal`s at the PJRT boundary. On the CPU client these
//! conversions are memcpys; the fused `local_train` artifact exists
//! precisely to amortize them (one execute per client per round instead of
//! tau — see EXPERIMENTS.md §Perf).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::engine::PjrtEngine;
use super::manifest::Manifest;
use super::{ModelBackend, Params};

/// An artifact compiled on first use. Eager compilation of every entry
/// point made `ModelRuntime::load` take ~60s for the `small` config
/// (7 executables); training typically touches 2-3 of them — lazy
/// compilation cut e2e startup ~4x (EXPERIMENTS.md §Perf L3-1).
struct LazyExe {
    path: std::path::PathBuf,
    cell: std::cell::OnceCell<xla::PjRtLoadedExecutable>,
}

impl LazyExe {
    fn new(path: std::path::PathBuf) -> Self {
        LazyExe { path, cell: std::cell::OnceCell::new() }
    }

    fn get(&self, engine: &PjrtEngine) -> Result<&xla::PjRtLoadedExecutable> {
        if self.cell.get().is_none() {
            let exe = engine.compile_hlo_text(&self.path)?;
            let _ = self.cell.set(exe);
        }
        Ok(self.cell.get().unwrap())
    }
}

/// A loaded model config: manifest + lazily-compiled executables.
pub struct ModelRuntime {
    pub manifest: Manifest,
    engine: PjrtEngine,
    exe_eval: LazyExe,
    exe_grad: LazyExe,
    exe_step: LazyExe,
    exe_local: HashMap<usize, LazyExe>,
    exe_grad_multi: HashMap<usize, LazyExe>,
    batch_size: usize,
    tokens_per_example: usize,
    vocab_size: usize,
    pad_id: i32,
}

impl ModelRuntime {
    /// Load config `name` from `artifacts_dir`. Executables are compiled
    /// lazily, on first use.
    pub fn load(artifacts_dir: &Path, name: &str) -> Result<Self> {
        let engine = PjrtEngine::cpu()?;
        Self::load_with_engine(engine, artifacts_dir, name)
    }

    pub fn load_with_engine(
        engine: PjrtEngine,
        artifacts_dir: &Path,
        name: &str,
    ) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir, name)?;
        let need = |f: &str| -> Result<LazyExe> {
            let a = manifest
                .artifact(f, None)
                .with_context(|| format!("manifest lacks artifact {f}"))?;
            let path = manifest.artifact_path(a);
            if !path.exists() {
                anyhow::bail!("artifact file missing: {}", path.display());
            }
            Ok(LazyExe::new(path))
        };
        let exe_eval = need("eval_loss")?;
        let exe_grad = need("grad")?;
        let exe_step = need("sgd_step")?;
        let mut exe_local = HashMap::new();
        let mut exe_grad_multi = HashMap::new();
        for a in &manifest.artifacts {
            if let Some(tau) = a.tau {
                let lazy = LazyExe::new(manifest.artifact_path(a));
                match a.func.as_str() {
                    "local_train" => {
                        exe_local.insert(tau, lazy);
                    }
                    "grad_multi" => {
                        exe_grad_multi.insert(tau, lazy);
                    }
                    _ => {}
                }
            }
        }
        let batch_size = manifest.meta_usize("batch_size")?;
        let seq_len = manifest.meta_usize("seq_len")?;
        let vocab_size = manifest.meta_usize("vocab_size")?;
        let pad_id = manifest.meta_usize("pad_id")? as i32;
        Ok(ModelRuntime {
            manifest,
            engine,
            exe_eval,
            exe_grad,
            exe_step,
            exe_local,
            exe_grad_multi,
            batch_size,
            tokens_per_example: seq_len + 1,
            vocab_size,
            pad_id,
        })
    }

    pub fn platform(&self) -> String {
        self.engine.platform()
    }

    pub fn num_param_tensors(&self) -> usize {
        self.manifest.params.len()
    }

    // -- literal conversion helpers --------------------------------------

    fn params_to_literals(&self, params: &Params) -> Result<Vec<xla::Literal>> {
        if params.len() != self.manifest.params.len() {
            bail!(
                "params arity {} != manifest {}",
                params.len(),
                self.manifest.params.len()
            );
        }
        let mut out = Vec::with_capacity(params.len());
        for (spec, vals) in self.manifest.params.iter().zip(params) {
            if vals.len() != spec.num_elements() {
                bail!("param {} has {} elements, want {}", spec.name, vals.len(), spec.num_elements());
            }
            let lit = xla::Literal::vec1(vals);
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            out.push(lit.reshape(&dims).map_err(anyhow::Error::msg)?);
        }
        Ok(out)
    }

    fn tokens_literal(&self, tokens: &[i32], tau: Option<usize>) -> Result<xla::Literal> {
        let per = self.batch_size * self.tokens_per_example;
        let want = per * tau.unwrap_or(1);
        if tokens.len() != want {
            bail!("token buffer has {} ints, want {want}", tokens.len());
        }
        let lit = xla::Literal::vec1(tokens);
        let dims: Vec<i64> = match tau {
            None => vec![self.batch_size as i64, self.tokens_per_example as i64],
            Some(t) => vec![t as i64, self.batch_size as i64, self.tokens_per_example as i64],
        };
        lit.reshape(&dims).map_err(anyhow::Error::msg)
    }

    /// Execute and untuple into (leading params-like tensors, trailing scalar).
    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
        expect_params_out: bool,
    ) -> Result<(Params, f32)> {
        let result = exe.execute::<xla::Literal>(args).map_err(anyhow::Error::msg)?;
        let out = result[0][0].to_literal_sync().map_err(anyhow::Error::msg)?;
        let mut elems = out.to_tuple().map_err(anyhow::Error::msg)?;
        if elems.is_empty() {
            bail!("executable returned empty tuple");
        }
        let loss_lit = elems.pop().unwrap();
        let loss = loss_lit
            .to_vec::<f32>()
            .map_err(anyhow::Error::msg)?
            .first()
            .copied()
            .context("empty loss literal")?;
        let params = if expect_params_out {
            if elems.len() != self.manifest.params.len() {
                bail!(
                    "executable returned {} tensors, want {}",
                    elems.len(),
                    self.manifest.params.len()
                );
            }
            elems
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(anyhow::Error::msg))
                .collect::<Result<Params>>()?
        } else {
            Params::new()
        };
        Ok((params, loss))
    }
}

impl ModelBackend for ModelRuntime {
    fn init_params(&self) -> Params {
        self.manifest
            .load_init_params()
            .expect("init params blob missing/corrupt — rerun `make artifacts`")
    }

    fn batch_shape(&self) -> (usize, usize) {
        (self.batch_size, self.tokens_per_example)
    }

    fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    fn pad_id(&self) -> i32 {
        self.pad_id
    }

    fn eval_loss(&self, params: &Params, tokens: &[i32]) -> Result<f32> {
        let mut args = self.params_to_literals(params)?;
        args.push(self.tokens_literal(tokens, None)?);
        let (_, loss) = self.run(self.exe_eval.get(&self.engine)?, &args, false)?;
        Ok(loss)
    }

    fn grad(&self, params: &Params, tokens: &[i32]) -> Result<(Params, f32)> {
        let mut args = self.params_to_literals(params)?;
        args.push(self.tokens_literal(tokens, None)?);
        self.run(self.exe_grad.get(&self.engine)?, &args, true)
    }

    fn sgd_step(&self, params: &Params, tokens: &[i32], lr: f32) -> Result<(Params, f32)> {
        let mut args = self.params_to_literals(params)?;
        args.push(self.tokens_literal(tokens, None)?);
        args.push(xla::Literal::scalar(lr));
        self.run(self.exe_step.get(&self.engine)?, &args, true)
    }

    fn local_train(
        &self,
        params: &Params,
        tokens: &[i32],
        tau: usize,
        lr: f32,
    ) -> Result<(Params, f32)> {
        match self.exe_local.get(&tau) {
            Some(exe) => {
                let mut args = self.params_to_literals(params)?;
                args.push(self.tokens_literal(tokens, Some(tau))?);
                args.push(xla::Literal::scalar(lr));
                self.run(exe.get(&self.engine)?, &args, true)
            }
            None => {
                // No fused executable for this tau: loop the single-step one.
                let (b, t) = self.batch_shape();
                let per = b * t;
                if tokens.len() != tau * per {
                    bail!("token buffer has {} ints, want {}", tokens.len(), tau * per);
                }
                let mut p = params.clone();
                let mut loss_sum = 0.0f32;
                for i in 0..tau {
                    let (np, l) = self.sgd_step(&p, &tokens[i * per..(i + 1) * per], lr)?;
                    p = np;
                    loss_sum += l;
                }
                Ok((p, loss_sum / tau as f32))
            }
        }
    }

    fn grad_multi(&self, params: &Params, tokens: &[i32], tau: usize) -> Result<(Params, f32)> {
        match self.exe_grad_multi.get(&tau) {
            Some(exe) => {
                let mut args = self.params_to_literals(params)?;
                args.push(self.tokens_literal(tokens, Some(tau))?);
                self.run(exe.get(&self.engine)?, &args, true)
            }
            None => {
                // Fall back to the default loop over single-batch grads.
                let (b, t) = self.batch_shape();
                let per = b * t;
                if tokens.len() != tau * per {
                    bail!("token buffer has {} ints, want {}", tokens.len(), tau * per);
                }
                let mut acc: Option<Params> = None;
                let mut loss_sum = 0.0f32;
                for i in 0..tau {
                    let (g, l) = self.grad(params, &tokens[i * per..(i + 1) * per])?;
                    loss_sum += l;
                    match &mut acc {
                        None => acc = Some(g),
                        Some(a) => {
                            for (at, gt) in a.iter_mut().zip(&g) {
                                for (av, gv) in at.iter_mut().zip(gt) {
                                    *av += gv;
                                }
                            }
                        }
                    }
                }
                let mut mean = acc.unwrap();
                for te in mean.iter_mut() {
                    for v in te.iter_mut() {
                        *v /= tau as f32;
                    }
                }
                Ok((mean, loss_sum / tau as f32))
            }
        }
    }

    fn has_fused_tau(&self, tau: usize) -> bool {
        self.exe_local.contains_key(&tau)
    }
}

// Integration coverage for ModelRuntime lives in rust/tests/runtime_artifacts.rs
// (requires `make artifacts`); unit tests here cover argument validation only.
#[cfg(test)]
mod tests {
    #[test]
    fn params_type_is_plain_vectors() {
        let p: super::Params = vec![vec![1.0, 2.0], vec![3.0]];
        assert_eq!(p.iter().map(|v| v.len()).sum::<usize>(), 3);
    }
}
