//! PJRT engine: the `xla`-crate wrapper that loads HLO-text artifacts and
//! compiles them on the CPU PJRT client (the pattern of
//! /opt/xla-example/load_hlo).

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client + compile cache entry point.
pub struct PjrtEngine {
    client: xla::PjRtClient,
}

impl PjrtEngine {
    /// CPU client (the only backend in this environment).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow::Error::msg)?;
        Ok(PjrtEngine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO **text** file and compile it to an executable.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let e = PjrtEngine::cpu().unwrap();
        assert!(e.device_count() >= 1);
        assert!(!e.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let e = PjrtEngine::cpu().unwrap();
        match e.compile_hlo_text(Path::new("/nonexistent/x.hlo.txt")) {
            Ok(_) => panic!("expected an error"),
            Err(err) => assert!(err.to_string().contains("x.hlo.txt")),
        }
    }
}
