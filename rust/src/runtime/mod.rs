//! The PJRT runtime: loads the AOT artifacts `make artifacts` produced and
//! executes them from the Rust hot path. Python never runs at request
//! time — the interchange is HLO *text* (see DESIGN.md §3 for why text,
//! not serialized protos).
//!
//! * [`manifest`] — parses `artifacts/<cfg>.manifest` (param order/shapes,
//!   model meta, artifact file list).
//! * [`engine`] — thin wrapper over `xla::PjRtClient` (CPU):
//!   `HloModuleProto::from_text_file -> XlaComputation -> compile`.
//! * [`model`] — [`ModelRuntime`]: typed entry points (eval_loss / grad /
//!   sgd_step / fused local_train) over flattened host parameters.
//! * [`mock`] — [`mock::MockRuntime`]: a pure-Rust quadratic model with the
//!   same [`ModelBackend`] trait, so the federated layer is fully testable
//!   without artifacts or PJRT.

pub mod engine;
pub mod manifest;
pub mod mock;
pub mod model;

pub use engine::PjrtEngine;
pub use manifest::Manifest;
pub use mock::MockRuntime;
pub use model::ModelRuntime;

use anyhow::Result;

/// Host-side flattened parameters: one `Vec<f32>` per tensor, in manifest
/// order. The federated layer treats these as opaque vectors (its server
/// optimizers are elementwise).
pub type Params = Vec<Vec<f32>>;

/// Persist parameters (checkpointing for benches/experiments): per tensor,
/// `u64 LE length` then raw LE f32s.
pub fn save_params(params: &Params, path: &std::path::Path) -> Result<()> {
    use std::io::Write;
    if let Some(d) = path.parent() {
        std::fs::create_dir_all(d)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    for t in params {
        f.write_all(&(t.len() as u64).to_le_bytes())?;
        for v in t {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    f.flush()?;
    Ok(())
}

/// Inverse of [`save_params`].
pub fn load_params(path: &std::path::Path) -> Result<Params> {
    use std::io::Read;
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        f.read_exact(&mut b8)?;
        let len = u64::from_le_bytes(b8) as usize;
        let mut t = Vec::with_capacity(len);
        let mut b4 = [0u8; 4];
        for _ in 0..len {
            f.read_exact(&mut b4)?;
            t.push(f32::from_le_bytes(b4));
        }
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod param_io_tests {
    #[test]
    fn save_load_roundtrip() {
        let p: super::Params = vec![vec![1.5, -2.5], vec![], vec![0.0; 7]];
        let path = std::env::temp_dir().join("grouper_params_io").join("p.bin");
        super::save_params(&p, &path).unwrap();
        assert_eq!(super::load_params(&path).unwrap(), p);
    }
}

/// What the federated layer needs from a model, independent of backend
/// (PJRT artifacts or the pure-Rust mock).
///
/// Deliberately not `Send`/`Sync`: the PJRT executables hold `Rc` client
/// handles, and the round loop is sequential by design (clients within a
/// round share one CPU device; parallelism lives in the data pipeline).
pub trait ModelBackend {
    /// Fresh initial parameters (deterministic).
    fn init_params(&self) -> Params;

    /// (batch_size, tokens_per_example): clients feed token buffers of
    /// exactly `batch * tokens_per_example` i32s per batch.
    fn batch_shape(&self) -> (usize, usize);

    /// Vocabulary size (token ids must be < this).
    fn vocab_size(&self) -> usize;

    /// Padding token id (masked out of the loss).
    fn pad_id(&self) -> i32;

    /// Mean masked CE loss of one batch.
    fn eval_loss(&self, params: &Params, tokens: &[i32]) -> Result<f32>;

    /// (gradients, loss) of one batch — the FedSGD client step.
    fn grad(&self, params: &Params, tokens: &[i32]) -> Result<(Params, f32)>;

    /// Fused FedSGD client: mean gradient (and loss) over `tau` stacked
    /// batches, all at the broadcast parameters. Backends without a fused
    /// executable fall back to looping [`ModelBackend::grad`].
    fn grad_multi(&self, params: &Params, tokens: &[i32], tau: usize) -> Result<(Params, f32)> {
        let (b, t) = self.batch_shape();
        let per = b * t;
        assert_eq!(tokens.len(), tau * per, "grad_multi token buffer size");
        let mut acc: Option<Params> = None;
        let mut loss_sum = 0.0f32;
        for i in 0..tau {
            let (g, l) = self.grad(params, &tokens[i * per..(i + 1) * per])?;
            loss_sum += l;
            match &mut acc {
                None => acc = Some(g),
                Some(a) => {
                    for (at, gt) in a.iter_mut().zip(&g) {
                        for (av, gv) in at.iter_mut().zip(gt) {
                            *av += gv;
                        }
                    }
                }
            }
        }
        let mut mean = acc.unwrap();
        for t in mean.iter_mut() {
            for v in t.iter_mut() {
                *v /= tau as f32;
            }
        }
        Ok((mean, loss_sum / tau as f32))
    }

    /// One client SGD step; returns (new params, loss).
    fn sgd_step(&self, params: &Params, tokens: &[i32], lr: f32) -> Result<(Params, f32)>;

    /// Fused tau-step local training over `tau` stacked batches
    /// (tokens.len() == tau * batch * tokens_per_example). Returns
    /// (new params, mean loss). Backends without a fused executable for
    /// this tau fall back to looping [`ModelBackend::sgd_step`].
    fn local_train(
        &self,
        params: &Params,
        tokens: &[i32],
        tau: usize,
        lr: f32,
    ) -> Result<(Params, f32)> {
        let (b, t) = self.batch_shape();
        let per = b * t;
        assert_eq!(tokens.len(), tau * per, "local_train token buffer size");
        let mut p = params.clone();
        let mut loss_sum = 0.0f32;
        for i in 0..tau {
            let (np, l) = self.sgd_step(&p, &tokens[i * per..(i + 1) * per], lr)?;
            p = np;
            loss_sum += l;
        }
        Ok((p, loss_sum / tau as f32))
    }

    /// Whether `local_train` for this tau executes as one fused PJRT call
    /// (perf introspection for Table 4 / EXPERIMENTS.md §Perf).
    fn has_fused_tau(&self, tau: usize) -> bool {
        let _ = tau;
        false
    }
}
