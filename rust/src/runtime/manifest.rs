//! Parser for the artifact manifest `aot.py` writes.
//!
//! Grammar (line-oriented, whitespace-separated):
//! ```text
//! meta <key> <value>
//! param <name> <dtype> <rank> <dims...>
//! artifact <fn> <file> [tau]
//! ```
//! Param lines define the canonical flat-parameter order shared by the
//! Python model (`model.param_spec`) and every HLO entry point.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One parameter tensor's name/shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported HLO artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub func: String,
    pub file: String,
    pub tau: Option<usize>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub meta: BTreeMap<String, String>,
    pub params: Vec<ParamSpec>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path, config: &str) -> Result<Manifest> {
        let path = artifacts_dir.join(format!("{config}.manifest"));
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(artifacts_dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let mut meta = BTreeMap::new();
        let mut params = Vec::new();
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.is_empty() {
                continue;
            }
            match parts[0] {
                "meta" => {
                    if parts.len() != 3 {
                        bail!("manifest line {}: meta needs key+value", lineno + 1);
                    }
                    meta.insert(parts[1].to_string(), parts[2].to_string());
                }
                "param" => {
                    if parts.len() < 4 {
                        bail!("manifest line {}: short param", lineno + 1);
                    }
                    let rank: usize = parts[3].parse()?;
                    if parts.len() != 4 + rank {
                        bail!("manifest line {}: rank/dims mismatch", lineno + 1);
                    }
                    let shape = parts[4..4 + rank]
                        .iter()
                        .map(|d| d.parse::<usize>())
                        .collect::<Result<Vec<_>, _>>()?;
                    params.push(ParamSpec {
                        name: parts[1].to_string(),
                        dtype: parts[2].to_string(),
                        shape,
                    });
                }
                "artifact" => {
                    if parts.len() < 3 || parts.len() > 4 {
                        bail!("manifest line {}: bad artifact", lineno + 1);
                    }
                    let tau = if parts.len() == 4 { Some(parts[3].parse()?) } else { None };
                    artifacts.push(ArtifactSpec {
                        func: parts[1].to_string(),
                        file: parts[2].to_string(),
                        tau,
                    });
                }
                other => bail!("manifest line {}: unknown directive {other:?}", lineno + 1),
            }
        }
        if params.is_empty() {
            bail!("manifest has no params");
        }
        Ok(Manifest { dir: dir.to_path_buf(), meta, params, artifacts })
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .with_context(|| format!("manifest missing meta {key}"))?
            .parse()
            .with_context(|| format!("meta {key} not an integer"))
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(|p| p.num_elements()).sum()
    }

    pub fn artifact(&self, func: &str, tau: Option<usize>) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.func == func && a.tau == tau)
    }

    pub fn artifact_path(&self, a: &ArtifactSpec) -> PathBuf {
        self.dir.join(&a.file)
    }

    /// Available fused local_train tau values.
    pub fn tau_variants(&self) -> Vec<usize> {
        self.artifacts.iter().filter_map(|a| a.tau).collect()
    }

    /// Load the initial-parameter blob (raw LE f32, manifest order).
    pub fn load_init_params(&self) -> Result<super::Params> {
        let file = self
            .meta
            .get("init_params")
            .context("manifest missing meta init_params")?;
        let blob = std::fs::read(self.dir.join(file))?;
        let expect = 4 * self.num_params();
        if blob.len() != expect {
            bail!("init params blob is {} bytes, want {expect}", blob.len());
        }
        let mut out = Vec::with_capacity(self.params.len());
        let mut off = 0usize;
        for p in &self.params {
            let n = p.num_elements();
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let b = &blob[off + 4 * i..off + 4 * i + 4];
                v.push(f32::from_le_bytes(b.try_into().unwrap()));
            }
            off += 4 * n;
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
meta config tiny
meta vocab_size 256
meta batch_size 4
meta seq_len 32
meta num_params 10
meta init_params tiny_init_params.bin
param embed f32 2 5 2
param bias f32 0
artifact eval_loss tiny_eval_loss.hlo.txt
artifact local_train tiny_local_train_tau4.hlo.txt 4
";

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.meta["config"], "tiny");
        assert_eq!(m.meta_usize("vocab_size").unwrap(), 256);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].shape, vec![5, 2]);
        assert_eq!(m.params[1].shape, Vec::<usize>::new());
        assert_eq!(m.num_params(), 11);
        assert!(m.artifact("eval_loss", None).is_some());
        assert!(m.artifact("local_train", Some(4)).is_some());
        assert!(m.artifact("local_train", Some(8)).is_none());
        assert_eq!(m.tau_variants(), vec![4]);
    }

    #[test]
    fn rejects_malformed() {
        let d = Path::new("/tmp");
        assert!(Manifest::parse(d, "meta only_one\nparam x f32 0\n").is_err());
        assert!(Manifest::parse(d, "param x f32 2 5\n").is_err());
        assert!(Manifest::parse(d, "bogus line here\nparam x f32 0\n").is_err());
        assert!(Manifest::parse(d, "meta a b\n").is_err()); // no params
    }

    #[test]
    fn init_blob_roundtrip() {
        let dir = std::env::temp_dir().join("grouper_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let m = Manifest::parse(&dir, SAMPLE).unwrap();
        let vals: Vec<f32> = (0..11).map(|i| i as f32 * 0.5).collect();
        let blob: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("tiny_init_params.bin"), &blob).unwrap();
        let params = m.load_init_params().unwrap();
        assert_eq!(params.len(), 2);
        assert_eq!(params[0].len(), 10);
        assert_eq!(params[1].len(), 1);
        assert_eq!(params[1][0], 5.0);
        // wrong size rejected
        std::fs::write(dir.join("tiny_init_params.bin"), &blob[..8]).unwrap();
        assert!(m.load_init_params().is_err());
    }
}
