//! [`MockRuntime`]: a pure-Rust model with the [`ModelBackend`] trait, so
//! the federated layer (algorithms, schedules, personalization, trainer)
//! is exhaustively testable without PJRT or artifacts.
//!
//! The model is a per-token-bucket quadratic:
//! `loss = mean_t (w[t mod K] - target(t))^2` over the non-pad tokens `t`
//! of a batch, where `target(t) = (t mod M) / M`. Clients whose token
//! distributions differ (heterogeneity!) pull different coordinates of
//! `w`, which reproduces — in a model we can reason about exactly — the
//! FedAvg-as-meta-learner phenomenology the paper studies: local steps fit
//! a client's own buckets almost perfectly (tiny post-personalization
//! loss) while the server average compromises across clients.

use anyhow::{bail, Result};

use super::{ModelBackend, Params};

#[derive(Debug, Clone)]
pub struct MockRuntime {
    pub dim: usize,
    pub batch_size: usize,
    pub tokens_per_example: usize,
    pub vocab: usize,
    pub target_mod: usize,
}

impl MockRuntime {
    pub fn new(dim: usize, batch_size: usize, tokens_per_example: usize, vocab: usize) -> Self {
        MockRuntime { dim, batch_size, tokens_per_example, vocab, target_mod: 7 }
    }

    /// Default shape used across the fed tests.
    pub fn standard() -> Self {
        MockRuntime::new(16, 4, 9, 64)
    }

    fn target(&self, token: i32) -> f32 {
        (token as usize % self.target_mod) as f32 / self.target_mod as f32
    }

    /// loss and gradient in closed form.
    fn loss_and_grad(&self, w: &[f32], tokens: &[i32]) -> (f32, Vec<f32>) {
        let mut grad = vec![0.0f32; self.dim];
        let mut loss = 0.0f32;
        let mut n = 0usize;
        for &t in tokens {
            if t == self.pad_id() {
                continue;
            }
            let i = t as usize % self.dim;
            let d = w[i] - self.target(t);
            loss += d * d;
            grad[i] += 2.0 * d;
            n += 1;
        }
        let n = n.max(1) as f32;
        for g in grad.iter_mut() {
            *g /= n;
        }
        (loss / n, grad)
    }

    fn check(&self, params: &Params, tokens: &[i32]) -> Result<()> {
        if params.len() != 1 || params[0].len() != self.dim {
            bail!("mock expects a single [dim] parameter tensor");
        }
        let per = self.batch_size * self.tokens_per_example;
        if tokens.len() % per != 0 || tokens.is_empty() {
            bail!("token buffer {} not a multiple of batch {per}", tokens.len());
        }
        Ok(())
    }
}

impl ModelBackend for MockRuntime {
    fn init_params(&self) -> Params {
        vec![vec![0.5f32; self.dim]]
    }

    fn batch_shape(&self) -> (usize, usize) {
        (self.batch_size, self.tokens_per_example)
    }

    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn pad_id(&self) -> i32 {
        0
    }

    fn eval_loss(&self, params: &Params, tokens: &[i32]) -> Result<f32> {
        self.check(params, tokens)?;
        Ok(self.loss_and_grad(&params[0], tokens).0)
    }

    fn grad(&self, params: &Params, tokens: &[i32]) -> Result<(Params, f32)> {
        self.check(params, tokens)?;
        let (loss, g) = self.loss_and_grad(&params[0], tokens);
        Ok((vec![g], loss))
    }

    fn sgd_step(&self, params: &Params, tokens: &[i32], lr: f32) -> Result<(Params, f32)> {
        self.check(params, tokens)?;
        let (loss, g) = self.loss_and_grad(&params[0], tokens);
        let w: Vec<f32> = params[0].iter().zip(&g).map(|(w, g)| w - lr * g).collect();
        Ok((vec![w], loss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens(ids: &[i32], mock: &MockRuntime) -> Vec<i32> {
        // Tile ids into a full batch buffer (avoiding pad id 0).
        let per = mock.batch_size * mock.tokens_per_example;
        (0..per).map(|i| ids[i % ids.len()]).collect()
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let m = MockRuntime::standard();
        let p = m.init_params();
        let toks = tokens(&[3, 17, 5, 40, 9], &m);
        let (g, _) = m.grad(&p, &toks).unwrap();
        let eps = 1e-3f32;
        for i in 0..m.dim {
            let mut p_hi = p.clone();
            p_hi[0][i] += eps;
            let mut p_lo = p.clone();
            p_lo[0][i] -= eps;
            let fd = (m.eval_loss(&p_hi, &toks).unwrap() - m.eval_loss(&p_lo, &toks).unwrap())
                / (2.0 * eps);
            assert!((fd - g[0][i]).abs() < 1e-3, "coord {i}: fd {fd} vs {}", g[0][i]);
        }
    }

    #[test]
    fn sgd_converges_to_zero_loss_on_fixed_batch() {
        let m = MockRuntime::standard();
        let mut p = m.init_params();
        let toks = tokens(&[3, 17, 5], &m);
        let mut last = f32::INFINITY;
        for _ in 0..200 {
            let (np, l) = m.sgd_step(&p, &toks, 0.4).unwrap();
            p = np;
            assert!(l <= last + 1e-6);
            last = l;
        }
        assert!(last < 1e-4, "loss {last}");
    }

    #[test]
    fn default_local_train_equals_manual_loop() {
        let m = MockRuntime::standard();
        let p = m.init_params();
        let per = m.batch_size * m.tokens_per_example;
        let buf: Vec<i32> = (0..3 * per).map(|i| 1 + (i as i32 * 13) % 60).collect();
        let (p_fused, l_fused) = m.local_train(&p, &buf, 3, 0.1).unwrap();
        let mut q = p.clone();
        let mut ls = 0.0;
        for i in 0..3 {
            let (nq, l) = m.sgd_step(&q, &buf[i * per..(i + 1) * per], 0.1).unwrap();
            q = nq;
            ls += l;
        }
        assert_eq!(p_fused, q);
        assert!((l_fused - ls / 3.0).abs() < 1e-6);
    }

    #[test]
    fn pad_tokens_are_ignored() {
        let m = MockRuntime::standard();
        let p = m.init_params();
        let toks = tokens(&[5, 5, 5], &m);
        let mut padded = toks.clone();
        for i in 0..padded.len() / 2 {
            padded[2 * i] = 0; // pad
        }
        let a = m.eval_loss(&p, &toks).unwrap();
        let b = m.eval_loss(&p, &padded).unwrap();
        assert!((a - b).abs() < 1e-6, "pad changed loss: {a} vs {b}");
    }

    #[test]
    fn shape_validation() {
        let m = MockRuntime::standard();
        let p = m.init_params();
        assert!(m.eval_loss(&p, &[1, 2, 3]).is_err());
        assert!(m.eval_loss(&vec![vec![0.0; 3]], &tokens(&[1], &m)).is_err());
    }
}
