//! Server learning-rate schedules (§5.2, Appendix C.4).
//!
//! All schedules are applied at the *server* (the paper applies none at
//! clients). Warmup is linear from 0 over the first 10% of rounds; decay
//! then runs to (near) zero at the final round. `eta` is the *maximum*
//! learning rate (attained at the end of warmup), matching the paper's
//! convention for tuned values.

use crate::config::ScheduleKind;

/// A resolved schedule: total rounds + peak LR + shape.
#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub kind: ScheduleKind,
    pub eta: f32,
    pub total_rounds: usize,
    pub warmup_rounds: usize,
}

impl Schedule {
    pub fn new(kind: ScheduleKind, eta: f32, total_rounds: usize) -> Self {
        assert!(total_rounds > 0 && eta > 0.0);
        let warmup_rounds = match kind {
            ScheduleKind::Constant => 0,
            _ => (total_rounds / 10).max(1),
        };
        Schedule { kind, eta, total_rounds, warmup_rounds }
    }

    /// LR at round `t` (0-based).
    pub fn lr(&self, t: usize) -> f32 {
        match self.kind {
            ScheduleKind::Constant => self.eta,
            _ => {
                if t < self.warmup_rounds {
                    // Linear warmup starting at 0 (first step slightly above).
                    return self.eta * (t as f32 + 1.0) / (self.warmup_rounds as f32);
                }
                let remain = (self.total_rounds - self.warmup_rounds).max(1) as f32;
                let progress = (t - self.warmup_rounds) as f32 / remain; // [0, 1)
                match self.kind {
                    ScheduleKind::WarmupExp => {
                        // Decay to ~1e-3 * eta at the end.
                        self.eta * (0.001f32).powf(progress)
                    }
                    ScheduleKind::WarmupCosine => {
                        self.eta * 0.5 * (1.0 + (std::f32::consts::PI * progress).cos())
                    }
                    ScheduleKind::Constant => unreachable!(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_flat() {
        let s = Schedule::new(ScheduleKind::Constant, 1e-3, 100);
        assert_eq!(s.lr(0), 1e-3);
        assert_eq!(s.lr(99), 1e-3);
    }

    #[test]
    fn warmup_rises_then_decays() {
        for kind in [ScheduleKind::WarmupExp, ScheduleKind::WarmupCosine] {
            let s = Schedule::new(kind, 1.0, 100);
            assert_eq!(s.warmup_rounds, 10);
            // rising during warmup
            assert!(s.lr(0) < s.lr(5));
            assert!(s.lr(5) < s.lr(9));
            // peak at end of warmup
            assert!((s.lr(10) - 1.0).abs() < 0.06, "{kind:?} {}", s.lr(10));
            // monotone decay afterwards
            let mut prev = s.lr(10);
            for t in 11..100 {
                let v = s.lr(t);
                assert!(v <= prev + 1e-7, "{kind:?} rose at {t}");
                prev = v;
            }
            // near zero at the end
            assert!(s.lr(99) < 0.01, "{kind:?} final {}", s.lr(99));
        }
    }

    #[test]
    fn cosine_halfway_is_half() {
        let s = Schedule::new(ScheduleKind::WarmupCosine, 2.0, 110);
        let mid = 11 + (110 - 11) / 2;
        assert!((s.lr(mid) - 1.0).abs() < 0.05, "{}", s.lr(mid));
    }

    #[test]
    fn warmup_at_least_one_round() {
        let s = Schedule::new(ScheduleKind::WarmupExp, 1.0, 5);
        assert_eq!(s.warmup_rounds, 1);
        assert!(s.lr(0) > 0.0);
    }
}
