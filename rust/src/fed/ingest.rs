//! Live ingestion: a seeded writer that keeps appending client examples
//! — for existing groups and newly arriving ones — into a live
//! [`PagedStore`] or [`PagedShardSet`] while a trainer samples cohorts
//! from epoch-pinned snapshots next door.
//!
//! This is the workload half of the live-ingestion story (the reader
//! half is [`super::source::RefreshingSource`]): the storage engine
//! already guarantees that snapshot readers are bit-stable while the
//! single live writer appends, checkpoints and compacts — the
//! [`IngestRunner`] exists to *drive* that churn, deterministically, so
//! tests can soak it and benches can measure round-time degradation
//! versus ingest rate (Table 4e).
//!
//! Two drive modes:
//!
//! * **stepped** — [`IngestRunner::step`] appends one batch, commits,
//!   and runs the checkpoint/compaction schedule; fully deterministic
//!   given [`IngestConfig::seed`], which is what the churn soak test
//!   interleaves with training rounds;
//! * **threaded** — [`IngestRunner::spawn`] steps on a background
//!   thread at a fixed interval until stopped, which is what `grouper
//!   train --ingest-rate` and the Table 4e bench use.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::formats::paged::PagedStore;
use crate::formats::paged_sharded::PagedShardSet;
use crate::records::Example;
use crate::util::rng::Rng;

/// The live store an [`IngestRunner`] appends into — the runner owns
/// it, upholding the engine's single-live-writer rule.
pub enum IngestTarget {
    /// A single paged store (`<prefix>.pstore`).
    Single(PagedStore),
    /// A hash-sharded set (`<prefix>.pset`).
    Sharded(PagedShardSet),
}

impl IngestTarget {
    fn keys(&self) -> Vec<Vec<u8>> {
        match self {
            IngestTarget::Single(s) => s.keys(),
            IngestTarget::Sharded(s) => s.keys(),
        }
    }

    fn append(&mut self, group: &[u8], ex: &Example) -> Result<()> {
        match self {
            IngestTarget::Single(s) => s.append(group, ex),
            IngestTarget::Sharded(s) => s.append(group, ex),
        }
    }

    fn commit(&mut self) -> Result<()> {
        match self {
            IngestTarget::Single(s) => s.commit(),
            IngestTarget::Sharded(s) => s.commit(),
        }
    }

    fn checkpoint(&mut self) -> Result<()> {
        match self {
            IngestTarget::Single(s) => s.checkpoint(),
            IngestTarget::Sharded(s) => s.checkpoint(),
        }
    }

    fn compact(&mut self) -> Result<()> {
        // Reports are dropped: live-writer compaction is churn here,
        // not a space-accounting operation. With reader pins held it
        // may legitimately reclaim nothing.
        match self {
            IngestTarget::Single(s) => s.compact().map(|_| ()),
            IngestTarget::Sharded(s) => s.compact().map(|_| ()),
        }
    }
}

/// Shape of the seeded ingest stream and its checkpoint/compaction
/// churn schedule.
#[derive(Debug, Clone, Copy)]
pub struct IngestConfig {
    /// Seed for group choice and document text — the whole stream is a
    /// pure function of it.
    pub seed: u64,
    /// Examples appended (then committed) per [`IngestRunner::step`].
    pub examples_per_step: usize,
    /// Every Nth appended example mints a brand-new group (`ingest-K`)
    /// instead of extending an existing one; 0 = existing groups only.
    pub new_group_every: usize,
    /// Checkpoint after every N steps (0 = never) — this is what makes
    /// appends visible to fresh snapshots.
    pub checkpoint_every: usize,
    /// Compact after every N checkpoints (0 = never). With snapshot
    /// pins held the engine's gate may make this a no-op; the point is
    /// exercising the gate under churn, not reclaiming space.
    pub compact_every: usize,
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig {
            seed: 7,
            examples_per_step: 8,
            new_group_every: 16,
            checkpoint_every: 4,
            compact_every: 4,
        }
    }
}

/// What an ingest run did — counters only, all monotone.
#[derive(Debug, Clone, Copy, Default)]
pub struct IngestStats {
    /// Completed [`IngestRunner::step`] calls.
    pub steps: u64,
    /// Examples appended across all steps.
    pub appended: u64,
    /// Brand-new groups minted.
    pub new_groups: u64,
    /// Checkpoints published.
    pub checkpoints: u64,
    /// Compaction passes attempted.
    pub compactions: u64,
}

/// A seeded live writer: appends synthetic documents into existing and
/// newly minted groups with periodic checkpoint + compaction churn.
pub struct IngestRunner {
    target: IngestTarget,
    cfg: IngestConfig,
    rng: Rng,
    groups: Vec<Vec<u8>>,
    stats: IngestStats,
    seq: u64,
}

impl IngestRunner {
    /// Wrap a live writer. The target's current key set seeds the
    /// population that appends route into.
    ///
    /// # Errors
    /// An empty target with `new_group_every == 0` (nothing to append
    /// to, and no way to mint), or a zero `examples_per_step`.
    pub fn new(target: IngestTarget, cfg: IngestConfig) -> Result<IngestRunner> {
        if cfg.examples_per_step == 0 {
            bail!("ingest examples_per_step must be at least 1");
        }
        let groups = target.keys();
        if groups.is_empty() && cfg.new_group_every == 0 {
            bail!("ingest target holds no groups and new_group_every = 0 never mints one");
        }
        Ok(IngestRunner {
            target,
            rng: Rng::new(cfg.seed),
            cfg,
            groups,
            stats: IngestStats::default(),
            seq: 0,
        })
    }

    /// Counters so far.
    pub fn stats(&self) -> IngestStats {
        self.stats
    }

    /// Append one batch ([`IngestConfig::examples_per_step`] examples),
    /// commit it, and run the checkpoint/compaction schedule.
    ///
    /// # Errors
    /// Any append/commit/checkpoint/compact failure of the underlying
    /// store (which poisons the writer like any paged-store failure).
    pub fn step(&mut self) -> Result<()> {
        for _ in 0..self.cfg.examples_per_step {
            self.seq += 1;
            let mint = self.groups.is_empty()
                || (self.cfg.new_group_every > 0
                    && self.seq % self.cfg.new_group_every as u64 == 0);
            let key = if mint {
                let key = format!("ingest-{:06}", self.stats.new_groups).into_bytes();
                self.stats.new_groups += 1;
                self.groups.push(key.clone());
                key
            } else {
                self.groups[self.rng.gen_range_usize(self.groups.len())].clone()
            };
            let text = format!(
                "live doc {} for {} tok{}",
                self.seq,
                String::from_utf8_lossy(&key),
                self.rng.gen_range(97)
            );
            self.target.append(&key, &Example::text(&text)).context("ingest append")?;
            self.stats.appended += 1;
        }
        self.target.commit().context("ingest commit")?;
        self.stats.steps += 1;
        if self.cfg.checkpoint_every > 0 && self.stats.steps % self.cfg.checkpoint_every as u64 == 0
        {
            self.target.checkpoint().context("ingest checkpoint")?;
            self.stats.checkpoints += 1;
            if self.cfg.compact_every > 0
                && self.stats.checkpoints % self.cfg.compact_every as u64 == 0
            {
                self.target.compact().context("ingest compaction")?;
                self.stats.compactions += 1;
            }
        }
        Ok(())
    }

    /// Run `n` steps back to back.
    ///
    /// # Errors
    /// Same conditions as [`IngestRunner::step`].
    pub fn run_steps(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.step()?;
        }
        Ok(())
    }

    /// Move the runner onto a background thread that steps every
    /// `interval` until [`IngestHandle::stop`] (or drop). A final
    /// checkpoint on shutdown publishes whatever the last steps
    /// appended, so a quiescing store ends fully visible.
    pub fn spawn(mut self, interval: Duration) -> IngestHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("grouper-ingest".into())
            .spawn(move || -> Result<IngestStats> {
                while !stop_flag.load(Ordering::Relaxed) {
                    self.step()?;
                    std::thread::sleep(interval);
                }
                if self.cfg.checkpoint_every > 0 {
                    self.target.checkpoint().context("final ingest checkpoint")?;
                    self.stats.checkpoints += 1;
                }
                Ok(self.stats)
            })
            .expect("spawn ingest thread");
        IngestHandle { stop, thread: Some(thread) }
    }
}

/// Owner handle for a spawned [`IngestRunner`] thread; stops (and
/// joins) the writer on [`IngestHandle::stop`] or drop.
pub struct IngestHandle {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<Result<IngestStats>>>,
}

impl IngestHandle {
    /// Signal the writer to stop, wait for its final checkpoint, and
    /// return the run's counters.
    ///
    /// # Errors
    /// Whatever the ingest thread failed with, or its panic rendered
    /// as an error.
    pub fn stop(mut self) -> Result<IngestStats> {
        self.stop.store(true, Ordering::Relaxed);
        let thread = self.thread.take().expect("stop() runs once");
        match thread.join() {
            Ok(result) => result,
            Err(p) => Err(anyhow!(
                "ingest thread panicked: {}",
                p.downcast_ref::<String>().cloned().unwrap_or_else(|| p
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .unwrap_or_else(|| "non-string panic payload".into()))
            )),
        }
    }
}

impl Drop for IngestHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(thread) = self.thread.take() {
            let _ = thread.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::paged::PagedReader;
    use crate::store::vfs::{MemVfs, Vfs};
    use std::path::PathBuf;

    fn mem_store(vfs: &dyn Vfs, groups: usize) -> PagedStore {
        let dir = PathBuf::from("/mem");
        let mut store = PagedStore::create_with(vfs, &dir, "live", 32).unwrap();
        for g in 0..groups {
            let key = format!("seed-{g:02}");
            for d in 0..3 {
                store.append(key.as_bytes(), &Example::text(&format!("doc {d} of {key}"))).unwrap();
            }
        }
        store.commit().unwrap();
        store.checkpoint().unwrap();
        store
    }

    #[test]
    fn stepped_ingest_is_deterministic_and_mints_groups() {
        let run = |steps: usize| -> (IngestStats, Vec<Vec<u8>>, u64) {
            let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new());
            let store = mem_store(vfs.as_ref(), 6);
            let cfg = IngestConfig { seed: 3, ..Default::default() };
            let mut runner = IngestRunner::new(IngestTarget::Single(store), cfg).unwrap();
            runner.run_steps(steps).unwrap();
            let stats = runner.stats();
            drop(runner);
            let r =
                PagedReader::open_snapshot_with(vfs.as_ref(), &PathBuf::from("/mem"), "live", 32)
                    .unwrap();
            (stats, r.keys().to_vec(), r.num_examples())
        };
        let (s1, k1, n1) = run(12);
        let (s2, k2, n2) = run(12);
        assert_eq!(s1.appended, s2.appended);
        assert_eq!(k1, k2, "seeded ingest must materialize identical key sets");
        assert_eq!(n1, n2);
        assert_eq!(s1.steps, 12);
        assert_eq!(s1.appended, 12 * 8);
        assert!(s1.new_groups > 0, "new groups must arrive");
        assert_eq!(s1.checkpoints, 3);
        assert!(k1.iter().any(|k| k.starts_with(b"ingest-")));
        // Only checkpointed appends are snapshot-visible: 2 full
        // checkpoint cycles beyond the seed data are in, the last
        // uncheckpointed steps are not.
        assert!(n1 > 6 * 3, "ingested examples must be visible after checkpoints");
    }

    #[test]
    fn empty_target_without_minting_is_refused() {
        let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new());
        let store = PagedStore::create_with(vfs.as_ref(), &PathBuf::from("/mem"), "e", 16).unwrap();
        let cfg = IngestConfig { new_group_every: 0, ..Default::default() };
        assert!(IngestRunner::new(IngestTarget::Single(store), cfg).is_err());
        let vfs2: Arc<dyn Vfs> = Arc::new(MemVfs::new());
        let store2 =
            PagedStore::create_with(vfs2.as_ref(), &PathBuf::from("/mem"), "e", 16).unwrap();
        let bad = IngestConfig { examples_per_step: 0, ..Default::default() };
        assert!(IngestRunner::new(IngestTarget::Single(store2), bad).is_err());
    }

    #[test]
    fn spawned_ingest_stops_cleanly_with_final_checkpoint() {
        let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new());
        let store = mem_store(vfs.as_ref(), 4);
        let cfg = IngestConfig { seed: 9, checkpoint_every: 2, ..Default::default() };
        let runner = IngestRunner::new(IngestTarget::Single(store), cfg).unwrap();
        let handle = runner.spawn(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(50));
        let stats = handle.stop().unwrap();
        assert!(stats.steps > 0, "the thread never stepped");
        assert!(stats.checkpoints > 0);
        // The final checkpoint makes every appended example visible.
        let r = PagedReader::open_snapshot_with(vfs.as_ref(), &PathBuf::from("/mem"), "live", 32)
            .unwrap();
        assert_eq!(r.num_examples(), 4 * 3 + stats.appended);
    }
}
