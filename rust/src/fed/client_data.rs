//! The client-side data pipeline (Appendix C.1, scaled):
//!
//! 1. tokenize the client's text (WordPiece);
//! 2. concatenate all tokens into sequences of length S+1, padding the
//!    last sequence as needed;
//! 3. batch with batch size B;
//! 4. repeat (cycling sequences) and truncate so the client yields exactly
//!    `tau` batches per round (paper: every client is equalized to 1024
//!    examples = 64 batches of 16).
//!
//! Reading the group's examples stops as soon as enough tokens are
//! buffered (`max_tokens`), which is the nested-stream payoff: a client
//! backed by a 100MB book costs only `tau*B*(S+1)` tokens of work.

use anyhow::Result;

use crate::formats::streaming::StreamedGroup;
use crate::tokenizer::WordPiece;

/// A client's round-ready token batches.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientBatches {
    /// `tau` batches, each `batch_size * (seq_len+1)` i32 ids, concatenated.
    pub tokens: Vec<i32>,
    pub tau: usize,
    pub batch_size: usize,
    pub tokens_per_example: usize,
    /// Distinct (pre-repeat) sequences the client actually had.
    pub distinct_sequences: usize,
    /// Raw token count before repeat/truncate.
    pub raw_tokens: usize,
}

impl ClientBatches {
    /// Tokens of batch `i`.
    pub fn batch(&self, i: usize) -> &[i32] {
        let per = self.batch_size * self.tokens_per_example;
        &self.tokens[i * per..(i + 1) * per]
    }

    pub fn num_batches(&self) -> usize {
        self.tau
    }
}

/// Build round batches for one client from a streamed group.
///
/// `pad_id` fills the tail of the client's last (partial) sequence;
/// clients cycle through their own sequences when they have fewer than
/// `tau * batch_size`.
pub fn build_client_batches(
    group: &mut StreamedGroup,
    tokenizer: &WordPiece,
    tau: usize,
    batch_size: usize,
    tokens_per_example: usize,
    pad_id: i32,
) -> Result<ClientBatches> {
    assert!(tau > 0 && batch_size > 0 && tokens_per_example > 1);
    let needed_tokens = tau * batch_size * tokens_per_example;

    // 1+2: tokenize and concatenate, stopping early once we have enough.
    let mut ids: Vec<u32> = Vec::with_capacity(needed_tokens.min(1 << 20));
    group.for_each_example(|ex| {
        if let Some(text) = ex.get_str("text") {
            tokenizer.encode(text, &mut ids);
        }
        ids.len() < needed_tokens
    })?;
    let raw_tokens = ids.len();

    // Sequences of S+1, padding the final partial one.
    let mut sequences: Vec<Vec<i32>> = ids
        .chunks(tokens_per_example)
        .map(|c| c.iter().map(|&t| t as i32).collect())
        .collect();
    if sequences.is_empty() {
        sequences.push(vec![pad_id; tokens_per_example]);
    }
    if let Some(last) = sequences.last_mut() {
        while last.len() < tokens_per_example {
            last.push(pad_id);
        }
    }
    let distinct_sequences = sequences.len();

    // 3+4: batch, repeat (cycle), truncate to exactly tau batches.
    let total_sequences = tau * batch_size;
    let mut tokens = Vec::with_capacity(needed_tokens);
    for i in 0..total_sequences {
        tokens.extend_from_slice(&sequences[i % sequences.len()]);
    }

    Ok(ClientBatches {
        tokens,
        tau,
        batch_size,
        tokens_per_example,
        distinct_sequences,
        raw_tokens,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, SyntheticTextDataset};
    use crate::formats::streaming::{StreamingConfig, StreamingDataset};
    use crate::pipeline::{run_partition, FeatureKey, PartitionOptions};
    use crate::tokenizer::{VocabBuilder, PAD_ID};

    fn setup(groups: usize, max_words: usize) -> (StreamingDataset, WordPiece) {
        let dir = std::env::temp_dir().join(format!("grouper_cdata_test_{groups}_{max_words}"));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::fedccnews_mini(groups, 31);
        spec.max_group_words = max_words;
        let ds = SyntheticTextDataset::new(spec);
        run_partition(
            &ds,
            &FeatureKey::new("domain"),
            &dir,
            "d",
            &PartitionOptions { num_shards: 2, num_workers: 2, ..Default::default() },
        )
        .unwrap();
        let mut vb = VocabBuilder::new();
        for g in ds.stream_all_text() {
            vb.feed(&g);
        }
        let wp = vb.build(512);
        let sd = StreamingDataset::open(&dir, "d", StreamingConfig::sequential()).unwrap();
        (sd, wp)
    }

    #[test]
    fn batches_have_exact_shape() {
        let (sd, wp) = setup(6, 3000);
        for g in sd.stream() {
            let mut g = g.unwrap();
            let cb = build_client_batches(&mut g, &wp, 3, 4, 17, PAD_ID as i32).unwrap();
            assert_eq!(cb.tokens.len(), 3 * 4 * 17);
            assert_eq!(cb.num_batches(), 3);
            assert_eq!(cb.batch(2).len(), 4 * 17);
            assert!(cb.tokens.iter().all(|&t| t >= 0 && (t as usize) < wp.vocab_size()));
        }
    }

    #[test]
    fn small_clients_repeat_their_sequences() {
        let (sd, wp) = setup(8, 30); // tiny clients
        let mut g = sd.stream().next().unwrap().unwrap();
        let cb = build_client_batches(&mut g, &wp, 4, 4, 33, PAD_ID as i32).unwrap();
        // A client with ~30 words can't fill 16 distinct 33-token
        // sequences: repetition must occur.
        assert!(cb.distinct_sequences < 16);
        let per = 33;
        let first = &cb.tokens[..per];
        let reps = cb
            .tokens
            .chunks(per)
            .filter(|c| *c == first)
            .count();
        assert!(reps >= 2, "expected cycling, found {reps} copies");
    }

    #[test]
    fn large_clients_stop_reading_early() {
        let (sd, wp) = setup(4, 50_000);
        let mut g = sd.stream().next().unwrap().unwrap();
        let cb = build_client_batches(&mut g, &wp, 2, 2, 17, PAD_ID as i32).unwrap();
        // Early stop: raw tokens buffered stay within one example of the
        // need (examples are ~316 words), not the client's ~50K words.
        assert!(cb.raw_tokens < 2 * 2 * 17 + 4000, "read too much: {}", cb.raw_tokens);
        assert!(cb.distinct_sequences >= 2 * 2);
    }

    #[test]
    fn deterministic_given_same_group() {
        let (sd, wp) = setup(5, 2000);
        let collect = || {
            let sd2 = StreamingDataset::open(
                // reopen the same materialization
                std::path::Path::new(&std::env::temp_dir().join("grouper_cdata_test_5_2000")),
                "d",
                StreamingConfig::sequential(),
            );
            let _ = sd2;
        };
        collect();
        let mut g1 = sd.stream().next().unwrap().unwrap();
        let cb1 = build_client_batches(&mut g1, &wp, 3, 2, 9, PAD_ID as i32).unwrap();
        let sd2 = setup(5, 2000).0;
        let mut g2 = sd2.stream().next().unwrap().unwrap();
        let cb2 = build_client_batches(&mut g2, &wp, 3, 2, 9, PAD_ID as i32).unwrap();
        assert_eq!(cb1, cb2);
    }

    #[test]
    fn empty_group_yields_all_pad() {
        // Construct a group whose example has no text feature.
        let dir = std::env::temp_dir().join("grouper_cdata_empty");
        let _ = std::fs::remove_dir_all(&dir);
        let ds = crate::corpus::GroupedCifarLike {
            num_groups: 2,
            examples_per_group: 2,
            height: 2,
            width: 2,
            channels: 1,
            seed: 0,
        };
        run_partition(
            &ds,
            &FeatureKey::new("label"),
            &dir,
            "img",
            &PartitionOptions { num_shards: 1, num_workers: 1, count_words: false, ..Default::default() },
        )
        .unwrap();
        let sd = StreamingDataset::open(&dir, "img", StreamingConfig::sequential()).unwrap();
        let mut vb = VocabBuilder::new();
        vb.feed("a b c");
        let wp = vb.build(64);
        let mut g = sd.stream().next().unwrap().unwrap();
        let cb = build_client_batches(&mut g, &wp, 1, 2, 5, PAD_ID as i32).unwrap();
        assert!(cb.tokens.iter().all(|&t| t == PAD_ID as i32));
    }
}
