//! FedOpt server optimizers (Reddi et al. [30], Appendix C.4).
//!
//! The server treats the cohort-averaged client delta as a gradient
//! estimate ("pseudo-gradient") and applies a first-order optimizer to the
//! global model. The paper's configuration: Adam with beta1=0.9,
//! beta2=0.999, eps=1e-8; only the learning rate is tuned/scheduled.

use crate::runtime::Params;

/// A server optimizer: consumes the pseudo-gradient, updates the model.
pub trait ServerOptimizer {
    /// Apply one update. `lr` comes from the round's schedule.
    fn step(&mut self, params: &mut Params, pseudo_grad: &Params, lr: f32);

    fn name(&self) -> &'static str;
}

/// Plain server SGD (the FedAvg of McMahan et al. is Adam->SGD with lr=1).
pub struct Sgd;

impl ServerOptimizer for Sgd {
    fn step(&mut self, params: &mut Params, g: &Params, lr: f32) {
        for (p, gi) in params.iter_mut().zip(g) {
            debug_assert_eq!(p.len(), gi.len());
            for (pv, gv) in p.iter_mut().zip(gi) {
                *pv -= lr * gv;
            }
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Adam with bias correction (the paper's server optimizer).
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Option<Params>,
    v: Option<Params>,
    t: u64,
}

impl Adam {
    /// Paper defaults: beta1=0.9, beta2=0.999, eps=1e-8.
    pub fn new() -> Self {
        Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8, m: None, v: None, t: 0 }
    }
}

impl Default for Adam {
    fn default() -> Self {
        Adam::new()
    }
}

impl ServerOptimizer for Adam {
    fn step(&mut self, params: &mut Params, g: &Params, lr: f32) {
        if self.m.is_none() {
            self.m = Some(g.iter().map(|t| vec![0.0; t.len()]).collect());
            self.v = Some(g.iter().map(|t| vec![0.0; t.len()]).collect());
        }
        self.t += 1;
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let m = self.m.as_mut().unwrap();
        let v = self.v.as_mut().unwrap();
        for ((p, gi), (mi, vi)) in params.iter_mut().zip(g).zip(m.iter_mut().zip(v.iter_mut())) {
            debug_assert_eq!(p.len(), gi.len());
            for k in 0..p.len() {
                mi[k] = b1 * mi[k] + (1.0 - b1) * gi[k];
                vi[k] = b2 * vi[k] + (1.0 - b2) * gi[k] * gi[k];
                let mhat = mi[k] / bc1;
                let vhat = vi[k] / bc2;
                p[k] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(v: &[f32]) -> Params {
        vec![v.to_vec()]
    }

    #[test]
    fn sgd_step_exact() {
        let mut p = params(&[1.0, 2.0]);
        Sgd.step(&mut p, &params(&[0.5, -1.0]), 0.1);
        assert_eq!(p[0], vec![0.95, 2.1]);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // With bias correction, |first update| ~= lr regardless of gradient
        // magnitude (the classic Adam property).
        for scale in [1e-3f32, 1.0, 1e3] {
            let mut p = params(&[0.0]);
            let mut adam = Adam::new();
            adam.step(&mut p, &params(&[scale]), 0.01);
            assert!((p[0][0] + 0.01).abs() < 1e-4, "scale {scale}: {}", p[0][0]);
        }
    }

    #[test]
    fn adam_minimizes_quadratic() {
        // min (x-3)^2 via its gradient.
        let mut p = params(&[0.0]);
        let mut adam = Adam::new();
        for _ in 0..2000 {
            let g = params(&[2.0 * (p[0][0] - 3.0)]);
            adam.step(&mut p, &g, 0.05);
        }
        assert!((p[0][0] - 3.0).abs() < 0.05, "{}", p[0][0]);
    }

    #[test]
    fn adam_matches_reference_trace() {
        // Hand-computed two-step trace (g = [1], lr = 0.1).
        let mut p = params(&[0.0]);
        let mut adam = Adam::new();
        adam.step(&mut p, &params(&[1.0]), 0.1);
        // t=1: mhat=1, vhat=1 -> p = -0.1 * 1/(1+eps) ~ -0.1
        assert!((p[0][0] + 0.1).abs() < 1e-6);
        adam.step(&mut p, &params(&[1.0]), 0.1);
        // t=2: m=0.19/bc1(0.19)=1, v and vhat = 1 -> another -0.1
        assert!((p[0][0] + 0.2).abs() < 1e-5, "{}", p[0][0]);
    }

    #[test]
    fn multi_tensor_shapes() {
        let mut p = vec![vec![1.0, 1.0], vec![2.0]];
        let g = vec![vec![1.0, -1.0], vec![0.5]];
        let mut adam = Adam::new();
        adam.step(&mut p, &g, 0.1);
        assert!(p[0][0] < 1.0 && p[0][1] > 1.0 && p[1][0] < 2.0);
    }
}
