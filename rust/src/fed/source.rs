//! `ClientSource` — one trainer-facing interface over every storage
//! backend, local or remote.
//!
//! The round loop needs exactly three things from storage: the universe
//! of group keys (to sample cohorts from), one group's examples as a
//! prefetched [`StreamedGroup`] (to tokenize + batch), and bulk counts
//! for logging. Every format in [`crate::formats`] — and the remote
//! store server in [`crate::serve`] — can provide those, so the trait
//! makes `fetch_cohort`, `train_with_source`, and `build_eval_clients`
//! backend-agnostic:
//!
//! * **in-memory** ([`InMemoryDataset`]) — groups re-framed from the
//!   resident map;
//! * **streaming-gindex** ([`GindexSource`], and [`PartitionedDataset`]
//!   which lazily opens one) — positioned extent reads over the
//!   TFRecord shards;
//! * **paged** ([`PagedReader`]) / **sharded-paged**
//!   ([`ShardedPagedReader`]) — pinned-snapshot B+tree reads;
//! * **remote** ([`crate::serve::RemoteClientSource`]) — the same
//!   surface over a TCP connection to a `grouper serve` process.
//!
//! Group payloads are bit-identical across backends (the re-framed
//! bytes are the same canonical [`Example`](crate::records::Example)
//! encodings in the same order), so swapping the backend never changes
//! training results — only where the bytes come from.

use anyhow::Result;

use crate::formats::paged::PagedReader;
use crate::formats::paged_sharded::ShardedPagedReader;
use crate::formats::streaming::{GindexSource, StreamedGroup};
use crate::formats::InMemoryDataset;
use crate::grouper::PartitionedDataset;
use crate::records::tfrecord::RecordWriter;

/// A backend the federated trainer can sample client datasets from.
///
/// Implementations must be `Send + Sync`: the cohort fetch fans out
/// over the trainer's read-worker pool with the source behind an `Arc`.
/// All methods take `&self`; concurrent fetches must be safe.
///
/// The canonical key order is **sorted**: `group_keys` returns the same
/// list for the same group set no matter which backend serves it, so a
/// seeded cohort sampler draws identical cohorts from any of them.
pub trait ClientSource: Send + Sync {
    /// Human-readable description of the backend (for logs).
    fn describe(&self) -> String;

    /// Every group key, in sorted (canonical) order.
    fn group_keys(&self) -> Vec<Vec<u8>>;

    /// Distinct groups.
    fn num_groups(&self) -> usize;

    /// Total examples across all groups.
    fn num_examples(&self) -> u64;

    /// One group's examples as a prefetched [`StreamedGroup`]; `None`
    /// for a key the source does not hold.
    ///
    /// # Errors
    /// Any backend read failure.
    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>>;

    /// Whether [`ClientSource::fetch_groups`] is cheaper than per-key
    /// [`ClientSource::streamed_group`] calls. Remote backends return
    /// true (one batched round trip per cohort); local backends keep
    /// the default false and let the caller parallelize per key.
    fn batched(&self) -> bool {
        false
    }

    /// Fetch many groups at once, order-preserving (`out[i]` answers
    /// `keys[i]`; `None` for unknown keys). The default loops
    /// [`ClientSource::streamed_group`]; batched backends override it.
    ///
    /// # Errors
    /// Any backend read failure.
    fn fetch_groups(&self, keys: &[Vec<u8>]) -> Result<Vec<Option<StreamedGroup>>> {
        keys.iter().map(|k| self.streamed_group(k)).collect()
    }
}

impl ClientSource for ShardedPagedReader {
    fn describe(&self) -> String {
        format!(
            "sharded paged set ({} shards, {} groups, epochs {:?})",
            self.num_shards(),
            ShardedPagedReader::num_groups(self),
            self.epochs()
        )
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        self.keys().to_vec()
    }

    fn num_groups(&self) -> usize {
        ShardedPagedReader::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        ShardedPagedReader::num_examples(self)
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        ShardedPagedReader::streamed_group(self, key)
    }
}

impl ClientSource for PagedReader {
    fn describe(&self) -> String {
        format!(
            "paged store ({} groups, epoch {})",
            PagedReader::num_groups(self),
            self.epoch()
        )
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        self.keys().to_vec()
    }

    fn num_groups(&self) -> usize {
        PagedReader::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        PagedReader::num_examples(self)
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        PagedReader::streamed_group(self, key)
    }
}

impl ClientSource for GindexSource {
    fn describe(&self) -> String {
        format!("streaming-gindex source ({} groups)", GindexSource::num_groups(self))
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        self.keys().to_vec()
    }

    fn num_groups(&self) -> usize {
        GindexSource::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        GindexSource::num_examples(self)
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        GindexSource::streamed_group(self, key)
    }
}

impl ClientSource for InMemoryDataset {
    fn describe(&self) -> String {
        format!("in-memory dataset ({} groups)", InMemoryDataset::num_groups(self))
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        let mut keys = self.keys().to_vec();
        keys.sort();
        keys
    }

    fn num_groups(&self) -> usize {
        InMemoryDataset::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        self.keys().iter().filter_map(|k| self.group(k)).map(|g| g.len() as u64).sum()
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        let Some(examples) = self.group(key) else {
            return Ok(None);
        };
        // Re-frame the resident examples exactly like the paged
        // backends do, so the payload is bit-identical across formats.
        let mut w = RecordWriter::new(Vec::new());
        for ex in examples {
            w.write_record(&ex.encode())?;
        }
        Ok(Some(StreamedGroup::from_framed_bytes(
            key.to_vec(),
            examples.len() as u64,
            0,
            w.into_inner(),
        )))
    }
}

impl ClientSource for PartitionedDataset {
    fn describe(&self) -> String {
        format!(
            "streaming materialization {}/{} ({} groups)",
            self.dir().display(),
            self.prefix(),
            PartitionedDataset::num_groups(self)
        )
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        let mut keys: Vec<Vec<u8>> =
            self.index().entries.iter().map(|e| e.key.clone()).collect();
        keys.sort();
        keys
    }

    fn num_groups(&self) -> usize {
        PartitionedDataset::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        PartitionedDataset::num_examples(self)
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        self.gindex_source()?.streamed_group(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, SyntheticTextDataset};
    use crate::pipeline::{
        run_partition, run_partition_paged, FeatureKey, PagedPartitionOptions, PartitionOptions,
    };

    fn materialize(dir: &std::path::Path) -> SyntheticTextDataset {
        let _ = std::fs::remove_dir_all(dir);
        let mut spec = DatasetSpec::fedccnews_mini(12, 31);
        spec.max_group_words = 500;
        let ds = SyntheticTextDataset::new(spec);
        let popts = PartitionOptions { num_shards: 2, num_workers: 2, ..Default::default() };
        run_partition(&ds, &FeatureKey::new("domain"), dir, "t", &popts).unwrap();
        run_partition_paged(
            &ds,
            &FeatureKey::new("domain"),
            &dir.join("paged"),
            "t",
            &popts,
            &PagedPartitionOptions { shards: 3, ..Default::default() },
        )
        .unwrap();
        ds
    }

    /// Every local backend must expose the same canonical key list and
    /// serve byte-identical group payloads.
    #[test]
    fn backends_agree_on_keys_and_payloads() {
        let dir = std::env::temp_dir().join("grouper_client_source_test");
        materialize(&dir);
        let sources: Vec<Box<dyn ClientSource>> = vec![
            Box::new(GindexSource::open(&dir, "t").unwrap()),
            Box::new(PartitionedDataset::open(&dir, "t").unwrap()),
            Box::new(InMemoryDataset::load(&dir, "t").unwrap()),
            Box::new(ShardedPagedReader::open(&dir.join("paged"), "t", 16).unwrap()),
        ];
        let keys = sources[0].group_keys();
        assert_eq!(keys.len(), 12);
        assert!(keys.windows(2).all(|w| w[0] < w[1]), "keys must be sorted");
        for s in &sources[1..] {
            assert_eq!(s.group_keys(), keys, "{} disagrees on keys", s.describe());
        }
        for key in &keys {
            let mut payloads = Vec::new();
            for s in &sources {
                let mut g = s.streamed_group(key).unwrap().unwrap();
                assert_eq!(g.key, *key);
                let ex: Vec<Vec<u8>> =
                    g.examples().unwrap().iter().map(|e| e.encode()).collect();
                payloads.push(ex);
            }
            for p in &payloads[1..] {
                assert_eq!(p, &payloads[0], "backends disagree on group payload");
            }
        }
        for s in &sources {
            assert!(s.streamed_group(b"no-such-group").unwrap().is_none());
            assert_eq!(s.num_groups(), 12);
            assert_eq!(s.num_examples(), sources[0].num_examples());
            assert!(!s.batched());
        }
    }

    #[test]
    fn fetch_groups_default_preserves_order_and_maps_misses() {
        let dir = std::env::temp_dir().join("grouper_client_source_batch_test");
        materialize(&dir);
        let src = GindexSource::open(&dir, "t").unwrap();
        let keys = ClientSource::group_keys(&src);
        let ask =
            vec![keys[3].clone(), b"missing".to_vec(), keys[0].clone(), keys[3].clone()];
        let got = ClientSource::fetch_groups(&src, &ask).unwrap();
        assert_eq!(got.len(), 4);
        assert_eq!(got[0].as_ref().unwrap().key, keys[3]);
        assert!(got[1].is_none());
        assert_eq!(got[2].as_ref().unwrap().key, keys[0]);
        assert_eq!(got[3].as_ref().unwrap().key, keys[3]);
    }
}
