//! `ClientSource` — one trainer-facing interface over every storage
//! backend, local or remote.
//!
//! The round loop needs exactly three things from storage: the universe
//! of group keys (to sample cohorts from), one group's examples as a
//! prefetched [`StreamedGroup`] (to tokenize + batch), and bulk counts
//! for logging. Every format in [`crate::formats`] — and the remote
//! store server in [`crate::serve`] — can provide those, so the trait
//! makes `fetch_cohort`, `train_with_source`, and `build_eval_clients`
//! backend-agnostic:
//!
//! * **in-memory** ([`InMemoryDataset`]) — groups re-framed from the
//!   resident map;
//! * **streaming-gindex** ([`GindexSource`], and [`PartitionedDataset`]
//!   which lazily opens one) — positioned extent reads over the
//!   TFRecord shards;
//! * **paged** ([`PagedReader`]) / **sharded-paged**
//!   ([`ShardedPagedReader`]) — pinned-snapshot B+tree reads;
//! * **remote** ([`crate::serve::RemoteClientSource`]) — the same
//!   surface over a TCP connection to a `grouper serve` process.
//!
//! Group payloads are bit-identical across backends (the re-framed
//! bytes are the same canonical [`Example`](crate::records::Example)
//! encodings in the same order), so swapping the backend never changes
//! training results — only where the bytes come from.
//!
//! For **live ingestion** — training while a writer keeps appending —
//! wrap any backend in a [`RefreshingSource`]: with
//! `TrainerConfig::refresh_source` on, the trainer calls
//! [`ClientSource::refresh`] at every round boundary (a no-op on plain
//! backends), and the wrapper re-opens its snapshot so each round sees
//! the freshest committed checkpoint while staying bit-stable *within*
//! the round.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::formats::paged::PagedReader;
use crate::formats::paged_sharded::ShardedPagedReader;
use crate::formats::streaming::{GindexSource, StreamedGroup};
use crate::formats::InMemoryDataset;
use crate::grouper::PartitionedDataset;
use crate::records::tfrecord::RecordWriter;

/// A backend the federated trainer can sample client datasets from.
///
/// Implementations must be `Send + Sync`: the cohort fetch fans out
/// over the trainer's read-worker pool with the source behind an `Arc`.
/// All methods take `&self`; concurrent fetches must be safe.
///
/// The canonical key order is **sorted**: `group_keys` returns the same
/// list for the same group set no matter which backend serves it, so a
/// seeded cohort sampler draws identical cohorts from any of them.
pub trait ClientSource: Send + Sync {
    /// Human-readable description of the backend (for logs).
    fn describe(&self) -> String;

    /// Every group key, in sorted (canonical) order.
    fn group_keys(&self) -> Vec<Vec<u8>>;

    /// Distinct groups.
    fn num_groups(&self) -> usize;

    /// Total examples across all groups.
    fn num_examples(&self) -> u64;

    /// One group's examples as a prefetched [`StreamedGroup`]; `None`
    /// for a key the source does not hold.
    ///
    /// # Errors
    /// Any backend read failure.
    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>>;

    /// Whether [`ClientSource::fetch_groups`] is cheaper than per-key
    /// [`ClientSource::streamed_group`] calls. Remote backends return
    /// true (one batched round trip per cohort); local backends keep
    /// the default false and let the caller parallelize per key.
    fn batched(&self) -> bool {
        false
    }

    /// Fetch many groups at once, order-preserving (`out[i]` answers
    /// `keys[i]`; `None` for unknown keys). The default loops
    /// [`ClientSource::streamed_group`]; batched backends override it.
    ///
    /// # Errors
    /// Any backend read failure.
    fn fetch_groups(&self, keys: &[Vec<u8>]) -> Result<Vec<Option<StreamedGroup>>> {
        keys.iter().map(|k| self.streamed_group(k)).collect()
    }

    /// Advance to the freshest committed state, when the backend
    /// supports it. The trainer calls this at every round boundary when
    /// `TrainerConfig::refresh_source` is on; the default is a no-op
    /// returning `false` (a plain source's key universe cannot change
    /// mid-run), so classic training paths are bit-for-bit unaffected.
    /// [`RefreshingSource`] overrides it to
    /// re-open its snapshot; `true` means the key universe may have
    /// changed and the caller should re-read [`ClientSource::group_keys`].
    ///
    /// # Errors
    /// A failed re-open/reconnect, or a refreshed snapshot whose
    /// checkpoint epochs regressed.
    fn refresh(&self) -> Result<bool> {
        Ok(false)
    }

    /// Checkpoint epochs currently visible through this source, one per
    /// shard — empty when the backend has no epoch notion (in-memory,
    /// streaming). Refresh wrappers and soak tests use this to assert
    /// freshness is monotone: epochs never decrease across refreshes.
    fn source_epochs(&self) -> Vec<u64> {
        Vec::new()
    }
}

impl ClientSource for ShardedPagedReader {
    fn describe(&self) -> String {
        format!(
            "sharded paged set ({} shards, {} groups, epochs {:?})",
            self.num_shards(),
            ShardedPagedReader::num_groups(self),
            self.epochs()
        )
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        self.keys().to_vec()
    }

    fn num_groups(&self) -> usize {
        ShardedPagedReader::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        ShardedPagedReader::num_examples(self)
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        ShardedPagedReader::streamed_group(self, key)
    }

    fn source_epochs(&self) -> Vec<u64> {
        self.epochs()
    }
}

impl ClientSource for PagedReader {
    fn describe(&self) -> String {
        format!(
            "paged store ({} groups, epoch {})",
            PagedReader::num_groups(self),
            self.epoch()
        )
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        self.keys().to_vec()
    }

    fn num_groups(&self) -> usize {
        PagedReader::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        PagedReader::num_examples(self)
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        PagedReader::streamed_group(self, key)
    }

    fn source_epochs(&self) -> Vec<u64> {
        vec![self.epoch()]
    }
}

impl ClientSource for GindexSource {
    fn describe(&self) -> String {
        format!("streaming-gindex source ({} groups)", GindexSource::num_groups(self))
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        self.keys().to_vec()
    }

    fn num_groups(&self) -> usize {
        GindexSource::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        GindexSource::num_examples(self)
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        GindexSource::streamed_group(self, key)
    }
}

impl ClientSource for InMemoryDataset {
    fn describe(&self) -> String {
        format!("in-memory dataset ({} groups)", InMemoryDataset::num_groups(self))
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        let mut keys = self.keys().to_vec();
        keys.sort();
        keys
    }

    fn num_groups(&self) -> usize {
        InMemoryDataset::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        self.keys().iter().filter_map(|k| self.group(k)).map(|g| g.len() as u64).sum()
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        let Some(examples) = self.group(key) else {
            return Ok(None);
        };
        // Re-frame the resident examples exactly like the paged
        // backends do, so the payload is bit-identical across formats.
        let mut w = RecordWriter::new(Vec::new());
        for ex in examples {
            w.write_record(&ex.encode())?;
        }
        Ok(Some(StreamedGroup::from_framed_bytes(
            key.to_vec(),
            examples.len() as u64,
            0,
            w.into_inner(),
        )))
    }
}

impl ClientSource for PartitionedDataset {
    fn describe(&self) -> String {
        format!(
            "streaming materialization {}/{} ({} groups)",
            self.dir().display(),
            self.prefix(),
            PartitionedDataset::num_groups(self)
        )
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        let mut keys: Vec<Vec<u8>> =
            self.index().entries.iter().map(|e| e.key.clone()).collect();
        keys.sort();
        keys
    }

    fn num_groups(&self) -> usize {
        PartitionedDataset::num_groups(self)
    }

    fn num_examples(&self) -> u64 {
        PartitionedDataset::num_examples(self)
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        self.gindex_source()?.streamed_group(key)
    }
}

/// Opens (or re-opens) a [`ClientSource`] at the freshest committed
/// state. Boxed so any backend can refresh the same way: paged and
/// sharded backends re-open a pinned snapshot against the store
/// directory, remote backends reconnect (the server pins a fresh
/// snapshot per connection).
pub type SourceFactory = Box<dyn Fn() -> Result<Arc<dyn ClientSource>> + Send + Sync>;

/// A [`ClientSource`] wrapper that re-opens its backend at round
/// boundaries — the trainer-side half of live ingestion.
///
/// The refresh contract:
///
/// * **within-round stability** — between two [`ClientSource::refresh`]
///   calls every read goes through one held snapshot, so a round's
///   cohort is bit-stable no matter what the live writer does;
/// * **between-round freshness** — each `refresh` swaps in a snapshot
///   of the newest *committed checkpoint*, so new groups and grown
///   payloads become visible at the next round boundary;
/// * **monotone epochs** — a refresh that would move any shard's
///   checkpoint epoch backwards is refused with a typed error (a store
///   only moves forward under its single live writer; regression means
///   the factory opened the wrong store).
///
/// Dropping the previous snapshot on swap releases its epoch pin, so
/// the writer's compaction gate only ever waits on the *current* round,
/// never on history.
pub struct RefreshingSource {
    factory: SourceFactory,
    inner: RwLock<Arc<dyn ClientSource>>,
    last_epochs: Mutex<Vec<u64>>,
    refreshes: AtomicU64,
}

impl RefreshingSource {
    /// Open the initial snapshot through `factory` and wrap it.
    ///
    /// # Errors
    /// Whatever the factory's first open fails with.
    pub fn new(factory: SourceFactory) -> Result<RefreshingSource> {
        let initial = factory().context("opening initial snapshot for refreshing source")?;
        let epochs = initial.source_epochs();
        Ok(RefreshingSource {
            factory,
            inner: RwLock::new(initial),
            last_epochs: Mutex::new(epochs),
            refreshes: AtomicU64::new(0),
        })
    }

    /// How many refreshes have completed successfully.
    pub fn refreshes(&self) -> u64 {
        self.refreshes.load(Ordering::Relaxed)
    }

    /// The epochs observed at the most recent (re-)open.
    pub fn current_epochs(&self) -> Vec<u64> {
        self.last_epochs.lock().unwrap().clone()
    }

    fn snapshot(&self) -> Arc<dyn ClientSource> {
        // Clone out of the lock so a slow backend read never holds it.
        Arc::clone(&self.inner.read().unwrap())
    }
}

impl ClientSource for RefreshingSource {
    fn describe(&self) -> String {
        format!("refreshing[{}]", self.snapshot().describe())
    }

    fn group_keys(&self) -> Vec<Vec<u8>> {
        self.snapshot().group_keys()
    }

    fn num_groups(&self) -> usize {
        self.snapshot().num_groups()
    }

    fn num_examples(&self) -> u64 {
        self.snapshot().num_examples()
    }

    fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        self.snapshot().streamed_group(key)
    }

    fn batched(&self) -> bool {
        self.snapshot().batched()
    }

    fn fetch_groups(&self, keys: &[Vec<u8>]) -> Result<Vec<Option<StreamedGroup>>> {
        self.snapshot().fetch_groups(keys)
    }

    fn refresh(&self) -> Result<bool> {
        let fresh = (self.factory)().context("re-opening snapshot at the round boundary")?;
        let new_epochs = fresh.source_epochs();
        {
            let mut last = self.last_epochs.lock().unwrap();
            if last.len() != new_epochs.len() {
                bail!(
                    "refreshed snapshot changed shard count: {} -> {} shards",
                    last.len(),
                    new_epochs.len()
                );
            }
            if let Some((i, (old, new))) =
                last.iter().zip(&new_epochs).enumerate().find(|(_, (o, n))| n < o)
            {
                bail!(
                    "refreshed snapshot regressed shard {i}'s checkpoint epoch {old} -> {new} \
                     (stores only move forward; is the factory opening the right store?)"
                );
            }
            *last = new_epochs;
        }
        *self.inner.write().unwrap() = fresh;
        self.refreshes.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    fn source_epochs(&self) -> Vec<u64> {
        self.snapshot().source_epochs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, SyntheticTextDataset};
    use crate::pipeline::{
        run_partition, run_partition_paged, FeatureKey, PagedPartitionOptions, PartitionOptions,
    };

    fn materialize(dir: &std::path::Path) -> SyntheticTextDataset {
        let _ = std::fs::remove_dir_all(dir);
        let mut spec = DatasetSpec::fedccnews_mini(12, 31);
        spec.max_group_words = 500;
        let ds = SyntheticTextDataset::new(spec);
        let popts = PartitionOptions { num_shards: 2, num_workers: 2, ..Default::default() };
        run_partition(&ds, &FeatureKey::new("domain"), dir, "t", &popts).unwrap();
        run_partition_paged(
            &ds,
            &FeatureKey::new("domain"),
            &dir.join("paged"),
            "t",
            &popts,
            &PagedPartitionOptions { shards: 3, ..Default::default() },
        )
        .unwrap();
        ds
    }

    /// Every local backend must expose the same canonical key list and
    /// serve byte-identical group payloads.
    #[test]
    fn backends_agree_on_keys_and_payloads() {
        let dir = std::env::temp_dir().join("grouper_client_source_test");
        materialize(&dir);
        let sources: Vec<Box<dyn ClientSource>> = vec![
            Box::new(GindexSource::open(&dir, "t").unwrap()),
            Box::new(PartitionedDataset::open(&dir, "t").unwrap()),
            Box::new(InMemoryDataset::load(&dir, "t").unwrap()),
            Box::new(ShardedPagedReader::open(&dir.join("paged"), "t", 16).unwrap()),
        ];
        let keys = sources[0].group_keys();
        assert_eq!(keys.len(), 12);
        assert!(keys.windows(2).all(|w| w[0] < w[1]), "keys must be sorted");
        for s in &sources[1..] {
            assert_eq!(s.group_keys(), keys, "{} disagrees on keys", s.describe());
        }
        for key in &keys {
            let mut payloads = Vec::new();
            for s in &sources {
                let mut g = s.streamed_group(key).unwrap().unwrap();
                assert_eq!(g.key, *key);
                let ex: Vec<Vec<u8>> =
                    g.examples().unwrap().iter().map(|e| e.encode()).collect();
                payloads.push(ex);
            }
            for p in &payloads[1..] {
                assert_eq!(p, &payloads[0], "backends disagree on group payload");
            }
        }
        for s in &sources {
            assert!(s.streamed_group(b"no-such-group").unwrap().is_none());
            assert_eq!(s.num_groups(), 12);
            assert_eq!(s.num_examples(), sources[0].num_examples());
            assert!(!s.batched());
        }
    }

    #[test]
    fn refreshing_source_delegates_and_counts_refreshes() {
        let dir = std::env::temp_dir().join("grouper_refreshing_source_test");
        materialize(&dir);
        let paged = dir.join("paged");
        let factory_dir = paged.clone();
        let src = RefreshingSource::new(Box::new(move || {
            Ok(Arc::new(ShardedPagedReader::open_snapshot(&factory_dir, "t", 16)?)
                as Arc<dyn ClientSource>)
        }))
        .unwrap();
        let raw = ShardedPagedReader::open_snapshot(&paged, "t", 16).unwrap();
        assert_eq!(src.group_keys(), ClientSource::group_keys(&raw));
        assert_eq!(src.source_epochs(), raw.epochs());
        assert!(!src.batched());
        let key = src.group_keys()[0].clone();
        let before = src.streamed_group(&key).unwrap().unwrap().framed_bytes().unwrap().to_vec();
        // A quiescent store refreshes without changing anything.
        assert!(src.refresh().unwrap());
        assert_eq!(src.refreshes(), 1);
        assert_eq!(src.current_epochs(), raw.epochs());
        let after = src.streamed_group(&key).unwrap().unwrap().framed_bytes().unwrap().to_vec();
        assert_eq!(before, after, "quiescent refresh must be byte-stable");
    }

    /// A factory that hands back a snapshot with regressed checkpoint
    /// epochs (or a different shard count) is refused with a typed
    /// error — freshness must be monotone.
    #[test]
    fn refreshing_source_refuses_epoch_regression() {
        use std::sync::atomic::{AtomicU64, Ordering};

        struct FakeEpochs(Vec<u64>);
        impl ClientSource for FakeEpochs {
            fn describe(&self) -> String {
                "fake".into()
            }
            fn group_keys(&self) -> Vec<Vec<u8>> {
                vec![b"k".to_vec()]
            }
            fn num_groups(&self) -> usize {
                1
            }
            fn num_examples(&self) -> u64 {
                1
            }
            fn streamed_group(&self, _key: &[u8]) -> Result<Option<StreamedGroup>> {
                Ok(None)
            }
            fn source_epochs(&self) -> Vec<u64> {
                self.0.clone()
            }
        }

        let opens = Arc::new(AtomicU64::new(0));
        let counter = Arc::clone(&opens);
        let src = RefreshingSource::new(Box::new(move || {
            // Epochs go 5, 6, 3: the second refresh must be refused.
            let epochs = match counter.fetch_add(1, Ordering::SeqCst) {
                0 => vec![5],
                1 => vec![6],
                _ => vec![3],
            };
            Ok(Arc::new(FakeEpochs(epochs)) as Arc<dyn ClientSource>)
        }))
        .unwrap();
        assert!(src.refresh().unwrap());
        let err = src.refresh().expect_err("epoch regression must be refused");
        assert!(err.to_string().contains("regressed"), "unexpected error: {err:#}");
        // The failed refresh left the last good snapshot in place.
        assert_eq!(src.current_epochs(), vec![6]);

        let shrink = Arc::new(AtomicU64::new(0));
        let counter = Arc::clone(&shrink);
        let src = RefreshingSource::new(Box::new(move || {
            let epochs =
                if counter.fetch_add(1, Ordering::SeqCst) == 0 { vec![1, 1] } else { vec![2] };
            Ok(Arc::new(FakeEpochs(epochs)) as Arc<dyn ClientSource>)
        }))
        .unwrap();
        let err = src.refresh().expect_err("shard-count change must be refused");
        assert!(err.to_string().contains("shard count"), "unexpected error: {err:#}");
    }

    #[test]
    fn fetch_groups_default_preserves_order_and_maps_misses() {
        let dir = std::env::temp_dir().join("grouper_client_source_batch_test");
        materialize(&dir);
        let src = GindexSource::open(&dir, "t").unwrap();
        let keys = ClientSource::group_keys(&src);
        let ask =
            vec![keys[3].clone(), b"missing".to_vec(), keys[0].clone(), keys[3].clone()];
        let got = ClientSource::fetch_groups(&src, &ask).unwrap();
        assert_eq!(got.len(), 4);
        assert_eq!(got[0].as_ref().unwrap().key, keys[3]);
        assert!(got[1].is_none());
        assert_eq!(got[2].as_ref().unwrap().key, keys[0]);
        assert_eq!(got[3].as_ref().unwrap().key, keys[3]);
    }
}
