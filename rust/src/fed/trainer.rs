//! The federated round loop: cohort stream -> client computation -> server
//! update, with the per-round data-iteration vs training-time accounting
//! that backs the Table 4 reproduction.
//!
//! Matches §5.1/Appendix C: clients are shuffled (buffered) once into a
//! stream and consumed in windows of `cohort_size`; every client is
//! equalized to `tau` batches; the server optimizer is Adam under the
//! configured LR schedule.
//!
//! The data phase of a round reads the cohort's client datasets
//! *concurrently* when [`TrainerConfig::read_workers`] > 1: tokenizing
//! and batching each client is independent work, so it fans out over
//! [`crate::util::threadpool::ThreadPool`]. Results are order-preserving
//! and `build_client_batches` is deterministic per group, so training is
//! bit-identical at any worker count — only the wall-clock of the data
//! phase changes (Table 4's read-workers column measures it). A panic in
//! any fetch worker fails the round with an error instead of hanging the
//! cohort barrier.

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::algorithms::{fedavg_round, fedsgd_round};
use super::client_data::{build_client_batches, ClientBatches};
use super::schedules::Schedule;
use super::server_opt::{Adam, ServerOptimizer};
pub use super::source::ClientSource;
use crate::config::{FedAlgorithm, FedConfig};
use crate::formats::paged_sharded::ShardedPagedReader;
use crate::formats::streaming::{StreamedGroup, StreamingConfig};
use crate::grouper::PartitionedDataset;
use crate::runtime::{ModelBackend, Params};
use crate::tokenizer::WordPiece;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use crate::util::timer::Timer;

/// Per-round record (Figure 4's curves; Table 4's timing columns).
#[derive(Debug, Clone)]
pub struct RoundMetrics {
    pub round: usize,
    pub lr: f32,
    pub train_loss: f32,
    /// Seconds spent pulling groups + tokenizing + batching.
    pub data_secs: f64,
    /// Seconds spent in backend computation (client work + server update).
    pub train_secs: f64,
}

/// Completed training run.
pub struct TrainOutput {
    pub params: Params,
    pub rounds: Vec<RoundMetrics>,
}

impl TrainOutput {
    pub fn final_loss(&self) -> f32 {
        self.rounds.last().map(|r| r.train_loss).unwrap_or(f32::NAN)
    }

    pub fn loss_curve(&self) -> Vec<(usize, f32)> {
        self.rounds.iter().map(|r| (r.round, r.train_loss)).collect()
    }
}

/// Extra knobs beyond [`FedConfig`].
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub fed: FedConfig,
    /// Print a progress line every N rounds (0 = silent).
    pub log_every: usize,
    /// Worker threads for the cohort's client-dataset fetch (tokenize +
    /// batch). 1 (or 0) = serial. Results are identical at any value;
    /// only the data phase's wall-clock changes.
    pub read_workers: usize,
    /// Overlap data and compute in [`train_with_source`]: while round
    /// *r* trains, round *r+1*'s cohort is fetched into a bounded
    /// (depth-1) double-buffer on the `read_workers` pool. Cohorts are
    /// bit-identical to the synchronous path — the sampler draws the
    /// same key sequence, each fetch sees one consistent snapshot —
    /// only the round's data-wait shrinks. With a refreshing source the
    /// round-boundary refresh happens when the prefetch launches, so
    /// round *r+1* sees the freshest checkpoint as of the *start* of
    /// round *r*'s compute phase (one round staler, never mixed).
    pub prefetch: bool,
    /// Call [`ClientSource::refresh`] at every round boundary in
    /// [`train_with_source`], so a source over a store that is still
    /// being written re-pins the freshest committed checkpoint between
    /// rounds (and a grown key universe reseeds the cohort sampler).
    /// Off (the default), the source is never refreshed and training is
    /// frozen on the snapshot it opened with — the classic path.
    pub refresh_source: bool,
}

impl TrainerConfig {
    pub fn new(fed: FedConfig) -> Self {
        TrainerConfig { fed, log_every: 0, read_workers: 1, prefetch: false, refresh_source: false }
    }

    /// Builder-style override of [`TrainerConfig::read_workers`].
    pub fn with_read_workers(mut self, read_workers: usize) -> Self {
        self.read_workers = read_workers;
        self
    }

    /// Builder-style override of [`TrainerConfig::prefetch`].
    pub fn with_prefetch(mut self, prefetch: bool) -> Self {
        self.prefetch = prefetch;
        self
    }

    /// Builder-style override of [`TrainerConfig::refresh_source`].
    pub fn with_refresh_source(mut self, refresh_source: bool) -> Self {
        self.refresh_source = refresh_source;
        self
    }
}

/// Shape of one client's round batches, bundled so the cohort-fetch
/// helpers stay under a sane argument count (mirrors the per-round
/// parameters `train` derives from its backend + [`FedConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct CohortFetchSpec {
    /// Batches per client per round.
    pub tau: usize,
    /// Sequences per batch.
    pub batch_size: usize,
    /// Tokens per sequence (S+1).
    pub tokens_per_example: usize,
    /// Pad token id for the tail sequence.
    pub pad_id: i32,
}

/// Build one round's cohort of client batches straight from a
/// **sharded paged set**: each group key routes to its shard's pinned
/// snapshot, so when the fetch fans out over `pool` (the trainer's
/// `read_workers` pool) concurrent clients stripe across S independent
/// page caches and index trees instead of queueing on one reader.
///
/// Order-preserving and deterministic per group, so the result is
/// bit-identical at any worker count — the same contract as the
/// trainer's streaming fetch path. A panic in any fetch job fails the
/// cohort loudly instead of stalling its caller.
///
/// # Errors
/// A cohort key missing from the set, any shard read failure, or a
/// crashed fetch job.
pub fn fetch_cohort_sharded(
    reader: &Arc<ShardedPagedReader>,
    keys: &[Vec<u8>],
    tokenizer: &Arc<WordPiece>,
    spec: CohortFetchSpec,
    pool: Option<&ThreadPool>,
) -> Result<Vec<ClientBatches>> {
    let source: Arc<dyn ClientSource> = Arc::clone(reader) as Arc<dyn ClientSource>;
    fetch_cohort(&source, keys, tokenizer, spec, pool)
}

fn batches_from_group(
    group: &mut StreamedGroup,
    tokenizer: &WordPiece,
    spec: CohortFetchSpec,
) -> Result<ClientBatches> {
    build_client_batches(
        group,
        tokenizer,
        spec.tau,
        spec.batch_size,
        spec.tokens_per_example,
        spec.pad_id,
    )
}

/// Build one round's cohort of client batches from **any**
/// [`ClientSource`] backend — the generalization of
/// [`fetch_cohort_sharded`] the serving layer plugs into.
///
/// Two shapes, both order-preserving and bit-identical at any worker
/// count:
///
/// * **per-key fan-out** (local backends): each key's fetch + tokenize +
///   batch is one job on `pool`, so concurrent clients stripe across
///   the backend's independent shards/caches;
/// * **batched fetch** (backends with [`ClientSource::batched`], i.e.
///   remote): one `fetch_groups` call pulls the whole cohort — a single
///   round trip over the wire — then tokenize + batch fans out over
///   `pool`.
///
/// # Errors
/// A cohort key missing from the source, any backend read failure, or a
/// crashed fetch job.
pub fn fetch_cohort(
    source: &Arc<dyn ClientSource>,
    keys: &[Vec<u8>],
    tokenizer: &Arc<WordPiece>,
    spec: CohortFetchSpec,
    pool: Option<&ThreadPool>,
) -> Result<Vec<ClientBatches>> {
    fn missing(key: &[u8]) -> anyhow::Error {
        anyhow!("cohort group {:?} not served by the source", String::from_utf8_lossy(key))
    }
    fn fetch_one(
        source: &dyn ClientSource,
        tokenizer: &WordPiece,
        spec: CohortFetchSpec,
        key: &[u8],
    ) -> Result<ClientBatches> {
        let mut group = source.streamed_group(key)?.ok_or_else(|| missing(key))?;
        batches_from_group(&mut group, tokenizer, spec)
    }
    if source.batched() {
        let groups = source.fetch_groups(keys)?.into_iter().zip(keys.iter());
        let fetched: Vec<(Vec<u8>, StreamedGroup)> = groups
            .map(|(g, key)| g.map(|g| (key.clone(), g)).ok_or_else(|| missing(key)))
            .collect::<Result<_>>()?;
        return match pool {
            None => fetched
                .into_iter()
                .map(|(_, mut g)| batches_from_group(&mut g, tokenizer, spec))
                .collect(),
            Some(pool) => {
                let tokenizer = Arc::clone(tokenizer);
                pool.try_map(fetched, move |(_, mut g)| {
                    batches_from_group(&mut g, &tokenizer, spec)
                })
                .map_err(|p| anyhow!("parallel cohort batching crashed: {p}"))?
                .into_iter()
                .collect::<Result<Vec<_>>>()
                .context("building client batches")
            }
        };
    }
    match pool {
        None => keys.iter().map(|k| fetch_one(source.as_ref(), tokenizer, spec, k)).collect(),
        Some(pool) => {
            let source = Arc::clone(source);
            let tokenizer = Arc::clone(tokenizer);
            let fetched = pool
                .try_map(keys.to_vec(), move |key| {
                    fetch_one(source.as_ref(), &tokenizer, spec, &key)
                })
                .map_err(|p| anyhow!("parallel cohort fetch crashed: {p}"))?;
            fetched.into_iter().collect::<Result<Vec<_>>>().context("building client batches")
        }
    }
}

/// Build the validation clients used by personalization eval: the first
/// `n` groups of `source`'s canonical (sorted) key order, batched like
/// training clients. Any [`ClientSource`] backend works — a
/// [`PartitionedDataset`] coerces directly, so eval clients can come
/// from the same backend as training cohorts.
///
/// # Errors
/// Any backend read failure while fetching or batching a group.
pub fn build_eval_clients(
    source: &dyn ClientSource,
    tokenizer: &WordPiece,
    backend: &dyn ModelBackend,
    tau: usize,
    n: usize,
) -> Result<Vec<ClientBatches>> {
    let (b, t) = backend.batch_shape();
    let keys = source.group_keys();
    let mut out = Vec::with_capacity(n.min(keys.len()));
    for key in keys.iter().take(n) {
        let mut g = source.streamed_group(key)?.with_context(|| {
            format!("eval group {:?} vanished from the source", String::from_utf8_lossy(key))
        })?;
        out.push(build_client_batches(&mut g, tokenizer, tau, b, t, backend.pad_id())?);
    }
    Ok(out)
}

/// Run federated training; returns the final model and per-round metrics.
pub fn train(
    backend: &dyn ModelBackend,
    dataset: &PartitionedDataset,
    tokenizer: &WordPiece,
    cfg: &TrainerConfig,
) -> Result<TrainOutput> {
    let fed = &cfg.fed;
    let (b, t) = backend.batch_shape();
    let schedule = Schedule::new(fed.schedule, fed.server_lr, fed.rounds);
    let mut server_opt = Adam::new();
    let mut params = backend.init_params();

    // Infinite shuffled client stream consumed in cohort windows.
    let stream_cfg = StreamingConfig {
        repeats: None,
        shuffle_buffer: fed.shuffle_buffer.max(2 * fed.cohort_size),
        seed: fed.seed,
        ..Default::default()
    };
    let mut cohorts = dataset.build_cohort_stream(stream_cfg, fed.cohort_size)?;

    // Parallel client fetch: one pool for the whole run, plus a shared
    // tokenizer the 'static jobs can own. Serial path when <= 1 worker.
    let read_workers = cfg.read_workers.max(1);
    let fetch_pool = (read_workers > 1).then(|| ThreadPool::new(read_workers));
    let shared_tokenizer: Option<Arc<WordPiece>> =
        fetch_pool.as_ref().map(|_| Arc::new(tokenizer.clone()));

    let mut rounds = Vec::with_capacity(fed.rounds);
    for round in 0..fed.rounds {
        // --- data phase: pull the cohort and build client batches.
        let data_t = Timer::start();
        let cohort_groups = cohorts
            .next()
            .context("client stream ended unexpectedly")??;
        let cohort: Vec<ClientBatches> = match &fetch_pool {
            None => {
                let mut cohort = Vec::with_capacity(fed.cohort_size);
                for mut g in cohort_groups {
                    cohort.push(build_client_batches(
                        &mut g,
                        tokenizer,
                        fed.tau,
                        b,
                        t,
                        backend.pad_id(),
                    )?);
                }
                cohort
            }
            Some(pool) => {
                // Fan the cohort across the pool; order is preserved, so
                // the round is identical to the serial path. try_map
                // converts a worker panic into an error here — the round
                // fails loudly instead of stalling the barrier.
                let tok =
                    Arc::clone(shared_tokenizer.as_ref().expect("pool implies shared tokenizer"));
                let tau = fed.tau;
                let pad = backend.pad_id();
                let fetched = pool
                    .try_map(cohort_groups, move |mut g| {
                        build_client_batches(&mut g, &tok, tau, b, t, pad)
                    })
                    .map_err(|p| anyhow!("parallel client fetch crashed: {p}"))?;
                fetched
                    .into_iter()
                    .collect::<Result<Vec<_>>>()
                    .context("building client batches")?
            }
        };
        let data_secs = data_t.elapsed_secs();

        // --- compute phase: client work + server update.
        let train_t = Timer::start();
        let lr = schedule.lr(round);
        let out = match fed.algorithm {
            FedAlgorithm::FedAvg => fedavg_round(backend, &params, &cohort, fed.client_lr)?,
            FedAlgorithm::FedSgd => fedsgd_round(backend, &params, &cohort)?,
        };
        server_opt.step(&mut params, &out.pseudo_grad, lr);
        let train_secs = train_t.elapsed_secs();

        if cfg.log_every > 0 && (round % cfg.log_every == 0 || round + 1 == fed.rounds) {
            println!(
                "round {round:>5}  loss {:.4}  lr {lr:.2e}  data {:.3}s  train {:.3}s",
                out.mean_client_loss, data_secs, train_secs
            );
        }
        rounds.push(RoundMetrics {
            round,
            lr,
            train_loss: out.mean_client_loss,
            data_secs,
            train_secs,
        });
    }
    Ok(TrainOutput { params, rounds })
}

/// Infinite shuffled key stream consumed in cohort windows: each epoch
/// is a full seeded permutation of the (sorted) key set, epochs are
/// concatenated, and windows may span an epoch boundary — the
/// `ClientSource` analogue of the streaming trainer's infinite
/// buffered-shuffle cohort stream. Deterministic given (key set, seed),
/// independent of which backend supplied the keys.
struct KeyCohorts {
    /// The key set in sorted (canonical) order — the identity a
    /// refreshed universe is compared against.
    canonical: Vec<Vec<u8>>,
    keys: Vec<Vec<u8>>,
    seed: u64,
    cohort: usize,
    epoch: u64,
    pos: usize,
}

impl KeyCohorts {
    fn new(mut keys: Vec<Vec<u8>>, seed: u64, cohort: usize) -> KeyCohorts {
        assert!(!keys.is_empty() && cohort > 0);
        // Canonical order first: the stream is then a pure function of
        // the key *set* and the seed.
        keys.sort();
        let canonical = keys.clone();
        let mut kc = KeyCohorts { canonical, keys, seed, cohort, epoch: 0, pos: 0 };
        kc.shuffle_epoch();
        kc
    }

    /// Swap in a refreshed key universe. When the sorted set is
    /// unchanged this is a no-op and the stream continues bit-for-bit —
    /// the property the quiescent-store identity tests pin down. When
    /// it changed (live ingestion grew the store), the sampler advances
    /// to a fresh epoch over the new set, so newly arrived groups
    /// become eligible immediately and the stream stays a pure function
    /// of `(seed, the sequence of key sets observed at refresh points)`.
    fn update_keys(&mut self, mut new_keys: Vec<Vec<u8>>) -> bool {
        new_keys.sort();
        if new_keys == self.canonical {
            return false;
        }
        self.canonical = new_keys.clone();
        self.keys = new_keys;
        self.epoch += 1;
        self.shuffle_epoch();
        true
    }

    fn shuffle_epoch(&mut self) {
        // Same per-epoch seed derivation as the streaming shuffle.
        let mut rng = Rng::new(self.seed ^ self.epoch.wrapping_mul(0x9E37));
        rng.shuffle(&mut self.keys);
        self.pos = 0;
    }

    fn next_cohort(&mut self) -> Vec<Vec<u8>> {
        let mut out = Vec::with_capacity(self.cohort);
        while out.len() < self.cohort {
            if self.pos == self.keys.len() {
                self.epoch += 1;
                self.shuffle_epoch();
            }
            out.push(self.keys[self.pos].clone());
            self.pos += 1;
        }
        out
    }
}

/// Refresh `source` at a round boundary (when
/// [`TrainerConfig::refresh_source`] is on) and fold a changed key
/// universe into the sampler. No-op (and no cost) for plain sources.
fn refresh_and_resample(
    source: &Arc<dyn ClientSource>,
    sampler: &mut KeyCohorts,
    enabled: bool,
) -> Result<()> {
    if !enabled {
        return Ok(());
    }
    if source.refresh().context("refreshing client source at the round boundary")? {
        let keys = source.group_keys();
        if keys.is_empty() {
            bail!("refreshed source {} holds no groups", source.describe());
        }
        sampler.update_keys(keys);
    }
    Ok(())
}

/// One in-flight prefetched cohort — the bounded (depth-1) double
/// buffer: round *r* trains while this thread fetches round *r+1*.
type PrefetchHandle = std::thread::JoinHandle<Result<Vec<ClientBatches>>>;

/// Render a prefetch thread's panic payload for the typed round-
/// boundary error (mirrors the thread pool's panics-as-values policy).
fn panic_to_string(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run federated training with cohorts sampled from **any**
/// [`ClientSource`] backend — in-memory, streaming-gindex, paged,
/// sharded-paged, or remote ([`crate::serve::RemoteClientSource`]).
///
/// Identical round mechanics to [`train`] (same schedules, optimizers,
/// timing accounting); only the cohort sampler differs — an infinite
/// shuffled stream over the source's canonical key list instead of the
/// streaming format's interleave + buffered shuffle. Because the key
/// order and group payloads are backend-independent, the same `(seed,
/// key set)` trains bit-identically on every backend.
///
/// **Live ingestion**: with [`TrainerConfig::refresh_source`], the
/// source's [`ClientSource::refresh`] runs at every round boundary (a
/// snapshot re-open for [`super::source::RefreshingSource`], a re-pin
/// handshake for a remote source), so a store that is still being
/// written feeds each round the freshest committed checkpoint while
/// every round reads one consistent snapshot. With
/// [`TrainerConfig::prefetch`], the next round's cohort is fetched on a
/// background thread (over the same `read_workers` pool) while the
/// current round trains; a failed or crashed prefetch surfaces as a
/// typed error at the round boundary instead of hanging the buffer.
///
/// # Errors
/// An empty source, a zero `fed.cohort_size`, any cohort fetch,
/// refresh, or prefetch failure, or a backend round failure.
pub fn train_with_source(
    backend: &dyn ModelBackend,
    source: &Arc<dyn ClientSource>,
    tokenizer: &WordPiece,
    cfg: &TrainerConfig,
) -> Result<TrainOutput> {
    let fed = &cfg.fed;
    let (b, t) = backend.batch_shape();
    let schedule = Schedule::new(fed.schedule, fed.server_lr, fed.rounds);
    let mut server_opt = Adam::new();
    let mut params = backend.init_params();

    let keys = source.group_keys();
    if keys.is_empty() {
        return Err(anyhow!("client source {} holds no groups", source.describe()));
    }
    if fed.cohort_size == 0 {
        return Err(anyhow!("fed.cohort_size must be at least 1 to sample cohorts"));
    }
    let mut sampler = KeyCohorts::new(keys, fed.seed, fed.cohort_size);
    let spec = CohortFetchSpec {
        tau: fed.tau,
        batch_size: b,
        tokens_per_example: t,
        pad_id: backend.pad_id(),
    };

    // Arc so the prefetch thread can share the pool: during a round's
    // compute phase the main thread never touches it, so the background
    // fetch gets the full worker set to itself.
    let read_workers = cfg.read_workers.max(1);
    let fetch_pool = (read_workers > 1).then(|| Arc::new(ThreadPool::new(read_workers)));
    let shared_tokenizer = Arc::new(tokenizer.clone());

    let mut pending: Option<PrefetchHandle> = None;
    let mut rounds = Vec::with_capacity(fed.rounds);
    for round in 0..fed.rounds {
        // --- data phase: wait on the prefetched cohort, or (first
        // round / prefetch off) refresh + sample + fetch synchronously.
        let data_t = Timer::start();
        let cohort = match pending.take() {
            Some(handle) => handle
                .join()
                .map_err(|p| {
                    anyhow!(
                        "cohort prefetch thread for round {round} crashed: {}",
                        panic_to_string(p)
                    )
                })?
                .with_context(|| format!("prefetched cohort for round {round}"))?,
            None => {
                refresh_and_resample(source, &mut sampler, cfg.refresh_source)?;
                let cohort_keys = sampler.next_cohort();
                fetch_cohort(source, &cohort_keys, &shared_tokenizer, spec, fetch_pool.as_deref())?
            }
        };
        let data_secs = data_t.elapsed_secs();

        // --- launch the next round's prefetch before compute starts.
        // The refresh happens *here* (not when the buffer is consumed),
        // so the prefetched round reads one consistent snapshot — the
        // freshest checkpoint as of this round's compute start.
        if cfg.prefetch && round + 1 < fed.rounds {
            refresh_and_resample(source, &mut sampler, cfg.refresh_source)?;
            let next_keys = sampler.next_cohort();
            let src = Arc::clone(source);
            let tok = Arc::clone(&shared_tokenizer);
            let pool = fetch_pool.clone();
            pending = Some(
                std::thread::Builder::new()
                    .name("grouper-prefetch".into())
                    .spawn(move || fetch_cohort(&src, &next_keys, &tok, spec, pool.as_deref()))
                    .context("spawning the cohort prefetch thread")?,
            );
        }

        // --- compute phase: client work + server update.
        let train_t = Timer::start();
        let lr = schedule.lr(round);
        let out = match fed.algorithm {
            FedAlgorithm::FedAvg => fedavg_round(backend, &params, &cohort, fed.client_lr)?,
            FedAlgorithm::FedSgd => fedsgd_round(backend, &params, &cohort)?,
        };
        server_opt.step(&mut params, &out.pseudo_grad, lr);
        let train_secs = train_t.elapsed_secs();

        if cfg.log_every > 0 && (round % cfg.log_every == 0 || round + 1 == fed.rounds) {
            println!(
                "round {round:>5}  loss {:.4}  lr {lr:.2e}  data {:.3}s  train {:.3}s",
                out.mean_client_loss, data_secs, train_secs
            );
        }
        rounds.push(RoundMetrics {
            round,
            lr,
            train_loss: out.mean_client_loss,
            data_secs,
            train_secs,
        });
    }
    Ok(TrainOutput { params, rounds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScheduleKind;
    use crate::corpus::{DatasetSpec, SyntheticTextDataset};
    use crate::pipeline::{run_partition, FeatureKey, PartitionOptions};
    use crate::runtime::MockRuntime;
    use crate::tokenizer::VocabBuilder;

    fn setup() -> (PartitionedDataset, WordPiece, MockRuntime) {
        let dir = std::env::temp_dir().join("grouper_trainer_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::fedccnews_mini(24, 77);
        spec.max_group_words = 800;
        let ds = SyntheticTextDataset::new(spec);
        run_partition(
            &ds,
            &FeatureKey::new("domain"),
            &dir,
            "train",
            &PartitionOptions { num_shards: 2, num_workers: 2, ..Default::default() },
        )
        .unwrap();
        let mut vb = VocabBuilder::new();
        for text in ds.stream_all_text() {
            vb.feed(&text);
        }
        let wp = vb.build(64); // matches MockRuntime vocab
        let pd = PartitionedDataset::open(&dir, "train").unwrap();
        (pd, wp, MockRuntime::standard())
    }

    fn fed(alg: FedAlgorithm, rounds: usize) -> FedConfig {
        FedConfig {
            algorithm: alg,
            rounds,
            cohort_size: 4,
            tau: 3,
            client_lr: 0.3,
            server_lr: 0.05,
            schedule: ScheduleKind::Constant,
            shuffle_buffer: 8,
            seed: 5,
        }
    }

    #[test]
    fn fedavg_training_reduces_loss() {
        let (pd, wp, mock) = setup();
        let out = train(&mock, &pd, &wp, &TrainerConfig::new(fed(FedAlgorithm::FedAvg, 40)))
            .unwrap();
        assert_eq!(out.rounds.len(), 40);
        let first = out.rounds[0].train_loss;
        let last = out.final_loss();
        // The mock's heterogeneity floor bounds how far the global loss
        // can fall; require clear descent.
        assert!(last < first * 0.85, "{first} -> {last}");
    }

    #[test]
    fn fedsgd_training_reduces_loss() {
        let (pd, wp, mock) = setup();
        let out = train(&mock, &pd, &wp, &TrainerConfig::new(fed(FedAlgorithm::FedSgd, 40)))
            .unwrap();
        let first = out.rounds[0].train_loss;
        let last = out.final_loss();
        assert!(last < first * 0.9, "{first} -> {last}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (pd, wp, mock) = setup();
        let a = train(&mock, &pd, &wp, &TrainerConfig::new(fed(FedAlgorithm::FedAvg, 5)))
            .unwrap();
        let b = train(&mock, &pd, &wp, &TrainerConfig::new(fed(FedAlgorithm::FedAvg, 5)))
            .unwrap();
        assert_eq!(a.params, b.params);
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.train_loss, y.train_loss);
        }
    }

    #[test]
    fn schedule_is_applied() {
        let (pd, wp, mock) = setup();
        let mut f = fed(FedAlgorithm::FedAvg, 20);
        f.schedule = ScheduleKind::WarmupCosine;
        let out = train(&mock, &pd, &wp, &TrainerConfig::new(f)).unwrap();
        assert!(out.rounds[0].lr < out.rounds[2].lr, "warmup missing");
        assert!(out.rounds[19].lr < out.rounds[3].lr, "decay missing");
    }

    #[test]
    fn eval_clients_built_consistently() {
        let (pd, wp, mock) = setup();
        let clients = build_eval_clients(&pd, &wp, &mock, 3, 10).unwrap();
        assert_eq!(clients.len(), 10);
        let (b, t) = mock.batch_shape();
        for c in &clients {
            assert_eq!(c.tokens.len(), 3 * b * t);
        }
    }

    #[test]
    fn parallel_client_fetch_matches_serial_bit_for_bit() {
        let (pd, wp, mock) = setup();
        let serial = train(&mock, &pd, &wp, &TrainerConfig::new(fed(FedAlgorithm::FedAvg, 6)))
            .unwrap();
        let parallel = train(
            &mock,
            &pd,
            &wp,
            &TrainerConfig::new(fed(FedAlgorithm::FedAvg, 6)).with_read_workers(4),
        )
        .unwrap();
        assert_eq!(serial.params, parallel.params, "worker count must not change training");
        for (s, p) in serial.rounds.iter().zip(&parallel.rounds) {
            assert_eq!(s.train_loss, p.train_loss);
        }
    }

    #[test]
    fn sharded_cohort_fetch_is_striped_and_order_preserving() {
        use crate::formats::ShardedPagedReader;
        use crate::pipeline::{run_partition_paged, PagedPartitionOptions};

        let dir = std::env::temp_dir().join("grouper_trainer_sharded_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::fedccnews_mini(24, 77);
        spec.max_group_words = 800;
        let ds = SyntheticTextDataset::new(spec);
        let popts = PartitionOptions { num_shards: 2, num_workers: 2, ..Default::default() };
        for shards in [1usize, 4] {
            let out = dir.join(format!("s{shards}"));
            run_partition_paged(
                &ds,
                &FeatureKey::new("domain"),
                &out,
                "train",
                &popts,
                &PagedPartitionOptions { shards, ..Default::default() },
            )
            .unwrap();
        }
        let mut vb = VocabBuilder::new();
        for text in ds.stream_all_text() {
            vb.feed(&text);
        }
        let tokenizer = Arc::new(vb.build(64));
        let fetch = CohortFetchSpec { tau: 3, batch_size: 4, tokens_per_example: 9, pad_id: 0 };

        let sharded = Arc::new(ShardedPagedReader::open(&dir.join("s4"), "train", 16).unwrap());
        let single = Arc::new(ShardedPagedReader::open(&dir.join("s1"), "train", 16).unwrap());
        assert_eq!(sharded.num_shards(), 4);
        let keys: Vec<Vec<u8>> = sharded.keys().to_vec();
        assert_eq!(keys.len(), 24);

        let serial = fetch_cohort_sharded(&sharded, &keys, &tokenizer, fetch, None).unwrap();
        let pool = ThreadPool::new(4);
        let parallel =
            fetch_cohort_sharded(&sharded, &keys, &tokenizer, fetch, Some(&pool)).unwrap();
        assert_eq!(serial, parallel, "worker count must not change the cohort");
        // And shard count must not change it either: the 4-shard set
        // serves the same client batches as the single-store layout.
        let unsharded = fetch_cohort_sharded(&single, &keys, &tokenizer, fetch, None).unwrap();
        assert_eq!(serial, unsharded, "shard count must not change the cohort");
        // A key outside the set fails loudly instead of padding silently.
        let missing = fetch_cohort_sharded(
            &sharded,
            &[b"no-such-group".to_vec()],
            &tokenizer,
            fetch,
            Some(&pool),
        );
        assert!(missing.is_err());
    }

    #[test]
    fn train_with_source_is_backend_invariant_and_descends() {
        use crate::formats::{GindexSource, InMemoryDataset, ShardedPagedReader};
        use crate::pipeline::{run_partition_paged, PagedPartitionOptions};

        let dir = std::env::temp_dir().join("grouper_trainer_source_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::fedccnews_mini(24, 77);
        spec.max_group_words = 800;
        let ds = SyntheticTextDataset::new(spec);
        let popts = PartitionOptions { num_shards: 2, num_workers: 2, ..Default::default() };
        run_partition(&ds, &FeatureKey::new("domain"), &dir, "train", &popts).unwrap();
        run_partition_paged(
            &ds,
            &FeatureKey::new("domain"),
            &dir.join("paged"),
            "train",
            &popts,
            &PagedPartitionOptions { shards: 4, ..Default::default() },
        )
        .unwrap();
        let mut vb = VocabBuilder::new();
        for text in ds.stream_all_text() {
            vb.feed(&text);
        }
        let wp = vb.build(64);
        let mock = MockRuntime::standard();

        let sources: Vec<Arc<dyn ClientSource>> = vec![
            Arc::new(GindexSource::open(&dir, "train").unwrap()),
            Arc::new(InMemoryDataset::load(&dir, "train").unwrap()),
            Arc::new(ShardedPagedReader::open(&dir.join("paged"), "train", 16).unwrap()),
        ];
        let tc = TrainerConfig::new(fed(FedAlgorithm::FedAvg, 10));
        let runs: Vec<TrainOutput> = sources
            .iter()
            .map(|s| train_with_source(&mock, s, &wp, &tc).unwrap())
            .collect();
        for out in &runs[1..] {
            assert_eq!(out.params, runs[0].params, "backend must not change training");
            for (a, b) in out.rounds.iter().zip(&runs[0].rounds) {
                assert_eq!(a.train_loss, b.train_loss);
            }
        }
        // Parallel fetch over any backend is bit-identical too.
        let parallel = train_with_source(&mock, &sources[2], &wp, &tc.clone().with_read_workers(4))
            .unwrap();
        assert_eq!(parallel.params, runs[0].params);
        // And training actually trains.
        let longer = TrainerConfig::new(fed(FedAlgorithm::FedAvg, 40));
        let out = train_with_source(&mock, &sources[0], &wp, &longer).unwrap();
        assert!(out.final_loss() < out.rounds[0].train_loss * 0.85);
    }

    #[test]
    fn prefetched_training_is_bit_identical_to_synchronous() {
        use crate::formats::GindexSource;

        let dir = std::env::temp_dir().join("grouper_trainer_prefetch_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::fedccnews_mini(24, 77);
        spec.max_group_words = 800;
        let ds = SyntheticTextDataset::new(spec);
        let popts = PartitionOptions { num_shards: 2, num_workers: 2, ..Default::default() };
        run_partition(&ds, &FeatureKey::new("domain"), &dir, "train", &popts).unwrap();
        let mut vb = VocabBuilder::new();
        for text in ds.stream_all_text() {
            vb.feed(&text);
        }
        let wp = vb.build(64);
        let mock = MockRuntime::standard();
        let source: Arc<dyn ClientSource> = Arc::new(GindexSource::open(&dir, "train").unwrap());
        let tc = TrainerConfig::new(fed(FedAlgorithm::FedAvg, 8));
        let sync = train_with_source(&mock, &source, &wp, &tc).unwrap();
        for (workers, prefetch) in [(1usize, true), (4, true), (4, false)] {
            let tc = tc.clone().with_read_workers(workers).with_prefetch(prefetch);
            let got = train_with_source(&mock, &source, &wp, &tc).unwrap();
            assert_eq!(
                got.params, sync.params,
                "prefetch={prefetch} workers={workers} changed training"
            );
            for (a, b) in got.rounds.iter().zip(&sync.rounds) {
                assert_eq!(a.train_loss, b.train_loss);
            }
        }
    }

    #[test]
    fn key_cohorts_update_is_noop_on_same_set_and_reseeds_on_change() {
        let keys: Vec<Vec<u8>> = (0..9).map(|i| format!("k{i}").into_bytes()).collect();
        let mut a = KeyCohorts::new(keys.clone(), 11, 2);
        let mut b = KeyCohorts::new(keys.clone(), 11, 2);
        assert_eq!(a.next_cohort(), b.next_cohort());
        // Same set (any order) must not perturb the stream.
        let mut shuffled = keys.clone();
        shuffled.reverse();
        assert!(!a.update_keys(shuffled));
        for _ in 0..10 {
            assert_eq!(a.next_cohort(), b.next_cohort());
        }
        // A grown set advances to a fresh epoch over the new universe,
        // and the newcomer is reachable within one pass.
        let mut grown = keys.clone();
        grown.push(b"newcomer".to_vec());
        assert!(a.update_keys(grown));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..5 {
            for k in a.next_cohort() {
                seen.insert(k);
            }
        }
        assert!(seen.contains(&b"newcomer".to_vec()), "new group never sampled");
        // Determinism: the same history replays identically.
        let mut c = KeyCohorts::new(keys.clone(), 11, 2);
        c.next_cohort();
        let mut grown = keys;
        grown.push(b"newcomer".to_vec());
        assert!(c.update_keys(grown));
        let mut b2 = KeyCohorts::new((0..9).map(|i| format!("k{i}").into_bytes()).collect(), 11, 2);
        b2.next_cohort();
        let mut grown2: Vec<Vec<u8>> = (0..9).map(|i| format!("k{i}").into_bytes()).collect();
        grown2.push(b"newcomer".to_vec());
        assert!(b2.update_keys(grown2));
        for _ in 0..10 {
            assert_eq!(c.next_cohort(), b2.next_cohort());
        }
    }

    #[test]
    fn zero_cohort_size_is_a_typed_error_not_a_panic() {
        use crate::formats::GindexSource;

        let dir = std::env::temp_dir().join("grouper_trainer_zero_cohort_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::fedccnews_mini(8, 77);
        spec.max_group_words = 400;
        let ds = SyntheticTextDataset::new(spec);
        let popts = PartitionOptions { num_shards: 1, num_workers: 1, ..Default::default() };
        run_partition(&ds, &FeatureKey::new("domain"), &dir, "train", &popts).unwrap();
        let mut vb = VocabBuilder::new();
        for text in ds.stream_all_text() {
            vb.feed(&text);
        }
        let wp = vb.build(64);
        let mock = MockRuntime::standard();
        let source: Arc<dyn ClientSource> = Arc::new(GindexSource::open(&dir, "train").unwrap());
        let mut f = fed(FedAlgorithm::FedAvg, 2);
        f.cohort_size = 0;
        let err = train_with_source(&mock, &source, &wp, &TrainerConfig::new(f))
            .expect_err("a config with cohort_size = 0 must be rejected");
        assert!(err.to_string().contains("cohort_size"), "unexpected error: {err:#}");
    }

    #[test]
    fn timing_fields_populated() {
        let (pd, wp, mock) = setup();
        let out = train(&mock, &pd, &wp, &TrainerConfig::new(fed(FedAlgorithm::FedAvg, 3)))
            .unwrap();
        for r in &out.rounds {
            assert!(r.data_secs >= 0.0 && r.train_secs >= 0.0);
        }
    }
}
