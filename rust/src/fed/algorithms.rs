//! FedAvg and FedSGD round computations (Appendix C.3).
//!
//! Both algorithms broadcast the server model `x^t` to the round's cohort;
//! each client computes gradients over its `tau` batches. They differ in
//! *where*:
//!
//! * **FedAvg** — the client locally updates after every batch (`tau`
//!   SGD steps, executed as one fused `local_train` PJRT call when the
//!   artifact exists) and returns `delta_c = x^t - x_c^t`.
//! * **FedSGD** — all `tau` gradients are computed *at* `x^t` and
//!   averaged; `delta_c` is that average gradient.
//!
//! The server averages `delta_c` uniformly over the cohort (weighted ==
//! uniform here: every client is equalized to `tau` batches) and hands the
//! pseudo-gradient to the server optimizer.

use anyhow::Result;

use super::client_data::ClientBatches;
use crate::runtime::{ModelBackend, Params};

/// One round's aggregate: the pseudo-gradient and the mean client loss
/// (computed exactly as the paper's Figure 4 does — average over batches
/// within a client, then over clients; for FedAvg this tracks the locally
/// adapting model, for FedSGD the broadcast model).
pub struct RoundOutput {
    pub pseudo_grad: Params,
    pub mean_client_loss: f32,
    pub clients: usize,
}

fn zeros_like(p: &Params) -> Params {
    p.iter().map(|t| vec![0.0f32; t.len()]).collect()
}

fn accumulate(acc: &mut Params, x: &Params, scale: f32) {
    for (a, t) in acc.iter_mut().zip(x) {
        for (av, tv) in a.iter_mut().zip(t) {
            *av += scale * tv;
        }
    }
}

/// FedAvg: fused tau-step local SGD per client.
pub fn fedavg_round(
    backend: &dyn ModelBackend,
    params: &Params,
    cohort: &[ClientBatches],
    client_lr: f32,
) -> Result<RoundOutput> {
    assert!(!cohort.is_empty());
    let mut pseudo = zeros_like(params);
    let mut loss_sum = 0.0f32;
    let scale = 1.0 / cohort.len() as f32;
    for cb in cohort {
        let (client_params, mean_loss) =
            backend.local_train(params, &cb.tokens, cb.tau, client_lr)?;
        loss_sum += mean_loss;
        // delta_c = x^t - x_c^t  (a descent direction for the server).
        for ((acc, x0), x1) in pseudo.iter_mut().zip(params).zip(&client_params) {
            for k in 0..acc.len() {
                acc[k] += scale * (x0[k] - x1[k]);
            }
        }
    }
    Ok(RoundOutput {
        pseudo_grad: pseudo,
        mean_client_loss: loss_sum / cohort.len() as f32,
        clients: cohort.len(),
    })
}

/// FedSGD: tau minibatch gradients at the broadcast model, averaged.
/// Executed as one fused `grad_multi` call per client when the backend has
/// the artifact (EXPERIMENTS.md §Perf L2-1), falling back to per-batch
/// `grad` otherwise — both paths are numerically identical.
pub fn fedsgd_round(
    backend: &dyn ModelBackend,
    params: &Params,
    cohort: &[ClientBatches],
) -> Result<RoundOutput> {
    assert!(!cohort.is_empty());
    let mut pseudo = zeros_like(params);
    let mut loss_sum = 0.0f32;
    let cohort_scale = 1.0 / cohort.len() as f32;
    for cb in cohort {
        let (g, mean_loss) = backend.grad_multi(params, &cb.tokens, cb.tau)?;
        accumulate(&mut pseudo, &g, cohort_scale);
        loss_sum += mean_loss;
    }
    Ok(RoundOutput {
        pseudo_grad: pseudo,
        mean_client_loss: loss_sum / cohort.len() as f32,
        clients: cohort.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockRuntime;

    fn batches_for(mock: &MockRuntime, tau: usize, fill: impl Fn(usize) -> i32) -> ClientBatches {
        let (b, t) = mock.batch_shape();
        ClientBatches {
            tokens: (0..tau * b * t).map(fill).collect(),
            tau,
            batch_size: b,
            tokens_per_example: t,
            distinct_sequences: tau * b,
            raw_tokens: tau * b * t,
        }
    }

    #[test]
    fn fedsgd_equals_large_batch_gradient() {
        // With one client, FedSGD's pseudo-grad must equal the mean of the
        // per-batch gradients at the broadcast model — exactly.
        let mock = MockRuntime::standard();
        let p = mock.init_params();
        let cb = batches_for(&mock, 3, |i| 1 + (i as i32 * 7) % 50);
        let out = fedsgd_round(&mock, &p, &[cb.clone()]).unwrap();
        let per = cb.batch_size * cb.tokens_per_example;
        let mut want = vec![0.0f32; 16];
        for i in 0..3 {
            let (g, _) = mock.grad(&p, &cb.tokens[i * per..(i + 1) * per]).unwrap();
            for k in 0..16 {
                want[k] += g[0][k] / 3.0;
            }
        }
        for k in 0..16 {
            assert!((out.pseudo_grad[0][k] - want[k]).abs() < 1e-6);
        }
    }

    #[test]
    fn fedavg_tau1_direction_matches_fedsgd() {
        // tau=1: FedAvg's delta = lr * grad, i.e. proportional to FedSGD's
        // pseudo-gradient ("effectively the same algorithm up to
        // normalization", Appendix D.2).
        let mock = MockRuntime::standard();
        let p = mock.init_params();
        let cb = batches_for(&mock, 1, |i| 1 + (i as i32 * 11) % 50);
        let avg = fedavg_round(&mock, &p, &[cb.clone()], 0.25).unwrap();
        let sgd = fedsgd_round(&mock, &p, &[cb]).unwrap();
        for k in 0..16 {
            assert!(
                (avg.pseudo_grad[0][k] - 0.25 * sgd.pseudo_grad[0][k]).abs() < 1e-6,
                "coord {k}"
            );
        }
    }

    #[test]
    fn fedavg_loss_below_fedsgd_loss_on_same_data() {
        // The paper's §5.2 observation: FedAvg's reported train loss is
        // lower because the client adapts while computing it.
        let mock = MockRuntime::standard();
        let p = mock.init_params();
        let cohort: Vec<ClientBatches> = (0..4)
            .map(|c| batches_for(&mock, 8, move |i| 1 + ((i + 13 * c) as i32 * 5) % 50))
            .collect();
        let avg = fedavg_round(&mock, &p, &cohort, 0.3).unwrap();
        let sgd = fedsgd_round(&mock, &p, &cohort).unwrap();
        assert!(
            avg.mean_client_loss < sgd.mean_client_loss,
            "{} !< {}",
            avg.mean_client_loss,
            sgd.mean_client_loss
        );
    }

    #[test]
    fn cohort_average_is_uniform() {
        let mock = MockRuntime::standard();
        let p = mock.init_params();
        let a = batches_for(&mock, 2, |i| 1 + (i as i32) % 30);
        let b = batches_for(&mock, 2, |i| 31 + (i as i32) % 30);
        let out_ab = fedsgd_round(&mock, &p, &[a.clone(), b.clone()]).unwrap();
        let out_a = fedsgd_round(&mock, &p, &[a]).unwrap();
        let out_b = fedsgd_round(&mock, &p, &[b]).unwrap();
        for k in 0..16 {
            let want = 0.5 * (out_a.pseudo_grad[0][k] + out_b.pseudo_grad[0][k]);
            assert!((out_ab.pseudo_grad[0][k] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn fedavg_descends_under_server_sgd() {
        use crate::fed::server_opt::{ServerOptimizer, Sgd};
        let mock = MockRuntime::standard();
        let mut p = mock.init_params();
        let cohort: Vec<ClientBatches> = (0..3)
            .map(|c| batches_for(&mock, 4, move |i| 1 + ((i * 3 + c * 17) as i32) % 50))
            .collect();
        let eval = |p: &crate::runtime::Params| {
            cohort
                .iter()
                .map(|cb| mock.eval_loss(p, cb.batch(0)).unwrap())
                .sum::<f32>()
        };
        let before = eval(&p);
        let mut opt = Sgd;
        for _ in 0..30 {
            let out = fedavg_round(&mock, &p, &cohort, 0.2).unwrap();
            opt.step(&mut p, &out.pseudo_grad, 1.0);
        }
        let after = eval(&p);
        // The mock has an irreducible heterogeneity floor (clients disagree
        // per bucket), so require solid but not total descent.
        assert!(after < before * 0.85, "{before} -> {after}");
    }
}
