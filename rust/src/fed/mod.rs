//! Federated training over group streams — the paper's §5 experiment
//! engine (Appendix C semantics, scaled):
//!
//! * [`schedules`] — server LR schedules: constant, 10% linear warmup +
//!   exponential decay, warmup + cosine decay (Figure 4).
//! * [`server_opt`] — FedOpt server optimizers (Adam with the paper's
//!   beta/epsilon defaults; SGD for ablations) applied to the averaged
//!   client delta ("pseudo-gradient", Reddi et al. [30]).
//! * [`client_data`] — the client-side data pipeline: tokenize, concatenate
//!   into length-(S+1) sequences (pad the last), batch, repeat/truncate to
//!   tau batches per round.
//! * [`algorithms`] — FedAvg (client SGD local steps via the fused
//!   `local_train` artifact) and FedSGD (average of tau minibatch
//!   gradients at the broadcast model).
//! * [`personalize`] — pre-/post-personalization evaluation (Table 5,
//!   Figures 5-7): fine-tune one epoch of client SGD, compare losses.
//! * [`trainer`] — the round loop: cohort stream -> client work -> server
//!   update, with per-round data-vs-compute timing (Table 4), optional
//!   between-round snapshot refresh and depth-1 cohort prefetch.
//! * [`ingest`] — the live-ingestion workload: a seeded writer that keeps
//!   appending (and checkpointing/compacting) a paged store while the
//!   trainer samples from refreshing snapshots (Table 4e).

pub mod algorithms;
pub mod client_data;
pub mod ingest;
pub mod personalize;
pub mod schedules;
pub mod server_opt;
pub mod source;
pub mod trainer;

pub use algorithms::{fedavg_round, fedsgd_round, RoundOutput};
pub use client_data::ClientBatches;
pub use personalize::{personalization_eval, PersonalizationResult};
pub use schedules::Schedule;
pub use server_opt::{Adam, ServerOptimizer, Sgd};
pub use ingest::{IngestConfig, IngestHandle, IngestRunner, IngestStats, IngestTarget};
pub use source::{ClientSource, RefreshingSource, SourceFactory};
pub use trainer::{
    fetch_cohort, fetch_cohort_sharded, train, train_with_source, CohortFetchSpec, RoundMetrics,
    TrainOutput, TrainerConfig,
};
