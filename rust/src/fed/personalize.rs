//! Pre-/post-personalization evaluation (§5.2, Table 5, Figures 5-7).
//!
//! For every validation client: (1) **pre** — average loss of the trained
//! model over the client's batches; (2) personalize — one epoch of client
//! SGD on those batches (the same scheme FedAvg clients use in training);
//! (3) **post** — average loss of the personalized model on the same
//! batches. Appendix C.5 semantics.

use anyhow::Result;

use super::client_data::ClientBatches;
use crate::metrics::percentile::Summary;
use crate::runtime::{ModelBackend, Params};

/// Per-client pre/post losses plus the cohort-level summaries.
#[derive(Debug, Clone)]
pub struct PersonalizationResult {
    pub pre: Vec<f32>,
    pub post: Vec<f32>,
}

impl PersonalizationResult {
    pub fn pre_summary(&self) -> Summary {
        Summary::of(&self.pre.iter().map(|&x| x as f64).collect::<Vec<_>>())
    }

    pub fn post_summary(&self) -> Summary {
        Summary::of(&self.post.iter().map(|&x| x as f64).collect::<Vec<_>>())
    }
}

/// Average eval loss over a client's batches.
pub fn client_eval_loss(
    backend: &dyn ModelBackend,
    params: &Params,
    cb: &ClientBatches,
) -> Result<f32> {
    let mut sum = 0.0f32;
    for i in 0..cb.tau {
        sum += backend.eval_loss(params, cb.batch(i))?;
    }
    Ok(sum / cb.tau as f32)
}

/// Evaluate one client: returns (pre, post) losses.
pub fn personalize_client(
    backend: &dyn ModelBackend,
    params: &Params,
    cb: &ClientBatches,
    personalize_lr: f32,
) -> Result<(f32, f32)> {
    let pre = client_eval_loss(backend, params, cb)?;
    // One epoch of client SGD = tau steps over the client's batches.
    let (personalized, _) = backend.local_train(params, &cb.tokens, cb.tau, personalize_lr)?;
    let post = client_eval_loss(backend, &personalized, cb)?;
    Ok((pre, post))
}

/// Evaluate a set of validation clients.
pub fn personalization_eval(
    backend: &dyn ModelBackend,
    params: &Params,
    clients: &[ClientBatches],
    personalize_lr: f32,
) -> Result<PersonalizationResult> {
    let mut pre = Vec::with_capacity(clients.len());
    let mut post = Vec::with_capacity(clients.len());
    for cb in clients {
        let (a, b) = personalize_client(backend, params, cb, personalize_lr)?;
        pre.push(a);
        post.push(b);
    }
    Ok(PersonalizationResult { pre, post })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockRuntime;

    fn client(mock: &MockRuntime, tau: usize, offset: i32) -> ClientBatches {
        let (b, t) = mock.batch_shape();
        ClientBatches {
            tokens: (0..tau * b * t).map(|i| 1 + (i as i32 + offset) % 50).collect(),
            tau,
            batch_size: b,
            tokens_per_example: t,
            distinct_sequences: tau * b,
            raw_tokens: tau * b * t,
        }
    }

    #[test]
    fn personalization_reduces_loss() {
        let mock = MockRuntime::standard();
        let params = mock.init_params();
        let clients: Vec<ClientBatches> = (0..6).map(|c| client(&mock, 6, 7 * c)).collect();
        let res = personalization_eval(&mock, &params, &clients, 0.4).unwrap();
        assert_eq!(res.pre.len(), 6);
        for (a, b) in res.pre.iter().zip(&res.post) {
            assert!(b < a, "post {b} !< pre {a}");
        }
        let s_pre = res.pre_summary();
        let s_post = res.post_summary();
        assert!(s_post.median < s_pre.median);
    }

    #[test]
    fn pre_loss_matches_direct_eval() {
        let mock = MockRuntime::standard();
        let params = mock.init_params();
        let cb = client(&mock, 4, 3);
        let (pre, _) = personalize_client(&mock, &params, &cb, 0.1).unwrap();
        let direct = client_eval_loss(&mock, &params, &cb).unwrap();
        assert_eq!(pre, direct);
    }

    #[test]
    fn personalization_does_not_mutate_global_params() {
        let mock = MockRuntime::standard();
        let params = mock.init_params();
        let snapshot = params.clone();
        let clients = vec![client(&mock, 3, 0)];
        personalization_eval(&mock, &params, &clients, 0.5).unwrap();
        assert_eq!(params, snapshot);
    }
}
