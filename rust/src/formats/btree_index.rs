//! An immutable on-disk paged B-tree — the "SQL database" substrate under
//! the hierarchical format.
//!
//! TFF's hierarchical format stores one row per example in a SQLite file
//! keyed by client id; constructing a client's dataset issues an indexed
//! range query whose cost is page fetches + in-page searches + row
//! decoding. This module reproduces that cost model faithfully:
//!
//! * fixed 4 KiB pages ([`PAGE_SIZE`], shared with [`crate::store`]),
//!   bulk-loaded bottom-up from sorted (key, value) rows; leaves are
//!   chained for range scans;
//! * lookups descend from the root reading pages **through the shared
//!   pager** ([`crate::store::shared::SharedPager`], so any number of
//!   threads can query one open index): page fetches go through a
//!   bounded LRU cache whose size is a constructor knob
//!   ([`BTreeFile::open_with_cache`]), defaulting to a tiny hot set
//!   ([`DEFAULT_CACHE_PAGES`]) so every cold group construction still
//!   pays real page I/O + binary search — exactly what makes Table 3's
//!   hierarchical column slow at scale, now with a tunable dial instead
//!   of hardcoded root-only caching;
//! * range scans (`scan_prefix`) walk chained leaves.
//!
//! For an *appendable* B-tree (insert with page splits, copy-on-write),
//! see [`crate::store::btree`] — this module stays bulk-load-only because
//! the hierarchical format's prep-time cheapness is part of its cost
//! model.
//!
//! Layout: page 0 = header (magic, root id, page count, levels); then
//! pages. Leaf page: `u8 tag=1 | u16 count | u32 next_leaf |
//! (u16 klen | u16 vlen | key | value)*`. Internal page: `u8 tag=2 |
//! u16 count | (u16 klen | key | u32 child)*` where child covers keys
//! `>=` its key (first child covers everything below the second key).

use std::io::{self, Write};
use std::path::Path;

use crate::store::cache::CacheStats;
use crate::store::page::Page;
use crate::store::pager::PageRead;
use crate::store::shared::{ReadSnapshot, SharedPager};
use crate::store::vfs::{OpenMode, StdVfs, Vfs, VfsCursor};

pub use crate::store::page::PAGE_SIZE;

/// Default LRU frames for an opened index: a tiny hot set (SQLite keeps a
/// small page cache; caching everything would defeat the cost model this
/// substrate exists to reproduce).
pub const DEFAULT_CACHE_PAGES: usize = 8;

const MAGIC: &[u8; 8] = b"GRPBTR01";
const LEAF: u8 = 1;
const INTERNAL: u8 = 2;

/// Bulk-load a B-tree from rows sorted by key (strictly ascending keys are
/// not required; duplicate keys are allowed and scanned in input order).
pub struct BTreeBuilder {
    rows: Vec<(Vec<u8>, Vec<u8>)>,
}

impl BTreeBuilder {
    pub fn new() -> Self {
        BTreeBuilder { rows: Vec::new() }
    }

    /// Queue one row. Errors (rather than panicking) when the row cannot
    /// fit a page — e.g. a pathologically long group key.
    pub fn push(&mut self, key: Vec<u8>, value: Vec<u8>) -> io::Result<()> {
        if key.len() + value.len() + 6 > PAGE_SIZE - 16 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "btree row of {} bytes (key {} + value {}) exceeds the {} byte page budget",
                    key.len() + value.len(),
                    key.len(),
                    value.len(),
                    PAGE_SIZE - 22
                ),
            ));
        }
        if let Some((last, _)) = self.rows.last() {
            debug_assert!(*last <= key, "rows must be pushed in sorted order");
        }
        self.rows.push((key, value));
        Ok(())
    }

    /// Bulk-load the queued rows and write the tree to `path` on the
    /// real filesystem.
    pub fn write<P: AsRef<Path>>(self, path: P) -> io::Result<()> {
        self.write_with(&StdVfs, path.as_ref())
    }

    /// Bulk-load the queued rows and write the tree to `path` on `vfs`.
    pub fn write_with(self, vfs: &dyn Vfs, path: &Path) -> io::Result<()> {
        if let Some(d) = path.parent() {
            vfs.create_dir_all(d)?;
        }
        let mut pages: Vec<Vec<u8>> = vec![Vec::new()]; // page 0 = header
        // --- leaves
        let mut leaf_ids: Vec<(Vec<u8>, u32)> = Vec::new(); // (first key, page)
        let mut cur: Vec<u8> = Vec::with_capacity(PAGE_SIZE);
        let mut cur_count: u16 = 0;
        let mut cur_first: Option<Vec<u8>> = None;
        let flush_leaf =
            |cur: &mut Vec<u8>, count: &mut u16, first: &mut Option<Vec<u8>>,
             pages: &mut Vec<Vec<u8>>, leaf_ids: &mut Vec<(Vec<u8>, u32)>| {
                if *count == 0 {
                    return;
                }
                let mut page = Vec::with_capacity(PAGE_SIZE);
                page.push(LEAF);
                page.extend_from_slice(&count.to_le_bytes());
                page.extend_from_slice(&0u32.to_le_bytes()); // next patched later
                page.extend_from_slice(cur);
                let id = pages.len() as u32;
                pages.push(page);
                leaf_ids.push((first.take().unwrap(), id));
                cur.clear();
                *count = 0;
            };
        for (k, v) in &self.rows {
            let need = 4 + k.len() + v.len();
            if 7 + cur.len() + need > PAGE_SIZE {
                flush_leaf(&mut cur, &mut cur_count, &mut cur_first, &mut pages, &mut leaf_ids);
            }
            if cur_first.is_none() {
                cur_first = Some(k.clone());
            }
            cur.extend_from_slice(&(k.len() as u16).to_le_bytes());
            cur.extend_from_slice(&(v.len() as u16).to_le_bytes());
            cur.extend_from_slice(k);
            cur.extend_from_slice(v);
            cur_count += 1;
        }
        flush_leaf(&mut cur, &mut cur_count, &mut cur_first, &mut pages, &mut leaf_ids);
        // chain leaves
        for w in leaf_ids.windows(2) {
            let (cur_id, next_id) = (w[0].1 as usize, w[1].1);
            pages[cur_id][3..7].copy_from_slice(&next_id.to_le_bytes());
        }

        // --- internal levels
        let mut level: Vec<(Vec<u8>, u32)> = leaf_ids;
        let mut levels = 1u32;
        while level.len() > 1 {
            let mut next: Vec<(Vec<u8>, u32)> = Vec::new();
            let mut page = Vec::with_capacity(PAGE_SIZE);
            let mut count: u16 = 0;
            let mut first: Option<Vec<u8>> = None;
            let mut body: Vec<u8> = Vec::new();
            for (k, child) in &level {
                let need = 6 + k.len();
                if 3 + body.len() + need > PAGE_SIZE {
                    page.push(INTERNAL);
                    page.extend_from_slice(&count.to_le_bytes());
                    page.extend_from_slice(&body);
                    let id = pages.len() as u32;
                    pages.push(std::mem::take(&mut page));
                    next.push((first.take().unwrap(), id));
                    body.clear();
                    count = 0;
                }
                if first.is_none() {
                    first = Some(k.clone());
                }
                body.extend_from_slice(&(k.len() as u16).to_le_bytes());
                body.extend_from_slice(k);
                body.extend_from_slice(&child.to_le_bytes());
                count += 1;
            }
            if count > 0 {
                page.push(INTERNAL);
                page.extend_from_slice(&count.to_le_bytes());
                page.extend_from_slice(&body);
                let id = pages.len() as u32;
                pages.push(page);
                next.push((first.take().unwrap(), id));
            }
            level = next;
            levels += 1;
        }
        let root = level.first().map(|(_, id)| *id).unwrap_or(0);

        // header
        let mut header = Vec::with_capacity(PAGE_SIZE);
        header.extend_from_slice(MAGIC);
        header.extend_from_slice(&root.to_le_bytes());
        header.extend_from_slice(&(pages.len() as u32).to_le_bytes());
        header.extend_from_slice(&levels.to_le_bytes());
        header.extend_from_slice(&(self.rows.len() as u64).to_le_bytes());
        pages[0] = header;

        let file = vfs.open(path, OpenMode::CreateTruncate)?;
        let mut f = io::BufWriter::new(VfsCursor::new(file));
        for mut p in pages {
            p.resize(PAGE_SIZE, 0);
            f.write_all(&p)?;
        }
        f.flush()
    }
}

impl Default for BTreeBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// Read side: descends from the root, fetching pages through a shared
/// concurrent pager's sharded LRU cache. `Send + Sync`: many threads can
/// query one `BTreeFile` (the file is immutable once bulk-loaded, so
/// every read handle is bounded by the whole file).
pub struct BTreeFile {
    pager: SharedPager,
    snapshot: ReadSnapshot,
    root: u32,
    levels: u32,
    num_rows: u64,
}

impl BTreeFile {
    /// Open with the default (deliberately tiny) cache.
    pub fn open<P: AsRef<Path>>(path: P) -> io::Result<Self> {
        Self::open_with_cache(path, DEFAULT_CACHE_PAGES)
    }

    /// Open with an explicit LRU cache size in pages — the knob Table 3's
    /// paged column turns. Clamped to at least 2 frames.
    pub fn open_with_cache<P: AsRef<Path>>(path: P, cache_pages: usize) -> io::Result<Self> {
        Self::open_with(&StdVfs, path.as_ref(), cache_pages)
    }

    /// Open on an explicit [`Vfs`] with an explicit cache size.
    pub fn open_with(vfs: &dyn Vfs, path: &Path, cache_pages: usize) -> io::Result<Self> {
        let pager = SharedPager::open_with(vfs, path, cache_pages.max(2))?;
        let header = pager.read_header_fresh()?;
        if header.get_bytes(0, 8) != MAGIC {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "bad btree magic"));
        }
        let root = header.get_u32(8);
        let num_pages = header.get_u32(12);
        let levels = header.get_u32(16);
        let num_rows = header.get_u64(20);
        // The file is immutable: the snapshot is simply "all pages".
        let snapshot = ReadSnapshot { bound: num_pages, epoch: 0 };
        let this = BTreeFile { pager, snapshot, root, levels, num_rows };
        if num_rows > 0 {
            // Warm the root (the hot set every descent shares).
            this.page(this.root)?;
        }
        Ok(this)
    }

    pub fn num_rows(&self) -> u64 {
        self.num_rows
    }

    pub fn levels(&self) -> u32 {
        self.levels
    }

    /// Pages fetched from disk so far (cache misses; cost introspection
    /// for benches), summed across all querying threads.
    pub fn pages_read(&self) -> u64 {
        self.pager.disk_reads()
    }

    /// Cache hit/miss/eviction counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.pager.cache_stats()
    }

    fn page(&self, id: u32) -> io::Result<Page> {
        self.pager.reader(self.snapshot).read_page(id)
    }

    /// Find the leaf that may contain `key`, descending internal pages.
    fn descend(&self, key: &[u8]) -> io::Result<u32> {
        let mut id = self.root;
        loop {
            let page = self.page(id)?;
            let b = page.as_slice();
            match b[0] {
                LEAF => return Ok(id),
                INTERNAL => {
                    let count = u16::from_le_bytes(b[1..3].try_into().unwrap()) as usize;
                    let mut p = 3usize;
                    let mut chosen: Option<u32> = None;
                    let mut first_child: Option<u32> = None;
                    for _ in 0..count {
                        let klen =
                            u16::from_le_bytes(b[p..p + 2].try_into().unwrap()) as usize;
                        let k = &b[p + 2..p + 2 + klen];
                        let child = u32::from_le_bytes(
                            b[p + 2 + klen..p + 6 + klen].try_into().unwrap(),
                        );
                        if first_child.is_none() {
                            first_child = Some(child);
                        }
                        if k <= key {
                            chosen = Some(child);
                        } else {
                            break;
                        }
                        p += 6 + klen;
                    }
                    id = chosen.or(first_child).ok_or_else(|| {
                        io::Error::new(io::ErrorKind::InvalidData, "empty internal page")
                    })?;
                }
                t => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("bad page tag {t}"),
                    ))
                }
            }
        }
    }

    /// Visit every row whose key starts with `prefix`, in key order.
    /// Returns the number of rows visited.
    pub fn scan_prefix(
        &self,
        prefix: &[u8],
        mut f: impl FnMut(&[u8], &[u8]),
    ) -> io::Result<usize> {
        if self.num_rows == 0 {
            return Ok(0);
        }
        let mut leaf_id = self.descend(prefix)?;
        let mut visited = 0usize;
        loop {
            let page = self.page(leaf_id)?;
            let b = page.as_slice();
            debug_assert_eq!(b[0], LEAF);
            let count = u16::from_le_bytes(b[1..3].try_into().unwrap()) as usize;
            let next = u32::from_le_bytes(b[3..7].try_into().unwrap());
            let mut p = 7usize;
            let mut past_prefix = false;
            for _ in 0..count {
                let klen = u16::from_le_bytes(b[p..p + 2].try_into().unwrap()) as usize;
                let vlen =
                    u16::from_le_bytes(b[p + 2..p + 4].try_into().unwrap()) as usize;
                let k = &b[p + 4..p + 4 + klen];
                let v = &b[p + 4 + klen..p + 4 + klen + vlen];
                if k.starts_with(prefix) {
                    f(k, v);
                    visited += 1;
                } else if k > prefix {
                    past_prefix = true;
                    break;
                }
                p += 4 + klen + vlen;
            }
            if past_prefix || next == 0 {
                return Ok(visited);
            }
            leaf_id = next;
        }
    }

    /// Exact-match lookup of the first row with `key`.
    pub fn get(&self, key: &[u8]) -> io::Result<Option<Vec<u8>>> {
        let mut out = None;
        self.scan_prefix(key, |k, v| {
            if out.is_none() && k == key {
                out = Some(v.to_vec());
            }
        })?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, gen_bytes, prop_assert_eq};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grouper_btree_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn build(rows: &[(Vec<u8>, Vec<u8>)], name: &str) -> BTreeFile {
        let mut b = BTreeBuilder::new();
        let mut sorted = rows.to_vec();
        sorted.sort();
        for (k, v) in sorted {
            b.push(k, v).unwrap();
        }
        let p = tmp(name);
        b.write(&p).unwrap();
        BTreeFile::open(&p).unwrap()
    }

    #[test]
    fn empty_tree() {
        let t = build(&[], "empty.btree");
        assert_eq!(t.num_rows(), 0);
        assert_eq!(t.get(b"x").unwrap(), None);
    }

    #[test]
    fn single_and_small() {
        let t = build(&[(b"k".to_vec(), b"v".to_vec())], "one.btree");
        assert_eq!(t.get(b"k").unwrap(), Some(b"v".to_vec()));
        assert_eq!(t.get(b"j").unwrap(), None);
        assert_eq!(t.get(b"l").unwrap(), None);
    }

    #[test]
    fn oversized_row_is_an_error_not_a_panic() {
        let mut b = BTreeBuilder::new();
        let err = b.push(vec![b'k'; 3000], vec![b'v'; 2000]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("exceeds"));
        // The builder is still usable afterwards.
        b.push(b"ok".to_vec(), b"v".to_vec()).unwrap();
    }

    #[test]
    fn multi_level_lookup_and_scan() {
        // Enough rows to force several leaf pages and >= 2 levels.
        let rows: Vec<(Vec<u8>, Vec<u8>)> = (0..5000u32)
            .map(|i| {
                (
                    format!("group-{:04}/ex{:03}", i / 10, i % 10).into_bytes(),
                    i.to_le_bytes().to_vec(),
                )
            })
            .collect();
        let t = build(&rows, "multi.btree");
        assert!(t.levels() >= 2, "levels {}", t.levels());
        assert_eq!(t.num_rows(), 5000);
        // exact lookups
        assert_eq!(
            t.get(b"group-0123/ex007").unwrap(),
            Some(1237u32.to_le_bytes().to_vec())
        );
        assert_eq!(t.get(b"group-9999/ex000").unwrap(), None);
        // prefix scan = one group's rows in order
        let mut got = Vec::new();
        let n = t
            .scan_prefix(b"group-0042/", |_k, v| {
                got.push(u32::from_le_bytes(v.try_into().unwrap()))
            })
            .unwrap();
        assert_eq!(n, 10);
        assert_eq!(got, (420..430).collect::<Vec<u32>>());
        // scans cost page reads (the point of the substrate)
        assert!(t.pages_read() > 0);
    }

    #[test]
    fn scan_prefix_across_leaf_boundary() {
        // One huge group spanning multiple leaves.
        let rows: Vec<(Vec<u8>, Vec<u8>)> = (0..2000u32)
            .map(|i| (format!("g/{i:08}").into_bytes(), vec![7u8; 64]))
            .collect();
        let t = build(&rows, "span.btree");
        let mut n = 0;
        t.scan_prefix(b"g/", |_, _| n += 1).unwrap();
        assert_eq!(n, 2000);
    }

    #[test]
    fn larger_cache_means_fewer_disk_reads() {
        let rows: Vec<(Vec<u8>, Vec<u8>)> = (0..8000u32)
            .map(|i| (format!("k{:06}", i).into_bytes(), vec![3u8; 32]))
            .collect();
        let mut b = BTreeBuilder::new();
        for (k, v) in &rows {
            b.push(k.clone(), v.clone()).unwrap();
        }
        let p = tmp("cachesize.btree");
        b.write(&p).unwrap();
        let probe = |cache: usize| -> u64 {
            let t = BTreeFile::open_with_cache(&p, cache).unwrap();
            let mut rng = Rng::new(5);
            for _ in 0..300 {
                let i = rng.gen_range(8000);
                let key = format!("k{:06}", i).into_bytes();
                assert!(t.get(&key).unwrap().is_some());
            }
            t.pages_read()
        };
        let cold = probe(2);
        let warm = probe(4096);
        assert!(
            warm < cold,
            "a large cache must do fewer page fetches ({warm} vs {cold})"
        );
    }

    #[test]
    fn property_random_rows_roundtrip() {
        check(15, |rng| {
            let n = 1 + rng.gen_range_usize(400);
            let mut rows: Vec<(Vec<u8>, Vec<u8>)> = (0..n)
                .map(|i| {
                    let mut k = gen_bytes(rng, 1..=20);
                    k.extend_from_slice(&(i as u32).to_be_bytes()); // unique
                    (k, gen_bytes(rng, 0..=40))
                })
                .collect();
            rows.sort();
            let t = build(&rows, &format!("prop{}.btree", rng.next_u32()));
            let mut r2 = Rng::new(1);
            for _ in 0..20.min(n) {
                let (k, v) = &rows[r2.gen_range_usize(n)];
                prop_assert_eq(t.get(k).unwrap(), Some(v.clone()), "lookup")?;
            }
            Ok(())
        });
    }
}
