//! An immutable on-disk paged B-tree — the "SQL database" substrate under
//! the hierarchical format.
//!
//! TFF's hierarchical format stores one row per example in a SQLite file
//! keyed by client id; constructing a client's dataset issues an indexed
//! range query whose cost is page fetches + in-page searches + row
//! decoding. This module reproduces that cost model faithfully:
//!
//! * fixed 4 KiB pages, bulk-loaded bottom-up from sorted (key, value)
//!   rows; leaves are chained for range scans;
//! * lookups descend from the root *reading pages from the file on
//!   demand* — no resident index (only the root page is cached), so every
//!   group construction pays real page I/O + binary search, exactly what
//!   makes Table 3's hierarchical column slow at scale;
//! * range scans (`scan_prefix`) walk chained leaves.
//!
//! Layout: page 0 = header (magic, root id, page count, levels); then
//! pages. Leaf page: `u8 tag=1 | u16 count | u32 next_leaf |
//! (u16 klen | u16 vlen | key | value)*`. Internal page: `u8 tag=2 |
//! u16 count | (u16 klen | key | u32 child)*` where child covers keys
//! `>=` its key (first child covers everything below the second key).

use std::fs::File;
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::Path;

pub const PAGE_SIZE: usize = 4096;
const MAGIC: &[u8; 8] = b"GRPBTR01";
const LEAF: u8 = 1;
const INTERNAL: u8 = 2;

/// Bulk-load a B-tree from rows sorted by key (strictly ascending keys are
/// not required; duplicate keys are allowed and scanned in input order).
pub struct BTreeBuilder {
    rows: Vec<(Vec<u8>, Vec<u8>)>,
}

impl BTreeBuilder {
    pub fn new() -> Self {
        BTreeBuilder { rows: Vec::new() }
    }

    pub fn push(&mut self, key: Vec<u8>, value: Vec<u8>) {
        assert!(key.len() + value.len() + 6 <= PAGE_SIZE - 16, "row exceeds page");
        if let Some((last, _)) = self.rows.last() {
            debug_assert!(*last <= key, "rows must be pushed in sorted order");
        }
        self.rows.push((key, value));
    }

    pub fn write<P: AsRef<Path>>(self, path: P) -> io::Result<()> {
        if let Some(d) = path.as_ref().parent() {
            std::fs::create_dir_all(d)?;
        }
        let mut pages: Vec<Vec<u8>> = vec![Vec::new()]; // page 0 = header
        // --- leaves
        let mut leaf_ids: Vec<(Vec<u8>, u32)> = Vec::new(); // (first key, page)
        let mut cur: Vec<u8> = Vec::with_capacity(PAGE_SIZE);
        let mut cur_count: u16 = 0;
        let mut cur_first: Option<Vec<u8>> = None;
        let flush_leaf =
            |cur: &mut Vec<u8>, count: &mut u16, first: &mut Option<Vec<u8>>,
             pages: &mut Vec<Vec<u8>>, leaf_ids: &mut Vec<(Vec<u8>, u32)>| {
                if *count == 0 {
                    return;
                }
                let mut page = Vec::with_capacity(PAGE_SIZE);
                page.push(LEAF);
                page.extend_from_slice(&count.to_le_bytes());
                page.extend_from_slice(&0u32.to_le_bytes()); // next patched later
                page.extend_from_slice(cur);
                let id = pages.len() as u32;
                pages.push(page);
                leaf_ids.push((first.take().unwrap(), id));
                cur.clear();
                *count = 0;
            };
        for (k, v) in &self.rows {
            let need = 4 + k.len() + v.len();
            if 7 + cur.len() + need > PAGE_SIZE {
                flush_leaf(&mut cur, &mut cur_count, &mut cur_first, &mut pages, &mut leaf_ids);
            }
            if cur_first.is_none() {
                cur_first = Some(k.clone());
            }
            cur.extend_from_slice(&(k.len() as u16).to_le_bytes());
            cur.extend_from_slice(&(v.len() as u16).to_le_bytes());
            cur.extend_from_slice(k);
            cur.extend_from_slice(v);
            cur_count += 1;
        }
        flush_leaf(&mut cur, &mut cur_count, &mut cur_first, &mut pages, &mut leaf_ids);
        // chain leaves
        for w in leaf_ids.windows(2) {
            let (cur_id, next_id) = (w[0].1 as usize, w[1].1);
            pages[cur_id][3..7].copy_from_slice(&next_id.to_le_bytes());
        }

        // --- internal levels
        let mut level: Vec<(Vec<u8>, u32)> = leaf_ids;
        let mut levels = 1u32;
        while level.len() > 1 {
            let mut next: Vec<(Vec<u8>, u32)> = Vec::new();
            let mut page = Vec::with_capacity(PAGE_SIZE);
            let mut count: u16 = 0;
            let mut first: Option<Vec<u8>> = None;
            let mut body: Vec<u8> = Vec::new();
            for (k, child) in &level {
                let need = 6 + k.len();
                if 3 + body.len() + need > PAGE_SIZE {
                    page.push(INTERNAL);
                    page.extend_from_slice(&count.to_le_bytes());
                    page.extend_from_slice(&body);
                    let id = pages.len() as u32;
                    pages.push(std::mem::take(&mut page));
                    next.push((first.take().unwrap(), id));
                    body.clear();
                    count = 0;
                }
                if first.is_none() {
                    first = Some(k.clone());
                }
                body.extend_from_slice(&(k.len() as u16).to_le_bytes());
                body.extend_from_slice(k);
                body.extend_from_slice(&child.to_le_bytes());
                count += 1;
            }
            if count > 0 {
                page.push(INTERNAL);
                page.extend_from_slice(&count.to_le_bytes());
                page.extend_from_slice(&body);
                let id = pages.len() as u32;
                pages.push(page);
                next.push((first.take().unwrap(), id));
            }
            level = next;
            levels += 1;
        }
        let root = level.first().map(|(_, id)| *id).unwrap_or(0);

        // header
        let mut header = Vec::with_capacity(PAGE_SIZE);
        header.extend_from_slice(MAGIC);
        header.extend_from_slice(&root.to_le_bytes());
        header.extend_from_slice(&(pages.len() as u32).to_le_bytes());
        header.extend_from_slice(&levels.to_le_bytes());
        header.extend_from_slice(&(self.rows.len() as u64).to_le_bytes());
        pages[0] = header;

        let mut f = io::BufWriter::new(File::create(path)?);
        for mut p in pages {
            p.resize(PAGE_SIZE, 0);
            f.write_all(&p)?;
        }
        f.flush()
    }
}

impl Default for BTreeBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// Read side: descends from the root, fetching pages on demand.
pub struct BTreeFile {
    file: File,
    root: u32,
    levels: u32,
    num_rows: u64,
    /// Only the root page is cached (SQLite keeps a tiny hot set; caching
    /// everything would defeat the cost model this substrate exists for).
    root_page: Vec<u8>,
    /// Page fetch counter (cost introspection for benches).
    pub pages_read: std::cell::Cell<u64>,
}

impl BTreeFile {
    pub fn open<P: AsRef<Path>>(path: P) -> io::Result<Self> {
        let mut file = File::open(path)?;
        let mut header = vec![0u8; PAGE_SIZE];
        file.read_exact(&mut header)?;
        if &header[..8] != MAGIC {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "bad btree magic"));
        }
        let root = u32::from_le_bytes(header[8..12].try_into().unwrap());
        let levels = u32::from_le_bytes(header[16..20].try_into().unwrap());
        let num_rows = u64::from_le_bytes(header[20..28].try_into().unwrap());
        let mut this = BTreeFile {
            file,
            root,
            levels,
            num_rows,
            root_page: Vec::new(),
            pages_read: std::cell::Cell::new(0),
        };
        if num_rows > 0 {
            this.root_page = this.fetch_page(root)?;
        }
        Ok(this)
    }

    pub fn num_rows(&self) -> u64 {
        self.num_rows
    }

    pub fn levels(&self) -> u32 {
        self.levels
    }

    fn fetch_page(&self, id: u32) -> io::Result<Vec<u8>> {
        let mut buf = vec![0u8; PAGE_SIZE];
        let mut f = &self.file;
        f.seek(SeekFrom::Start(id as u64 * PAGE_SIZE as u64))?;
        f.read_exact(&mut buf)?;
        self.pages_read.set(self.pages_read.get() + 1);
        Ok(buf)
    }

    fn page(&self, id: u32) -> io::Result<std::borrow::Cow<'_, [u8]>> {
        if id == self.root {
            Ok(std::borrow::Cow::Borrowed(&self.root_page))
        } else {
            Ok(std::borrow::Cow::Owned(self.fetch_page(id)?))
        }
    }

    /// Find the leaf that may contain `key`, descending internal pages.
    fn descend(&self, key: &[u8]) -> io::Result<u32> {
        let mut id = self.root;
        loop {
            let page = self.page(id)?;
            match page[0] {
                LEAF => return Ok(id),
                INTERNAL => {
                    let count = u16::from_le_bytes(page[1..3].try_into().unwrap()) as usize;
                    let mut p = 3usize;
                    let mut chosen: Option<u32> = None;
                    let mut first_child: Option<u32> = None;
                    for _ in 0..count {
                        let klen =
                            u16::from_le_bytes(page[p..p + 2].try_into().unwrap()) as usize;
                        let k = &page[p + 2..p + 2 + klen];
                        let child = u32::from_le_bytes(
                            page[p + 2 + klen..p + 6 + klen].try_into().unwrap(),
                        );
                        if first_child.is_none() {
                            first_child = Some(child);
                        }
                        if k <= key {
                            chosen = Some(child);
                        } else {
                            break;
                        }
                        p += 6 + klen;
                    }
                    id = chosen.or(first_child).ok_or_else(|| {
                        io::Error::new(io::ErrorKind::InvalidData, "empty internal page")
                    })?;
                }
                t => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("bad page tag {t}"),
                    ))
                }
            }
        }
    }

    /// Visit every row whose key starts with `prefix`, in key order.
    /// Returns the number of rows visited.
    pub fn scan_prefix(
        &self,
        prefix: &[u8],
        mut f: impl FnMut(&[u8], &[u8]),
    ) -> io::Result<usize> {
        if self.num_rows == 0 {
            return Ok(0);
        }
        let mut leaf_id = self.descend(prefix)?;
        let mut visited = 0usize;
        loop {
            let page = self.page(leaf_id)?;
            debug_assert_eq!(page[0], LEAF);
            let count = u16::from_le_bytes(page[1..3].try_into().unwrap()) as usize;
            let next = u32::from_le_bytes(page[3..7].try_into().unwrap());
            let mut p = 7usize;
            let mut past_prefix = false;
            for _ in 0..count {
                let klen = u16::from_le_bytes(page[p..p + 2].try_into().unwrap()) as usize;
                let vlen =
                    u16::from_le_bytes(page[p + 2..p + 4].try_into().unwrap()) as usize;
                let k = &page[p + 4..p + 4 + klen];
                let v = &page[p + 4 + klen..p + 4 + klen + vlen];
                if k.starts_with(prefix) {
                    f(k, v);
                    visited += 1;
                } else if k > prefix {
                    past_prefix = true;
                    break;
                }
                p += 4 + klen + vlen;
            }
            if past_prefix || next == 0 {
                return Ok(visited);
            }
            leaf_id = next;
        }
    }

    /// Exact-match lookup of the first row with `key`.
    pub fn get(&self, key: &[u8]) -> io::Result<Option<Vec<u8>>> {
        let mut out = None;
        self.scan_prefix(key, |k, v| {
            if out.is_none() && k == key {
                out = Some(v.to_vec());
            }
        })?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, gen_bytes, prop_assert_eq};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("grouper_btree_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn build(rows: &[(Vec<u8>, Vec<u8>)], name: &str) -> BTreeFile {
        let mut b = BTreeBuilder::new();
        let mut sorted = rows.to_vec();
        sorted.sort();
        for (k, v) in sorted {
            b.push(k, v);
        }
        let p = tmp(name);
        b.write(&p).unwrap();
        BTreeFile::open(&p).unwrap()
    }

    #[test]
    fn empty_tree() {
        let t = build(&[], "empty.btree");
        assert_eq!(t.num_rows(), 0);
        assert_eq!(t.get(b"x").unwrap(), None);
    }

    #[test]
    fn single_and_small() {
        let t = build(&[(b"k".to_vec(), b"v".to_vec())], "one.btree");
        assert_eq!(t.get(b"k").unwrap(), Some(b"v".to_vec()));
        assert_eq!(t.get(b"j").unwrap(), None);
        assert_eq!(t.get(b"l").unwrap(), None);
    }

    #[test]
    fn multi_level_lookup_and_scan() {
        // Enough rows to force several leaf pages and >= 2 levels.
        let rows: Vec<(Vec<u8>, Vec<u8>)> = (0..5000u32)
            .map(|i| {
                (
                    format!("group-{:04}/ex{:03}", i / 10, i % 10).into_bytes(),
                    i.to_le_bytes().to_vec(),
                )
            })
            .collect();
        let t = build(&rows, "multi.btree");
        assert!(t.levels() >= 2, "levels {}", t.levels());
        assert_eq!(t.num_rows(), 5000);
        // exact lookups
        assert_eq!(
            t.get(b"group-0123/ex007").unwrap(),
            Some(1237u32.to_le_bytes().to_vec())
        );
        assert_eq!(t.get(b"group-9999/ex000").unwrap(), None);
        // prefix scan = one group's rows in order
        let mut got = Vec::new();
        let n = t
            .scan_prefix(b"group-0042/", |_k, v| {
                got.push(u32::from_le_bytes(v.try_into().unwrap()))
            })
            .unwrap();
        assert_eq!(n, 10);
        assert_eq!(got, (420..430).collect::<Vec<u32>>());
        // scans cost page reads (the point of the substrate)
        assert!(t.pages_read.get() > 0);
    }

    #[test]
    fn scan_prefix_across_leaf_boundary() {
        // One huge group spanning multiple leaves.
        let rows: Vec<(Vec<u8>, Vec<u8>)> = (0..2000u32)
            .map(|i| (format!("g/{i:08}").into_bytes(), vec![7u8; 64]))
            .collect();
        let t = build(&rows, "span.btree");
        let mut n = 0;
        t.scan_prefix(b"g/", |_, _| n += 1).unwrap();
        assert_eq!(n, 2000);
    }

    #[test]
    fn property_random_rows_roundtrip() {
        check(15, |rng| {
            let n = 1 + rng.gen_range_usize(400);
            let mut rows: Vec<(Vec<u8>, Vec<u8>)> = (0..n)
                .map(|i| {
                    let mut k = gen_bytes(rng, 1..=20);
                    k.extend_from_slice(&(i as u32).to_be_bytes()); // unique
                    (k, gen_bytes(rng, 0..=40))
                })
                .collect();
            rows.sort();
            let t = build(&rows, &format!("prop{}.btree", rng.next_u32()));
            let mut r2 = Rng::new(1);
            for _ in 0..20.min(n) {
                let (k, v) = &rows[r2.gen_range_usize(n)];
                prop_assert_eq(t.get(k).unwrap(), Some(v.clone()), "lookup")?;
            }
            Ok(())
        });
    }
}
