//! The streaming format — Dataset Grouper's core contribution (§3.1).
//!
//! Groups live contiguously inside TFRecord shards (the pipeline's
//! group-by-key paid that cost once). Reading then restricts itself to
//! stream-level operations, in exchange for sequential I/O and
//! total-iteration time that scales linearly in the number of groups:
//!
//! * **interleave(cycle)** — round-robin across shards at group
//!   granularity, like `tf.data.interleave` over per-shard group streams;
//! * **buffered shuffle(B)** — a fixed-size buffer of *group handles*
//!   (index extents, not data!) sampled uniformly, exactly tf.data's
//!   `shuffle` lifted to the group stream — arbitrary access is never
//!   required;
//! * **repeat(n | forever)** — re-iteration for multi-epoch training;
//! * **prefetch** — a background thread reads upcoming group extents
//!   (raw framed bytes) into a bounded channel, overlapping I/O with
//!   consumer compute.
//!
//! A yielded [`StreamedGroup`] decodes its examples lazily; extents larger
//! than `prefetch_cap_bytes` bypass prefetch and stream straight from the
//! file so a pathological group never has to fit in memory.

use std::collections::{HashMap, VecDeque};
use std::io::BufReader;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::pipeline::{GroupIndex, GroupIndexEntry};
use crate::records::sharded::discover_shards_with;
use crate::records::tfrecord::RecordReader;
use crate::records::Example;
use crate::store::vfs::{OpenMode, StdVfs, Vfs, VfsCursor, VfsFile};
use crate::util::rng::Rng;

/// Stream construction options.
#[derive(Debug, Clone)]
pub struct StreamingConfig {
    /// Shards cycled per interleave round.
    pub interleave: usize,
    /// Buffered-shuffle size over group handles (0 or 1 = no shuffle).
    pub shuffle_buffer: usize,
    pub seed: u64,
    /// Number of passes over the group stream (None = infinite repeat).
    pub repeats: Option<usize>,
    /// Groups prefetched ahead of the consumer.
    pub prefetch_groups: usize,
    /// Extents above this size bypass prefetch and stream from the file.
    pub prefetch_cap_bytes: u64,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            interleave: 4,
            shuffle_buffer: 64,
            seed: 0,
            repeats: Some(1),
            prefetch_groups: 8,
            prefetch_cap_bytes: 32 << 20,
        }
    }
}

impl StreamingConfig {
    /// Plain sequential single-pass read (Table 3's serial iteration).
    pub fn sequential() -> Self {
        StreamingConfig { shuffle_buffer: 0, ..Default::default() }
    }
}

/// One group pulled from the stream; decodes examples lazily.
pub struct StreamedGroup {
    pub key: Vec<u8>,
    pub num_examples: u64,
    pub words: u64,
    source: GroupSource,
}

enum GroupSource {
    /// Raw framed bytes of the whole extent (prefetched).
    Buffer(Vec<u8>),
    /// Large extent: positioned reader + remaining record count.
    File { reader: RecordReader<BufReader<VfsCursor>>, remaining: u64 },
}

impl StreamedGroup {
    /// Build a prefetched group from already-framed record bytes (the
    /// standard TFRecord framing of each example's encoding, one after
    /// another). This is how the paged formats hand a group to the
    /// client-data pipeline: `ShardedPagedReader` re-frames a group's
    /// examples into one buffer and the trainer consumes it exactly like
    /// a streamed group.
    pub fn from_framed_bytes(
        key: Vec<u8>,
        num_examples: u64,
        words: u64,
        framed: Vec<u8>,
    ) -> StreamedGroup {
        StreamedGroup { key, num_examples, words, source: GroupSource::Buffer(framed) }
    }

    /// The group's raw framed bytes, when the group was prefetched into
    /// one buffer ([`StreamedGroup::from_framed_bytes`] — every paged,
    /// gindex and remote read). `None` for the large-extent
    /// positioned-reader form. The store server ([`crate::serve`]) uses
    /// this to put a group on the wire without decoding it.
    pub fn framed_bytes(&self) -> Option<&[u8]> {
        match &self.source {
            GroupSource::Buffer(b) => Some(b),
            GroupSource::File { .. } => None,
        }
    }

    /// Visit each example in order; stop early by returning `false`.
    pub fn for_each_example(&mut self, mut f: impl FnMut(Example) -> bool) -> Result<()> {
        match &mut self.source {
            GroupSource::Buffer(bytes) => {
                let mut r = RecordReader::new(&bytes[..]);
                let mut buf = Vec::new();
                while r.read_into(&mut buf)? {
                    if !f(Example::decode(&buf)?) {
                        break;
                    }
                }
            }
            GroupSource::File { reader, remaining } => {
                let mut buf = Vec::new();
                while *remaining > 0 {
                    if !reader.read_into(&mut buf)? {
                        anyhow::bail!("shard truncated mid-group");
                    }
                    *remaining -= 1;
                    if !f(Example::decode(&buf)?) {
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    /// Collect all examples (tests / small groups).
    pub fn examples(&mut self) -> Result<Vec<Example>> {
        let mut out = Vec::new();
        self.for_each_example(|e| {
            out.push(e);
            true
        })?;
        Ok(out)
    }
}

/// The open streaming dataset.
pub struct StreamingDataset {
    vfs: Arc<dyn Vfs>,
    shards: Vec<PathBuf>,
    index: GroupIndex,
    config: StreamingConfig,
}

impl StreamingDataset {
    /// Open a pipeline materialization on the real filesystem.
    pub fn open(dir: &Path, prefix: &str, config: StreamingConfig) -> Result<Self> {
        Self::open_with(Arc::new(StdVfs), dir, prefix, config)
    }

    /// [`StreamingDataset::open`] with every file — shards and the
    /// `.gindex` sidecar — served by an explicit [`Vfs`].
    pub fn open_with(
        vfs: Arc<dyn Vfs>,
        dir: &Path,
        prefix: &str,
        config: StreamingConfig,
    ) -> Result<Self> {
        let mut index =
            GroupIndex::read_with(vfs.as_ref(), &dir.join(format!("{prefix}.gindex")))
                .with_context(|| format!("opening streaming dataset {prefix}"))?;
        index.sort_physical();
        let shards = discover_shards_with(vfs.as_ref(), dir, prefix)?;
        Ok(StreamingDataset { vfs, shards, index, config })
    }

    pub fn num_groups(&self) -> usize {
        self.index.num_groups()
    }

    pub fn total_examples(&self) -> u64 {
        self.index.total_examples()
    }

    pub fn index(&self) -> &GroupIndex {
        &self.index
    }

    /// The interleaved + buffer-shuffled order of group handles for one
    /// epoch. Pure function of (index, config, epoch).
    fn epoch_order(&self, epoch: usize) -> Vec<usize> {
        // Per-shard queues in physical order.
        let nshards = self.shards.len();
        let mut per_shard: Vec<VecDeque<usize>> = vec![VecDeque::new(); nshards];
        for (i, e) in self.index.entries.iter().enumerate() {
            per_shard[e.shard as usize].push_back(i);
        }
        // Interleave: cycle over `interleave` open shards, one group each.
        let mut interleaved = Vec::with_capacity(self.index.num_groups());
        let cycle = self.config.interleave.max(1);
        let mut open: VecDeque<usize> = (0..nshards).collect();
        let mut active: VecDeque<usize> = VecDeque::new();
        while !open.is_empty() || !active.is_empty() {
            while active.len() < cycle && !open.is_empty() {
                active.push_back(open.pop_front().unwrap());
            }
            let Some(s) = active.pop_front() else { break };
            if let Some(g) = per_shard[s].pop_front() {
                interleaved.push(g);
                active.push_back(s);
            } // else: shard exhausted, drop from rotation
        }
        // Buffered shuffle over handles.
        let b = self.config.shuffle_buffer;
        if b <= 1 {
            return interleaved;
        }
        let mut rng = Rng::new(self.config.seed ^ (epoch as u64).wrapping_mul(0x9E37));
        let mut out = Vec::with_capacity(interleaved.len());
        let mut buf: Vec<usize> = Vec::with_capacity(b);
        for g in interleaved {
            buf.push(g);
            if buf.len() == b {
                let i = rng.gen_range_usize(buf.len());
                out.push(buf.swap_remove(i));
            }
        }
        while !buf.is_empty() {
            let i = rng.gen_range_usize(buf.len());
            out.push(buf.swap_remove(i));
        }
        out
    }

    /// Start the stream: spawns the prefetch thread, returns the iterator.
    pub fn stream(&self) -> GroupStream {
        let (tx, rx) = sync_channel::<Result<Prefetched>>(self.config.prefetch_groups.max(1));
        let vfs = self.vfs.clone();
        let shards = self.shards.clone();
        let entries = self.index.entries.clone();
        let config = self.config.clone();
        let orders: Vec<Vec<usize>> = match config.repeats {
            Some(n) => (0..n).map(|e| self.epoch_order(e)).collect(),
            None => Vec::new(), // generated on the fly below
        };
        let dataset_for_infinite = if config.repeats.is_none() {
            Some((self.index.clone(), self.shards.len()))
        } else {
            None
        };
        let this_config = config.clone();
        let handle = std::thread::spawn(move || {
            prefetch_loop(tx, vfs, shards, entries, orders, dataset_for_infinite, this_config)
        });
        GroupStream { rx, _handle: handle }
    }
}

struct Prefetched {
    entry: GroupIndexEntry,
    source: GroupSource,
}

fn prefetch_loop(
    tx: SyncSender<Result<Prefetched>>,
    vfs: Arc<dyn Vfs>,
    shards: Vec<PathBuf>,
    entries: Vec<GroupIndexEntry>,
    orders: Vec<Vec<usize>>,
    infinite: Option<(GroupIndex, usize)>,
    config: StreamingConfig,
) {
    // Persistent per-shard raw file handles: extents are read with
    // positioned reads (the VFS layer's `read_exact_at`), so no
    // per-group open/seek syscalls and no reader state to maintain
    // (§Perf L3-2: the previous implementation re-opened the shard file
    // for every group).
    let mut files: Vec<Option<Arc<dyn VfsFile>>> = (0..shards.len()).map(|_| None).collect();

    let mut fetch = |gi: usize| -> Result<Prefetched> {
        let e = &entries[gi];
        let shard = e.shard as usize;
        let file = match &mut files[shard] {
            Some(f) => f,
            slot => {
                *slot = Some(vfs.open(&shards[shard], OpenMode::Read)?);
                slot.as_mut().unwrap()
            }
        };
        if e.bytes <= config.prefetch_cap_bytes {
            // Read the whole extent's framed bytes in one positioned read.
            let mut raw = vec![0u8; e.bytes as usize];
            file.read_exact_at(&mut raw, e.offset)
                .map_err(|err| anyhow::anyhow!("shard truncated mid-extent: {err}"))?;
            Ok(Prefetched { entry: e.clone(), source: GroupSource::Buffer(raw) })
        } else {
            // Too large to buffer: hand the consumer its own positioned reader.
            let mut r = RecordReader::new(BufReader::new(VfsCursor::new(file.clone())));
            r.seek_to(e.offset)?;
            Ok(Prefetched {
                entry: e.clone(),
                source: GroupSource::File { reader: r, remaining: e.num_examples },
            })
        }
    };

    match infinite {
        None => {
            for order in orders {
                for gi in order {
                    let item = fetch(gi);
                    let failed = item.is_err();
                    if tx.send(item).is_err() || failed {
                        return; // consumer dropped or error delivered
                    }
                }
            }
        }
        Some((index, _nshards)) => {
            // Infinite repeat: regenerate each epoch's order lazily.
            let ds = StreamingDataset {
                vfs: vfs.clone(),
                shards: shards.clone(),
                index,
                config: config.clone(),
            };
            let mut epoch = 0usize;
            loop {
                for gi in ds.epoch_order(epoch) {
                    let item = fetch(gi);
                    let failed = item.is_err();
                    if tx.send(item).is_err() || failed {
                        return;
                    }
                }
                epoch += 1;
            }
        }
    }
}

/// Random access over a streaming materialization: the `.gindex`
/// sidecar already maps every group key to a (shard, offset, bytes)
/// extent, so one positioned read serves any group without walking the
/// stream. This is the trainer-facing "streaming-gindex" backend of the
/// `ClientSource` abstraction (`crate::fed::source`): same files as
/// [`StreamingDataset`], arbitrary-order group fetches instead of
/// stream-order iteration.
///
/// Thread-safe: shard file handles are opened lazily (under a mutex)
/// and all reads are positional, so concurrent fetches never contend on
/// a seek cursor. Whole extents are buffered per fetch — there is no
/// large-group file fallback here, matching the paged backends'
/// re-framed-buffer behavior.
pub struct GindexSource {
    vfs: Arc<dyn Vfs>,
    shards: Vec<PathBuf>,
    /// Lazily opened positional handles, one slot per shard.
    files: Mutex<Vec<Option<Arc<dyn VfsFile>>>>,
    by_key: HashMap<Vec<u8>, GroupIndexEntry>,
    /// Group keys in sorted (canonical) order.
    keys: Vec<Vec<u8>>,
    total_examples: u64,
}

impl GindexSource {
    /// Open `dir/<prefix>.gindex` (+ its TFRecord shards) on the real
    /// filesystem.
    ///
    /// # Errors
    /// A missing/corrupt group index, or a shard-discovery failure.
    pub fn open(dir: &Path, prefix: &str) -> Result<GindexSource> {
        GindexSource::open_with(Arc::new(StdVfs), dir, prefix)
    }

    /// [`GindexSource::open`] with every file served by an explicit
    /// [`Vfs`]. Shard files themselves are opened lazily on first
    /// fetch, so open cost is one index read + one directory listing.
    ///
    /// # Errors
    /// Same conditions as [`GindexSource::open`].
    pub fn open_with(vfs: Arc<dyn Vfs>, dir: &Path, prefix: &str) -> Result<GindexSource> {
        let index = GroupIndex::read_with(vfs.as_ref(), &dir.join(format!("{prefix}.gindex")))
            .with_context(|| format!("opening group index for {prefix}"))?;
        let shards = discover_shards_with(vfs.as_ref(), dir, prefix)?;
        let total_examples = index.total_examples();
        let mut keys: Vec<Vec<u8>> = index.entries.iter().map(|e| e.key.clone()).collect();
        keys.sort();
        let by_key: HashMap<Vec<u8>, GroupIndexEntry> =
            index.entries.into_iter().map(|e| (e.key.clone(), e)).collect();
        let files = Mutex::new(vec![None; shards.len()]);
        Ok(GindexSource { vfs, shards, files, by_key, keys, total_examples })
    }

    /// Distinct groups in the index.
    pub fn num_groups(&self) -> usize {
        self.keys.len()
    }

    /// Total examples across all groups.
    pub fn num_examples(&self) -> u64 {
        self.total_examples
    }

    /// Group keys in sorted order.
    pub fn keys(&self) -> &[Vec<u8>] {
        &self.keys
    }

    /// One group as a prefetched [`StreamedGroup`]: a single positioned
    /// read of the extent's framed bytes. `None` for an unknown group.
    ///
    /// # Errors
    /// A shard open/read failure, or an index entry whose shard number
    /// is out of range (corrupt sidecar).
    pub fn streamed_group(&self, key: &[u8]) -> Result<Option<StreamedGroup>> {
        let Some(e) = self.by_key.get(key) else {
            return Ok(None);
        };
        let shard = e.shard as usize;
        if shard >= self.shards.len() {
            anyhow::bail!("group index names shard {shard} but only {} exist", self.shards.len());
        }
        let file = {
            let mut files = self.files.lock().unwrap();
            match &files[shard] {
                Some(f) => Arc::clone(f),
                None => {
                    let f = self.vfs.open(&self.shards[shard], OpenMode::Read)?;
                    files[shard] = Some(Arc::clone(&f));
                    f
                }
            }
        };
        let mut raw = vec![0u8; e.bytes as usize];
        file.read_exact_at(&mut raw, e.offset)
            .map_err(|err| anyhow::anyhow!("shard truncated mid-extent: {err}"))?;
        Ok(Some(StreamedGroup::from_framed_bytes(e.key.clone(), e.num_examples, e.words, raw)))
    }
}

/// The consumer side: an iterator of [`StreamedGroup`]s.
pub struct GroupStream {
    rx: Receiver<Result<Prefetched>>,
    _handle: JoinHandle<()>,
}

impl Iterator for GroupStream {
    type Item = Result<StreamedGroup>;

    fn next(&mut self) -> Option<Self::Item> {
        match self.rx.recv() {
            Err(_) => None, // prefetcher finished
            Ok(Err(e)) => Some(Err(e)),
            Ok(Ok(p)) => Some(Ok(StreamedGroup {
                key: p.entry.key,
                num_examples: p.entry.num_examples,
                words: p.entry.words,
                source: p.source,
            })),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{BaseDataset, DatasetSpec, SyntheticTextDataset};
    use crate::pipeline::{run_partition, FeatureKey, PartitionOptions};

    fn materialize(name: &str, groups: usize) -> (PathBuf, SyntheticTextDataset) {
        let dir = std::env::temp_dir().join("grouper_streaming_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::fedccnews_mini(groups, 21);
        spec.max_group_words = 1200;
        let ds = SyntheticTextDataset::new(spec);
        run_partition(
            &ds,
            &FeatureKey::new("domain"),
            &dir,
            "s",
            &PartitionOptions { num_shards: 4, num_workers: 2, ..Default::default() },
        )
        .unwrap();
        (dir, ds)
    }

    #[test]
    fn sequential_stream_covers_everything_once() {
        let (dir, ds) = materialize("cover", 20);
        let sd = StreamingDataset::open(&dir, "s", StreamingConfig::sequential()).unwrap();
        assert_eq!(sd.num_groups(), 20);
        let mut seen_groups = 0;
        let mut seen_examples = 0u64;
        for g in sd.stream() {
            let mut g = g.unwrap();
            seen_groups += 1;
            g.for_each_example(|_| {
                seen_examples += 1;
                true
            })
            .unwrap();
        }
        assert_eq!(seen_groups, 20);
        assert_eq!(seen_examples as usize, ds.len());
    }

    #[test]
    fn group_contents_match_oracle() {
        let (dir, ds) = materialize("oracle", 12);
        let sd = StreamingDataset::open(&dir, "s", StreamingConfig::sequential()).unwrap();
        let mut by_key: std::collections::HashMap<Vec<u8>, Vec<Vec<u8>>> = Default::default();
        for g in sd.stream() {
            let mut g = g.unwrap();
            let key = g.key.clone();
            let ex = g.examples().unwrap();
            by_key.insert(key, ex.into_iter().map(|e| e.encode()).collect());
        }
        for gi in 0..12 {
            let key = ds.spec.group_key(gi).into_bytes();
            let want: Vec<_> = ds.group_examples_iter(gi).map(|e| e.encode()).collect();
            assert_eq!(by_key.get(&key).unwrap(), &want, "group {gi}");
        }
    }

    #[test]
    fn shuffle_is_permutation_and_seed_dependent() {
        let (dir, _) = materialize("shuffle", 30);
        let order_with = |seed| {
            let cfg = StreamingConfig { shuffle_buffer: 8, seed, ..Default::default() };
            let sd = StreamingDataset::open(&dir, "s", cfg).unwrap();
            sd.stream().map(|g| g.unwrap().key).collect::<Vec<_>>()
        };
        let a = order_with(1);
        let b = order_with(1);
        let c = order_with(2);
        assert_eq!(a, b, "same seed must reproduce");
        assert_ne!(a, c, "different seeds must differ");
        let mut sa = a.clone();
        let mut sc = c.clone();
        sa.sort();
        sc.sort();
        assert_eq!(sa, sc, "shuffle must be a permutation");
        assert_eq!(a.len(), 30);
    }

    #[test]
    fn repeats_multiply_the_stream() {
        let (dir, _) = materialize("repeat", 10);
        let cfg = StreamingConfig { repeats: Some(3), shuffle_buffer: 4, ..Default::default() };
        let sd = StreamingDataset::open(&dir, "s", cfg).unwrap();
        let keys: Vec<_> = sd.stream().map(|g| g.unwrap().key).collect();
        assert_eq!(keys.len(), 30);
        let mut counts: std::collections::HashMap<&Vec<u8>, usize> = Default::default();
        for k in &keys {
            *counts.entry(k).or_default() += 1;
        }
        assert!(counts.values().all(|&c| c == 3));
    }

    #[test]
    fn early_drop_of_stream_is_clean() {
        let (dir, _) = materialize("drop", 20);
        let sd = StreamingDataset::open(&dir, "s", StreamingConfig::sequential()).unwrap();
        let mut stream = sd.stream();
        let _first = stream.next().unwrap().unwrap();
        drop(stream); // prefetcher must exit without panicking
    }

    #[test]
    fn early_stop_within_group() {
        let (dir, _) = materialize("stop", 8);
        let sd = StreamingDataset::open(&dir, "s", StreamingConfig::sequential()).unwrap();
        for g in sd.stream() {
            let mut g = g.unwrap();
            let mut n = 0;
            g.for_each_example(|_| {
                n += 1;
                n < 2 // stop after 2
            })
            .unwrap();
            assert!(n <= 2);
        }
    }

    #[test]
    fn large_extents_use_file_fallback() {
        let (dir, ds) = materialize("fallback", 10);
        let cfg = StreamingConfig {
            prefetch_cap_bytes: 64, // force the File path for all groups
            shuffle_buffer: 0,
            ..Default::default()
        };
        let sd = StreamingDataset::open(&dir, "s", cfg).unwrap();
        let mut total = 0u64;
        for g in sd.stream() {
            let mut g = g.unwrap();
            assert!(matches!(g.source, GroupSource::File { .. }));
            g.for_each_example(|_| {
                total += 1;
                true
            })
            .unwrap();
        }
        assert_eq!(total as usize, ds.len());
    }

    #[test]
    fn infinite_repeat_streams_beyond_one_epoch() {
        let (dir, _) = materialize("inf", 6);
        let cfg = StreamingConfig { repeats: None, shuffle_buffer: 3, ..Default::default() };
        let sd = StreamingDataset::open(&dir, "s", cfg).unwrap();
        let keys: Vec<_> = sd.stream().take(20).map(|g| g.unwrap().key).collect();
        assert_eq!(keys.len(), 20);
    }

    #[test]
    fn interleave_mixes_shards() {
        let (dir, _) = materialize("interleave", 40);
        let cfg = StreamingConfig { interleave: 4, shuffle_buffer: 0, ..Default::default() };
        let sd = StreamingDataset::open(&dir, "s", cfg).unwrap();
        // Map keys back to shards via the index.
        let shard_of: std::collections::HashMap<Vec<u8>, u32> = sd
            .index()
            .entries
            .iter()
            .map(|e| (e.key.clone(), e.shard))
            .collect();
        let shards_in_order: Vec<u32> = sd
            .stream()
            .map(|g| shard_of[&g.unwrap().key])
            .collect();
        // The first few items must not all come from one shard.
        let head: std::collections::HashSet<u32> =
            shards_in_order.iter().take(4).copied().collect();
        assert!(head.len() >= 2, "no interleaving: {shards_in_order:?}");
    }
}
