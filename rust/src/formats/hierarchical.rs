//! The hierarchical format: arbitrary group access over an on-disk store,
//! TFF-style (the paper: "TensorFlow Federated uses SQL databases to both
//! store and access client datasets ... constructing an arbitrary group's
//! dataset can be slow, as it is often bottlenecked by indexing and
//! searching over a large number of (possibly distributed) files").
//!
//! Reproduced cost model, faithfully:
//!
//! * examples are stored in *arrival order*, scattered round-robin across
//!   shards (prep is trivially cheap — that's the format's appeal);
//! * the index is an on-disk paged **B-tree** ([`super::btree_index`],
//!   the SQLite-row analogue: one row per example keyed by
//!   `group_key \0 seq`), NOT a resident hash map;
//! * constructing one group's dataset = descend the B-tree + range-scan
//!   leaf pages (real page I/O per query) + one random data-shard read
//!   per example.
//!
//! This is exactly what makes Table 3's hierarchical column degrade with
//! example count while Table 12's memory stays tiny.

use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::btree_index::{BTreeBuilder, BTreeFile};
use crate::corpus::BaseDataset;
use crate::pipeline::Partitioner;
use crate::records::sharded::{discover_shards_with, shard_name};
use crate::records::tfrecord::{RecordReader, RecordWriter};
use crate::records::Example;
use crate::store::vfs::{OpenMode, StdVfs, Vfs, VfsCursor, VfsFile};

/// Builder: materialize a base dataset into the hierarchical layout.
pub struct HierarchicalStore;

impl HierarchicalStore {
    /// Write `<prefix>-*.tfrecord` (arrival order, round-robin),
    /// `<prefix>.btree` (example index) and `<prefix>.hgroups` (group key
    /// list) on the real filesystem. Single-threaded: the format's cost
    /// lives at read time.
    pub fn build(
        dataset: &dyn BaseDataset,
        partitioner: &dyn Partitioner,
        dir: &Path,
        prefix: &str,
        num_shards: usize,
    ) -> Result<usize> {
        Self::build_with(&StdVfs, dataset, partitioner, dir, prefix, num_shards)
    }

    /// [`HierarchicalStore::build`] on an explicit [`Vfs`].
    pub fn build_with(
        vfs: &dyn Vfs,
        dataset: &dyn BaseDataset,
        partitioner: &dyn Partitioner,
        dir: &Path,
        prefix: &str,
        num_shards: usize,
    ) -> Result<usize> {
        assert!(num_shards > 0);
        vfs.create_dir_all(dir)?;
        let mut writers: Vec<RecordWriter<BufWriter<VfsCursor>>> = (0..num_shards)
            .map(|i| -> io::Result<RecordWriter<BufWriter<VfsCursor>>> {
                let path = dir.join(shard_name(prefix, i, num_shards));
                let file = vfs.open(&path, OpenMode::CreateTruncate)?;
                Ok(RecordWriter::new(BufWriter::new(VfsCursor::new(file))))
            })
            .collect::<io::Result<Vec<_>>>()?;
        let mut per_group_seq: HashMap<Vec<u8>, u64> = HashMap::new();
        let mut order: Vec<Vec<u8>> = Vec::new();
        let mut rows: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
        let mut next = 0usize;
        let mut n = 0usize;
        for ex in dataset.examples() {
            let key = partitioner.key(&ex);
            let shard = next;
            next = (next + 1) % num_shards;
            let offset = writers[shard].bytes_written();
            writers[shard].write_record(&ex.encode())?;
            let seq = per_group_seq.entry(key.clone()).or_insert_with(|| {
                order.push(key.clone());
                0
            });
            rows.push((row_key(&key, *seq), row_value(shard as u32, offset)));
            *seq += 1;
            n += 1;
        }
        for w in &mut writers {
            w.flush()?;
        }
        // Bulk-load the B-tree (rows must be sorted by key).
        rows.sort();
        let mut builder = BTreeBuilder::new();
        for (k, v) in rows {
            builder
                .push(k, v)
                .context("indexing example (group key too long for a page?)")?;
        }
        builder.write_with(vfs, &dir.join(format!("{prefix}.btree")))?;
        // Group key list (for enumeration; a DB would SELECT DISTINCT).
        let hgroups = vfs.open(&dir.join(format!("{prefix}.hgroups")), OpenMode::CreateTruncate)?;
        let mut f = BufWriter::new(VfsCursor::new(hgroups));
        for key in &order {
            f.write_all(&(key.len() as u32).to_le_bytes())?;
            f.write_all(key)?;
        }
        f.flush()?;
        Ok(n)
    }
}

fn row_key(group: &[u8], seq: u64) -> Vec<u8> {
    let mut k = Vec::with_capacity(group.len() + 9);
    k.extend_from_slice(group);
    k.push(0);
    k.extend_from_slice(&seq.to_be_bytes()); // big-endian: sorts in order
    k
}

fn row_value(shard: u32, offset: u64) -> Vec<u8> {
    let mut v = Vec::with_capacity(12);
    v.extend_from_slice(&shard.to_le_bytes());
    v.extend_from_slice(&offset.to_le_bytes());
    v
}

/// Reader: B-tree-indexed arbitrary group access. `Send + Sync` — the
/// index reads through the concurrent [`crate::store::shared::SharedPager`]
/// and every query opens its own shard cursors, so threads can construct
/// different groups' datasets through one shared reader.
pub struct HierarchicalReader {
    /// One shared positional handle per shard; each query layers its own
    /// cursors on top.
    shards: Vec<Arc<dyn VfsFile>>,
    btree: BTreeFile,
    keys: Vec<Vec<u8>>,
}

impl HierarchicalReader {
    /// Open with the default (deliberately tiny) index cache.
    pub fn open(dir: &Path, prefix: &str) -> Result<Self> {
        Self::open_with_cache(dir, prefix, super::btree_index::DEFAULT_CACHE_PAGES)
    }

    /// Open with an explicit index LRU cache size (pages): the knob that
    /// used to be hardcoded to root-only caching. The index now reads
    /// through the shared pager ([`crate::store::shared::SharedPager`]).
    pub fn open_with_cache(dir: &Path, prefix: &str, cache_pages: usize) -> Result<Self> {
        Self::open_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// [`HierarchicalReader::open_with_cache`] on an explicit [`Vfs`].
    pub fn open_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<Self> {
        let shards = discover_shards_with(vfs, dir, prefix)?
            .into_iter()
            .map(|p| vfs.open(&p, OpenMode::Read))
            .collect::<io::Result<Vec<_>>>()?;
        let btree = BTreeFile::open_with(vfs, &dir.join(format!("{prefix}.btree")), cache_pages)
            .with_context(|| format!("opening {prefix}.btree"))?;
        let raw = vfs.read(&dir.join(format!("{prefix}.hgroups")))?;
        let mut keys = Vec::new();
        let mut pos = 0usize;
        while pos < raw.len() {
            if pos + 4 > raw.len() {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "truncated hgroups length",
                )
                .into());
            }
            let klen = u32::from_le_bytes(raw[pos..pos + 4].try_into().unwrap()) as usize;
            pos += 4;
            if pos + klen > raw.len() {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "truncated hgroups key",
                )
                .into());
            }
            keys.push(raw[pos..pos + klen].to_vec());
            pos += klen;
        }
        Ok(HierarchicalReader { shards, btree, keys })
    }

    pub fn num_groups(&self) -> usize {
        self.keys.len()
    }

    pub fn keys(&self) -> &[Vec<u8>] {
        &self.keys
    }

    /// Index page fetches so far (cost introspection).
    pub fn pages_read(&self) -> u64 {
        self.btree.pages_read()
    }

    /// Index cache hit/miss/eviction counters.
    pub fn index_cache_stats(&self) -> crate::store::cache::CacheStats {
        self.btree.cache_stats()
    }

    /// Construct one group's dataset: a B-tree range query for the
    /// locations, then one random shard read per example — the format's
    /// cost model.
    pub fn visit_group(&self, key: &[u8], mut f: impl FnMut(Example)) -> Result<bool> {
        let mut prefix = Vec::with_capacity(key.len() + 1);
        prefix.extend_from_slice(key);
        prefix.push(0);
        let mut locs: Vec<(u32, u64)> = Vec::new();
        self.btree.scan_prefix(&prefix, |_k, v| {
            let shard = u32::from_le_bytes(v[0..4].try_into().unwrap());
            let offset = u64::from_le_bytes(v[4..12].try_into().unwrap());
            locs.push((shard, offset));
        })?;
        if locs.is_empty() {
            return Ok(false);
        }
        // A fresh reader per shard per query (a DB "cursor"); re-seeked per
        // example because arrival order scatters them.
        let mut readers: HashMap<u32, RecordReader<BufReader<VfsCursor>>> = HashMap::new();
        for (shard, offset) in locs {
            let r = match readers.entry(shard) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(e) => e.insert(RecordReader::new(
                    BufReader::new(VfsCursor::new(self.shards[shard as usize].clone())),
                )),
            };
            r.seek_to(offset)?;
            let bytes = r.next_record()?.context("btree points past shard end")?;
            f(Example::decode(&bytes)?);
        }
        Ok(true)
    }

    /// Iterate all groups in `order` (Table 3's serial random-order walk).
    pub fn visit_all(&self, order: &[Vec<u8>], mut f: impl FnMut(&[u8], Example)) -> Result<()> {
        for key in order {
            self.visit_group(key, |ex| f(key, ex))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, SyntheticTextDataset};
    use crate::pipeline::FeatureKey;
    use std::path::PathBuf;

    fn build() -> (PathBuf, SyntheticTextDataset) {
        let dir = std::env::temp_dir().join("grouper_hier_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::fedccnews_mini(15, 9);
        spec.max_group_words = 1500;
        let ds = SyntheticTextDataset::new(spec);
        let n = HierarchicalStore::build(&ds, &FeatureKey::new("domain"), &dir, "news", 4).unwrap();
        assert_eq!(n, ds.len());
        (dir, ds)
    }

    #[test]
    fn group_contents_match_oracle() {
        let (dir, ds) = build();
        let r = HierarchicalReader::open(&dir, "news").unwrap();
        assert_eq!(r.num_groups(), 15);
        for g in 0..15 {
            let key = ds.spec.group_key(g).into_bytes();
            let mut got = Vec::new();
            assert!(r.visit_group(&key, |ex| got.push(ex.encode())).unwrap());
            let want: Vec<_> = ds.group_examples_iter(g).map(|e| e.encode()).collect();
            assert_eq!(got, want, "group {g}");
        }
    }

    #[test]
    fn missing_group_returns_false() {
        let (dir, _) = build();
        let r = HierarchicalReader::open(&dir, "news").unwrap();
        assert!(!r.visit_group(b"not-there", |_| {}).unwrap());
    }

    #[test]
    fn visit_all_respects_order_and_coverage() {
        let (dir, ds) = build();
        let r = HierarchicalReader::open(&dir, "news").unwrap();
        let mut order = r.keys().to_vec();
        order.reverse();
        let mut seen_keys = Vec::new();
        let mut count = 0;
        r.visit_all(&order, |k, _| {
            if seen_keys.last().map(|l: &Vec<u8>| l.as_slice()) != Some(k) {
                seen_keys.push(k.to_vec());
            }
            count += 1;
        })
        .unwrap();
        assert_eq!(count, ds.len());
        assert_eq!(seen_keys, order);
    }

    #[test]
    fn queries_pay_index_page_io() {
        // Enough groups/examples for a multi-page tree, so group queries
        // must fetch non-root pages.
        let dir = std::env::temp_dir().join("grouper_hier_pages");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::fedccnews_mini(120, 9);
        spec.max_group_words = 4000;
        let ds = SyntheticTextDataset::new(spec);
        HierarchicalStore::build(&ds, &FeatureKey::new("domain"), &dir, "big", 4).unwrap();
        let r = HierarchicalReader::open(&dir, "big").unwrap();
        let before = r.pages_read();
        for g in (0..120).step_by(17) {
            let key = ds.spec.group_key(g).into_bytes();
            r.visit_group(&key, |_| {}).unwrap();
        }
        assert!(r.pages_read() > before, "group queries did no page I/O");
    }

    #[test]
    fn group_key_is_not_a_prefix_trap() {
        // A group whose key is a prefix of another must not absorb the
        // longer key's rows (the \0 separator guarantees it).
        let dir = std::env::temp_dir().join("grouper_hier_prefix");
        let _ = std::fs::remove_dir_all(&dir);
        struct Two;
        impl crate::corpus::BaseDataset for Two {
            fn name(&self) -> &str {
                "two"
            }
            fn examples(&self) -> Box<dyn Iterator<Item = Example> + Send> {
                Box::new(
                    vec![
                        Example::text("one").with(
                            "domain",
                            crate::records::Feature::bytes_one(b"ab".to_vec()),
                        ),
                        Example::text("two").with(
                            "domain",
                            crate::records::Feature::bytes_one(b"abc".to_vec()),
                        ),
                    ]
                    .into_iter(),
                )
            }
            fn len(&self) -> usize {
                2
            }
        }
        HierarchicalStore::build(&Two, &FeatureKey::new("domain"), &dir, "p", 2).unwrap();
        let r = HierarchicalReader::open(&dir, "p").unwrap();
        let mut n = 0;
        r.visit_group(b"ab", |ex| {
            assert_eq!(ex.get_str("text"), Some("one"));
            n += 1;
        })
        .unwrap();
        assert_eq!(n, 1);
    }
}
