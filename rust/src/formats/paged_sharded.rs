//! Sharded paged stores: S independent [`PagedStore`]s behind one
//! group-addressed surface — the write-path scaling step the single
//! store cannot take.
//!
//! The paged engine is crash-safe and concurrently *readable*, but its
//! WAL serializes writers: one live [`PagedStore`] per store, by
//! contract. Materializing a large dataset through one WAL is therefore
//! the last serial stage of the pipeline, even though partitioning
//! itself is embarrassingly parallel. This module removes it by
//! **hash-sharding group keys across S stores**:
//!
//! * [`shard_of_key`] places every group on exactly one shard (FNV-1a of
//!   the group key, optionally reseeded, mod S) — the same function the
//!   partition runner uses for its group-by-key buckets, so when the
//!   output format is paged, each bucket's merge appends *straight into
//!   its own shard's store*, concurrently, with no intermediate TFRecord
//!   pass (see [`crate::pipeline::run_partition_paged`]);
//! * each shard is a complete, independent [`PagedStore`] — own pager,
//!   WAL, free list, and checkpoint epochs — so every crash-safety and
//!   snapshot invariant of the engine holds *per shard*, unchanged (a
//!   single-shard set is byte-identical to a plain store);
//! * a `.pset` manifest ([`PagedSetManifest`], CRC-framed) records the
//!   shard count, hash seed, per-shard prefixes and last published
//!   epochs, so a reader can discover and pin the whole set;
//! * [`ShardedPagedReader`] opens one snapshot per shard (each its own
//!   `SharedPager` + epoch pin, exactly like [`PagedReader`]) and
//!   exposes the same group surface — `visit_group`, `visit_all`,
//!   `keys` — routing by the manifest's hash placement.
//!
//! **Single live writer per shard.** The engine's single-live-writer
//! contract is unchanged; it just applies shard-locally. S bucket
//! writers appending to S *different* shards are fine (that is the whole
//! point); two writers on one shard are not — same rule as one store,
//! multiplied. The manifest itself is only written by the set's owner —
//! at checkpoint/compact, never at bare create, so an abandoned
//! materialization is not discoverable — and crash-safely despite the
//! VFS having no rename: a `.pset2` sidecar is written and synced
//! before the primary is rewritten in place, reads fall back to it when
//! the primary is torn (checksum-detected), and the shards underneath
//! stay intact and recoverable at every crash point.
//!
//! Cache accounting is **per shard**: every `cache_pages` parameter here
//! sizes each shard's LRU independently (an S-shard set holds up to
//! `S * cache_pages` frames), keeping shard behavior identical to a
//! standalone store at the same setting.

#![deny(missing_docs)]

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::formats::paged::{CompactReport, PagedReader, PagedStat, PagedStore};
use crate::formats::streaming::StreamedGroup;
use crate::records::crc32c::crc32c;
use crate::records::Example;
use crate::store::cache::CacheStats;
use crate::store::shared::ReadOpts;
use crate::store::vfs::{OpenMode, StdVfs, Vfs};
use crate::util::rng::fnv1a;
use crate::util::threadpool::parallel_for_each_mut;

/// `.pset` manifest magic (version 1).
const MAGIC: &[u8; 8] = b"GRPPSET1";

/// The shard a group key lives on: FNV-1a of the key (reseeded through a
/// SplitMix64 finalizer when `hash_seed != 0`), mod `shards`. Seed 0 is
/// the default and matches the partition runner's historical bucket
/// placement (`fnv1a(key) % shards`) exactly.
pub fn shard_of_key(key: &[u8], hash_seed: u64, shards: usize) -> usize {
    debug_assert!(shards > 0);
    let mut h = fnv1a(key);
    if hash_seed != 0 {
        // SplitMix64 finalizer over the xor, so a seed reshuffles
        // placement without correlating with the unseeded layout.
        h ^= hash_seed;
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
    }
    (h % shards as u64) as usize
}

/// The store prefix of shard `index` in a set of `total`. A single-shard
/// set uses the plain prefix — its files are named (and laid out)
/// exactly like a standalone [`PagedStore`], so `--shards 1` stays
/// byte-identical to the unsharded path.
pub fn shard_prefix(prefix: &str, index: usize, total: usize) -> String {
    if total == 1 {
        prefix.to_string()
    } else {
        format!("{prefix}-s{index:05}-of-{total:05}")
    }
}

/// The shard-store prefixes a **previous** materialization at
/// `dir/<prefix>` left behind that a new layout keeping exactly `keep`
/// would orphan: the old manifest's shard prefixes (when a readable
/// copy exists), plus the bare `prefix` itself when a plain pre-`.pset`
/// store (`<prefix>.pstore`) sits there. Capture this **before**
/// overwriting the manifest, and hand it to [`truncate_shard_stores`]
/// only **after** the new set is fully materialized and published — the
/// VFS has no delete, and zeroing the old data any earlier would turn a
/// crash mid-materialization into data loss instead of a mere leak.
pub fn stale_shard_stores(vfs: &dyn Vfs, dir: &Path, prefix: &str, keep: &[String]) -> Vec<String> {
    let mut candidates: Vec<String> = match PagedSetManifest::read_with(vfs, dir, prefix) {
        Ok(old) => old.shard_prefixes,
        Err(_) => Vec::new(),
    };
    // A plain single store from before this prefix was a set (or from a
    // `--shards 1` run) is shadowed the moment a manifest points
    // elsewhere — count it too.
    if vfs.open(&dir.join(format!("{prefix}.pstore")), OpenMode::Read).is_ok() {
        candidates.push(prefix.to_string());
    }
    candidates.sort();
    candidates.dedup();
    candidates.retain(|p| !keep.contains(p));
    candidates
}

/// Invalidate the `.pset`/`.pset2` manifest copies at `dir/<prefix>`
/// (truncating them to empty, which the magic check rejects) **iff** the
/// old manifest names a store prefix the new layout is about to
/// overwrite in place. Rationale: when old and new shard layouts share
/// prefixes, store creation truncates the old data immediately — the
/// old manifest then describes wreckage, and leaving it published would
/// let readers silently serve a half-written set after a mid-
/// materialization crash. Invalidated, every open fails loudly ("bad
/// paged set manifest magic") until the new set publishes. When the
/// layouts share nothing, the old manifest is deliberately left intact:
/// its data is untouched, and a crash should leave the *old* set
/// discoverable.
/// Returns the old manifest when it was invalidated, so the caller can
/// republish it ([`restore_manifest_if_intact`]) if the rebuild fails
/// before destroying anything.
///
/// # Errors
/// Any truncate/sync failure on a manifest copy — callers must abort
/// the re-materialization then, because proceeding would destroy the
/// stores while the old manifest stays published (the exact silent-
/// wreckage window this function exists to close).
pub fn invalidate_overlapping_manifest(
    vfs: &dyn Vfs,
    dir: &Path,
    prefix: &str,
    keep: &[String],
) -> Result<Option<PagedSetManifest>> {
    let old = match PagedSetManifest::read_with(vfs, dir, prefix) {
        Ok(old) => old,
        Err(_) => return Ok(None),
    };
    if !old.shard_prefixes.iter().any(|p| keep.contains(p)) {
        return Ok(None);
    }
    for path in [PagedSetManifest::path(dir, prefix), PagedSetManifest::sidecar_path(dir, prefix)]
    {
        let f = vfs
            .open(&path, OpenMode::CreateTruncate)
            .with_context(|| format!("unpublishing superseded manifest {}", path.display()))?;
        f.sync().with_context(|| format!("syncing unpublished manifest {}", path.display()))?;
    }
    Ok(Some(old))
}

/// Best-effort republish of an [`invalidate_overlapping_manifest`]'d
/// manifest after a rebuild failed: only when every store it names
/// still looks intact (non-empty `.pstore` — store creation's first
/// destructive act is truncating exactly that file), so a transient
/// failure *before* any data was destroyed leaves the old set
/// discoverable again, while a failure after destruction began keeps
/// it unpublished (republishing would point readers at wreckage).
/// Returns whether the manifest was restored.
pub fn restore_manifest_if_intact(
    vfs: &dyn Vfs,
    dir: &Path,
    prefix: &str,
    old: &PagedSetManifest,
) -> bool {
    let intact = old.shard_prefixes.iter().all(|p| {
        vfs.open(&dir.join(format!("{p}.pstore")), OpenMode::Read)
            .and_then(|f| f.len())
            .map(|len| len > 0)
            .unwrap_or(false)
    });
    intact && old.write_with(vfs, dir, prefix).is_ok()
}

/// Truncate the named shard stores to empty stubs, reclaiming their
/// space (the closest thing to deletion the VFS offers). Call only with
/// prefixes from [`stale_shard_stores`], after the superseding set is
/// durable. A store whose `.pstore` still has live snapshot pins — in
/// the process-wide registry or as on-disk pin files from readers in
/// other processes ([`crate::store::pins`]) — is left untouched:
/// truncating it would yank pages out from under a pinned snapshot. It
/// is returned so the caller can retry once the pins drop. Best-effort
/// otherwise: a store that cannot be opened is skipped.
pub fn truncate_shard_stores(vfs: &dyn Vfs, dir: &Path, prefixes: &[String]) -> Vec<String> {
    let mut still_pinned = Vec::new();
    for stale in prefixes {
        let pstore = dir.join(format!("{stale}.pstore"));
        let key = vfs.registry_key(&pstore);
        let pinned_in_process = crate::store::shared::pin_count(vfs.instance_id(), &key) > 0;
        // An unreadable pin directory counts as pinned: fail toward
        // protecting readers we cannot see.
        let pinned_on_disk = vfs.instance_id() == 0
            && !matches!(crate::store::pins::scan_min(&key), Ok(None));
        if pinned_in_process || pinned_on_disk {
            still_pinned.push(stale.clone());
            continue;
        }
        for suffix in ["pstore", "pdata", "pwal"] {
            let path = dir.join(format!("{stale}.{suffix}"));
            if let Ok(f) = vfs.open(&path, OpenMode::CreateTruncate) {
                f.sync().ok();
            }
        }
    }
    still_pinned
}

/// The `.pset` manifest describing one sharded paged set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PagedSetManifest {
    /// Placement seed fed to [`shard_of_key`] (0 = plain FNV-1a).
    pub hash_seed: u64,
    /// Store prefix of each shard, in shard order (`shards()` long).
    pub shard_prefixes: Vec<String>,
    /// Last checkpoint epoch the owner published per shard. Advisory:
    /// a reader pins each shard's *live* epoch at open; these record
    /// what the set looked like when last written.
    pub epochs: Vec<u64>,
}

impl PagedSetManifest {
    /// Manifest path: `dir/<prefix>.pset`.
    pub fn path(dir: &Path, prefix: &str) -> PathBuf {
        dir.join(format!("{prefix}.pset"))
    }

    /// Sidecar path: `dir/<prefix>.pset2`, the second copy that makes
    /// the in-place primary rewrite crash-safe (see
    /// [`PagedSetManifest::write_with`]).
    pub fn sidecar_path(dir: &Path, prefix: &str) -> PathBuf {
        dir.join(format!("{prefix}.pset2"))
    }

    /// Shard count.
    pub fn shards(&self) -> usize {
        self.shard_prefixes.len()
    }

    /// Serialize: magic, shard count, hash seed, per-shard prefix +
    /// epoch, trailing CRC32C over everything preceding it.
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.shard_prefixes.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.hash_seed.to_le_bytes());
        for (prefix, epoch) in self.shard_prefixes.iter().zip(&self.epochs) {
            out.extend_from_slice(&(prefix.len() as u16).to_le_bytes());
            out.extend_from_slice(prefix.as_bytes());
            out.extend_from_slice(&epoch.to_le_bytes());
        }
        let crc = crc32c(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    fn decode(bytes: &[u8]) -> Result<PagedSetManifest> {
        if bytes.len() < 8 + 4 + 8 + 4 || &bytes[..8] != MAGIC {
            bail!("bad paged set manifest magic");
        }
        let body = &bytes[..bytes.len() - 4];
        let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        if crc32c(body) != stored {
            bail!("paged set manifest checksum mismatch (torn or corrupt .pset)");
        }
        let shards = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
        if shards == 0 {
            bail!("paged set manifest declares zero shards");
        }
        let hash_seed = u64::from_le_bytes(body[12..20].try_into().unwrap());
        let mut shard_prefixes = Vec::with_capacity(shards);
        let mut epochs = Vec::with_capacity(shards);
        let mut p = 20;
        for _ in 0..shards {
            if p + 2 > body.len() {
                bail!("paged set manifest truncated inside its shard table");
            }
            let len = u16::from_le_bytes(body[p..p + 2].try_into().unwrap()) as usize;
            p += 2;
            if len == 0 || p + len + 8 > body.len() {
                bail!("paged set manifest holds a malformed shard entry");
            }
            let prefix = std::str::from_utf8(&body[p..p + len])
                .map_err(|_| anyhow!("paged set manifest shard prefix is not UTF-8"))?;
            shard_prefixes.push(prefix.to_string());
            p += len;
            epochs.push(u64::from_le_bytes(body[p..p + 8].try_into().unwrap()));
            p += 8;
        }
        if p != body.len() {
            bail!("paged set manifest has trailing bytes");
        }
        Ok(PagedSetManifest { hash_seed, shard_prefixes, epochs })
    }

    /// Write the manifest durably: the sidecar copy (`<prefix>.pset2`)
    /// first, synced, then the primary (`<prefix>.pset`), synced. The
    /// VFS has no rename, so the primary is rewritten in place — the
    /// ordering guarantees a crash at any point leaves at least one
    /// valid CRC-framed copy on disk. That is sufficient because a
    /// set's identity (shard count, prefixes, hash seed) is immutable
    /// after create and the epochs are advisory: *either* copy
    /// discovers the set correctly, and the shards carry their own
    /// recovery story.
    ///
    /// # Errors
    /// Mismatched `shard_prefixes`/`epochs` lengths (the encoding would
    /// silently zip-truncate into an undecodable frame — refuse before
    /// overwriting a valid pair), or any open/write/sync failure.
    pub fn write_with(&self, vfs: &dyn Vfs, dir: &Path, prefix: &str) -> Result<()> {
        if self.epochs.len() != self.shard_prefixes.len() {
            bail!(
                "paged set manifest shape mismatch: {} shard prefixes vs {} epochs",
                self.shard_prefixes.len(),
                self.epochs.len()
            );
        }
        let bytes = self.encode();
        for path in [
            PagedSetManifest::sidecar_path(dir, prefix),
            PagedSetManifest::path(dir, prefix),
        ] {
            let file = vfs
                .open(&path, OpenMode::CreateTruncate)
                .with_context(|| format!("creating paged set manifest {}", path.display()))?;
            file.write_all_at(&bytes, 0)?;
            file.sync()?;
        }
        Ok(())
    }

    /// Read and validate the manifest from `vfs`: the primary
    /// `dir/<prefix>.pset`, falling back to the `.pset2` sidecar when
    /// the primary is missing or torn (a crash window of
    /// [`PagedSetManifest::write_with`]).
    ///
    /// # Errors
    /// `NotFound` (via the VFS) when no manifest exists at all;
    /// otherwise the primary's read/validation error when the sidecar
    /// cannot save it.
    pub fn read_with(vfs: &dyn Vfs, dir: &Path, prefix: &str) -> Result<PagedSetManifest> {
        let path = PagedSetManifest::path(dir, prefix);
        let primary = vfs
            .read(&path)
            .with_context(|| format!("reading paged set manifest {}", path.display()))
            .and_then(|bytes| {
                PagedSetManifest::decode(&bytes)
                    .with_context(|| format!("parsing paged set manifest {}", path.display()))
            });
        match primary {
            Ok(m) => Ok(m),
            Err(primary_err) => {
                let sidecar = PagedSetManifest::sidecar_path(dir, prefix);
                let fallback = vfs
                    .read(&sidecar)
                    .map_err(anyhow::Error::from)
                    .and_then(|bytes| PagedSetManifest::decode(&bytes));
                match fallback {
                    Ok(m) => Ok(m),
                    // The sidecar can't save it: report the primary's
                    // error, which names the canonical path.
                    Err(_) => Err(primary_err),
                }
            }
        }
    }

    /// True when a manifest copy (primary or sidecar) exists on `vfs`
    /// (readable at all — validation happens at
    /// [`PagedSetManifest::read_with`]).
    pub fn exists_with(vfs: &dyn Vfs, dir: &Path, prefix: &str) -> bool {
        vfs.open(&PagedSetManifest::path(dir, prefix), OpenMode::Read).is_ok()
            || vfs.open(&PagedSetManifest::sidecar_path(dir, prefix), OpenMode::Read).is_ok()
    }

    /// [`PagedSetManifest::exists_with`] on the real filesystem — the
    /// CLI's "is this a sharded set?" dispatch.
    pub fn exists(dir: &Path, prefix: &str) -> bool {
        PagedSetManifest::exists_with(&StdVfs, dir, prefix)
    }
}

/// The writing side of a sharded set: S open [`PagedStore`]s plus the
/// manifest that binds them. One live `PagedShardSet` per set (the
/// engine's single-live-writer contract, applied per shard).
pub struct PagedShardSet {
    vfs: Arc<dyn Vfs>,
    dir: PathBuf,
    prefix: String,
    hash_seed: u64,
    stores: Vec<PagedStore>,
    shard_prefixes: Vec<String>,
    /// Stores a previous layout at this `dir/prefix` left behind
    /// (captured at create, before the manifest overwrite); truncated
    /// by the first checkpoint — i.e. only once this set is durable.
    stale_prefixes: Vec<String>,
    /// When set, [`PagedShardSet::commit`] flushes every shard's WAL
    /// first and then runs the per-shard fsyncs in parallel (group
    /// commit) instead of strictly serializing flush+fsync per shard.
    group_commit: bool,
}

impl PagedShardSet {
    /// Create a fresh set of `shards` empty stores on the real
    /// filesystem. Like [`PagedShardSet::create_with`], the manifest is
    /// published by the first checkpoint, not here.
    ///
    /// # Errors
    /// Same conditions as [`PagedShardSet::create_with`].
    pub fn create(
        dir: &Path,
        prefix: &str,
        shards: usize,
        cache_pages: usize,
        hash_seed: u64,
    ) -> Result<PagedShardSet> {
        PagedShardSet::create_with(Arc::new(StdVfs), dir, prefix, shards, cache_pages, hash_seed)
    }

    /// Create a fresh set on `vfs`: `shards` empty stores, each with its
    /// own `cache_pages`-frame LRU. The `.pset` manifest is **not**
    /// written yet — the first [`PagedShardSet::checkpoint`] publishes
    /// it, so an abandoned creation never becomes discoverable.
    ///
    /// # Errors
    /// `shards == 0`, or any store-creation failure.
    pub fn create_with(
        vfs: Arc<dyn Vfs>,
        dir: &Path,
        prefix: &str,
        shards: usize,
        cache_pages: usize,
        hash_seed: u64,
    ) -> Result<PagedShardSet> {
        if shards == 0 {
            bail!("a paged shard set needs at least one shard");
        }
        let shard_prefixes: Vec<String> =
            (0..shards).map(|i| shard_prefix(prefix, i, shards)).collect();
        // Captured now (before the new manifest overwrites the old one),
        // truncated only at the first checkpoint — i.e. once the new
        // set's contents are durable. A crash in between leaks the old
        // bytes; truncating eagerly would *lose* them instead.
        let stale_prefixes = stale_shard_stores(vfs.as_ref(), dir, prefix, &shard_prefixes);
        // Creating a store truncates any same-named predecessor in
        // place: refuse while a live reader still pins one of those
        // snapshots (best-effort — the single-live-writer contract
        // already requires the embedding process to serialize writers
        // against reader opens, this just fails the common mistake
        // loudly instead of corrupting the reader).
        for sp in &shard_prefixes {
            let pstore = dir.join(format!("{sp}.pstore"));
            let key = vfs.registry_key(&pstore);
            if crate::store::shared::pin_count(vfs.instance_id(), &key) > 0 {
                bail!(
                    "cannot recreate shard store {sp}: a live reader still pins a snapshot \
                     of the store being overwritten"
                );
            }
        }
        // When the new layout reuses the old one's store names, the old
        // data is destroyed at store creation below — unpublish the old
        // manifest first so a crash mid-materialization cannot leave it
        // pointing at wreckage.
        let unpublished =
            invalidate_overlapping_manifest(vfs.as_ref(), dir, prefix, &shard_prefixes)?;
        let mut stores = Vec::with_capacity(shards);
        for sp in &shard_prefixes {
            match PagedStore::create_with(vfs.as_ref(), dir, sp, cache_pages) {
                Ok(store) => stores.push(store),
                Err(e) => {
                    // A failure before any old data was destroyed should
                    // leave the old set discoverable; the restore helper
                    // verifies that before republishing.
                    if let Some(old) = &unpublished {
                        restore_manifest_if_intact(vfs.as_ref(), dir, prefix, old);
                    }
                    return Err(e).with_context(|| format!("creating shard store {sp}"));
                }
            }
        }
        // Deliberately NO manifest write here: the `.pset` is what makes
        // the set discoverable, and publishing it before any data is
        // durable would let readers auto-detect (and silently serve) a
        // failed or in-progress materialization. The first
        // [`PagedShardSet::checkpoint`] — or the partition runner, after
        // its integrity checks pass — publishes it.
        Ok(PagedShardSet {
            vfs,
            dir: dir.to_path_buf(),
            prefix: prefix.to_string(),
            hash_seed,
            stores,
            shard_prefixes,
            stale_prefixes,
            group_commit: false,
        })
    }

    /// Open an existing set on the real filesystem for appending,
    /// running per-shard crash recovery.
    ///
    /// # Errors
    /// Same conditions as [`PagedShardSet::open_with`].
    pub fn open(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedShardSet> {
        PagedShardSet::open_with(Arc::new(StdVfs), dir, prefix, cache_pages)
    }

    /// Open an existing set on `vfs` for appending: reads the manifest,
    /// then opens (and crash-recovers) every shard store.
    ///
    /// # Errors
    /// A missing/corrupt manifest, or any shard open/recovery failure.
    pub fn open_with(
        vfs: Arc<dyn Vfs>,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedShardSet> {
        let manifest = PagedSetManifest::read_with(vfs.as_ref(), dir, prefix)?;
        let mut stores = Vec::with_capacity(manifest.shards());
        for sp in &manifest.shard_prefixes {
            stores.push(
                PagedStore::open_with(vfs.as_ref(), dir, sp, cache_pages)
                    .with_context(|| format!("opening shard store {sp}"))?,
            );
        }
        Ok(PagedShardSet {
            vfs,
            dir: dir.to_path_buf(),
            prefix: prefix.to_string(),
            hash_seed: manifest.hash_seed,
            stores,
            shard_prefixes: manifest.shard_prefixes,
            stale_prefixes: Vec::new(),
            group_commit: false,
        })
    }

    /// Shard count.
    pub fn num_shards(&self) -> usize {
        self.stores.len()
    }

    /// The placement seed groups are routed with.
    pub fn hash_seed(&self) -> u64 {
        self.hash_seed
    }

    /// The shard `group` lives on.
    pub fn shard_for(&self, group: &[u8]) -> usize {
        shard_of_key(group, self.hash_seed, self.stores.len())
    }

    /// Append one example to its group's shard. Call
    /// [`PagedShardSet::commit`] to make a batch durable.
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::append`] on the routed shard.
    pub fn append(&mut self, group: &[u8], example: &Example) -> Result<()> {
        let s = self.shard_for(group);
        self.stores[s].append(group, example)
    }

    /// Opt in to (or out of) group commit: when enabled,
    /// [`PagedShardSet::commit`] flushes every shard's WAL buffer first,
    /// then runs the per-shard fsyncs **in parallel**, so a commit
    /// spanning S shards pays ~1 fsync latency instead of S. The
    /// durability promise is unchanged — commit still returns `Ok` only
    /// after *every* shard's WAL is fsynced.
    pub fn set_group_commit(&mut self, on: bool) {
        self.group_commit = on;
    }

    /// Whether group commit is enabled (see
    /// [`PagedShardSet::set_group_commit`]).
    pub fn group_commit(&self) -> bool {
        self.group_commit
    }

    /// Durability point: fsync every shard's WAL.
    ///
    /// With group commit enabled the fsyncs run in parallel behind a
    /// barrier: every shard's buffer is flushed first, then all shards
    /// sync, and commit returns `Ok` only when every sync did. A crash
    /// part-way through the sync phase is exactly as safe as one
    /// part-way through the serial loop: each shard's WAL recovery is
    /// independent, so every shard comes back at either its pre- or
    /// post-commit prefix (the crash matrix exercises both orders).
    ///
    /// # Errors
    /// The first shard commit failure (in shard order; with group
    /// commit, the remaining fsyncs still run before this returns).
    pub fn commit(&mut self) -> Result<()> {
        if !self.group_commit || self.stores.len() == 1 {
            for store in &mut self.stores {
                store.commit()?;
            }
            return Ok(());
        }
        // Flush phase: cheap buffered writes, strictly ordered so a
        // flush failure surfaces before any fsync is paid.
        for store in &mut self.stores {
            store.commit_flush()?;
        }
        // Sync phase: the expensive fsyncs, amortized across shards.
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let results: Vec<Result<()>> =
            parallel_for_each_mut(&mut self.stores, workers, |_, store| store.commit_sync());
        results.into_iter().collect::<Result<Vec<_>>>()?;
        Ok(())
    }

    /// Checkpoint every shard, then republish the manifest with the new
    /// per-shard epochs (and, now that this set's contents are durable,
    /// reclaim any stale stores a previous layout left behind).
    ///
    /// # Errors
    /// The first shard checkpoint failure, or the manifest write.
    pub fn checkpoint(&mut self) -> Result<()> {
        for store in &mut self.stores {
            store.checkpoint()?;
        }
        self.sync_manifest()?;
        self.reclaim_stale();
        Ok(())
    }

    /// Truncate the stale stores captured at create (see
    /// [`stale_shard_stores`]). Runs automatically from the first
    /// [`PagedShardSet::checkpoint`] — i.e. only once this set is
    /// durable, so a crash mid-materialization leaks the old bytes
    /// instead of losing them. A stale store still pinned by a live
    /// reader of the previous layout is kept for a later checkpoint
    /// (its snapshot stays byte-stable). Idempotent; a no-op when
    /// nothing is stale.
    pub fn reclaim_stale(&mut self) {
        if !self.stale_prefixes.is_empty() {
            self.stale_prefixes =
                truncate_shard_stores(self.vfs.as_ref(), &self.dir, &self.stale_prefixes);
        }
    }

    /// Compact every shard **in parallel** (each shard compaction is an
    /// independent rewrite→checkpoint→truncate loop on its own store),
    /// then republish the manifest. Reports come back in shard order.
    /// Concurrency is bounded by the machine's parallelism — a worker
    /// pool pops shards from a shared counter, so a 64-shard set does
    /// not run 64 simultaneous rewrites.
    ///
    /// # Errors
    /// The first shard compaction failure (other shards still finish
    /// their compaction before this returns), or the manifest write.
    pub fn compact(&mut self) -> Result<Vec<CompactReport>> {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let reports: Vec<Result<CompactReport>> =
            parallel_for_each_mut(&mut self.stores, workers, |_, store| store.compact());
        let reports = reports.into_iter().collect::<Result<Vec<_>>>()?;
        self.sync_manifest()?;
        self.reclaim_stale();
        Ok(reports)
    }

    /// Rewrite the `.pset` manifest from the live per-shard epochs. The
    /// bucket writers of the parallel materializer checkpoint their
    /// shards directly, then the runner publishes once via this.
    ///
    /// # Errors
    /// Any manifest write/sync failure.
    pub fn sync_manifest(&self) -> Result<()> {
        let manifest = PagedSetManifest {
            hash_seed: self.hash_seed,
            shard_prefixes: self.shard_prefixes.clone(),
            epochs: self.stores.iter().map(|s| s.epoch()).collect(),
        };
        manifest.write_with(self.vfs.as_ref(), &self.dir, &self.prefix)
    }

    /// Distinct groups across all shards (exact: placement is disjoint).
    pub fn num_groups(&self) -> usize {
        self.stores.iter().map(|s| s.num_groups()).sum()
    }

    /// Total examples across all shards.
    pub fn num_examples(&self) -> u64 {
        self.stores.iter().map(|s| s.num_examples()).sum()
    }

    /// All group keys, sorted (shards hold disjoint key sets).
    pub fn keys(&self) -> Vec<Vec<u8>> {
        let mut keys: Vec<Vec<u8>> = self.stores.iter().flat_map(|s| s.keys()).collect();
        keys.sort();
        keys
    }

    /// Visit one group's examples in append order (routed to its shard).
    /// Returns false for an unknown group.
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::visit_group`].
    pub fn visit_group(&mut self, group: &[u8], f: impl FnMut(Example)) -> Result<bool> {
        let s = self.shard_for(group);
        self.stores[s].visit_group(group, f)
    }

    /// Per-shard page accounting, in shard order.
    pub fn shard_stats(&self) -> Vec<PagedStat> {
        self.stores.iter().map(|s| s.stat()).collect()
    }

    /// Mutable access to the shard stores, in shard order — for the
    /// partition runner's bucket writers, which append bucket `i`
    /// straight into store `i` from `i`'s own thread. Routing through
    /// [`shard_of_key`] is the caller's responsibility here.
    pub(crate) fn shards_mut(&mut self) -> &mut [PagedStore] {
        &mut self.stores
    }
}

/// The reading side: one snapshot per shard (each a [`PagedReader`] with
/// its own `SharedPager` and epoch pin), unified behind the familiar
/// group surface. **`Send + Sync`** like the per-shard readers, so one
/// open `ShardedPagedReader` serves a whole cohort's worth of threads —
/// and because groups hash across shards, concurrent fetches stripe
/// across S independent page caches and index trees instead of queueing
/// on one.
///
/// Each shard is pinned independently: a live writer appending (or
/// compacting) any shard never disturbs what this reader sees — the
/// per-shard epoch pin and COW watermark guarantee it, exactly as for a
/// single store. To observe newer appends, open a new reader.
pub struct ShardedPagedReader {
    hash_seed: u64,
    shards: Vec<PagedReader>,
    manifest_epochs: Vec<u64>,
    keys: Vec<Vec<u8>>,
    num_examples: u64,
}

impl ShardedPagedReader {
    /// Open the set at `dir/<prefix>.pset` on the real filesystem.
    ///
    /// # Errors
    /// Same conditions as [`ShardedPagedReader::open_with`].
    pub fn open(dir: &Path, prefix: &str, cache_pages: usize) -> Result<ShardedPagedReader> {
        ShardedPagedReader::open_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// Open the set at `dir/<prefix>.pset` on `vfs`: reads the manifest,
    /// opens one pinned snapshot per shard (`cache_pages` LRU frames
    /// each), and merges the shard key lists. Like [`PagedReader`], a
    /// shard whose WAL is hot is recovered first — so the same
    /// single-live-writer caveat applies, per shard.
    ///
    /// # Errors
    /// A missing/corrupt manifest, or any shard open failure.
    pub fn open_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<ShardedPagedReader> {
        ShardedPagedReader::open_inner(vfs, dir, prefix, cache_pages, true, ReadOpts::default())
    }

    /// [`ShardedPagedReader::open_with`] with explicit hot-read-path
    /// options ([`ReadOpts`]), applied to every shard reader.
    ///
    /// # Errors
    /// Same conditions as [`ShardedPagedReader::open_with`].
    pub fn open_with_opts(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
        opts: ReadOpts,
    ) -> Result<ShardedPagedReader> {
        ShardedPagedReader::open_inner(vfs, dir, prefix, cache_pages, true, opts)
    }

    /// Open the last **checkpointed** snapshot of every shard at
    /// `dir/<prefix>.pset` on the real filesystem (see
    /// [`ShardedPagedReader::open_snapshot_with`]).
    ///
    /// # Errors
    /// Same conditions as [`ShardedPagedReader::open_snapshot_with`].
    pub fn open_snapshot(
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<ShardedPagedReader> {
        ShardedPagedReader::open_snapshot_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// Open the set with every shard opened via
    /// [`PagedReader::open_snapshot_with`]: no WAL is probed or
    /// recovered, so the open performs zero writes and is safe to run
    /// concurrently with a live [`PagedShardSet`] writer mid-append.
    /// Committed-but-not-yet-checkpointed appends are invisible. This is
    /// how the serving layer ([`crate::serve`]) pins a per-connection
    /// snapshot of a set its primary is still growing.
    ///
    /// # Errors
    /// A missing/corrupt manifest, or any shard open failure.
    pub fn open_snapshot_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<ShardedPagedReader> {
        ShardedPagedReader::open_inner(vfs, dir, prefix, cache_pages, false, ReadOpts::default())
    }

    /// [`ShardedPagedReader::open_snapshot_with`] with explicit
    /// hot-read-path options ([`ReadOpts`]), applied to every shard
    /// reader. Still performs zero writes.
    ///
    /// # Errors
    /// Same conditions as [`ShardedPagedReader::open_snapshot_with`].
    pub fn open_snapshot_with_opts(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
        opts: ReadOpts,
    ) -> Result<ShardedPagedReader> {
        ShardedPagedReader::open_inner(vfs, dir, prefix, cache_pages, false, opts)
    }

    fn open_inner(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
        recover_hot_wal: bool,
        opts: ReadOpts,
    ) -> Result<ShardedPagedReader> {
        let manifest = PagedSetManifest::read_with(vfs, dir, prefix)?;
        let mut shards = Vec::with_capacity(manifest.shards());
        for sp in &manifest.shard_prefixes {
            let shard = if recover_hot_wal {
                PagedReader::open_with_opts(vfs, dir, sp, cache_pages, opts)
            } else {
                PagedReader::open_snapshot_with_opts(vfs, dir, sp, cache_pages, opts)
            };
            shards.push(shard.with_context(|| format!("opening shard store {sp}"))?);
        }
        // Shards hold disjoint key sets; a plain merge-sort of the
        // per-shard (already sorted) lists gives the global order.
        let mut keys: Vec<Vec<u8>> = shards.iter().flat_map(|r| r.keys().to_vec()).collect();
        keys.sort();
        let num_examples = shards.iter().map(|r| r.num_examples()).sum();
        Ok(ShardedPagedReader {
            hash_seed: manifest.hash_seed,
            shards,
            manifest_epochs: manifest.epochs,
            keys,
            num_examples,
        })
    }

    /// Shard count.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The placement seed groups are routed with.
    pub fn hash_seed(&self) -> u64 {
        self.hash_seed
    }

    /// The shard `group` lives on.
    pub fn shard_for(&self, group: &[u8]) -> usize {
        shard_of_key(group, self.hash_seed, self.shards.len())
    }

    /// Distinct groups in the pinned snapshots.
    pub fn num_groups(&self) -> usize {
        self.keys.len()
    }

    /// Total examples in the pinned snapshots.
    pub fn num_examples(&self) -> u64 {
        self.num_examples
    }

    /// All group keys across shards, sorted.
    pub fn keys(&self) -> &[Vec<u8>] {
        &self.keys
    }

    /// The checkpoint epoch each shard snapshot is pinned to, in shard
    /// order (shards checkpoint independently, so these need not agree).
    pub fn epochs(&self) -> Vec<u64> {
        self.shards.iter().map(|r| r.epoch()).collect()
    }

    /// The per-shard epochs the manifest recorded when last published —
    /// at most [`ShardedPagedReader::epochs`] (a writer may have
    /// checkpointed since, which this snapshot deliberately cannot see).
    pub fn manifest_epochs(&self) -> &[u64] {
        &self.manifest_epochs
    }

    /// Construct one group's dataset (routed to its shard's snapshot).
    /// Returns false for an unknown group. Takes `&self`: safe from many
    /// threads at once.
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::visit_group`].
    pub fn visit_group(&self, group: &[u8], f: impl FnMut(Example)) -> Result<bool> {
        self.shards[self.shard_for(group)].visit_group(group, f)
    }

    /// [`ShardedPagedReader::visit_group`] without decoding: `f`
    /// receives each record's raw bytes in append order and returns
    /// whether to continue (see [`PagedReader::visit_group_raw`]).
    ///
    /// # Errors
    /// Same conditions as [`ShardedPagedReader::visit_group`].
    pub fn visit_group_raw(&self, group: &[u8], f: impl FnMut(&[u8]) -> bool) -> Result<bool> {
        self.shards[self.shard_for(group)].visit_group_raw(group, f)
    }

    /// Iterate groups in `order` (or one thread's slice of it).
    ///
    /// # Errors
    /// Same conditions as [`ShardedPagedReader::visit_group`].
    pub fn visit_all(&self, order: &[Vec<u8>], mut f: impl FnMut(&[u8], Example)) -> Result<()> {
        for key in order {
            self.visit_group(key, |ex| f(key, ex))?;
        }
        Ok(())
    }

    /// One group as a prefetched [`StreamedGroup`] — the adapter that
    /// lets the federated trainer's client-data pipeline consume a
    /// sharded paged set like any streamed cohort. Pure byte movement:
    /// the shard's raw record bytes are re-framed without ever decoding
    /// an example (see [`PagedReader::visit_group_raw`]). `None` for an
    /// unknown group. (The paged index does not track word counts; the
    /// group's `words` field is 0.)
    ///
    /// # Errors
    /// Same conditions as [`ShardedPagedReader::visit_group`].
    pub fn streamed_group(&self, group: &[u8]) -> Result<Option<StreamedGroup>> {
        self.shards[self.shard_for(group)].streamed_group(group)
    }

    /// Per-shard page accounting (header numbers of each pinned
    /// snapshot), in shard order.
    pub fn shard_stats(&self) -> Vec<PagedStat> {
        self.shards.iter().map(|r| r.stat()).collect()
    }

    /// Index page fetches from disk so far, summed across shards (and
    /// across all reading threads).
    pub fn pages_read(&self) -> u64 {
        self.shards.iter().map(|r| r.pages_read()).sum()
    }

    /// Uncached header (page 0) reads, summed across shards (see
    /// [`PagedReader::header_reads`]).
    pub fn header_reads(&self) -> u64 {
        self.shards.iter().map(|r| r.header_reads()).sum()
    }

    /// Aggregate index-cache counters, summed across shards.
    pub fn cache_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for r in &self.shards {
            let s = r.cache_stats();
            total.hits += s.hits;
            total.misses += s.misses;
            total.evictions += s.evictions;
        }
        total
    }

    /// Index tree depth per shard, in shard order (1 = single leaf).
    ///
    /// # Errors
    /// Any index page-read failure.
    pub fn index_depths(&self) -> Result<Vec<u32>> {
        self.shards.iter().map(|r| r.index_depth()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::vfs::MemVfs;

    fn mem_dir(name: &str) -> PathBuf {
        PathBuf::from("/mem").join(name)
    }

    #[test]
    fn shard_of_key_matches_the_runner_bucket_placement() {
        // Seed 0 is pinned to the historical bucket function: changing it
        // would silently re-shard every existing materialization.
        for (key, shards) in
            [(&b"nytimes.com"[..], 8usize), (b"g0", 3), (b"", 5), (b"rand-000042", 1)]
        {
            assert_eq!(shard_of_key(key, 0, shards), (fnv1a(key) % shards as u64) as usize);
        }
        // A seed actually moves placement (statistically: over many keys,
        // at least one must land elsewhere).
        let moved = (0..100)
            .filter(|i| {
                let k = format!("group-{i}");
                shard_of_key(k.as_bytes(), 0, 8) != shard_of_key(k.as_bytes(), 7, 8)
            })
            .count();
        assert!(moved > 50, "seed barely moves placement: {moved}");
    }

    #[test]
    fn shard_prefix_naming() {
        assert_eq!(shard_prefix("data", 0, 1), "data");
        assert_eq!(shard_prefix("data", 2, 8), "data-s00002-of-00008");
    }

    #[test]
    fn manifest_roundtrip_and_corruption_detection() {
        let vfs = MemVfs::new();
        let dir = mem_dir("manifest");
        let m = PagedSetManifest {
            hash_seed: 9,
            shard_prefixes: vec!["p-s00000-of-00002".into(), "p-s00001-of-00002".into()],
            epochs: vec![3, 7],
        };
        m.write_with(&vfs, &dir, "p").unwrap();
        assert!(PagedSetManifest::exists_with(&vfs, &dir, "p"));
        assert_eq!(PagedSetManifest::read_with(&vfs, &dir, "p").unwrap(), m);
        // Flip one byte of the *primary*: the checksum rejects it and
        // the read falls back to the intact sidecar copy — exactly the
        // crash window of the sidecar-then-primary write ordering.
        let path = PagedSetManifest::path(&dir, "p");
        let good = vfs.file_bytes(&path).unwrap();
        let mut torn = good.clone();
        torn[10] ^= 0xFF;
        vfs.install(&path, torn.clone());
        assert_eq!(
            PagedSetManifest::read_with(&vfs, &dir, "p").unwrap(),
            m,
            "a torn primary must fall back to the sidecar"
        );
        // Both copies torn: now the read must fail, naming the checksum.
        vfs.install(&PagedSetManifest::sidecar_path(&dir, "p"), torn);
        let err = PagedSetManifest::read_with(&vfs, &dir, "p").unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        assert!(!PagedSetManifest::exists_with(&vfs, &dir, "missing"));
    }

    #[test]
    fn sharded_set_round_trips_groups_across_reopen_and_reader() {
        let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new());
        let dir = mem_dir("roundtrip");
        let mut set =
            PagedShardSet::create_with(Arc::clone(&vfs), &dir, "x", 4, 16, 0).unwrap();
        for i in 0..120 {
            let g = format!("group-{}", i % 11);
            set.append(g.as_bytes(), &Example::text(&format!("t{i}"))).unwrap();
        }
        set.commit().unwrap();
        set.checkpoint().unwrap();
        assert_eq!(set.num_groups(), 11);
        assert_eq!(set.num_examples(), 120);
        let want: Vec<(Vec<u8>, Vec<Vec<u8>>)> = {
            let keys = set.keys();
            keys.iter()
                .map(|k| {
                    let mut v = Vec::new();
                    assert!(set.visit_group(k, |ex| v.push(ex.encode())).unwrap());
                    (k.clone(), v)
                })
                .collect()
        };
        drop(set);
        // Reopen for append: counts and contents survive.
        let mut reopened =
            PagedShardSet::open_with(Arc::clone(&vfs), &dir, "x", 16).unwrap();
        assert_eq!(reopened.num_examples(), 120);
        reopened.append(b"group-3", &Example::text("late")).unwrap();
        reopened.commit().unwrap();
        reopened.checkpoint().unwrap();
        drop(reopened);
        // The unified reader sees everything, routed per shard.
        let r = ShardedPagedReader::open_with(vfs.as_ref(), &dir, "x", 16).unwrap();
        assert_eq!(r.num_shards(), 4);
        assert_eq!(r.num_examples(), 121);
        assert_eq!(r.num_groups(), 11);
        for (k, v) in &want {
            let mut got = Vec::new();
            assert!(r.visit_group(k, |ex| got.push(ex.encode())).unwrap());
            if k == b"group-3" {
                assert_eq!(got.len(), v.len() + 1, "late append lands at the tail");
                assert_eq!(&got[..v.len()], &v[..]);
            } else {
                assert_eq!(&got, v);
            }
        }
        assert!(!r.visit_group(b"not-there", |_| {}).unwrap());
        assert_eq!(r.epochs().len(), 4);
        assert_eq!(r.manifest_epochs().len(), 4);
    }

    #[test]
    fn single_shard_set_is_a_plain_store_plus_manifest() {
        let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new());
        let dir = mem_dir("single");
        let mut set = PagedShardSet::create_with(Arc::clone(&vfs), &dir, "x", 1, 16, 0).unwrap();
        set.append(b"g", &Example::text("t")).unwrap();
        set.commit().unwrap();
        set.checkpoint().unwrap();
        drop(set);
        // The shard files carry the *plain* prefix: a standalone
        // PagedReader opens them directly.
        let r = PagedReader::open_with(vfs.as_ref(), &dir, "x", 16).unwrap();
        assert_eq!(r.num_examples(), 1);
        drop(r);
        let sr = ShardedPagedReader::open_with(vfs.as_ref(), &dir, "x", 16).unwrap();
        assert_eq!(sr.num_shards(), 1);
        assert_eq!(sr.num_examples(), 1);
    }

    #[test]
    fn recreating_with_fewer_shards_reclaims_the_stale_stores_after_checkpoint() {
        let vfs = Arc::new(MemVfs::new());
        let dir = mem_dir("shrink");
        {
            let mut set =
                PagedShardSet::create_with(Arc::clone(&vfs) as Arc<dyn Vfs>, &dir, "x", 4, 16, 0)
                    .unwrap();
            for i in 0..40 {
                set.append(format!("g{i}").as_bytes(), &Example::text("payload")).unwrap();
            }
            set.commit().unwrap();
            set.checkpoint().unwrap();
        }
        let old_pdata = dir.join(format!("{}.pdata", shard_prefix("x", 2, 4)));
        assert!(!vfs.file_bytes(&old_pdata).unwrap().is_empty());
        // Recreate the same dir/prefix with 2 shards. Until the new set
        // checkpoints, the old shards' bytes must survive (a crash here
        // must leak, not destroy); after the first checkpoint they are
        // reclaimed to empty stubs (the VFS cannot delete).
        let mut set =
            PagedShardSet::create_with(Arc::clone(&vfs) as Arc<dyn Vfs>, &dir, "x", 2, 16, 0)
                .unwrap();
        assert!(
            !vfs.file_bytes(&old_pdata).unwrap().is_empty(),
            "old data must survive until the new set is durable"
        );
        set.append(b"g", &Example::text("fresh")).unwrap();
        set.commit().unwrap();
        set.checkpoint().unwrap();
        for i in 2..4 {
            for suffix in ["pstore", "pdata", "pwal"] {
                let path = dir.join(format!("{}.{suffix}", shard_prefix("x", i, 4)));
                let bytes = vfs.file_bytes(&path).unwrap();
                assert!(bytes.is_empty(), "stale {} must be reclaimed", path.display());
            }
        }
        // Shards 0/1 of the old layout were never part of the new one
        // either — they are reclaimed too (different prefixes).
        assert!(vfs
            .file_bytes(&dir.join(format!("{}.pdata", shard_prefix("x", 0, 4))))
            .unwrap()
            .is_empty());
    }

    #[test]
    fn recreating_the_same_layout_unpublishes_the_old_manifest_until_checkpoint() {
        let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new());
        let dir = mem_dir("samelayout");
        {
            let mut set =
                PagedShardSet::create_with(Arc::clone(&vfs), &dir, "x", 2, 16, 0).unwrap();
            set.append(b"g", &Example::text("old")).unwrap();
            set.commit().unwrap();
            set.checkpoint().unwrap();
        }
        // Recreate with the SAME shard count: the store names collide,
        // so create truncates the old data in place — the old manifest
        // must be unpublished at that moment (reads fail loudly) rather
        // than keep describing wreckage across the rebuild window.
        let mut set = PagedShardSet::create_with(Arc::clone(&vfs), &dir, "x", 2, 16, 0).unwrap();
        assert!(
            PagedSetManifest::read_with(vfs.as_ref(), &dir, "x").is_err(),
            "an overwritten-in-place set must not stay discoverable mid-rebuild"
        );
        set.append(b"g", &Example::text("new")).unwrap();
        set.commit().unwrap();
        set.checkpoint().unwrap();
        let m = PagedSetManifest::read_with(vfs.as_ref(), &dir, "x").unwrap();
        assert_eq!(m.shards(), 2);
        let r = ShardedPagedReader::open_with(vfs.as_ref(), &dir, "x", 16).unwrap();
        assert_eq!(r.num_examples(), 1, "only the new materialization is visible");
    }

    #[test]
    fn streamed_group_adapter_replays_the_group() {
        let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new());
        let dir = mem_dir("streamed");
        let mut set = PagedShardSet::create_with(Arc::clone(&vfs), &dir, "x", 2, 16, 0).unwrap();
        for i in 0..5 {
            set.append(b"g", &Example::text(&format!("t{i}"))).unwrap();
        }
        set.commit().unwrap();
        set.checkpoint().unwrap();
        drop(set);
        let r = ShardedPagedReader::open_with(vfs.as_ref(), &dir, "x", 16).unwrap();
        let mut g = r.streamed_group(b"g").unwrap().expect("group exists");
        let texts: Vec<String> = g
            .examples()
            .unwrap()
            .iter()
            .map(|e| e.get_str("text").unwrap().to_string())
            .collect();
        assert_eq!(texts, vec!["t0", "t1", "t2", "t3", "t4"]);
        assert!(r.streamed_group(b"missing").unwrap().is_none());
    }

    #[test]
    fn parallel_compact_reclaims_every_shard() {
        let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new());
        let dir = mem_dir("compact");
        let mut set = PagedShardSet::create_with(Arc::clone(&vfs), &dir, "x", 3, 16, 0).unwrap();
        // Churn: repeated checkpoints strand COW'd pages on every shard.
        for round in 0..8 {
            for i in 0..30 {
                let g = format!("g{}", i % 9);
                set.append(g.as_bytes(), &Example::text(&format!("r{round}i{i}"))).unwrap();
            }
            set.commit().unwrap();
            set.checkpoint().unwrap();
        }
        let before: Vec<_> = set.shard_stats();
        assert!(before.iter().any(|s| s.free_pages > 0), "churn must strand garbage");
        let reports = set.compact().unwrap();
        assert_eq!(reports.len(), 3);
        let after = set.shard_stats();
        let total_before: u32 = before.iter().map(|s| s.total_pages).sum();
        let total_after: u32 = after.iter().map(|s| s.total_pages).sum();
        assert!(total_after < total_before, "{total_before} -> {total_after}");
        // Contents intact.
        let mut n = 0u64;
        for k in set.keys() {
            assert!(set.visit_group(&k, |_| n += 1).unwrap());
        }
        assert_eq!(n, 8 * 30);
    }
}
