//! The paged format: a WAL-backed **appendable** group store over the
//! storage engine ([`crate::store`]) — the fourth column of Table 2/3.
//!
//! The three seed formats are all materialize-once: none can grow after
//! prep, which is exactly the limitation the paper ascribes to both the
//! in-memory systems (LEAF, FedJAX) and the TFF/SQL-backed hierarchical
//! store. `PagedStore` removes it:
//!
//! * examples append to `<prefix>.pdata` (TFRecord framing, arrival
//!   order);
//! * the index is a *mutable* B+tree in `<prefix>.pstore` mapping
//!   `group \0 seq(BE u64)` to the example's data offset, growing by
//!   page splits — no rebuild, ever;
//! * every append is logged to `<prefix>.pwal` first.
//!   [`PagedStore::commit`] (WAL fsync) is the durability point;
//!   [`PagedStore::checkpoint`] makes the tree+data durable, swaps the
//!   header page, and resets the WAL. Because the B+tree is
//!   copy-on-write above the committed watermark, a crash at *any*
//!   point between checkpoints leaves the last committed tree intact on
//!   disk; reopening truncates torn tails and replays the WAL.
//!
//! Group access cost is governed by the pager's LRU cache size — the
//! tunable middle ground between the hierarchical format's cold index
//! walks and the in-memory format's everything-resident map.
//!
//! Reads are **concurrent**: [`PagedReader`] is `Send + Sync` and every
//! access method takes `&self`, so a FedAvg round can fetch its whole
//! cohort's client datasets in parallel through one shared reader (the
//! index goes through [`crate::store::shared::SharedPager`]'s sharded
//! cache; each call opens its own data cursor). A reader is a
//! *snapshot* at the checkpoint epoch current when it was opened: the
//! B+tree's copy-on-write watermark guarantees a concurrent appender
//! never mutates a page the snapshot can reach.
//!
//! Layout of the `.pstore` header (page 0): magic, B+tree root page,
//! committed page count, committed row count, durable `.pdata` byte
//! length, committed group count, checkpoint epoch, free-list trunk
//! chain head + free page count (see [`crate::store::freelist`]), and a
//! CRC32C over the preceding fields. The checksum lets a concurrent
//! reader detect a torn page-0 read (it races the checkpoint's in-place
//! header write) and retry, instead of parsing fields from two
//! different epochs.
//!
//! **Space reclamation.** Every page the COW index supersedes is freed
//! into the pager's free list; each checkpoint publishes the frees
//! (durably, as a linked trunk chain) and later appends *reuse* them
//! instead of growing the file — epoch-gated so an open [`PagedReader`]
//! snapshot is never disturbed (the reader pins its epoch in the
//! process-wide registry, `crate::store::shared::pin_epoch`).
//! [`PagedStore::compact`] goes further: it migrates live index pages
//! toward the file head and truncates the freed tail, so the `.pstore`
//! file shrinks back to (roughly) its live size.
//! [`PagedStore::stat`]/[`PagedReader::stat`] report live/free/total
//! pages so callers (and `grouper stats`) can see the garbage ratio.
//!
//! Known trade-off: `open` walks the committed index once (O(rows)
//! sequential leaf scan through the cache) to rebuild per-group counts /
//! the group list. A persisted `.hgroups`-style sidecar would make open
//! O(groups); left as follow-up since open happens once per process.
//!
//! Every byte of store I/O (index, WAL *and* `.pdata`) goes through the
//! [`crate::store::vfs`] layer: the `*_with` constructors take any
//! [`Vfs`], the plain ones default to [`StdVfs`]. That is what lets the
//! crash-matrix suite (`rust/tests/crash_matrix.rs`) run this exact
//! code under [`crate::store::vfs::FaultVfs`] and prove — not argue —
//! that recovery always lands on a committed prefix.

#![deny(missing_docs)]

use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::corpus::BaseDataset;
use crate::pipeline::Partitioner;
use crate::records::crc32c::crc32c;
use crate::records::tfrecord::{RecordReader, RecordWriter};
use crate::records::Example;
use crate::store::btree::BTree;
use crate::store::cache::CacheStats;
use crate::store::page::{Page, PageId, PAGE_SIZE};
use crate::store::pager::{PageRead, Pager};
use crate::store::pins::{self, DiskPin};
use crate::store::shared::{self, EpochPin, ReadOpts, ReadSnapshot, SharedPager};
use crate::store::vfs::{map_read_only, OpenMode, StdVfs, Vfs, VfsCursor, VfsFile};
use crate::store::wal::{self, WalWriter};

/// Format version 02: version 01 headers had no free-list fields.
const MAGIC: &[u8; 8] = b"GRPPAG02";

/// Default LRU cache size (pages) for stores and readers.
pub const DEFAULT_CACHE_PAGES: usize = 64;

/// WAL budget between automatic checkpoints while bulk-building
/// ([`PagedStore::build`] and the sharded materializer's bucket
/// writers): bounds the WAL size — and the memory/time a recovery from
/// a mid-build crash needs — regardless of dataset size.
pub const BUILD_CHECKPOINT_WAL_BYTES: u64 = 64 * 1024 * 1024;

pub(crate) fn pstore_path(dir: &Path, prefix: &str) -> PathBuf {
    dir.join(format!("{prefix}.pstore"))
}

pub(crate) fn pdata_path(dir: &Path, prefix: &str) -> PathBuf {
    dir.join(format!("{prefix}.pdata"))
}

pub(crate) fn pwal_path(dir: &Path, prefix: &str) -> PathBuf {
    dir.join(format!("{prefix}.pwal"))
}

/// `group \0 seq(BE)` — the fixed-width suffix makes the group recoverable
/// from any row key, and big-endian seq keeps a group's rows in append
/// order under the tree's byte ordering.
fn row_key(group: &[u8], seq: u64) -> Vec<u8> {
    let mut k = Vec::with_capacity(group.len() + 9);
    k.extend_from_slice(group);
    k.push(0);
    k.extend_from_slice(&seq.to_be_bytes());
    k
}

fn group_of_row_key(k: &[u8]) -> io::Result<&[u8]> {
    if k.len() < 9 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "paged row key shorter than its seq suffix",
        ));
    }
    Ok(&k[..k.len() - 9])
}

/// Header snapshot (page 0 of `.pstore`).
#[derive(Clone, Copy, Debug)]
struct StoreHeader {
    root: PageId,
    committed_pages: u32,
    num_rows: u64,
    data_len: u64,
    num_groups: u64,
    /// Checkpoint epoch. Every WAL record carries the epoch it was
    /// appended under; recovery applies only records with
    /// `epoch >= header.epoch`. That makes the crash window *between*
    /// the checkpoint's header swap and the WAL reset safe: such a WAL
    /// still holds records, but they carry the previous epoch and are
    /// recognized as already committed instead of being applied twice.
    epoch: u64,
    /// First trunk page of the durable free-list chain (0 = empty).
    freelist_head: PageId,
    /// Free pages listed in the chain (reporting; the chain is the
    /// truth).
    free_pages: u32,
}

/// Byte span of the header fields covered by the trailing checksum.
const HEADER_CRC_SPAN: usize = 56;

fn header_checksum_ok(page: &Page) -> bool {
    page.get_bytes(0, 8) == MAGIC
        && page.get_u32(HEADER_CRC_SPAN) == crc32c(page.get_bytes(0, HEADER_CRC_SPAN))
}

fn parse_header(page: &Page) -> Result<StoreHeader> {
    if page.get_bytes(0, 8) != MAGIC {
        bail!("bad paged store magic");
    }
    if !header_checksum_ok(page) {
        bail!("paged store header checksum mismatch (torn or corrupt header page)");
    }
    Ok(StoreHeader {
        root: page.get_u32(8),
        committed_pages: page.get_u32(12),
        num_rows: page.get_u64(16),
        data_len: page.get_u64(24),
        num_groups: page.get_u64(32),
        epoch: page.get_u64(40),
        freelist_head: page.get_u32(48),
        free_pages: page.get_u32(52),
    })
}

fn read_header(pager: &mut Pager) -> Result<StoreHeader> {
    let page = pager.read(0).context("reading paged store header")?;
    parse_header(page)
}

fn write_header(page: &mut Page, h: &StoreHeader) {
    page.put_bytes(0, MAGIC);
    page.put_u32(8, h.root);
    page.put_u32(12, h.committed_pages);
    page.put_u64(16, h.num_rows);
    page.put_u64(24, h.data_len);
    page.put_u64(32, h.num_groups);
    page.put_u64(40, h.epoch);
    page.put_u32(48, h.freelist_head);
    page.put_u32(52, h.free_pages);
    let crc = crc32c(page.get_bytes(0, HEADER_CRC_SPAN));
    page.put_u32(HEADER_CRC_SPAN, crc);
}

/// WAL payload: `u64 LE epoch | u32 LE group length | group | example`.
fn encode_wal(epoch: u64, group: &[u8], example_bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + group.len() + example_bytes.len());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(group.len() as u32).to_le_bytes());
    out.extend_from_slice(group);
    out.extend_from_slice(example_bytes);
    out
}

pub(crate) fn decode_wal(payload: &[u8]) -> io::Result<(u64, &[u8], &[u8])> {
    if payload.len() < 12 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "short wal payload"));
    }
    let epoch = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let klen = u32::from_le_bytes(payload[8..12].try_into().unwrap()) as usize;
    if 12 + klen > payload.len() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "wal payload group length out of bounds",
        ));
    }
    Ok((epoch, &payload[12..12 + klen], &payload[12 + klen..]))
}

/// The durable replication position of a paged store: what the last
/// checkpoint committed, plus the valid WAL prefix appended since. Two
/// stores with equal `CommittedState` *and* equal bytes over the three
/// committed prefixes (`committed_pages` index pages, `data_len` data
/// bytes, `wal_len` log bytes) are the same store — this is the unit
/// the serving layer's replication handshake compares
/// ([`crate::serve::replica`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CommittedState {
    /// Checkpoint epoch from the committed header.
    pub epoch: u64,
    /// Committed `.pstore` prefix, in pages (header page included).
    pub committed_pages: u32,
    /// Durable `.pdata` byte length at the last checkpoint.
    pub data_len: u64,
    /// Valid `.pwal` frame-prefix length in bytes.
    pub wal_len: u64,
}

impl CommittedState {
    /// The committed `.pstore` prefix in bytes.
    pub fn index_len(&self) -> u64 {
        u64::from(self.committed_pages.max(1)) * PAGE_SIZE as u64
    }
}

/// Read the durable position of the store at `dir`/`prefix` without
/// opening it: header page 0 (with a bounded torn-header retry, since a
/// live checkpointer swaps it in place) plus the WAL's valid frame
/// prefix. `Ok(None)` when no `.pstore` exists — a replication follower
/// that has not cold-started yet.
///
/// # Errors
/// A corrupt (never-valid) header, or any I/O failure reading the
/// header page or scanning the WAL.
pub fn committed_state_with(
    vfs: &dyn Vfs,
    dir: &Path,
    prefix: &str,
) -> Result<Option<CommittedState>> {
    let index_path = pstore_path(dir, prefix);
    let file = match vfs.open(&index_path, OpenMode::Read) {
        Ok(f) => f,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).context("opening paged store header"),
    };
    let mut header = None;
    for _ in 0..32 {
        let mut buf = vec![0u8; PAGE_SIZE];
        file.read_exact_at(&mut buf, 0)
            .with_context(|| format!("reading header page of {}", index_path.display()))?;
        let page = Page::from_vec(buf)?;
        if header_checksum_ok(&page) {
            header = Some(parse_header(&page)?);
            break;
        }
        // Torn read against an in-place header swap: retry briefly.
        std::thread::yield_now();
    }
    let Some(h) = header else {
        bail!(
            "paged store header at {} never parsed cleanly (corrupt store?)",
            index_path.display()
        );
    };
    let report = wal::replay_with(vfs, &pwal_path(dir, prefix), |_| Ok(()))?;
    Ok(Some(CommittedState {
        epoch: h.epoch,
        committed_pages: h.committed_pages,
        data_len: h.data_len,
        wal_len: report.valid_bytes,
    }))
}

/// Validate one WAL record payload for replication and return the epoch
/// it was appended under. A follower runs every shipped frame through
/// this *before* appending it to its own log, so a malformed record can
/// never enter a replica's durable state.
///
/// # Errors
/// [`io::ErrorKind::InvalidData`] when the payload is not a well-formed
/// paged-store WAL record.
pub fn wal_record_epoch(payload: &[u8]) -> io::Result<u64> {
    decode_wal(payload).map(|(epoch, _, _)| epoch)
}

/// One group's **raw record bytes** (each exactly one encoded
/// [`Example`]), shared by [`PagedStore`] and [`PagedReader`]: a B+tree
/// range scan for data offsets (cost governed by the LRU cache), then
/// one data-file read per example; `f` returns false to stop early
/// (remaining records are neither sought nor read). Returns false for
/// an unknown group. The zero-decode substrate of [`visit_group_via`] —
/// callers that only move bytes (re-framing a group for the trainer,
/// replication) skip the decode/re-encode round-trip entirely.
fn visit_group_raw_via<R: PageRead>(
    tree: &BTree,
    pager: &mut R,
    data: &Arc<dyn VfsFile>,
    group: &[u8],
    mut f: impl FnMut(&[u8]) -> bool,
) -> Result<bool> {
    let mut prefix = Vec::with_capacity(group.len() + 1);
    prefix.extend_from_slice(group);
    prefix.push(0);
    let expected_len = prefix.len() + 8;
    let mut offsets: Vec<u64> = Vec::new();
    let mut bad_value = false;
    tree.scan_prefix(pager, &prefix, |k, v| {
        if k.len() == expected_len {
            match <[u8; 8]>::try_from(v) {
                Ok(le) => offsets.push(u64::from_le_bytes(le)),
                Err(_) => bad_value = true,
            }
        }
    })?;
    if bad_value {
        bail!("paged index holds a corrupt offset value for group {:?}", group);
    }
    if offsets.is_empty() {
        return Ok(false);
    }
    let mut r = RecordReader::new(BufReader::new(VfsCursor::new(data.clone())));
    let mut buf = Vec::new();
    for off in offsets {
        r.seek_to(off)?;
        if !r.read_into(&mut buf)? {
            bail!("paged index points past data end");
        }
        if !f(&buf) {
            break;
        }
    }
    Ok(true)
}

/// [`visit_group_raw_via`] with each record decoded to an [`Example`];
/// a decode failure aborts the scan immediately (no point paying the
/// rest of the group's data I/O to surface it).
fn visit_group_via<R: PageRead>(
    tree: &BTree,
    pager: &mut R,
    data: &Arc<dyn VfsFile>,
    group: &[u8],
    mut f: impl FnMut(Example),
) -> Result<bool> {
    let mut decode_err: Option<io::Error> = None;
    let found = visit_group_raw_via(tree, pager, data, group, |bytes| {
        match Example::decode(bytes) {
            Ok(ex) => {
                f(ex);
                true
            }
            Err(e) => {
                decode_err = Some(e);
                false
            }
        }
    })?;
    if let Some(e) = decode_err {
        return Err(e).context("decoding paged example");
    }
    Ok(found)
}

/// What one [`PagedStore::compact`] run did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompactReport {
    /// Index pages (live + free) before compaction.
    pub pages_before: u32,
    /// Index pages after compaction.
    pub pages_after: u32,
    /// Live pages copied across all passes (compaction write cost).
    pub pages_moved: u32,
    /// Pages given back to the filesystem.
    pub pages_reclaimed: u32,
    /// Rewrite→checkpoint→truncate passes run (0 = already dense).
    pub passes: u32,
}

impl CompactReport {
    /// `.pstore` bytes before compaction.
    pub fn bytes_before(&self) -> u64 {
        u64::from(self.pages_before) * PAGE_SIZE as u64
    }

    /// `.pstore` bytes after compaction.
    pub fn bytes_after(&self) -> u64 {
        u64::from(self.pages_after) * PAGE_SIZE as u64
    }
}

/// Page accounting for one store (see [`PagedStore::stat`] /
/// [`PagedReader::stat`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PagedStat {
    /// Index pages in the file (header + live + free).
    pub total_pages: u32,
    /// Free index pages (durably free, plus — on a writer — frees not
    /// yet published by a checkpoint).
    pub free_pages: u32,
    /// `total_pages - free_pages`: header, tree and trunk pages.
    pub live_pages: u32,
    /// `.pstore` size in bytes (`total_pages * PAGE_SIZE`).
    pub index_bytes: u64,
    /// `.pdata` length in bytes.
    pub data_bytes: u64,
    /// Checkpoint epoch of this view.
    pub epoch: u64,
    /// Rows in the index.
    pub num_rows: u64,
    /// Distinct groups.
    pub num_groups: u64,
}

impl PagedStat {
    /// Free pages as a fraction of the whole file (0.0 when empty) —
    /// what `--auto-compact-threshold` compares against.
    pub fn free_fraction(&self) -> f64 {
        if self.total_pages == 0 {
            0.0
        } else {
            f64::from(self.free_pages) / f64::from(self.total_pages)
        }
    }
}

/// The appendable, WAL-backed group store (writer + read access).
pub struct PagedStore {
    pager: Pager,
    tree: BTree,
    wal: WalWriter,
    data: RecordWriter<BufWriter<VfsCursor>>,
    /// The shared `.pdata` handle: fsync target for checkpoints, and the
    /// source every read cursor positions over.
    data_file: Arc<dyn VfsFile>,
    /// Byte offset of `.pdata` where this writer session started.
    data_base: u64,
    /// Per-group example counts (`group -> next seq`).
    group_counts: HashMap<Vec<u8>, u64>,
    /// True when the data writer has unflushed buffered bytes.
    data_buffered: bool,
    /// Current checkpoint epoch (see [`StoreHeader::epoch`]).
    epoch: u64,
    /// Set when an append failed mid-apply (or a checkpoint failed after
    /// it began publishing): the in-memory tree, free-list and data
    /// writer are then suspect (a partial data frame may be buffered, a
    /// page split may be half-done, promoted frees may describe a state
    /// that never reached the header), so every further mutation — and
    /// every tree walk through this handle — is refused. Reopen (or use
    /// a [`PagedReader`]) to recover the last committed state.
    poisoned: bool,
    /// The snapshot-registry key readers pin under: the VFS instance id
    /// ([`Vfs::instance_id`]) plus the `.pstore` path in the VFS's
    /// canonical spelling ([`Vfs::registry_key`]). Cached as the ready
    /// tuple so the per-append gate refresh allocates nothing.
    pin_key: (u64, PathBuf),
    /// Cached minimum epoch over the on-disk pin files of readers in
    /// **other** processes ([`crate::store::pins`]); `u64::MAX` when
    /// none. Rescanned at open and right after every checkpoint's
    /// header swap — the pin-then-confirm protocol makes that enough
    /// (see the pins module docs) — so the per-append gate refresh
    /// never touches the filesystem.
    disk_gate: u64,
}

impl PagedStore {
    /// Create a fresh (empty) store on the real filesystem, truncating
    /// any existing one (equivalent to [`PagedStore::create_with`] over
    /// [`StdVfs`]). `cache_pages` is clamped to at least 2 frames
    /// (header + one node).
    ///
    /// # Errors
    /// Any failure creating the directory or the three store files.
    pub fn create(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedStore> {
        PagedStore::create_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// Create a fresh (empty) store on `vfs`, truncating any existing
    /// one.
    ///
    /// # Errors
    /// Any failure creating the directory or the three store files.
    pub fn create_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedStore> {
        let cache_pages = cache_pages.max(2);
        vfs.create_dir_all(dir)?;
        let index_path = pstore_path(dir, prefix);
        let mut pager = Pager::create_with(vfs, &index_path, cache_pages)?;
        let hdr = pager.allocate()?;
        debug_assert_eq!(hdr, 0);
        let header = StoreHeader {
            root: 0,
            committed_pages: 1,
            num_rows: 0,
            data_len: 0,
            num_groups: 0,
            epoch: 0,
            freelist_head: 0,
            free_pages: 0,
        };
        pager.update(0, |p| write_header(p, &header))?;
        pager.flush()?;
        pager.mark_committed();
        let wal = WalWriter::open_with(vfs, &pwal_path(dir, prefix), 0)?;
        let data_file = vfs.open(&pdata_path(dir, prefix), OpenMode::CreateTruncate)?;
        let data = RecordWriter::new(BufWriter::new(VfsCursor::new(data_file.clone())));
        let mut store = PagedStore {
            pager,
            tree: BTree::new_empty(1),
            wal,
            data,
            data_file,
            data_base: 0,
            group_counts: HashMap::new(),
            data_buffered: false,
            epoch: 0,
            poisoned: false,
            pin_key: (vfs.instance_id(), vfs.registry_key(&index_path)),
            disk_gate: u64::MAX,
        };
        store.rescan_disk_pins();
        Ok(store)
    }

    /// Open an existing store on the real filesystem (equivalent to
    /// [`PagedStore::open_with`] over [`StdVfs`]), running crash
    /// recovery: the header names the last committed tree/data state;
    /// any torn `.pdata`/`.pwal` tails are truncated, and intact WAL
    /// records are replayed on top.
    ///
    /// # Errors
    /// Fails on missing/corrupt store files (e.g. a data file shorter
    /// than the committed length) or any I/O error during replay.
    pub fn open(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedStore> {
        PagedStore::open_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// Open an existing store on `vfs`, running crash recovery.
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::open`].
    pub fn open_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedStore> {
        let cache_pages = cache_pages.max(2);
        let index_path = pstore_path(dir, prefix);
        let mut pager = Pager::open_with(vfs, &index_path, cache_pages)?;
        let header = read_header(&mut pager)?;
        // Discard uncommitted index pages beyond the committed watermark
        // (this also rewinds any free-list state), then rebuild the
        // free-list from the durable trunk chain — never from anything
        // newer, so a post-crash store can only hand out pages the
        // committed header accounts for.
        pager.reset_to(header.committed_pages.max(1))?;
        pager
            .load_freelist(header.freelist_head)
            .context("loading the paged store free-list chain")?;
        let tree = BTree::from_header(header.root, header.num_rows, header.committed_pages);

        // Rebuild per-group counts from the committed tree.
        let mut group_counts: HashMap<Vec<u8>, u64> = HashMap::new();
        let mut scan_err: Option<io::Error> = None;
        tree.scan_from(&mut pager, b"", |k, _v| match group_of_row_key(k) {
            Ok(g) => {
                *group_counts.entry(g.to_vec()).or_insert(0) += 1;
                true
            }
            Err(e) => {
                scan_err = Some(e);
                false
            }
        })?;
        if let Some(e) = scan_err {
            return Err(e).context("scanning committed paged index");
        }

        // Truncate the data file to the committed length (drops torn
        // appends; the WAL re-creates them) and position for append.
        let data_path = pdata_path(dir, prefix);
        let data_file = vfs.open(&data_path, OpenMode::Create)?;
        let actual = data_file.len()?;
        if actual < header.data_len {
            bail!(
                "paged data file {} is shorter ({actual}) than the committed length {}",
                data_path.display(),
                header.data_len
            );
        }
        data_file.set_len(header.data_len)?;
        let data =
            RecordWriter::new(BufWriter::new(VfsCursor::at(data_file.clone(), header.data_len)));

        // Collect intact WAL records, truncate any torn tail.
        let mut pending: Vec<Vec<u8>> = Vec::new();
        let report = wal::replay_with(vfs, &pwal_path(dir, prefix), |payload| {
            pending.push(payload.to_vec());
            Ok(())
        })?;
        let wal = WalWriter::open_with(vfs, &pwal_path(dir, prefix), report.valid_bytes)?;

        let mut store = PagedStore {
            pager,
            tree,
            wal,
            data,
            data_file,
            data_base: header.data_len,
            group_counts,
            data_buffered: false,
            epoch: header.epoch,
            poisoned: false,
            pin_key: (vfs.instance_id(), vfs.registry_key(&index_path)),
            disk_gate: u64::MAX,
        };
        store.rescan_disk_pins();
        store.refresh_reuse_gate();
        // Replay: re-apply each logged append to data + tree. Idempotent
        // across repeated crashes: nothing becomes durable until the next
        // checkpoint's header swap, and records from *before* the last
        // header swap (a crash between header flush and WAL reset) carry
        // an older epoch and are skipped as already committed.
        for payload in &pending {
            let (rec_epoch, group, ex_bytes) = decode_wal(payload)?;
            if rec_epoch < header.epoch {
                continue;
            }
            let (group, ex_bytes) = (group.to_vec(), ex_bytes.to_vec());
            store.apply(&group, &ex_bytes)?;
        }
        Ok(store)
    }

    /// Apply one append to the data file and index (no WAL write).
    fn apply(&mut self, group: &[u8], ex_bytes: &[u8]) -> Result<()> {
        let offset = self.data_base + self.data.bytes_written();
        self.data.write_record(ex_bytes)?;
        self.data_buffered = true;
        let seq = self.group_counts.get(group).copied().unwrap_or(0);
        let key = row_key(group, seq);
        self.tree
            .insert(&mut self.pager, &key, &offset.to_le_bytes())
            .context("inserting into paged index")?;
        // Counted only after the insert succeeded, so a failed apply
        // never leaves a phantom group (or an off-by-one seq) behind.
        self.group_counts.insert(group.to_vec(), seq + 1);
        Ok(())
    }

    /// Refuse mutations on a store whose in-memory state a failed append
    /// left suspect.
    fn check_poisoned(&self) -> Result<()> {
        if self.poisoned {
            bail!(
                "paged store is poisoned by an earlier failed append or checkpoint; \
                 reopen it to recover the last committed state"
            );
        }
        Ok(())
    }

    /// Sync the pager's reuse gate with the snapshot registry: free
    /// pages from epochs newer than the oldest pinned reader stay
    /// untouchable. Called before every mutation that might allocate,
    /// so a reader pinned since the last call is honored before any of
    /// its reachable pages could be handed out (pages it can reach are
    /// only *published* free by a later checkpoint, which refreshes
    /// again). Readers in other processes participate through the
    /// cached on-disk minimum ([`PagedStore::rescan_disk_pins`]).
    fn refresh_reuse_gate(&mut self) {
        if self.pager.reusable_page_count() == 0 {
            // Nothing is reusable, so no decision depends on the gate:
            // skip the process-global registry lock on the hot append
            // path. The first checkpoint that publishes frees runs with
            // a refreshed gate before any of them can be handed out
            // (every reuse/reclaim site refreshes first).
            return;
        }
        let gate = shared::min_pinned_epoch_for(&self.pin_key)
            .unwrap_or(u64::MAX)
            .min(self.disk_gate);
        self.pager.set_reuse_gate(gate);
    }

    /// Rescan the on-disk pin files ([`crate::store::pins`]) of readers
    /// in other processes and cache their minimum epoch for
    /// [`PagedStore::refresh_reuse_gate`]. Called at open and right
    /// after each checkpoint's header swap: a cross-process reader's
    /// pin-then-confirm only succeeds when its pin file landed before
    /// the swap — hence before this rescan — so every pin that protects
    /// the frees the swap just published is seen before any of them can
    /// be reused or truncated. Pins created later are at the new epoch
    /// or beyond and constrain only frees that later checkpoints
    /// publish, each behind its own rescan.
    fn rescan_disk_pins(&mut self) {
        if self.pin_key.0 != 0 {
            // Not the real filesystem: no other process can reach this
            // store, and the in-process registry covers everyone else.
            return;
        }
        self.disk_gate = match pins::scan_min(&self.pin_key.1) {
            Ok(Some(epoch)) => epoch,
            Ok(None) => u64::MAX,
            // An unreadable pin directory must block reuse, not allow
            // it: fail toward protecting unknown readers.
            Err(_) => 0,
        };
    }

    /// Append one example to a group: logged to the WAL, then applied.
    /// Call [`PagedStore::commit`] to make a batch of appends durable.
    ///
    /// # Errors
    /// Rejects (before logging) a group key that would overflow the
    /// index row budget; otherwise any WAL/data/index write failure. A
    /// failure while *applying* poisons the store — the half-mutated
    /// tree/data state cannot be trusted, so every later mutation is
    /// refused and the store must be reopened (recovering the last
    /// committed state, which can never include the failed append: its
    /// WAL frame is withdrawn).
    pub fn append(&mut self, group: &[u8], example: &Example) -> Result<()> {
        self.append_encoded(group, &example.encode())
    }

    /// [`PagedStore::append`] for an example already in its canonical
    /// [`Example::encode`] form — the parallel materialization path
    /// ([`crate::pipeline::run_partition_paged`]) moves encoded bytes
    /// from spill files straight into the store, and re-decoding them
    /// just to re-encode would double the write path's CPU cost.
    ///
    /// `ex_bytes` **must** be a valid `Example` encoding: the store
    /// treats it as opaque (nothing fails here on garbage), but every
    /// later `visit_group` would error decoding it.
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::append`].
    pub fn append_encoded(&mut self, group: &[u8], ex_bytes: &[u8]) -> Result<()> {
        self.check_poisoned()?;
        self.refresh_reuse_gate();
        // Validate BEFORE logging: a frame that cannot be applied must
        // never enter the WAL, or replay would fail on it at every
        // subsequent open (index row = group + 9-byte seq suffix key +
        // 8-byte offset value).
        if group.len() + 9 + 8 > crate::store::btree::MAX_ROW_BYTES {
            bail!(
                "group key of {} bytes exceeds the paged index row budget ({} bytes)",
                group.len(),
                crate::store::btree::MAX_ROW_BYTES - 17
            );
        }
        let mark = self.wal.mark();
        self.wal.append(&encode_wal(self.epoch, group, ex_bytes))?;
        if let Err(e) = self.apply(group, ex_bytes) {
            // The tree may be mid-split and the data writer may hold a
            // partial frame: no further mutation through this handle can
            // be trusted.
            self.poisoned = true;
            // Withdraw the frame: an append the caller is told failed
            // must never become durable at a later commit, or recovery
            // would replay an example the application believes was never
            // stored. (If the frame was already written out and its
            // truncation fails, the WAL's dirty-tail latch — plus the
            // poisoned flag above — keeps it out of every durability
            // promise.)
            self.wal.rewind(mark);
            return Err(e);
        }
        Ok(())
    }

    /// Durability point: fsync the WAL. Cheap — no index/data flush.
    ///
    /// # Errors
    /// Any WAL flush/fsync failure, or a store poisoned by an earlier
    /// failed append (see [`PagedStore::append`]).
    pub fn commit(&mut self) -> Result<()> {
        self.check_poisoned()?;
        self.wal.commit()?;
        Ok(())
    }

    /// The write half of [`PagedStore::commit`]: flush the WAL's append
    /// buffer (and truncate any dirty tail) without fsyncing. Nothing is
    /// durable until a later [`PagedStore::commit_sync`] succeeds. Used
    /// by the sharded store's group commit to flush every shard first
    /// and amortize the fsyncs afterwards.
    ///
    /// # Errors
    /// Any WAL truncation/flush failure, or a poisoned store.
    pub fn commit_flush(&mut self) -> Result<()> {
        self.check_poisoned()?;
        self.wal.commit_no_sync()?;
        Ok(())
    }

    /// The durability half of [`PagedStore::commit`]: fsync the WAL.
    /// Only a durability promise for appends already flushed by
    /// [`PagedStore::commit_flush`] (with nothing appended in between).
    ///
    /// # Errors
    /// Any fsync failure, or a poisoned store.
    pub fn commit_sync(&mut self) -> Result<()> {
        self.check_poisoned()?;
        self.wal.sync()?;
        Ok(())
    }

    /// Full checkpoint: data + index durable (ordered: data, free-list
    /// trunk chain + tree pages, then the single-page header swap), WAL
    /// reset, COW watermark advanced, and this epoch's frees published
    /// as reusable. Each checkpoint starts a new epoch — readers opened
    /// before it keep seeing the previous epoch's snapshot.
    ///
    /// # Errors
    /// Any flush/fsync failure at any of the ordered steps. The store on
    /// disk always stays recoverable (previous checkpoint + WAL), but a
    /// failure after the free-list serialization began **poisons this
    /// handle**: the in-memory list then describes a state the durable
    /// header never saw, and allocating from it could hand out pages the
    /// committed tree still owns. Reopen to recover. A store poisoned
    /// earlier is refused outright.
    pub fn checkpoint(&mut self) -> Result<()> {
        self.check_poisoned()?;
        self.refresh_reuse_gate();
        self.data.flush()?;
        self.data_file.sync()?;
        self.data_buffered = false;
        if let Err(e) = self.checkpoint_publish() {
            self.poisoned = true;
            return Err(e);
        }
        // The swap just made this epoch's frees reusable: pick up any
        // cross-process pins registered before it (their pin files are
        // on disk by now — see rescan_disk_pins) before a later
        // mutation can hand those pages out.
        self.rescan_disk_pins();
        self.refresh_reuse_gate();
        Ok(())
    }

    /// The poison-on-failure half of [`PagedStore::checkpoint`]: from
    /// the first free-list mutation to the WAL reset.
    fn checkpoint_publish(&mut self) -> Result<()> {
        let next_epoch = self.epoch + 1;
        let (freelist_head, free_pages) = self
            .pager
            .write_freelist(next_epoch)
            .context("serializing the free-list trunk chain")?;
        self.pager.flush()?;
        let header = StoreHeader {
            root: self.tree.root(),
            committed_pages: self.pager.num_pages(),
            num_rows: self.tree.num_rows(),
            data_len: self.data_base + self.data.bytes_written(),
            num_groups: self.group_counts.len() as u64,
            epoch: next_epoch,
            freelist_head,
            free_pages,
        };
        self.pager.update(0, |p| write_header(p, &header))?;
        self.pager.flush()?;
        self.tree.set_watermark(header.committed_pages);
        self.pager.mark_committed();
        self.epoch = next_epoch;
        self.wal.reset()?;
        Ok(())
    }

    /// Online compaction: migrate live index pages toward the file head
    /// and give the freed tail back to the filesystem, so the `.pstore`
    /// file shrinks to (roughly) its live size. Safe against crashes at
    /// any point — every move lands in free or fresh pages and is
    /// published by an ordinary checkpoint before anything it supersedes
    /// can be touched, so recovery always finds either the pre-pass or
    /// the post-pass committed state (logically identical). Safe against
    /// concurrent pinned readers too: pages their snapshots can reach
    /// are neither rewritten nor truncated (the epoch gate), at the cost
    /// of reclaiming less until the pins drop — with every free page
    /// gate-blocked, compact is a no-op (zero passes); with only some
    /// blocked, it skips relocation (whose copies could not land in the
    /// blocked holes and would grow the file) and just truncates any
    /// gate-eligible tail run.
    ///
    /// Unblocked, it runs up to four rewrite→checkpoint→truncate passes
    /// (the first pass's copies can land past the garbage they displace;
    /// later passes pull them down) and stops as soon as a pass reclaims
    /// nothing. Each pass rewrites the live tree once — compaction costs
    /// O(live) writes per pass, which is why it is an explicit call (or
    /// the CLI's `--auto-compact-threshold`) rather than automatic.
    ///
    /// # Errors
    /// Any I/O failure; a failure mid-pass poisons this handle (the
    /// durable store stays recoverable — reopen). A store poisoned
    /// earlier is refused outright.
    pub fn compact(&mut self) -> Result<CompactReport> {
        self.check_poisoned()?;
        self.checkpoint().context("checkpointing before compaction")?;
        let pages_before = self.pager.num_pages();
        let mut report = CompactReport {
            pages_before,
            pages_after: pages_before,
            pages_moved: 0,
            pages_reclaimed: 0,
            passes: 0,
        };
        loop {
            self.refresh_reuse_gate();
            let eligible = self.pager.reusable_under_gate();
            if eligible == 0 {
                // Already dense — or every free page is gate-blocked by
                // a pinned snapshot, so nothing can be relocated into or
                // truncated. Compact again once the readers are gone.
                break;
            }
            report.passes += 1;
            // Relocation only helps when NO free page is gate-blocked:
            // under a partial block the rewrite's copies would spill
            // past the blocked holes and the displaced pages (freed at
            // the new epoch) would be blocked too — the file would grow
            // by up to the live tree size per pass instead of shrinking.
            // With a partial block, settle for reclaiming whatever
            // gate-eligible run ends the file.
            let relocate = eligible == self.pager.reusable_page_count();
            if relocate {
                match self.tree.rewrite(&mut self.pager) {
                    Ok(moved) => report.pages_moved += moved,
                    Err(e) => {
                        self.poisoned = true;
                        return Err(e).context("rewriting live index pages");
                    }
                }
                self.checkpoint().context("publishing the compacted index")?;
                self.refresh_reuse_gate();
            }
            let reclaimed = self.pager.reclaim_tail();
            report.pages_reclaimed += reclaimed;
            if reclaimed > 0 {
                // Commit the smaller page count first; only then shrink
                // the file (a crash in between leaves a stale tail the
                // next open ignores).
                self.checkpoint().context("committing the reclaimed length")?;
                if let Err(e) = self.pager.sync_file_len() {
                    self.poisoned = true;
                    return Err(e).context("truncating the reclaimed tail");
                }
            }
            // Pass 1's copies often land past the garbage they displace
            // (nothing at the tail is free yet), so reclaiming nothing
            // only means "converged" from the second pass on.
            if !relocate || (report.passes >= 2 && reclaimed == 0) || report.passes >= 4 {
                break;
            }
        }
        report.pages_after = self.pager.num_pages();
        Ok(report)
    }

    /// Page-accounting snapshot: live/free/total index pages and file
    /// sizes (the Table-12b numbers, and `grouper stats`' garbage
    /// ratio). Uncommitted (pending) frees count as free.
    pub fn stat(&self) -> PagedStat {
        let total_pages = self.pager.num_pages();
        let free_pages = self.pager.free_page_count();
        PagedStat {
            total_pages,
            free_pages,
            live_pages: total_pages - free_pages,
            index_bytes: u64::from(total_pages) * PAGE_SIZE as u64,
            data_bytes: self.data_base + self.data.bytes_written(),
            epoch: self.epoch,
            num_rows: self.tree.num_rows(),
            num_groups: self.group_counts.len() as u64,
        }
    }

    /// Distinct groups appended so far (committed + uncommitted).
    pub fn num_groups(&self) -> usize {
        self.group_counts.len()
    }

    /// Current checkpoint epoch — the value a reader opened now would
    /// pin (advanced by every [`PagedStore::checkpoint`]).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Bytes currently in the WAL (including buffered, not-yet-written
    /// ones). Callers batching many appends bound their recovery cost by
    /// checkpointing once this passes a budget — exactly what
    /// [`PagedStore::build`] and the sharded materializer do.
    pub fn wal_len_bytes(&self) -> u64 {
        self.wal.len_bytes()
    }

    /// Total examples appended so far (committed + uncommitted).
    pub fn num_examples(&self) -> u64 {
        self.tree.num_rows()
    }

    /// Group keys in sorted order (deterministic across reopen).
    pub fn keys(&self) -> Vec<Vec<u8>> {
        let mut keys: Vec<Vec<u8>> = self.group_counts.keys().cloned().collect();
        keys.sort();
        keys
    }

    /// Visit one group's examples in append order. Returns false for an
    /// unknown group.
    ///
    /// # Errors
    /// Any index or data-file read failure, a corrupt index row, or a
    /// store poisoned by an earlier failed append (the half-mutated
    /// in-memory tree cannot be walked safely; reopen — or use a
    /// [`PagedReader`] — to read the committed state).
    pub fn visit_group(&mut self, group: &[u8], f: impl FnMut(Example)) -> Result<bool> {
        self.check_poisoned()?;
        if self.data_buffered {
            self.data.flush()?;
            self.data_buffered = false;
        }
        let data_file = self.data_file.clone();
        visit_group_via(&self.tree, &mut self.pager, &data_file, group, f)
    }

    /// Iterate groups in `order` (the Table 3 serial random-order walk).
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::visit_group`].
    pub fn visit_all(
        &mut self,
        order: &[Vec<u8>],
        mut f: impl FnMut(&[u8], Example),
    ) -> Result<()> {
        for key in order {
            self.visit_group(key, |ex| f(key, ex))?;
        }
        Ok(())
    }

    /// Index-cache hit/miss/eviction counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.pager.cache_stats()
    }

    /// Index page fetches from disk so far.
    pub fn pages_read(&self) -> u64 {
        self.pager.disk_reads()
    }

    /// Index pages physically written so far (evictions + flushes) —
    /// the numerator of the Table-12b write-amplification column.
    pub fn pages_written(&self) -> u64 {
        self.pager.disk_writes()
    }

    /// Materialize a whole base dataset (append + commit + checkpoint) —
    /// the builder mirroring `HierarchicalStore::build`. Returns the
    /// still-open (and still appendable) store so callers can report
    /// counts without paying a reopen + recovery scan.
    ///
    /// # Errors
    /// Any append, commit or checkpoint failure while materializing.
    pub fn build(
        dataset: &dyn BaseDataset,
        partitioner: &dyn Partitioner,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedStore> {
        PagedStore::build_with(&StdVfs, dataset, partitioner, dir, prefix, cache_pages)
    }

    /// [`PagedStore::build`] on an explicit [`Vfs`].
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::build`].
    pub fn build_with(
        vfs: &dyn Vfs,
        dataset: &dyn BaseDataset,
        partitioner: &dyn Partitioner,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedStore> {
        let mut store = PagedStore::create_with(vfs, dir, prefix, cache_pages)?;
        for ex in dataset.examples() {
            let key = partitioner.key(&ex);
            store.append(&key, &ex)?;
            if store.wal.len_bytes() >= BUILD_CHECKPOINT_WAL_BYTES {
                store.checkpoint()?;
            }
        }
        store.commit()?;
        store.checkpoint()?;
        Ok(store)
    }
}

/// Read-only view over a checkpointed store, with a bounded (sharded)
/// LRU cache. **`Send + Sync`**: wrap it in an `Arc` (or borrow it from
/// scoped threads) and any number of threads can call
/// [`PagedReader::visit_group`] simultaneously — each call reads the
/// index through its own snapshot-bounded handle and opens its own data
/// cursor, so no `&mut` is needed anywhere on the read path.
///
/// The reader is pinned to the checkpoint epoch current at open time
/// (see [`PagedReader::epoch`]): the storage engine's copy-on-write
/// contract means a writer appending to the same store can never mutate
/// a page this snapshot can reach, so reads stay consistent without any
/// reader/writer lock. To observe newer appends, open a new reader.
///
/// Opening a store whose WAL still holds records (a "hot journal") first
/// runs full recovery — open for append, checkpoint, drop — exactly the
/// SQLite open-time contract. **Because recovery rewrites the store**,
/// this path must not race a live [`PagedStore`] writer that has
/// committed but not yet checkpointed: like SQLite without its file
/// locks, the engine assumes a single live writer, so either open
/// readers after the writer checkpointed (the WAL is then cold and the
/// open is purely read-only), or keep writer and reader opens
/// serialized in the embedding process.
pub struct PagedReader {
    pager: SharedPager,
    snapshot: ReadSnapshot,
    tree: BTree,
    data_file: Arc<dyn VfsFile>,
    keys: Vec<Vec<u8>>,
    num_examples: u64,
    /// Registered in the process-wide snapshot registry for this
    /// reader's lifetime: while held, the writer's free-list will
    /// neither reuse nor truncate any page this snapshot can reach.
    _pin: EpochPin,
    /// The cross-process half of the same pin: an on-disk pin file
    /// ([`crate::store::pins`]) a writer in **another** process folds
    /// into its reuse gate. `None` off the real filesystem (no other
    /// process can reach the store) or on read-only media (no writer
    /// can exist there).
    _disk_pin: Option<DiskPin>,
    /// Header page accounting captured at open (for [`PagedReader::stat`]).
    free_pages: u32,
    data_len: u64,
}

impl PagedReader {
    /// Open the store at `dir/<prefix>` on the real filesystem
    /// (equivalent to [`PagedReader::open_with`] over [`StdVfs`]) for
    /// (possibly concurrent) reading, with `cache_pages` total LRU
    /// frames (clamped to at least 2).
    ///
    /// # Errors
    /// Fails when the store files are missing or corrupt, when WAL
    /// probing/recovery fails, or on any I/O error during the group
    /// enumeration scan.
    pub fn open(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedReader> {
        PagedReader::open_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// Open the store at `dir/<prefix>` on `vfs` for (possibly
    /// concurrent) reading.
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::open`].
    pub fn open_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedReader> {
        PagedReader::open_inner(vfs, dir, prefix, cache_pages, true, ReadOpts::default())
    }

    /// [`PagedReader::open_with`] with explicit hot-read-path options
    /// ([`ReadOpts`]): mmap-backed reads, vectored group-scan prefetch,
    /// and the cache replacement policy. All opt-in; the defaults
    /// reproduce [`PagedReader::open_with`] exactly.
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::open`].
    pub fn open_with_opts(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
        opts: ReadOpts,
    ) -> Result<PagedReader> {
        PagedReader::open_inner(vfs, dir, prefix, cache_pages, true, opts)
    }

    /// Open the last **checkpointed** snapshot at `dir/<prefix>` on the
    /// real filesystem (see [`PagedReader::open_snapshot_with`]).
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::open_snapshot_with`].
    pub fn open_snapshot(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedReader> {
        PagedReader::open_snapshot_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// Open the last **checkpointed** snapshot on `vfs`, never touching
    /// the WAL: committed-but-not-yet-checkpointed appends stay
    /// invisible instead of being replayed, and no recovery runs. This
    /// is the only open that never writes a store byte (its sole write
    /// is the sidecar pin file below, which no store read ever
    /// depends on), so — unlike the recovering
    /// [`PagedReader::open_with`] — it is safe to run concurrently with
    /// a live [`PagedStore`] writer mid-append, even one in another
    /// process. The serving layer ([`crate::serve`]) opens every
    /// per-connection snapshot this way; combined with the epoch pins
    /// it takes below — in-process registry plus on-disk pin file
    /// ([`crate::store::pins`]) — that is the whole single-live-writer
    /// + N-readers contract.
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::open`], minus WAL probing.
    pub fn open_snapshot_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedReader> {
        PagedReader::open_inner(vfs, dir, prefix, cache_pages, false, ReadOpts::default())
    }

    /// [`PagedReader::open_snapshot_with`] with explicit hot-read-path
    /// options ([`ReadOpts`]). Like the plain snapshot open it never
    /// touches the WAL and never writes a store byte, so it stays safe
    /// to run concurrently with a live writer.
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::open_snapshot_with`].
    pub fn open_snapshot_with_opts(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
        opts: ReadOpts,
    ) -> Result<PagedReader> {
        PagedReader::open_inner(vfs, dir, prefix, cache_pages, false, opts)
    }

    fn open_inner(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
        recover_hot_wal: bool,
        opts: ReadOpts,
    ) -> Result<PagedReader> {
        let cache_pages = cache_pages.max(2);
        if recover_hot_wal {
            let wal_path = pwal_path(dir, prefix);
            // An I/O error probing the journal must fail the open, not be
            // mistaken for "no journal" (which would silently serve stale
            // pre-WAL data).
            let hot =
                wal::has_valid_records_with(vfs, &wal_path).context("probing paged store WAL")?;
            if hot {
                let mut store = PagedStore::open_with(vfs, dir, prefix, cache_pages)
                    .context("recovering hot paged store")?;
                store.checkpoint()?;
            }
        }
        let index_path = pstore_path(dir, prefix);
        let pager = SharedPager::open_with_opts(vfs, &index_path, cache_pages, opts)?;
        // The checkpointing writer rewrites page 0 in place; a read that
        // races it can be torn. The header checksum detects that, and a
        // brief retry rides out the in-flight write.
        let read_header_checked = || -> Result<StoreHeader> {
            let mut page = pager.read_header_fresh()?;
            let mut attempts = 0;
            while !header_checksum_ok(&page) && attempts < 20 {
                std::thread::sleep(std::time::Duration::from_millis(1));
                page = pager.read_header_fresh()?;
                attempts += 1;
            }
            parse_header(&page).context("reading paged store header")
        };
        // Pin-then-confirm: the pin must be registered *before* the
        // header it describes can be superseded, or a checkpoint racing
        // this open could free-and-reuse pages of our snapshot in the
        // gap. Re-reading the header after pinning closes it: if the
        // epoch is unchanged, every later checkpoint (the only thing
        // that publishes frees) sees our pin when it consults the gate.
        // On the real filesystem the pin is registered twice — in the
        // process registry for a same-process writer, and as an on-disk
        // pin file for a writer in another process, whose post-swap
        // pin rescan plays the role the same confirm protects against
        // (see crate::store::pins).
        let vfs_id = vfs.instance_id();
        let registry_path = vfs.registry_key(&index_path);
        let durable = vfs_id == 0;
        let mut header = read_header_checked()?;
        let mut pin = shared::pin_epoch(vfs_id, &registry_path, header.epoch);
        let mut disk_pin = if durable {
            pins::create(&registry_path, header.epoch)
                .context("registering on-disk snapshot pin")?
        } else {
            None
        };
        let mut confirmed = false;
        for _ in 0..50 {
            let confirm = read_header_checked()?;
            if confirm.epoch == header.epoch {
                confirmed = true;
                break;
            }
            header = confirm;
            pin = shared::pin_epoch(vfs_id, &registry_path, header.epoch);
            if durable {
                // Create the new epoch's pin before the assignment
                // drops the old one, so some pin always covers us.
                disk_pin = pins::create(&registry_path, header.epoch)
                    .context("registering on-disk snapshot pin")?;
            }
        }
        if !confirmed {
            // Never proceed on an unconfirmed pin: one more checkpoint
            // could have slipped between the last header read and the
            // pin registration, and an unseen pin is exactly the gate
            // bypass this loop exists to prevent.
            bail!(
                "paged reader open raced a continuously checkpointing writer \
                 50 times without pinning a stable epoch; retry when the \
                 writer quiesces"
            );
        }
        let snapshot = ReadSnapshot { bound: header.committed_pages, epoch: header.epoch };
        let tree = BTree::from_header(header.root, header.num_rows, u32::MAX);
        // Enumerate distinct groups (one ordered leaf walk).
        let mut handle = pager.reader(snapshot);
        let mut keys: Vec<Vec<u8>> = Vec::new();
        let mut scan_err: Option<io::Error> = None;
        tree.scan_from(&mut handle, b"", |k, _| match group_of_row_key(k) {
            Ok(g) => {
                if keys.last().map(|l| l.as_slice()) != Some(g) {
                    keys.push(g.to_vec());
                }
                true
            }
            Err(e) => {
                scan_err = Some(e);
                false
            }
        })?;
        if let Some(e) = scan_err {
            return Err(e).context("enumerating paged groups");
        }
        let data_path = pdata_path(dir, prefix);
        let data_file = match vfs.open(&data_path, OpenMode::Read) {
            Ok(f) => f,
            Err(e) if e.kind() == io::ErrorKind::NotFound && header.data_len == 0 => {
                // A legal post-crash image: the data file was created but
                // never fsynced, so its directory entry is gone. Nothing
                // committed points into it — serve reads from a fresh
                // empty file, exactly like the writer's recovery does.
                vfs.open(&data_path, OpenMode::Create)?
            }
            Err(e) => return Err(e).context("opening paged data file"),
        };
        let data_file = if opts.mmap {
            // Same best-effort mapping the index handle got inside the
            // pager: bit-identical reads, plain pread fallback whenever
            // the file has no OS descriptor or the map is refused.
            map_read_only(&data_file).unwrap_or(data_file)
        } else {
            data_file
        };
        if data_file.len()? < header.data_len {
            bail!(
                "paged data file {} is shorter ({}) than the committed length {}",
                data_path.display(),
                data_file.len()?,
                header.data_len
            );
        }
        Ok(PagedReader {
            pager,
            snapshot,
            tree,
            data_file,
            keys,
            num_examples: header.num_rows,
            _pin: pin,
            _disk_pin: disk_pin,
            free_pages: header.free_pages,
            data_len: header.data_len,
        })
    }

    /// Page-accounting snapshot of the pinned checkpoint (header
    /// numbers; a concurrent writer's uncommitted work is invisible, as
    /// everywhere else on the read path).
    pub fn stat(&self) -> PagedStat {
        let total_pages = self.snapshot.bound;
        PagedStat {
            total_pages,
            free_pages: self.free_pages,
            live_pages: total_pages - self.free_pages,
            index_bytes: u64::from(total_pages) * PAGE_SIZE as u64,
            data_bytes: self.data_len,
            epoch: self.snapshot.epoch,
            num_rows: self.num_examples,
            num_groups: self.keys.len() as u64,
        }
    }

    /// Distinct groups in the snapshot.
    pub fn num_groups(&self) -> usize {
        self.keys.len()
    }

    /// Total examples in the snapshot.
    pub fn num_examples(&self) -> u64 {
        self.num_examples
    }

    /// Group keys in sorted order.
    pub fn keys(&self) -> &[Vec<u8>] {
        &self.keys
    }

    /// The checkpoint epoch this reader is pinned to: appends
    /// checkpointed after open land in a later epoch and are invisible
    /// here.
    pub fn epoch(&self) -> u64 {
        self.snapshot.epoch
    }

    /// Index page fetches from disk so far (cost introspection), summed
    /// across all reading threads.
    pub fn pages_read(&self) -> u64 {
        self.pager.disk_reads()
    }

    /// Aggregate index-cache hit/miss/eviction counters (all threads).
    pub fn cache_stats(&self) -> CacheStats {
        self.pager.cache_stats()
    }

    /// Uncached header (page 0) reads so far. Together with
    /// [`PagedReader::cache_stats`] this closes the accounting identity
    /// `pages_read == misses + header_reads` (absent I/O errors).
    pub fn header_reads(&self) -> u64 {
        self.pager.header_reads()
    }

    /// Index tree depth (1 = single leaf).
    ///
    /// # Errors
    /// Any index page-read failure.
    pub fn index_depth(&self) -> Result<u32> {
        Ok(self.tree.depth(&mut self.pager.reader(self.snapshot))?)
    }

    /// Construct one group's dataset: a B+tree range scan for locations
    /// (cost governed by the LRU cache), then one data read per example.
    /// Returns false for an unknown group. Takes `&self`: safe to call
    /// from many threads at once.
    ///
    /// # Errors
    /// Any index or data-file read failure, or a corrupt index row.
    pub fn visit_group(&self, group: &[u8], f: impl FnMut(Example)) -> Result<bool> {
        let mut handle = self.pager.reader(self.snapshot);
        visit_group_via(&self.tree, &mut handle, &self.data_file, group, f)
    }

    /// [`PagedReader::visit_group`] without decoding: `f` receives each
    /// record's raw bytes (one canonical [`Example::encode`] each, in
    /// append order) and returns whether to continue — false stops the
    /// scan without reading the group's remaining records. The
    /// byte-moving fast path: re-framing a group for the trainer's
    /// client pipeline costs zero serialization work here. Returns
    /// false for an unknown group; `&self`, so thread-safe like every
    /// read method.
    ///
    /// # Errors
    /// Any index or data-file read failure, or a corrupt index row.
    pub fn visit_group_raw(&self, group: &[u8], f: impl FnMut(&[u8]) -> bool) -> Result<bool> {
        let mut handle = self.pager.reader(self.snapshot);
        visit_group_raw_via(&self.tree, &mut handle, &self.data_file, group, f)
    }

    /// Iterate groups in `order` (Table 3's serial random-order walk —
    /// or one thread's slice of it).
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::visit_group`].
    pub fn visit_all(&self, order: &[Vec<u8>], mut f: impl FnMut(&[u8], Example)) -> Result<()> {
        for key in order {
            self.visit_group(key, |ex| f(key, ex))?;
        }
        Ok(())
    }

    /// One group as a prefetched
    /// [`StreamedGroup`](crate::formats::streaming::StreamedGroup) — the
    /// adapter that lets the federated trainer's client-data pipeline
    /// consume a paged store like any streamed cohort. Pure byte
    /// movement: the raw record bytes are re-framed without ever
    /// decoding an example (see [`PagedReader::visit_group_raw`]).
    /// `None` for an unknown group. (The paged index does not track word
    /// counts; the group's `words` field is 0.)
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::visit_group`].
    pub fn streamed_group(
        &self,
        group: &[u8],
    ) -> Result<Option<crate::formats::streaming::StreamedGroup>> {
        let mut w = RecordWriter::new(Vec::new());
        let mut frame_err: Option<io::Error> = None;
        let mut n = 0u64;
        let found = self.visit_group_raw(group, |bytes| match w.write_record(bytes) {
            Ok(()) => {
                n += 1;
                true
            }
            Err(e) => {
                frame_err = Some(e);
                false
            }
        })?;
        if let Some(e) = frame_err {
            return Err(e).context("re-framing group examples");
        }
        if !found {
            return Ok(None);
        }
        Ok(Some(crate::formats::streaming::StreamedGroup::from_framed_bytes(
            group.to_vec(),
            n,
            0,
            w.into_inner(),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, SyntheticTextDataset};
    use crate::pipeline::FeatureKey;
    use crate::store::vfs::MemVfs;

    /// Most tests here run disk-free over [`MemVfs`]; `mem_dir` is just a
    /// namespace inside it.
    fn mem_dir(name: &str) -> PathBuf {
        PathBuf::from("/mem").join(name)
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("grouper_paged_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn row_key_roundtrip() {
        let k = row_key(b"news.example.com", 42);
        assert_eq!(group_of_row_key(&k).unwrap(), b"news.example.com");
        // Seq is big-endian: append order == byte order.
        assert!(row_key(b"g", 1) < row_key(b"g", 2));
        assert!(row_key(b"g", 255) < row_key(b"g", 256));
    }

    #[test]
    fn build_and_read_matches_oracle() {
        let dir = tmp("oracle");
        let mut spec = DatasetSpec::fedccnews_mini(12, 5);
        spec.max_group_words = 1200;
        let ds = SyntheticTextDataset::new(spec);
        let store =
            PagedStore::build(&ds, &FeatureKey::new("domain"), &dir, "news", 32).unwrap();
        assert_eq!(store.num_examples(), ds.len() as u64);
        drop(store);
        let r = PagedReader::open(&dir, "news", 32).unwrap();
        assert_eq!(r.num_groups(), 12);
        assert_eq!(r.num_examples(), ds.len() as u64);
        for g in 0..12 {
            let key = ds.spec.group_key(g).into_bytes();
            let mut got = Vec::new();
            assert!(r.visit_group(&key, |ex| got.push(ex.encode())).unwrap());
            let want: Vec<_> = ds.group_examples_iter(g).map(|e| e.encode()).collect();
            assert_eq!(got, want, "group {g}");
        }
        assert!(!r.visit_group(b"not-there", |_| {}).unwrap());
        drop(r);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn appends_after_reopen_extend_existing_groups() {
        let vfs = MemVfs::new();
        let dir = mem_dir("reopen");
        {
            let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
            s.append(b"g1", &Example::text("a")).unwrap();
            s.append(b"g2", &Example::text("b")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        {
            let mut s = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
            assert_eq!(s.num_examples(), 2);
            s.append(b"g1", &Example::text("c")).unwrap();
            s.append(b"g3", &Example::text("d")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        let r = PagedReader::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(r.num_groups(), 3);
        let mut texts = Vec::new();
        assert!(r
            .visit_group(b"g1", |ex| texts.push(ex.get_str("text").unwrap().to_string()))
            .unwrap());
        assert_eq!(texts, vec!["a".to_string(), "c".to_string()]);
    }

    #[test]
    fn crash_without_checkpoint_recovers_from_wal() {
        let vfs = MemVfs::new();
        let dir = mem_dir("crash");
        {
            let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
            for i in 0..50 {
                let g = format!("group-{}", i % 7);
                s.append(g.as_bytes(), &Example::text(&format!("ex{i}"))).unwrap();
            }
            s.commit().unwrap();
            // Crash: drop without checkpoint. The index pages and header
            // were never flushed; only the WAL (and OS-buffered data
            // bytes) survive.
        }
        let mut s = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(s.num_examples(), 50, "WAL replay must restore every append");
        assert_eq!(s.num_groups(), 7);
        let mut count = 0;
        let keys = s.keys();
        for k in &keys {
            assert!(s.visit_group(k, |_| count += 1).unwrap());
        }
        assert_eq!(count, 50);
    }

    #[test]
    fn crash_between_header_swap_and_wal_reset_does_not_double_apply() {
        // The nastiest checkpoint window: header (with the new state) is
        // durable, but the WAL truncation never happened. Simulated by
        // saving the WAL right before checkpoint and restoring it after.
        let vfs = MemVfs::new();
        let dir = mem_dir("epoch");
        let wal_path = dir.join("x.pwal");
        {
            let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
            for i in 0..20 {
                let g = format!("g{}", i % 4);
                s.append(g.as_bytes(), &Example::text(&format!("t{i}"))).unwrap();
            }
            s.commit().unwrap();
            let saved_wal = vfs.file_bytes(&wal_path).unwrap();
            s.checkpoint().unwrap(); // header swap + wal reset
            drop(s);
            vfs.install(&wal_path, saved_wal); // reset "never happened"
        }
        let mut s = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(
            s.num_examples(),
            20,
            "stale-epoch WAL records must be recognized as already committed"
        );
        let mut count = 0;
        for k in &s.keys() {
            assert!(s.visit_group(k, |_| count += 1).unwrap());
        }
        assert_eq!(count, 20);
        // And the store keeps working: new appends land in the new epoch.
        s.append(b"g0", &Example::text("new")).unwrap();
        s.commit().unwrap();
        drop(s);
        let s2 = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(s2.num_examples(), 21);
    }

    #[test]
    fn oversized_group_key_is_rejected_before_logging() {
        let vfs = MemVfs::new();
        let dir = mem_dir("bigkey");
        let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
        let big = vec![b'g'; 4000];
        assert!(s.append(&big, &Example::text("t")).is_err());
        // The reject must not have poisoned the WAL: appends keep working
        // and the store reopens (replays) cleanly.
        s.append(b"ok", &Example::text("t")).unwrap();
        s.commit().unwrap();
        drop(s);
        let s2 = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(s2.num_examples(), 1);
    }

    #[test]
    fn torn_header_is_detected_not_misparsed() {
        let vfs = MemVfs::new();
        let dir = mem_dir("tornheader");
        {
            let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
            s.append(b"g", &Example::text("t")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        // Flip a byte inside the checksummed span (the epoch field), as a
        // torn in-place header write would.
        let pstore = dir.join("x.pstore");
        let mut bytes = vfs.file_bytes(&pstore).unwrap();
        bytes[40] ^= 0xFF;
        vfs.install(&pstore, bytes);
        let err = PagedReader::open_with(&vfs, &dir, "x", 16).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        assert!(PagedStore::open_with(&vfs, &dir, "x", 16).is_err());
    }

    /// A VFS that serves a torn image for the first N reads of a chosen
    /// file's page 0, then the real bytes — a deterministic stand-in for
    /// a reader racing the checkpoint's in-place header rewrite (no
    /// wall-clock, no flakes).
    struct TornHeaderVfs {
        inner: MemVfs,
        victim: PathBuf,
        torn: Vec<u8>,
        remaining: std::sync::atomic::AtomicU32,
    }

    impl Vfs for TornHeaderVfs {
        fn open(&self, path: &Path, mode: OpenMode) -> io::Result<Arc<dyn VfsFile>> {
            let inner = self.inner.open(path, mode)?;
            if path == self.victim {
                // The handle must be 'static (Arc<dyn VfsFile>), so the
                // torn state is shared into it rather than borrowed.
                Ok(Arc::new(TornHeaderFile {
                    inner,
                    torn: self.torn.clone(),
                    remaining: Arc::new(std::sync::atomic::AtomicU32::new(
                        self.remaining.load(std::sync::atomic::Ordering::Relaxed),
                    )),
                }))
            } else {
                Ok(inner)
            }
        }
        fn create_dir_all(&self, path: &Path) -> io::Result<()> {
            self.inner.create_dir_all(path)
        }
        fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
            self.inner.list_dir(dir)
        }
        fn instance_id(&self) -> u64 {
            self.inner.instance_id()
        }
    }

    /// The handle [`TornHeaderVfs::open`] hands out for the victim file.
    struct TornHeaderFile {
        inner: Arc<dyn VfsFile>,
        torn: Vec<u8>,
        remaining: Arc<std::sync::atomic::AtomicU32>,
    }

    impl VfsFile for TornHeaderFile {
        fn read_at(&self, buf: &mut [u8], offset: u64) -> io::Result<usize> {
            use std::sync::atomic::Ordering;
            if (offset as usize) < self.torn.len() {
                let left = self.remaining.load(Ordering::Relaxed);
                if left > 0 {
                    self.remaining.store(left - 1, Ordering::Relaxed);
                    let src = &self.torn[offset as usize..];
                    let n = buf.len().min(src.len());
                    buf[..n].copy_from_slice(&src[..n]);
                    return Ok(n);
                }
            }
            self.inner.read_at(buf, offset)
        }
        fn write_all_at(&self, buf: &[u8], offset: u64) -> io::Result<()> {
            self.inner.write_all_at(buf, offset)
        }
        fn set_len(&self, len: u64) -> io::Result<()> {
            self.inner.set_len(len)
        }
        fn sync(&self) -> io::Result<()> {
            self.inner.sync()
        }
        fn len(&self) -> io::Result<u64> {
            self.inner.len()
        }
    }

    #[test]
    fn torn_header_read_is_retried_until_the_writer_finishes() {
        // A reader racing the checkpoint's in-place header rewrite sees a
        // torn page 0, detects it by CRC, and retries until the rewrite
        // completes. Deterministic: the VFS serves the torn image for the
        // first 3 header reads (well inside the ~20-retry budget), then
        // the real bytes — no wall-clock race.
        let mem = MemVfs::new();
        let dir = mem_dir("tornretry");
        {
            let mut s = PagedStore::create_with(&mem, &dir, "x", 16).unwrap();
            s.append(b"g", &Example::text("t")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        let pstore = dir.join("x.pstore");
        let good = mem.file_bytes(&pstore).unwrap();
        let mut torn = good[..crate::store::PAGE_SIZE].to_vec();
        torn[40] ^= 0xFF; // mid-rewrite image: checksum cannot match
        let vfs = TornHeaderVfs {
            inner: mem,
            victim: pstore,
            torn,
            remaining: std::sync::atomic::AtomicU32::new(3),
        };
        let r = PagedReader::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(r.num_examples(), 1, "retry must land on the completed header");
    }

    #[test]
    fn failed_append_poisons_the_store_and_is_never_replayed() {
        // An append whose *apply* fails (here: an injected I/O error on a
        // cache-eviction write-back or data flush mid-append) withdraws
        // its WAL frame and poisons the handle: the half-mutated
        // tree/data state cannot be trusted, so further mutations are
        // refused, and reopening recovers the last committed state — the
        // failed example can never be resurrected.
        use crate::store::vfs::{FaultPlan, FaultVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let dir = mem_dir("failedappend");
        // Tiny cache: appends constantly evict, giving the injected
        // failure a write site inside apply().
        let mut s = PagedStore::create_with(&fv, &dir, "x", 2).unwrap();
        for i in 0..40 {
            let g = format!("g{}", i % 5);
            s.append(g.as_bytes(), &Example::text(&format!("t{i}"))).unwrap();
        }
        s.commit().unwrap();
        fv.set_plan(FaultPlan {
            fail_write: Some(fv.writes_attempted() + 1),
            ..Default::default()
        });
        let mut hit = false;
        for i in 40..400 {
            let g = format!("g{}", i % 5);
            if s.append(g.as_bytes(), &Example::text(&format!("t{i}"))).is_err() {
                hit = true;
                break;
            }
        }
        assert!(hit, "the injected write failure must hit an append");
        fv.disarm();
        // The handle is poisoned: every further mutation is refused.
        let err = s.append(b"g0", &Example::text("nope")).unwrap_err();
        assert!(format!("{err:#}").contains("poisoned"), "{err:#}");
        assert!(s.commit().is_err());
        assert!(s.checkpoint().is_err());
        assert!(
            s.visit_group(b"g0", |_| {}).is_err(),
            "tree walks through the poisoned handle are refused too"
        );
        drop(s);
        // Reopen: recovery lands on the last committed state; neither the
        // failed append nor anything after it exists.
        let s2 = PagedStore::open_with(&fv, &dir, "x", 8).unwrap();
        assert_eq!(
            s2.num_examples(),
            40,
            "recovery must land exactly on the last committed state"
        );
    }

    /// Deterministic churn: `rounds` of appends with a checkpoint after
    /// each, so every round's COW supersessions become published frees.
    fn churn(s: &mut PagedStore, rounds: u32, per_round: u32, tag: &str) {
        for r in 0..rounds {
            for i in 0..per_round {
                let g = format!("g{}", i % 5);
                s.append(g.as_bytes(), &Example::text(&format!("{tag}-{r}-{i}"))).unwrap();
            }
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
    }

    #[test]
    fn checkpoints_free_superseded_pages_and_appends_reuse_them() {
        let vfs = MemVfs::new();
        let dir = mem_dir("reclaim");
        let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
        churn(&mut s, 6, 40, "a");
        let stat = s.stat();
        assert!(stat.free_pages > 0, "COW churn must strand free pages");
        assert_eq!(stat.total_pages, stat.live_pages + stat.free_pages);
        assert_eq!(stat.num_rows, 240);
        // Identical further churn, once against the primed free list and
        // once (in a parallel store) against a freshly created one: total
        // growth must be slower when reuse is possible than the fresh
        // store's total footprint for the same appends.
        let before = s.stat().total_pages;
        churn(&mut s, 6, 40, "b");
        let grown = s.stat().total_pages - before;
        let mut fresh = PagedStore::create_with(&vfs, &mem_dir("reclaim-fresh"), "x", 16).unwrap();
        churn(&mut fresh, 6, 40, "b");
        assert!(
            grown < fresh.stat().total_pages,
            "reuse growth ({grown} pages) must undercut a from-scratch store \
             ({} pages) for the same appends",
            fresh.stat().total_pages
        );
    }

    #[test]
    fn free_list_survives_reopen() {
        let vfs = MemVfs::new();
        let dir = mem_dir("flreopen");
        let free_before;
        {
            let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
            churn(&mut s, 5, 30, "a");
            free_before = s.stat().free_pages;
            assert!(free_before > 0);
        }
        let s = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(
            s.stat().free_pages,
            free_before,
            "the durable trunk chain must reload the whole free list"
        );
    }

    #[test]
    fn compact_shrinks_the_file_and_preserves_every_group() {
        let vfs = MemVfs::new();
        let dir = mem_dir("compact");
        let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
        churn(&mut s, 8, 40, "a");
        // Oracle before compaction.
        let keys = s.keys();
        let mut want: Vec<(Vec<u8>, Vec<Vec<u8>>)> = Vec::new();
        for k in &keys {
            let mut v = Vec::new();
            assert!(s.visit_group(k, |ex| v.push(ex.encode())).unwrap());
            want.push((k.clone(), v));
        }
        let stat_before = s.stat();
        assert!(stat_before.free_pages > 0, "churn must have stranded garbage");
        let report = s.compact().unwrap();
        assert!(report.passes >= 1);
        assert!(
            report.pages_after < report.pages_before,
            "compaction must shrink the index file ({report:?})"
        );
        assert!(
            report.pages_reclaimed >= report.pages_before - report.pages_after,
            "reclaim accounting covers at least the net shrink ({report:?})"
        );
        let stat_after = s.stat();
        // File size is proportional to live data now: at least half the
        // stranded garbage must be gone (in practice nearly all of it —
        // only chain/bookkeeping slack survives).
        assert!(
            stat_after.total_pages <= stat_before.total_pages - stat_before.free_pages / 2,
            "compacted file must shed most of the garbage ({stat_before:?} -> {stat_after:?})"
        );
        // Contents survive compaction, through this handle…
        for (k, v) in &want {
            let mut got = Vec::new();
            assert!(s.visit_group(k, |ex| got.push(ex.encode())).unwrap());
            assert_eq!(&got, v, "group {k:?} after compact");
        }
        drop(s);
        // …through recovery…
        let mut reopened = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        for (k, v) in &want {
            let mut got = Vec::new();
            assert!(reopened.visit_group(k, |ex| got.push(ex.encode())).unwrap());
            assert_eq!(&got, v, "group {k:?} after compact + reopen");
        }
        // …and the store stays appendable.
        reopened.append(b"g0", &Example::text("post-compact")).unwrap();
        reopened.commit().unwrap();
        reopened.checkpoint().unwrap();
        drop(reopened);
        // …and through the concurrent reader.
        let r = PagedReader::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(r.num_examples(), 8 * 40 + 1);
        let rstat = r.stat();
        assert_eq!(rstat.total_pages, rstat.live_pages + rstat.free_pages);
    }

    #[test]
    fn compact_on_a_dense_store_is_a_cheap_no_op() {
        let vfs = MemVfs::new();
        let dir = mem_dir("denser");
        let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
        for i in 0..30 {
            s.append(b"g", &Example::text(&format!("t{i}"))).unwrap();
        }
        s.commit().unwrap();
        s.checkpoint().unwrap();
        let report = s.compact().unwrap();
        assert_eq!(report.passes, 0, "a store with no free pages has nothing to move");
        assert_eq!(report.pages_before, report.pages_after);
    }

    /// A reader in ANOTHER process never touches this process's pin
    /// registry — only its on-disk pin file protects it. The writer must
    /// fold that file into its reuse gate (at the checkpoint-time
    /// rescan) and refuse to reclaim anything the pin covers, then
    /// reclaim normally once the file is gone.
    #[test]
    fn a_foreign_process_disk_pin_blocks_compaction_until_removed() {
        let dir = tmp("foreign-pin");
        let mut s = PagedStore::create_with(&StdVfs, &dir, "x", 16).unwrap();
        // Simulate the foreign reader by writing its pin file directly,
        // bypassing the in-process registry entirely. It pins the empty
        // store's epoch, so every page freed below postdates it. (The
        // recorded pid is this test's own, so the liveness scan counts
        // the pin as alive.)
        let foreign = crate::store::pins::create(&s.pin_key.1, s.epoch())
            .unwrap()
            .expect("a real filesystem supports pin files");
        churn(&mut s, 8, 40, "a");
        assert!(s.stat().free_pages > 0, "churn must strand garbage");
        let blocked = s.compact().unwrap();
        assert_eq!(
            blocked.passes, 0,
            "every free page postdates the foreign pin; compaction must not touch any ({blocked:?})"
        );
        assert_eq!(blocked.pages_reclaimed, 0);
        // Reader exited: its pin file is removed, and the next
        // compaction's leading checkpoint rescans the pin directory.
        drop(foreign);
        let unblocked = s.compact().unwrap();
        assert!(
            unblocked.pages_reclaimed > 0,
            "with the pin gone compaction must reclaim the garbage ({unblocked:?})"
        );
    }

    #[test]
    fn append_to_a_freed_then_reused_page_crash_recovers_cleanly() {
        // A freed page that was reused (rewritten on disk) before the
        // crash must never leak its uncommitted bytes into recovery: the
        // durable header's tree cannot reach it, and the durable chain
        // still lists it as free.
        let vfs = MemVfs::new();
        let dir = mem_dir("reuse-crash");
        // Tiny cache so uncommitted appends hit the disk via evictions.
        let mut s = PagedStore::create_with(&vfs, &dir, "x", 2).unwrap();
        churn(&mut s, 4, 30, "a");
        let committed = {
            let mut out = std::collections::BTreeMap::new();
            for k in s.keys() {
                let mut v = Vec::new();
                assert!(s.visit_group(&k, |ex| v.push(ex.encode())).unwrap());
                out.insert(k, v);
            }
            out
        };
        assert!(s.stat().free_pages > 0);
        // Uncommitted epoch: plenty of appends (reusing freed pages,
        // evicting them to disk), neither committed nor checkpointed.
        for i in 0..60 {
            s.append(b"g0", &Example::text(&format!("uncommitted{i}"))).unwrap();
        }
        // "Crash": drop the handle; the WAL tail was never fsynced, and
        // on MemVfs the unflushed WAL buffer dies with the writer.
        drop(s);
        let mut recovered = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        let mut got = std::collections::BTreeMap::new();
        for k in recovered.keys() {
            let mut v = Vec::new();
            assert!(recovered.visit_group(&k, |ex| v.push(ex.encode())).unwrap());
            got.insert(k, v);
        }
        assert_eq!(got, committed, "recovery must land exactly on the committed state");
    }

    #[test]
    fn store_reads_its_own_uncommitted_appends() {
        let vfs = MemVfs::new();
        let dir = mem_dir("readback");
        let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
        s.append(b"g", &Example::text("one")).unwrap();
        s.append(b"g", &Example::text("two")).unwrap();
        let mut texts = Vec::new();
        assert!(s
            .visit_group(b"g", |ex| texts.push(ex.get_str("text").unwrap().to_string()))
            .unwrap());
        assert_eq!(texts, vec!["one".to_string(), "two".to_string()]);
    }
}
