//! The paged format: a WAL-backed **appendable** group store over the
//! storage engine ([`crate::store`]) — the fourth column of Table 2/3.
//!
//! The three seed formats are all materialize-once: none can grow after
//! prep, which is exactly the limitation the paper ascribes to both the
//! in-memory systems (LEAF, FedJAX) and the TFF/SQL-backed hierarchical
//! store. `PagedStore` removes it:
//!
//! * examples append to `<prefix>.pdata` (TFRecord framing, arrival
//!   order);
//! * the index is a *mutable* B+tree in `<prefix>.pstore` mapping
//!   `group \0 seq(BE u64)` to the example's data offset, growing by
//!   page splits — no rebuild, ever;
//! * every append is logged to `<prefix>.pwal` first.
//!   [`PagedStore::commit`] (WAL fsync) is the durability point;
//!   [`PagedStore::checkpoint`] makes the tree+data durable, swaps the
//!   header page, and resets the WAL. Because the B+tree is
//!   copy-on-write above the committed watermark, a crash at *any*
//!   point between checkpoints leaves the last committed tree intact on
//!   disk; reopening truncates torn tails and replays the WAL.
//!
//! Group access cost is governed by the pager's LRU cache size — the
//! tunable middle ground between the hierarchical format's cold index
//! walks and the in-memory format's everything-resident map.
//!
//! Reads are **concurrent**: [`PagedReader`] is `Send + Sync` and every
//! access method takes `&self`, so a FedAvg round can fetch its whole
//! cohort's client datasets in parallel through one shared reader (the
//! index goes through [`crate::store::shared::SharedPager`]'s sharded
//! cache; each call opens its own data cursor). A reader is a
//! *snapshot* at the checkpoint epoch current when it was opened: the
//! B+tree's copy-on-write watermark guarantees a concurrent appender
//! never mutates a page the snapshot can reach.
//!
//! Layout of the `.pstore` header (page 0): magic, B+tree root page,
//! committed page count, committed row count, durable `.pdata` byte
//! length, committed group count, checkpoint epoch, and a CRC32C over
//! the preceding fields. The checksum lets a concurrent reader detect a
//! torn page-0 read (it races the checkpoint's in-place header write)
//! and retry, instead of parsing fields from two different epochs.
//!
//! Known trade-off: `open` walks the committed index once (O(rows)
//! sequential leaf scan through the cache) to rebuild per-group counts /
//! the group list. A persisted `.hgroups`-style sidecar would make open
//! O(groups); left as follow-up since open happens once per process.

#![deny(missing_docs)]

use std::collections::HashMap;
use std::fs::File;
use std::io::{self, BufWriter, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::corpus::BaseDataset;
use crate::pipeline::Partitioner;
use crate::records::crc32c::crc32c;
use crate::records::tfrecord::{RecordReader, RecordWriter};
use crate::records::Example;
use crate::store::btree::BTree;
use crate::store::cache::CacheStats;
use crate::store::page::{Page, PageId};
use crate::store::pager::{PageRead, Pager};
use crate::store::shared::{ReadSnapshot, SharedPager};
use crate::store::wal::{self, WalWriter};

const MAGIC: &[u8; 8] = b"GRPPAG01";

/// Default LRU cache size (pages) for stores and readers.
pub const DEFAULT_CACHE_PAGES: usize = 64;

fn pstore_path(dir: &Path, prefix: &str) -> PathBuf {
    dir.join(format!("{prefix}.pstore"))
}

fn pdata_path(dir: &Path, prefix: &str) -> PathBuf {
    dir.join(format!("{prefix}.pdata"))
}

fn pwal_path(dir: &Path, prefix: &str) -> PathBuf {
    dir.join(format!("{prefix}.pwal"))
}

/// `group \0 seq(BE)` — the fixed-width suffix makes the group recoverable
/// from any row key, and big-endian seq keeps a group's rows in append
/// order under the tree's byte ordering.
fn row_key(group: &[u8], seq: u64) -> Vec<u8> {
    let mut k = Vec::with_capacity(group.len() + 9);
    k.extend_from_slice(group);
    k.push(0);
    k.extend_from_slice(&seq.to_be_bytes());
    k
}

fn group_of_row_key(k: &[u8]) -> io::Result<&[u8]> {
    if k.len() < 9 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "paged row key shorter than its seq suffix",
        ));
    }
    Ok(&k[..k.len() - 9])
}

/// Header snapshot (page 0 of `.pstore`).
#[derive(Clone, Copy, Debug)]
struct StoreHeader {
    root: PageId,
    committed_pages: u32,
    num_rows: u64,
    data_len: u64,
    num_groups: u64,
    /// Checkpoint epoch. Every WAL record carries the epoch it was
    /// appended under; recovery applies only records with
    /// `epoch >= header.epoch`. That makes the crash window *between*
    /// the checkpoint's header swap and the WAL reset safe: such a WAL
    /// still holds records, but they carry the previous epoch and are
    /// recognized as already committed instead of being applied twice.
    epoch: u64,
}

/// Byte span of the header fields covered by the trailing checksum.
const HEADER_CRC_SPAN: usize = 48;

fn header_checksum_ok(page: &Page) -> bool {
    page.get_bytes(0, 8) == MAGIC
        && page.get_u32(HEADER_CRC_SPAN) == crc32c(page.get_bytes(0, HEADER_CRC_SPAN))
}

fn parse_header(page: &Page) -> Result<StoreHeader> {
    if page.get_bytes(0, 8) != MAGIC {
        bail!("bad paged store magic");
    }
    if !header_checksum_ok(page) {
        bail!("paged store header checksum mismatch (torn or corrupt header page)");
    }
    Ok(StoreHeader {
        root: page.get_u32(8),
        committed_pages: page.get_u32(12),
        num_rows: page.get_u64(16),
        data_len: page.get_u64(24),
        num_groups: page.get_u64(32),
        epoch: page.get_u64(40),
    })
}

fn read_header(pager: &mut Pager) -> Result<StoreHeader> {
    let page = pager.read(0).context("reading paged store header")?;
    parse_header(page)
}

fn write_header(page: &mut Page, h: &StoreHeader) {
    page.put_bytes(0, MAGIC);
    page.put_u32(8, h.root);
    page.put_u32(12, h.committed_pages);
    page.put_u64(16, h.num_rows);
    page.put_u64(24, h.data_len);
    page.put_u64(32, h.num_groups);
    page.put_u64(40, h.epoch);
    let crc = crc32c(page.get_bytes(0, HEADER_CRC_SPAN));
    page.put_u32(HEADER_CRC_SPAN, crc);
}

/// WAL payload: `u64 LE epoch | u32 LE group length | group | example`.
fn encode_wal(epoch: u64, group: &[u8], example_bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + group.len() + example_bytes.len());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(group.len() as u32).to_le_bytes());
    out.extend_from_slice(group);
    out.extend_from_slice(example_bytes);
    out
}

fn decode_wal(payload: &[u8]) -> io::Result<(u64, &[u8], &[u8])> {
    if payload.len() < 12 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "short wal payload"));
    }
    let epoch = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let klen = u32::from_le_bytes(payload[8..12].try_into().unwrap()) as usize;
    if 12 + klen > payload.len() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "wal payload group length out of bounds",
        ));
    }
    Ok((epoch, &payload[12..12 + klen], &payload[12 + klen..]))
}

/// One group's dataset, shared by [`PagedStore`] and [`PagedReader`]: a
/// B+tree range scan for data offsets (cost governed by the LRU cache),
/// then one data-file read per example. Returns false for an unknown
/// group.
fn visit_group_via<R: PageRead>(
    tree: &BTree,
    pager: &mut R,
    data_path: &Path,
    group: &[u8],
    mut f: impl FnMut(Example),
) -> Result<bool> {
    let mut prefix = Vec::with_capacity(group.len() + 1);
    prefix.extend_from_slice(group);
    prefix.push(0);
    let expected_len = prefix.len() + 8;
    let mut offsets: Vec<u64> = Vec::new();
    let mut bad_value = false;
    tree.scan_prefix(pager, &prefix, |k, v| {
        if k.len() == expected_len {
            match <[u8; 8]>::try_from(v) {
                Ok(le) => offsets.push(u64::from_le_bytes(le)),
                Err(_) => bad_value = true,
            }
        }
    })?;
    if bad_value {
        bail!("paged index holds a corrupt offset value for group {:?}", group);
    }
    if offsets.is_empty() {
        return Ok(false);
    }
    let mut r = RecordReader::open(data_path)?;
    for off in offsets {
        r.seek_to(off)?;
        let bytes = r.next_record()?.context("paged index points past data end")?;
        f(Example::decode(&bytes)?);
    }
    Ok(true)
}

/// The appendable, WAL-backed group store (writer + read access).
pub struct PagedStore {
    dir: PathBuf,
    prefix: String,
    pager: Pager,
    tree: BTree,
    wal: WalWriter,
    data: RecordWriter<BufWriter<File>>,
    /// Handle for fsyncing `.pdata` (the writer owns a buffered clone).
    data_file: File,
    /// Byte offset of `.pdata` where this writer session started.
    data_base: u64,
    /// Per-group example counts (`group -> next seq`).
    group_counts: HashMap<Vec<u8>, u64>,
    /// True when the data writer has unflushed buffered bytes.
    data_buffered: bool,
    /// Current checkpoint epoch (see [`StoreHeader::epoch`]).
    epoch: u64,
}

impl PagedStore {
    /// Create a fresh (empty) store, truncating any existing one.
    /// `cache_pages` is clamped to at least 2 frames (header + one node).
    ///
    /// # Errors
    /// Any failure creating the directory or the three store files.
    pub fn create(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedStore> {
        let cache_pages = cache_pages.max(2);
        std::fs::create_dir_all(dir)?;
        let mut pager = Pager::create(&pstore_path(dir, prefix), cache_pages)?;
        let hdr = pager.allocate()?;
        debug_assert_eq!(hdr, 0);
        let header = StoreHeader {
            root: 0,
            committed_pages: 1,
            num_rows: 0,
            data_len: 0,
            num_groups: 0,
            epoch: 0,
        };
        pager.update(0, |p| write_header(p, &header))?;
        pager.flush()?;
        let wal = WalWriter::open(&pwal_path(dir, prefix), 0)?;
        let data_path = pdata_path(dir, prefix);
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&data_path)?;
        let data_file = file.try_clone()?;
        let data = RecordWriter::new(BufWriter::new(file));
        Ok(PagedStore {
            dir: dir.to_path_buf(),
            prefix: prefix.to_string(),
            pager,
            tree: BTree::new_empty(1),
            wal,
            data,
            data_file,
            data_base: 0,
            group_counts: HashMap::new(),
            data_buffered: false,
            epoch: 0,
        })
    }

    /// Open an existing store, running crash recovery: the header names
    /// the last committed tree/data state; any torn `.pdata`/`.pwal`
    /// tails are truncated, and intact WAL records are replayed on top.
    ///
    /// # Errors
    /// Fails on missing/corrupt store files (e.g. a data file shorter
    /// than the committed length) or any I/O error during replay.
    pub fn open(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedStore> {
        let cache_pages = cache_pages.max(2);
        let mut pager = Pager::open(&pstore_path(dir, prefix), cache_pages)?;
        let header = read_header(&mut pager)?;
        // Discard uncommitted index pages beyond the committed watermark.
        pager.reset_to(header.committed_pages.max(1))?;
        let tree = BTree::from_header(header.root, header.num_rows, header.committed_pages);

        // Rebuild per-group counts from the committed tree.
        let mut group_counts: HashMap<Vec<u8>, u64> = HashMap::new();
        let mut scan_err: Option<io::Error> = None;
        tree.scan_from(&mut pager, b"", |k, _v| match group_of_row_key(k) {
            Ok(g) => {
                *group_counts.entry(g.to_vec()).or_insert(0) += 1;
                true
            }
            Err(e) => {
                scan_err = Some(e);
                false
            }
        })?;
        if let Some(e) = scan_err {
            return Err(e).context("scanning committed paged index");
        }

        // Truncate the data file to the committed length (drops torn
        // appends; the WAL re-creates them) and position for append.
        let data_path = pdata_path(dir, prefix);
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&data_path)?;
        let actual = file.metadata()?.len();
        if actual < header.data_len {
            bail!(
                "paged data file {} is shorter ({actual}) than the committed length {}",
                data_path.display(),
                header.data_len
            );
        }
        file.set_len(header.data_len)?;
        let mut file = file;
        file.seek(SeekFrom::Start(header.data_len))?;
        let data_file = file.try_clone()?;
        let data = RecordWriter::new(BufWriter::new(file));

        // Collect intact WAL records, truncate any torn tail.
        let mut pending: Vec<Vec<u8>> = Vec::new();
        let report = wal::replay(&pwal_path(dir, prefix), |payload| {
            pending.push(payload.to_vec());
            Ok(())
        })?;
        let wal = WalWriter::open(&pwal_path(dir, prefix), report.valid_bytes)?;

        let mut store = PagedStore {
            dir: dir.to_path_buf(),
            prefix: prefix.to_string(),
            pager,
            tree,
            wal,
            data,
            data_file,
            data_base: header.data_len,
            group_counts,
            data_buffered: false,
            epoch: header.epoch,
        };
        // Replay: re-apply each logged append to data + tree. Idempotent
        // across repeated crashes: nothing becomes durable until the next
        // checkpoint's header swap, and records from *before* the last
        // header swap (a crash between header flush and WAL reset) carry
        // an older epoch and are skipped as already committed.
        for payload in &pending {
            let (rec_epoch, group, ex_bytes) = decode_wal(payload)?;
            if rec_epoch < header.epoch {
                continue;
            }
            let (group, ex_bytes) = (group.to_vec(), ex_bytes.to_vec());
            store.apply(&group, &ex_bytes)?;
        }
        Ok(store)
    }

    /// Apply one append to the data file and index (no WAL write).
    fn apply(&mut self, group: &[u8], ex_bytes: &[u8]) -> Result<()> {
        let offset = self.data_base + self.data.bytes_written();
        self.data.write_record(ex_bytes)?;
        self.data_buffered = true;
        let seq = self.group_counts.entry(group.to_vec()).or_insert(0);
        let key = row_key(group, *seq);
        *seq += 1;
        self.tree
            .insert(&mut self.pager, &key, &offset.to_le_bytes())
            .context("inserting into paged index")?;
        Ok(())
    }

    /// Append one example to a group: logged to the WAL, then applied.
    /// Call [`PagedStore::commit`] to make a batch of appends durable.
    ///
    /// # Errors
    /// Rejects (before logging) a group key that would overflow the
    /// index row budget; otherwise any WAL/data/index write failure.
    pub fn append(&mut self, group: &[u8], example: &Example) -> Result<()> {
        // Validate BEFORE logging: a frame that cannot be applied must
        // never enter the WAL, or replay would fail on it at every
        // subsequent open (index row = group + 9-byte seq suffix key +
        // 8-byte offset value).
        if group.len() + 9 + 8 > crate::store::btree::MAX_ROW_BYTES {
            bail!(
                "group key of {} bytes exceeds the paged index row budget ({} bytes)",
                group.len(),
                crate::store::btree::MAX_ROW_BYTES - 17
            );
        }
        let ex_bytes = example.encode();
        self.wal.append(&encode_wal(self.epoch, group, &ex_bytes))?;
        self.apply(group, &ex_bytes)
    }

    /// Durability point: fsync the WAL. Cheap — no index/data flush.
    ///
    /// # Errors
    /// Any WAL flush/fsync failure.
    pub fn commit(&mut self) -> Result<()> {
        self.wal.commit()?;
        Ok(())
    }

    /// Full checkpoint: data + index durable (ordered: data, tree pages,
    /// then the single-page header swap), WAL reset, COW watermark
    /// advanced. Each checkpoint starts a new epoch — readers opened
    /// before it keep seeing the previous epoch's snapshot.
    ///
    /// # Errors
    /// Any flush/fsync failure at any of the ordered steps; the store
    /// stays recoverable from the previous checkpoint + WAL.
    pub fn checkpoint(&mut self) -> Result<()> {
        self.data.flush()?;
        self.data_file.sync_data()?;
        self.data_buffered = false;
        self.pager.flush()?;
        let header = StoreHeader {
            root: self.tree.root(),
            committed_pages: self.pager.num_pages(),
            num_rows: self.tree.num_rows(),
            data_len: self.data_base + self.data.bytes_written(),
            num_groups: self.group_counts.len() as u64,
            epoch: self.epoch + 1,
        };
        self.pager.update(0, |p| write_header(p, &header))?;
        self.pager.flush()?;
        self.tree.set_watermark(header.committed_pages);
        self.epoch = header.epoch;
        self.wal.reset()?;
        Ok(())
    }

    /// Distinct groups appended so far (committed + uncommitted).
    pub fn num_groups(&self) -> usize {
        self.group_counts.len()
    }

    /// Total examples appended so far (committed + uncommitted).
    pub fn num_examples(&self) -> u64 {
        self.tree.num_rows()
    }

    /// Group keys in sorted order (deterministic across reopen).
    pub fn keys(&self) -> Vec<Vec<u8>> {
        let mut keys: Vec<Vec<u8>> = self.group_counts.keys().cloned().collect();
        keys.sort();
        keys
    }

    /// Visit one group's examples in append order. Returns false for an
    /// unknown group.
    ///
    /// # Errors
    /// Any index or data-file read failure, or a corrupt index row.
    pub fn visit_group(&mut self, group: &[u8], f: impl FnMut(Example)) -> Result<bool> {
        if self.data_buffered {
            self.data.flush()?;
            self.data_buffered = false;
        }
        let data_path = pdata_path(&self.dir, &self.prefix);
        visit_group_via(&self.tree, &mut self.pager, &data_path, group, f)
    }

    /// Iterate groups in `order` (the Table 3 serial random-order walk).
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::visit_group`].
    pub fn visit_all(
        &mut self,
        order: &[Vec<u8>],
        mut f: impl FnMut(&[u8], Example),
    ) -> Result<()> {
        for key in order {
            self.visit_group(key, |ex| f(key, ex))?;
        }
        Ok(())
    }

    /// Index-cache hit/miss/eviction counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.pager.cache_stats()
    }

    /// Index page fetches from disk so far.
    pub fn pages_read(&self) -> u64 {
        self.pager.disk_reads()
    }

    /// Materialize a whole base dataset (append + commit + checkpoint) —
    /// the builder mirroring `HierarchicalStore::build`. Returns the
    /// still-open (and still appendable) store so callers can report
    /// counts without paying a reopen + recovery scan.
    ///
    /// # Errors
    /// Any append, commit or checkpoint failure while materializing.
    pub fn build(
        dataset: &dyn BaseDataset,
        partitioner: &dyn Partitioner,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedStore> {
        // Checkpoint periodically so the WAL (and the memory a recovery
        // from a mid-build crash needs) stays bounded regardless of
        // dataset size.
        const CHECKPOINT_WAL_BYTES: u64 = 64 * 1024 * 1024;
        let mut store = PagedStore::create(dir, prefix, cache_pages)?;
        for ex in dataset.examples() {
            let key = partitioner.key(&ex);
            store.append(&key, &ex)?;
            if store.wal.len_bytes() >= CHECKPOINT_WAL_BYTES {
                store.checkpoint()?;
            }
        }
        store.commit()?;
        store.checkpoint()?;
        Ok(store)
    }
}

/// Read-only view over a checkpointed store, with a bounded (sharded)
/// LRU cache. **`Send + Sync`**: wrap it in an `Arc` (or borrow it from
/// scoped threads) and any number of threads can call
/// [`PagedReader::visit_group`] simultaneously — each call reads the
/// index through its own snapshot-bounded handle and opens its own data
/// cursor, so no `&mut` is needed anywhere on the read path.
///
/// The reader is pinned to the checkpoint epoch current at open time
/// (see [`PagedReader::epoch`]): the storage engine's copy-on-write
/// contract means a writer appending to the same store can never mutate
/// a page this snapshot can reach, so reads stay consistent without any
/// reader/writer lock. To observe newer appends, open a new reader.
///
/// Opening a store whose WAL still holds records (a "hot journal") first
/// runs full recovery — open for append, checkpoint, drop — exactly the
/// SQLite open-time contract. **Because recovery rewrites the store**,
/// this path must not race a live [`PagedStore`] writer that has
/// committed but not yet checkpointed: like SQLite without its file
/// locks, the engine assumes a single live writer, so either open
/// readers after the writer checkpointed (the WAL is then cold and the
/// open is purely read-only), or keep writer and reader opens
/// serialized in the embedding process.
pub struct PagedReader {
    pager: SharedPager,
    snapshot: ReadSnapshot,
    tree: BTree,
    data_path: PathBuf,
    keys: Vec<Vec<u8>>,
    num_examples: u64,
}

impl PagedReader {
    /// Open the store at `dir/<prefix>` for (possibly concurrent)
    /// reading, with `cache_pages` total LRU frames (clamped to at
    /// least 2).
    ///
    /// # Errors
    /// Fails when the store files are missing or corrupt, when WAL
    /// probing/recovery fails, or on any I/O error during the group
    /// enumeration scan.
    pub fn open(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedReader> {
        let cache_pages = cache_pages.max(2);
        let wal_path = pwal_path(dir, prefix);
        // An I/O error probing the journal must fail the open, not be
        // mistaken for "no journal" (which would silently serve stale
        // pre-WAL data).
        let hot = wal::has_valid_records(&wal_path).context("probing paged store WAL")?;
        if hot {
            let mut store = PagedStore::open(dir, prefix, cache_pages)
                .context("recovering hot paged store")?;
            store.checkpoint()?;
        }
        let pager = SharedPager::open(&pstore_path(dir, prefix), cache_pages)?;
        // The checkpointing writer rewrites page 0 in place; a read that
        // races it can be torn. The header checksum detects that, and a
        // brief retry rides out the in-flight write.
        let mut page = pager.read_header_fresh()?;
        let mut attempts = 0;
        while !header_checksum_ok(&page) && attempts < 20 {
            std::thread::sleep(std::time::Duration::from_millis(1));
            page = pager.read_header_fresh()?;
            attempts += 1;
        }
        let header = parse_header(&page).context("reading paged store header")?;
        let snapshot = ReadSnapshot { bound: header.committed_pages, epoch: header.epoch };
        let tree = BTree::from_header(header.root, header.num_rows, u32::MAX);
        // Enumerate distinct groups (one ordered leaf walk).
        let mut handle = pager.reader(snapshot);
        let mut keys: Vec<Vec<u8>> = Vec::new();
        let mut scan_err: Option<io::Error> = None;
        tree.scan_from(&mut handle, b"", |k, _| match group_of_row_key(k) {
            Ok(g) => {
                if keys.last().map(|l| l.as_slice()) != Some(g) {
                    keys.push(g.to_vec());
                }
                true
            }
            Err(e) => {
                scan_err = Some(e);
                false
            }
        })?;
        if let Some(e) = scan_err {
            return Err(e).context("enumerating paged groups");
        }
        Ok(PagedReader {
            pager,
            snapshot,
            tree,
            data_path: pdata_path(dir, prefix),
            keys,
            num_examples: header.num_rows,
        })
    }

    /// Distinct groups in the snapshot.
    pub fn num_groups(&self) -> usize {
        self.keys.len()
    }

    /// Total examples in the snapshot.
    pub fn num_examples(&self) -> u64 {
        self.num_examples
    }

    /// Group keys in sorted order.
    pub fn keys(&self) -> &[Vec<u8>] {
        &self.keys
    }

    /// The checkpoint epoch this reader is pinned to: appends
    /// checkpointed after open land in a later epoch and are invisible
    /// here.
    pub fn epoch(&self) -> u64 {
        self.snapshot.epoch
    }

    /// Index page fetches from disk so far (cost introspection), summed
    /// across all reading threads.
    pub fn pages_read(&self) -> u64 {
        self.pager.disk_reads()
    }

    /// Aggregate index-cache hit/miss/eviction counters (all threads).
    pub fn cache_stats(&self) -> CacheStats {
        self.pager.cache_stats()
    }

    /// Index tree depth (1 = single leaf).
    ///
    /// # Errors
    /// Any index page-read failure.
    pub fn index_depth(&self) -> Result<u32> {
        Ok(self.tree.depth(&mut self.pager.reader(self.snapshot))?)
    }

    /// Construct one group's dataset: a B+tree range scan for locations
    /// (cost governed by the LRU cache), then one data read per example.
    /// Returns false for an unknown group. Takes `&self`: safe to call
    /// from many threads at once.
    ///
    /// # Errors
    /// Any index or data-file read failure, or a corrupt index row.
    pub fn visit_group(&self, group: &[u8], f: impl FnMut(Example)) -> Result<bool> {
        let mut handle = self.pager.reader(self.snapshot);
        visit_group_via(&self.tree, &mut handle, &self.data_path, group, f)
    }

    /// Iterate groups in `order` (Table 3's serial random-order walk —
    /// or one thread's slice of it).
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::visit_group`].
    pub fn visit_all(&self, order: &[Vec<u8>], mut f: impl FnMut(&[u8], Example)) -> Result<()> {
        for key in order {
            self.visit_group(key, |ex| f(key, ex))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, SyntheticTextDataset};
    use crate::pipeline::FeatureKey;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("grouper_paged_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn row_key_roundtrip() {
        let k = row_key(b"news.example.com", 42);
        assert_eq!(group_of_row_key(&k).unwrap(), b"news.example.com");
        // Seq is big-endian: append order == byte order.
        assert!(row_key(b"g", 1) < row_key(b"g", 2));
        assert!(row_key(b"g", 255) < row_key(b"g", 256));
    }

    #[test]
    fn build_and_read_matches_oracle() {
        let dir = tmp("oracle");
        let mut spec = DatasetSpec::fedccnews_mini(12, 5);
        spec.max_group_words = 1200;
        let ds = SyntheticTextDataset::new(spec);
        let store =
            PagedStore::build(&ds, &FeatureKey::new("domain"), &dir, "news", 32).unwrap();
        assert_eq!(store.num_examples(), ds.len() as u64);
        drop(store);
        let r = PagedReader::open(&dir, "news", 32).unwrap();
        assert_eq!(r.num_groups(), 12);
        assert_eq!(r.num_examples(), ds.len() as u64);
        for g in 0..12 {
            let key = ds.spec.group_key(g).into_bytes();
            let mut got = Vec::new();
            assert!(r.visit_group(&key, |ex| got.push(ex.encode())).unwrap());
            let want: Vec<_> = ds.group_examples_iter(g).map(|e| e.encode()).collect();
            assert_eq!(got, want, "group {g}");
        }
        assert!(!r.visit_group(b"not-there", |_| {}).unwrap());
    }

    #[test]
    fn appends_after_reopen_extend_existing_groups() {
        let dir = tmp("reopen");
        {
            let mut s = PagedStore::create(&dir, "x", 16).unwrap();
            s.append(b"g1", &Example::text("a")).unwrap();
            s.append(b"g2", &Example::text("b")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        {
            let mut s = PagedStore::open(&dir, "x", 16).unwrap();
            assert_eq!(s.num_examples(), 2);
            s.append(b"g1", &Example::text("c")).unwrap();
            s.append(b"g3", &Example::text("d")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        let r = PagedReader::open(&dir, "x", 16).unwrap();
        assert_eq!(r.num_groups(), 3);
        let mut texts = Vec::new();
        assert!(r
            .visit_group(b"g1", |ex| texts.push(ex.get_str("text").unwrap().to_string()))
            .unwrap());
        assert_eq!(texts, vec!["a".to_string(), "c".to_string()]);
    }

    #[test]
    fn crash_without_checkpoint_recovers_from_wal() {
        let dir = tmp("crash");
        {
            let mut s = PagedStore::create(&dir, "x", 16).unwrap();
            for i in 0..50 {
                let g = format!("group-{}", i % 7);
                s.append(g.as_bytes(), &Example::text(&format!("ex{i}"))).unwrap();
            }
            s.commit().unwrap();
            // Crash: drop without checkpoint. The index pages and header
            // were never flushed; only the WAL (and OS-buffered data
            // bytes) survive.
        }
        let mut s = PagedStore::open(&dir, "x", 16).unwrap();
        assert_eq!(s.num_examples(), 50, "WAL replay must restore every append");
        assert_eq!(s.num_groups(), 7);
        let mut count = 0;
        let keys = s.keys();
        for k in &keys {
            assert!(s.visit_group(k, |_| count += 1).unwrap());
        }
        assert_eq!(count, 50);
    }

    #[test]
    fn crash_between_header_swap_and_wal_reset_does_not_double_apply() {
        // The nastiest checkpoint window: header (with the new state) is
        // durable, but the WAL truncation never happened. Simulated by
        // saving the WAL right before checkpoint and restoring it after.
        let dir = tmp("epoch");
        let wal_path = dir.join("x.pwal");
        {
            let mut s = PagedStore::create(&dir, "x", 16).unwrap();
            for i in 0..20 {
                let g = format!("g{}", i % 4);
                s.append(g.as_bytes(), &Example::text(&format!("t{i}"))).unwrap();
            }
            s.commit().unwrap();
            let saved_wal = std::fs::read(&wal_path).unwrap();
            s.checkpoint().unwrap(); // header swap + wal reset
            drop(s);
            std::fs::write(&wal_path, &saved_wal).unwrap(); // reset "never happened"
        }
        let mut s = PagedStore::open(&dir, "x", 16).unwrap();
        assert_eq!(
            s.num_examples(),
            20,
            "stale-epoch WAL records must be recognized as already committed"
        );
        let mut count = 0;
        for k in &s.keys() {
            assert!(s.visit_group(k, |_| count += 1).unwrap());
        }
        assert_eq!(count, 20);
        // And the store keeps working: new appends land in the new epoch.
        s.append(b"g0", &Example::text("new")).unwrap();
        s.commit().unwrap();
        drop(s);
        let s2 = PagedStore::open(&dir, "x", 16).unwrap();
        assert_eq!(s2.num_examples(), 21);
    }

    #[test]
    fn oversized_group_key_is_rejected_before_logging() {
        let dir = tmp("bigkey");
        let mut s = PagedStore::create(&dir, "x", 16).unwrap();
        let big = vec![b'g'; 4000];
        assert!(s.append(&big, &Example::text("t")).is_err());
        // The reject must not have poisoned the WAL: appends keep working
        // and the store reopens (replays) cleanly.
        s.append(b"ok", &Example::text("t")).unwrap();
        s.commit().unwrap();
        drop(s);
        let s2 = PagedStore::open(&dir, "x", 16).unwrap();
        assert_eq!(s2.num_examples(), 1);
    }

    #[test]
    fn torn_header_is_detected_not_misparsed() {
        let dir = tmp("tornheader");
        {
            let mut s = PagedStore::create(&dir, "x", 16).unwrap();
            s.append(b"g", &Example::text("t")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        // Flip a byte inside the checksummed span (the epoch field), as a
        // torn in-place header write would.
        let pstore = dir.join("x.pstore");
        let mut bytes = std::fs::read(&pstore).unwrap();
        bytes[40] ^= 0xFF;
        std::fs::write(&pstore, &bytes).unwrap();
        let err = PagedReader::open(&dir, "x", 16).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        assert!(PagedStore::open(&dir, "x", 16).is_err());
    }

    #[test]
    fn store_reads_its_own_uncommitted_appends() {
        let dir = tmp("readback");
        let mut s = PagedStore::create(&dir, "x", 16).unwrap();
        s.append(b"g", &Example::text("one")).unwrap();
        s.append(b"g", &Example::text("two")).unwrap();
        let mut texts = Vec::new();
        assert!(s
            .visit_group(b"g", |ex| texts.push(ex.get_str("text").unwrap().to_string()))
            .unwrap());
        assert_eq!(texts, vec!["one".to_string(), "two".to_string()]);
    }
}
