//! The paged format: a WAL-backed **appendable** group store over the
//! storage engine ([`crate::store`]) — the fourth column of Table 2/3.
//!
//! The three seed formats are all materialize-once: none can grow after
//! prep, which is exactly the limitation the paper ascribes to both the
//! in-memory systems (LEAF, FedJAX) and the TFF/SQL-backed hierarchical
//! store. `PagedStore` removes it:
//!
//! * examples append to `<prefix>.pdata` (TFRecord framing, arrival
//!   order);
//! * the index is a *mutable* B+tree in `<prefix>.pstore` mapping
//!   `group \0 seq(BE u64)` to the example's data offset, growing by
//!   page splits — no rebuild, ever;
//! * every append is logged to `<prefix>.pwal` first.
//!   [`PagedStore::commit`] (WAL fsync) is the durability point;
//!   [`PagedStore::checkpoint`] makes the tree+data durable, swaps the
//!   header page, and resets the WAL. Because the B+tree is
//!   copy-on-write above the committed watermark, a crash at *any*
//!   point between checkpoints leaves the last committed tree intact on
//!   disk; reopening truncates torn tails and replays the WAL.
//!
//! Group access cost is governed by the pager's LRU cache size — the
//! tunable middle ground between the hierarchical format's cold index
//! walks and the in-memory format's everything-resident map.
//!
//! Reads are **concurrent**: [`PagedReader`] is `Send + Sync` and every
//! access method takes `&self`, so a FedAvg round can fetch its whole
//! cohort's client datasets in parallel through one shared reader (the
//! index goes through [`crate::store::shared::SharedPager`]'s sharded
//! cache; each call opens its own data cursor). A reader is a
//! *snapshot* at the checkpoint epoch current when it was opened: the
//! B+tree's copy-on-write watermark guarantees a concurrent appender
//! never mutates a page the snapshot can reach.
//!
//! Layout of the `.pstore` header (page 0): magic, B+tree root page,
//! committed page count, committed row count, durable `.pdata` byte
//! length, committed group count, checkpoint epoch, and a CRC32C over
//! the preceding fields. The checksum lets a concurrent reader detect a
//! torn page-0 read (it races the checkpoint's in-place header write)
//! and retry, instead of parsing fields from two different epochs.
//!
//! Known trade-off: `open` walks the committed index once (O(rows)
//! sequential leaf scan through the cache) to rebuild per-group counts /
//! the group list. A persisted `.hgroups`-style sidecar would make open
//! O(groups); left as follow-up since open happens once per process.
//!
//! Every byte of store I/O (index, WAL *and* `.pdata`) goes through the
//! [`crate::store::vfs`] layer: the `*_with` constructors take any
//! [`Vfs`], the plain ones default to [`StdVfs`]. That is what lets the
//! crash-matrix suite (`rust/tests/crash_matrix.rs`) run this exact
//! code under [`crate::store::vfs::FaultVfs`] and prove — not argue —
//! that recovery always lands on a committed prefix.

#![deny(missing_docs)]

use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::corpus::BaseDataset;
use crate::pipeline::Partitioner;
use crate::records::crc32c::crc32c;
use crate::records::tfrecord::{RecordReader, RecordWriter};
use crate::records::Example;
use crate::store::btree::BTree;
use crate::store::cache::CacheStats;
use crate::store::page::{Page, PageId};
use crate::store::pager::{PageRead, Pager};
use crate::store::shared::{ReadSnapshot, SharedPager};
use crate::store::vfs::{OpenMode, StdVfs, Vfs, VfsCursor, VfsFile};
use crate::store::wal::{self, WalWriter};

const MAGIC: &[u8; 8] = b"GRPPAG01";

/// Default LRU cache size (pages) for stores and readers.
pub const DEFAULT_CACHE_PAGES: usize = 64;

fn pstore_path(dir: &Path, prefix: &str) -> PathBuf {
    dir.join(format!("{prefix}.pstore"))
}

fn pdata_path(dir: &Path, prefix: &str) -> PathBuf {
    dir.join(format!("{prefix}.pdata"))
}

fn pwal_path(dir: &Path, prefix: &str) -> PathBuf {
    dir.join(format!("{prefix}.pwal"))
}

/// `group \0 seq(BE)` — the fixed-width suffix makes the group recoverable
/// from any row key, and big-endian seq keeps a group's rows in append
/// order under the tree's byte ordering.
fn row_key(group: &[u8], seq: u64) -> Vec<u8> {
    let mut k = Vec::with_capacity(group.len() + 9);
    k.extend_from_slice(group);
    k.push(0);
    k.extend_from_slice(&seq.to_be_bytes());
    k
}

fn group_of_row_key(k: &[u8]) -> io::Result<&[u8]> {
    if k.len() < 9 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "paged row key shorter than its seq suffix",
        ));
    }
    Ok(&k[..k.len() - 9])
}

/// Header snapshot (page 0 of `.pstore`).
#[derive(Clone, Copy, Debug)]
struct StoreHeader {
    root: PageId,
    committed_pages: u32,
    num_rows: u64,
    data_len: u64,
    num_groups: u64,
    /// Checkpoint epoch. Every WAL record carries the epoch it was
    /// appended under; recovery applies only records with
    /// `epoch >= header.epoch`. That makes the crash window *between*
    /// the checkpoint's header swap and the WAL reset safe: such a WAL
    /// still holds records, but they carry the previous epoch and are
    /// recognized as already committed instead of being applied twice.
    epoch: u64,
}

/// Byte span of the header fields covered by the trailing checksum.
const HEADER_CRC_SPAN: usize = 48;

fn header_checksum_ok(page: &Page) -> bool {
    page.get_bytes(0, 8) == MAGIC
        && page.get_u32(HEADER_CRC_SPAN) == crc32c(page.get_bytes(0, HEADER_CRC_SPAN))
}

fn parse_header(page: &Page) -> Result<StoreHeader> {
    if page.get_bytes(0, 8) != MAGIC {
        bail!("bad paged store magic");
    }
    if !header_checksum_ok(page) {
        bail!("paged store header checksum mismatch (torn or corrupt header page)");
    }
    Ok(StoreHeader {
        root: page.get_u32(8),
        committed_pages: page.get_u32(12),
        num_rows: page.get_u64(16),
        data_len: page.get_u64(24),
        num_groups: page.get_u64(32),
        epoch: page.get_u64(40),
    })
}

fn read_header(pager: &mut Pager) -> Result<StoreHeader> {
    let page = pager.read(0).context("reading paged store header")?;
    parse_header(page)
}

fn write_header(page: &mut Page, h: &StoreHeader) {
    page.put_bytes(0, MAGIC);
    page.put_u32(8, h.root);
    page.put_u32(12, h.committed_pages);
    page.put_u64(16, h.num_rows);
    page.put_u64(24, h.data_len);
    page.put_u64(32, h.num_groups);
    page.put_u64(40, h.epoch);
    let crc = crc32c(page.get_bytes(0, HEADER_CRC_SPAN));
    page.put_u32(HEADER_CRC_SPAN, crc);
}

/// WAL payload: `u64 LE epoch | u32 LE group length | group | example`.
fn encode_wal(epoch: u64, group: &[u8], example_bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + group.len() + example_bytes.len());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(group.len() as u32).to_le_bytes());
    out.extend_from_slice(group);
    out.extend_from_slice(example_bytes);
    out
}

fn decode_wal(payload: &[u8]) -> io::Result<(u64, &[u8], &[u8])> {
    if payload.len() < 12 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "short wal payload"));
    }
    let epoch = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let klen = u32::from_le_bytes(payload[8..12].try_into().unwrap()) as usize;
    if 12 + klen > payload.len() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "wal payload group length out of bounds",
        ));
    }
    Ok((epoch, &payload[12..12 + klen], &payload[12 + klen..]))
}

/// One group's dataset, shared by [`PagedStore`] and [`PagedReader`]: a
/// B+tree range scan for data offsets (cost governed by the LRU cache),
/// then one data-file read per example. Returns false for an unknown
/// group.
fn visit_group_via<R: PageRead>(
    tree: &BTree,
    pager: &mut R,
    data: &Arc<dyn VfsFile>,
    group: &[u8],
    mut f: impl FnMut(Example),
) -> Result<bool> {
    let mut prefix = Vec::with_capacity(group.len() + 1);
    prefix.extend_from_slice(group);
    prefix.push(0);
    let expected_len = prefix.len() + 8;
    let mut offsets: Vec<u64> = Vec::new();
    let mut bad_value = false;
    tree.scan_prefix(pager, &prefix, |k, v| {
        if k.len() == expected_len {
            match <[u8; 8]>::try_from(v) {
                Ok(le) => offsets.push(u64::from_le_bytes(le)),
                Err(_) => bad_value = true,
            }
        }
    })?;
    if bad_value {
        bail!("paged index holds a corrupt offset value for group {:?}", group);
    }
    if offsets.is_empty() {
        return Ok(false);
    }
    let mut r = RecordReader::new(BufReader::new(VfsCursor::new(data.clone())));
    for off in offsets {
        r.seek_to(off)?;
        let bytes = r.next_record()?.context("paged index points past data end")?;
        f(Example::decode(&bytes)?);
    }
    Ok(true)
}

/// The appendable, WAL-backed group store (writer + read access).
pub struct PagedStore {
    pager: Pager,
    tree: BTree,
    wal: WalWriter,
    data: RecordWriter<BufWriter<VfsCursor>>,
    /// The shared `.pdata` handle: fsync target for checkpoints, and the
    /// source every read cursor positions over.
    data_file: Arc<dyn VfsFile>,
    /// Byte offset of `.pdata` where this writer session started.
    data_base: u64,
    /// Per-group example counts (`group -> next seq`).
    group_counts: HashMap<Vec<u8>, u64>,
    /// True when the data writer has unflushed buffered bytes.
    data_buffered: bool,
    /// Current checkpoint epoch (see [`StoreHeader::epoch`]).
    epoch: u64,
    /// Set when an append failed mid-apply: the in-memory tree and data
    /// writer are then suspect (a partial data frame may be buffered, a
    /// page split may be half-done), so every further mutation — and
    /// every tree walk through this handle — is refused. Reopen (or use
    /// a [`PagedReader`]) to recover the last committed state.
    poisoned: bool,
}

impl PagedStore {
    /// Create a fresh (empty) store on the real filesystem, truncating
    /// any existing one (equivalent to [`PagedStore::create_with`] over
    /// [`StdVfs`]). `cache_pages` is clamped to at least 2 frames
    /// (header + one node).
    ///
    /// # Errors
    /// Any failure creating the directory or the three store files.
    pub fn create(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedStore> {
        PagedStore::create_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// Create a fresh (empty) store on `vfs`, truncating any existing
    /// one.
    ///
    /// # Errors
    /// Any failure creating the directory or the three store files.
    pub fn create_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedStore> {
        let cache_pages = cache_pages.max(2);
        vfs.create_dir_all(dir)?;
        let mut pager = Pager::create_with(vfs, &pstore_path(dir, prefix), cache_pages)?;
        let hdr = pager.allocate()?;
        debug_assert_eq!(hdr, 0);
        let header = StoreHeader {
            root: 0,
            committed_pages: 1,
            num_rows: 0,
            data_len: 0,
            num_groups: 0,
            epoch: 0,
        };
        pager.update(0, |p| write_header(p, &header))?;
        pager.flush()?;
        let wal = WalWriter::open_with(vfs, &pwal_path(dir, prefix), 0)?;
        let data_file = vfs.open(&pdata_path(dir, prefix), OpenMode::CreateTruncate)?;
        let data = RecordWriter::new(BufWriter::new(VfsCursor::new(data_file.clone())));
        Ok(PagedStore {
            pager,
            tree: BTree::new_empty(1),
            wal,
            data,
            data_file,
            data_base: 0,
            group_counts: HashMap::new(),
            data_buffered: false,
            epoch: 0,
            poisoned: false,
        })
    }

    /// Open an existing store on the real filesystem (equivalent to
    /// [`PagedStore::open_with`] over [`StdVfs`]), running crash
    /// recovery: the header names the last committed tree/data state;
    /// any torn `.pdata`/`.pwal` tails are truncated, and intact WAL
    /// records are replayed on top.
    ///
    /// # Errors
    /// Fails on missing/corrupt store files (e.g. a data file shorter
    /// than the committed length) or any I/O error during replay.
    pub fn open(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedStore> {
        PagedStore::open_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// Open an existing store on `vfs`, running crash recovery.
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::open`].
    pub fn open_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedStore> {
        let cache_pages = cache_pages.max(2);
        let mut pager = Pager::open_with(vfs, &pstore_path(dir, prefix), cache_pages)?;
        let header = read_header(&mut pager)?;
        // Discard uncommitted index pages beyond the committed watermark.
        pager.reset_to(header.committed_pages.max(1))?;
        let tree = BTree::from_header(header.root, header.num_rows, header.committed_pages);

        // Rebuild per-group counts from the committed tree.
        let mut group_counts: HashMap<Vec<u8>, u64> = HashMap::new();
        let mut scan_err: Option<io::Error> = None;
        tree.scan_from(&mut pager, b"", |k, _v| match group_of_row_key(k) {
            Ok(g) => {
                *group_counts.entry(g.to_vec()).or_insert(0) += 1;
                true
            }
            Err(e) => {
                scan_err = Some(e);
                false
            }
        })?;
        if let Some(e) = scan_err {
            return Err(e).context("scanning committed paged index");
        }

        // Truncate the data file to the committed length (drops torn
        // appends; the WAL re-creates them) and position for append.
        let data_path = pdata_path(dir, prefix);
        let data_file = vfs.open(&data_path, OpenMode::Create)?;
        let actual = data_file.len()?;
        if actual < header.data_len {
            bail!(
                "paged data file {} is shorter ({actual}) than the committed length {}",
                data_path.display(),
                header.data_len
            );
        }
        data_file.set_len(header.data_len)?;
        let data =
            RecordWriter::new(BufWriter::new(VfsCursor::at(data_file.clone(), header.data_len)));

        // Collect intact WAL records, truncate any torn tail.
        let mut pending: Vec<Vec<u8>> = Vec::new();
        let report = wal::replay_with(vfs, &pwal_path(dir, prefix), |payload| {
            pending.push(payload.to_vec());
            Ok(())
        })?;
        let wal = WalWriter::open_with(vfs, &pwal_path(dir, prefix), report.valid_bytes)?;

        let mut store = PagedStore {
            pager,
            tree,
            wal,
            data,
            data_file,
            data_base: header.data_len,
            group_counts,
            data_buffered: false,
            epoch: header.epoch,
            poisoned: false,
        };
        // Replay: re-apply each logged append to data + tree. Idempotent
        // across repeated crashes: nothing becomes durable until the next
        // checkpoint's header swap, and records from *before* the last
        // header swap (a crash between header flush and WAL reset) carry
        // an older epoch and are skipped as already committed.
        for payload in &pending {
            let (rec_epoch, group, ex_bytes) = decode_wal(payload)?;
            if rec_epoch < header.epoch {
                continue;
            }
            let (group, ex_bytes) = (group.to_vec(), ex_bytes.to_vec());
            store.apply(&group, &ex_bytes)?;
        }
        Ok(store)
    }

    /// Apply one append to the data file and index (no WAL write).
    fn apply(&mut self, group: &[u8], ex_bytes: &[u8]) -> Result<()> {
        let offset = self.data_base + self.data.bytes_written();
        self.data.write_record(ex_bytes)?;
        self.data_buffered = true;
        let seq = self.group_counts.get(group).copied().unwrap_or(0);
        let key = row_key(group, seq);
        self.tree
            .insert(&mut self.pager, &key, &offset.to_le_bytes())
            .context("inserting into paged index")?;
        // Counted only after the insert succeeded, so a failed apply
        // never leaves a phantom group (or an off-by-one seq) behind.
        self.group_counts.insert(group.to_vec(), seq + 1);
        Ok(())
    }

    /// Refuse mutations on a store whose in-memory state a failed append
    /// left suspect.
    fn check_poisoned(&self) -> Result<()> {
        if self.poisoned {
            bail!(
                "paged store is poisoned by an earlier failed append; \
                 reopen it to recover the last committed state"
            );
        }
        Ok(())
    }

    /// Append one example to a group: logged to the WAL, then applied.
    /// Call [`PagedStore::commit`] to make a batch of appends durable.
    ///
    /// # Errors
    /// Rejects (before logging) a group key that would overflow the
    /// index row budget; otherwise any WAL/data/index write failure. A
    /// failure while *applying* poisons the store — the half-mutated
    /// tree/data state cannot be trusted, so every later mutation is
    /// refused and the store must be reopened (recovering the last
    /// committed state, which can never include the failed append: its
    /// WAL frame is withdrawn).
    pub fn append(&mut self, group: &[u8], example: &Example) -> Result<()> {
        self.check_poisoned()?;
        // Validate BEFORE logging: a frame that cannot be applied must
        // never enter the WAL, or replay would fail on it at every
        // subsequent open (index row = group + 9-byte seq suffix key +
        // 8-byte offset value).
        if group.len() + 9 + 8 > crate::store::btree::MAX_ROW_BYTES {
            bail!(
                "group key of {} bytes exceeds the paged index row budget ({} bytes)",
                group.len(),
                crate::store::btree::MAX_ROW_BYTES - 17
            );
        }
        let ex_bytes = example.encode();
        let mark = self.wal.mark();
        self.wal.append(&encode_wal(self.epoch, group, &ex_bytes))?;
        if let Err(e) = self.apply(group, &ex_bytes) {
            // The tree may be mid-split and the data writer may hold a
            // partial frame: no further mutation through this handle can
            // be trusted.
            self.poisoned = true;
            // Withdraw the frame: an append the caller is told failed
            // must never become durable at a later commit, or recovery
            // would replay an example the application believes was never
            // stored. (If the frame was already written out and its
            // truncation fails, the WAL's dirty-tail latch — plus the
            // poisoned flag above — keeps it out of every durability
            // promise.)
            self.wal.rewind(mark);
            return Err(e);
        }
        Ok(())
    }

    /// Durability point: fsync the WAL. Cheap — no index/data flush.
    ///
    /// # Errors
    /// Any WAL flush/fsync failure, or a store poisoned by an earlier
    /// failed append (see [`PagedStore::append`]).
    pub fn commit(&mut self) -> Result<()> {
        self.check_poisoned()?;
        self.wal.commit()?;
        Ok(())
    }

    /// Full checkpoint: data + index durable (ordered: data, tree pages,
    /// then the single-page header swap), WAL reset, COW watermark
    /// advanced. Each checkpoint starts a new epoch — readers opened
    /// before it keep seeing the previous epoch's snapshot.
    ///
    /// # Errors
    /// Any flush/fsync failure at any of the ordered steps (the store
    /// stays recoverable from the previous checkpoint + WAL), or a store
    /// poisoned by an earlier failed append.
    pub fn checkpoint(&mut self) -> Result<()> {
        self.check_poisoned()?;
        self.data.flush()?;
        self.data_file.sync()?;
        self.data_buffered = false;
        self.pager.flush()?;
        let header = StoreHeader {
            root: self.tree.root(),
            committed_pages: self.pager.num_pages(),
            num_rows: self.tree.num_rows(),
            data_len: self.data_base + self.data.bytes_written(),
            num_groups: self.group_counts.len() as u64,
            epoch: self.epoch + 1,
        };
        self.pager.update(0, |p| write_header(p, &header))?;
        self.pager.flush()?;
        self.tree.set_watermark(header.committed_pages);
        self.epoch = header.epoch;
        self.wal.reset()?;
        Ok(())
    }

    /// Distinct groups appended so far (committed + uncommitted).
    pub fn num_groups(&self) -> usize {
        self.group_counts.len()
    }

    /// Total examples appended so far (committed + uncommitted).
    pub fn num_examples(&self) -> u64 {
        self.tree.num_rows()
    }

    /// Group keys in sorted order (deterministic across reopen).
    pub fn keys(&self) -> Vec<Vec<u8>> {
        let mut keys: Vec<Vec<u8>> = self.group_counts.keys().cloned().collect();
        keys.sort();
        keys
    }

    /// Visit one group's examples in append order. Returns false for an
    /// unknown group.
    ///
    /// # Errors
    /// Any index or data-file read failure, a corrupt index row, or a
    /// store poisoned by an earlier failed append (the half-mutated
    /// in-memory tree cannot be walked safely; reopen — or use a
    /// [`PagedReader`] — to read the committed state).
    pub fn visit_group(&mut self, group: &[u8], f: impl FnMut(Example)) -> Result<bool> {
        self.check_poisoned()?;
        if self.data_buffered {
            self.data.flush()?;
            self.data_buffered = false;
        }
        let data_file = self.data_file.clone();
        visit_group_via(&self.tree, &mut self.pager, &data_file, group, f)
    }

    /// Iterate groups in `order` (the Table 3 serial random-order walk).
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::visit_group`].
    pub fn visit_all(
        &mut self,
        order: &[Vec<u8>],
        mut f: impl FnMut(&[u8], Example),
    ) -> Result<()> {
        for key in order {
            self.visit_group(key, |ex| f(key, ex))?;
        }
        Ok(())
    }

    /// Index-cache hit/miss/eviction counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.pager.cache_stats()
    }

    /// Index page fetches from disk so far.
    pub fn pages_read(&self) -> u64 {
        self.pager.disk_reads()
    }

    /// Materialize a whole base dataset (append + commit + checkpoint) —
    /// the builder mirroring `HierarchicalStore::build`. Returns the
    /// still-open (and still appendable) store so callers can report
    /// counts without paying a reopen + recovery scan.
    ///
    /// # Errors
    /// Any append, commit or checkpoint failure while materializing.
    pub fn build(
        dataset: &dyn BaseDataset,
        partitioner: &dyn Partitioner,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedStore> {
        PagedStore::build_with(&StdVfs, dataset, partitioner, dir, prefix, cache_pages)
    }

    /// [`PagedStore::build`] on an explicit [`Vfs`].
    ///
    /// # Errors
    /// Same conditions as [`PagedStore::build`].
    pub fn build_with(
        vfs: &dyn Vfs,
        dataset: &dyn BaseDataset,
        partitioner: &dyn Partitioner,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedStore> {
        // Checkpoint periodically so the WAL (and the memory a recovery
        // from a mid-build crash needs) stays bounded regardless of
        // dataset size.
        const CHECKPOINT_WAL_BYTES: u64 = 64 * 1024 * 1024;
        let mut store = PagedStore::create_with(vfs, dir, prefix, cache_pages)?;
        for ex in dataset.examples() {
            let key = partitioner.key(&ex);
            store.append(&key, &ex)?;
            if store.wal.len_bytes() >= CHECKPOINT_WAL_BYTES {
                store.checkpoint()?;
            }
        }
        store.commit()?;
        store.checkpoint()?;
        Ok(store)
    }
}

/// Read-only view over a checkpointed store, with a bounded (sharded)
/// LRU cache. **`Send + Sync`**: wrap it in an `Arc` (or borrow it from
/// scoped threads) and any number of threads can call
/// [`PagedReader::visit_group`] simultaneously — each call reads the
/// index through its own snapshot-bounded handle and opens its own data
/// cursor, so no `&mut` is needed anywhere on the read path.
///
/// The reader is pinned to the checkpoint epoch current at open time
/// (see [`PagedReader::epoch`]): the storage engine's copy-on-write
/// contract means a writer appending to the same store can never mutate
/// a page this snapshot can reach, so reads stay consistent without any
/// reader/writer lock. To observe newer appends, open a new reader.
///
/// Opening a store whose WAL still holds records (a "hot journal") first
/// runs full recovery — open for append, checkpoint, drop — exactly the
/// SQLite open-time contract. **Because recovery rewrites the store**,
/// this path must not race a live [`PagedStore`] writer that has
/// committed but not yet checkpointed: like SQLite without its file
/// locks, the engine assumes a single live writer, so either open
/// readers after the writer checkpointed (the WAL is then cold and the
/// open is purely read-only), or keep writer and reader opens
/// serialized in the embedding process.
pub struct PagedReader {
    pager: SharedPager,
    snapshot: ReadSnapshot,
    tree: BTree,
    data_file: Arc<dyn VfsFile>,
    keys: Vec<Vec<u8>>,
    num_examples: u64,
}

impl PagedReader {
    /// Open the store at `dir/<prefix>` on the real filesystem
    /// (equivalent to [`PagedReader::open_with`] over [`StdVfs`]) for
    /// (possibly concurrent) reading, with `cache_pages` total LRU
    /// frames (clamped to at least 2).
    ///
    /// # Errors
    /// Fails when the store files are missing or corrupt, when WAL
    /// probing/recovery fails, or on any I/O error during the group
    /// enumeration scan.
    pub fn open(dir: &Path, prefix: &str, cache_pages: usize) -> Result<PagedReader> {
        PagedReader::open_with(&StdVfs, dir, prefix, cache_pages)
    }

    /// Open the store at `dir/<prefix>` on `vfs` for (possibly
    /// concurrent) reading.
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::open`].
    pub fn open_with(
        vfs: &dyn Vfs,
        dir: &Path,
        prefix: &str,
        cache_pages: usize,
    ) -> Result<PagedReader> {
        let cache_pages = cache_pages.max(2);
        let wal_path = pwal_path(dir, prefix);
        // An I/O error probing the journal must fail the open, not be
        // mistaken for "no journal" (which would silently serve stale
        // pre-WAL data).
        let hot = wal::has_valid_records_with(vfs, &wal_path).context("probing paged store WAL")?;
        if hot {
            let mut store = PagedStore::open_with(vfs, dir, prefix, cache_pages)
                .context("recovering hot paged store")?;
            store.checkpoint()?;
        }
        let pager = SharedPager::open_with(vfs, &pstore_path(dir, prefix), cache_pages)?;
        // The checkpointing writer rewrites page 0 in place; a read that
        // races it can be torn. The header checksum detects that, and a
        // brief retry rides out the in-flight write.
        let mut page = pager.read_header_fresh()?;
        let mut attempts = 0;
        while !header_checksum_ok(&page) && attempts < 20 {
            std::thread::sleep(std::time::Duration::from_millis(1));
            page = pager.read_header_fresh()?;
            attempts += 1;
        }
        let header = parse_header(&page).context("reading paged store header")?;
        let snapshot = ReadSnapshot { bound: header.committed_pages, epoch: header.epoch };
        let tree = BTree::from_header(header.root, header.num_rows, u32::MAX);
        // Enumerate distinct groups (one ordered leaf walk).
        let mut handle = pager.reader(snapshot);
        let mut keys: Vec<Vec<u8>> = Vec::new();
        let mut scan_err: Option<io::Error> = None;
        tree.scan_from(&mut handle, b"", |k, _| match group_of_row_key(k) {
            Ok(g) => {
                if keys.last().map(|l| l.as_slice()) != Some(g) {
                    keys.push(g.to_vec());
                }
                true
            }
            Err(e) => {
                scan_err = Some(e);
                false
            }
        })?;
        if let Some(e) = scan_err {
            return Err(e).context("enumerating paged groups");
        }
        let data_path = pdata_path(dir, prefix);
        let data_file = match vfs.open(&data_path, OpenMode::Read) {
            Ok(f) => f,
            Err(e) if e.kind() == io::ErrorKind::NotFound && header.data_len == 0 => {
                // A legal post-crash image: the data file was created but
                // never fsynced, so its directory entry is gone. Nothing
                // committed points into it — serve reads from a fresh
                // empty file, exactly like the writer's recovery does.
                vfs.open(&data_path, OpenMode::Create)?
            }
            Err(e) => return Err(e).context("opening paged data file"),
        };
        if data_file.len()? < header.data_len {
            bail!(
                "paged data file {} is shorter ({}) than the committed length {}",
                data_path.display(),
                data_file.len()?,
                header.data_len
            );
        }
        Ok(PagedReader {
            pager,
            snapshot,
            tree,
            data_file,
            keys,
            num_examples: header.num_rows,
        })
    }

    /// Distinct groups in the snapshot.
    pub fn num_groups(&self) -> usize {
        self.keys.len()
    }

    /// Total examples in the snapshot.
    pub fn num_examples(&self) -> u64 {
        self.num_examples
    }

    /// Group keys in sorted order.
    pub fn keys(&self) -> &[Vec<u8>] {
        &self.keys
    }

    /// The checkpoint epoch this reader is pinned to: appends
    /// checkpointed after open land in a later epoch and are invisible
    /// here.
    pub fn epoch(&self) -> u64 {
        self.snapshot.epoch
    }

    /// Index page fetches from disk so far (cost introspection), summed
    /// across all reading threads.
    pub fn pages_read(&self) -> u64 {
        self.pager.disk_reads()
    }

    /// Aggregate index-cache hit/miss/eviction counters (all threads).
    pub fn cache_stats(&self) -> CacheStats {
        self.pager.cache_stats()
    }

    /// Index tree depth (1 = single leaf).
    ///
    /// # Errors
    /// Any index page-read failure.
    pub fn index_depth(&self) -> Result<u32> {
        Ok(self.tree.depth(&mut self.pager.reader(self.snapshot))?)
    }

    /// Construct one group's dataset: a B+tree range scan for locations
    /// (cost governed by the LRU cache), then one data read per example.
    /// Returns false for an unknown group. Takes `&self`: safe to call
    /// from many threads at once.
    ///
    /// # Errors
    /// Any index or data-file read failure, or a corrupt index row.
    pub fn visit_group(&self, group: &[u8], f: impl FnMut(Example)) -> Result<bool> {
        let mut handle = self.pager.reader(self.snapshot);
        visit_group_via(&self.tree, &mut handle, &self.data_file, group, f)
    }

    /// Iterate groups in `order` (Table 3's serial random-order walk —
    /// or one thread's slice of it).
    ///
    /// # Errors
    /// Same conditions as [`PagedReader::visit_group`].
    pub fn visit_all(&self, order: &[Vec<u8>], mut f: impl FnMut(&[u8], Example)) -> Result<()> {
        for key in order {
            self.visit_group(key, |ex| f(key, ex))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, SyntheticTextDataset};
    use crate::pipeline::FeatureKey;
    use crate::store::vfs::MemVfs;

    /// Most tests here run disk-free over [`MemVfs`]; `mem_dir` is just a
    /// namespace inside it.
    fn mem_dir(name: &str) -> PathBuf {
        PathBuf::from("/mem").join(name)
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("grouper_paged_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn row_key_roundtrip() {
        let k = row_key(b"news.example.com", 42);
        assert_eq!(group_of_row_key(&k).unwrap(), b"news.example.com");
        // Seq is big-endian: append order == byte order.
        assert!(row_key(b"g", 1) < row_key(b"g", 2));
        assert!(row_key(b"g", 255) < row_key(b"g", 256));
    }

    #[test]
    fn build_and_read_matches_oracle() {
        let dir = tmp("oracle");
        let mut spec = DatasetSpec::fedccnews_mini(12, 5);
        spec.max_group_words = 1200;
        let ds = SyntheticTextDataset::new(spec);
        let store =
            PagedStore::build(&ds, &FeatureKey::new("domain"), &dir, "news", 32).unwrap();
        assert_eq!(store.num_examples(), ds.len() as u64);
        drop(store);
        let r = PagedReader::open(&dir, "news", 32).unwrap();
        assert_eq!(r.num_groups(), 12);
        assert_eq!(r.num_examples(), ds.len() as u64);
        for g in 0..12 {
            let key = ds.spec.group_key(g).into_bytes();
            let mut got = Vec::new();
            assert!(r.visit_group(&key, |ex| got.push(ex.encode())).unwrap());
            let want: Vec<_> = ds.group_examples_iter(g).map(|e| e.encode()).collect();
            assert_eq!(got, want, "group {g}");
        }
        assert!(!r.visit_group(b"not-there", |_| {}).unwrap());
        drop(r);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn appends_after_reopen_extend_existing_groups() {
        let vfs = MemVfs::new();
        let dir = mem_dir("reopen");
        {
            let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
            s.append(b"g1", &Example::text("a")).unwrap();
            s.append(b"g2", &Example::text("b")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        {
            let mut s = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
            assert_eq!(s.num_examples(), 2);
            s.append(b"g1", &Example::text("c")).unwrap();
            s.append(b"g3", &Example::text("d")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        let r = PagedReader::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(r.num_groups(), 3);
        let mut texts = Vec::new();
        assert!(r
            .visit_group(b"g1", |ex| texts.push(ex.get_str("text").unwrap().to_string()))
            .unwrap());
        assert_eq!(texts, vec!["a".to_string(), "c".to_string()]);
    }

    #[test]
    fn crash_without_checkpoint_recovers_from_wal() {
        let vfs = MemVfs::new();
        let dir = mem_dir("crash");
        {
            let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
            for i in 0..50 {
                let g = format!("group-{}", i % 7);
                s.append(g.as_bytes(), &Example::text(&format!("ex{i}"))).unwrap();
            }
            s.commit().unwrap();
            // Crash: drop without checkpoint. The index pages and header
            // were never flushed; only the WAL (and OS-buffered data
            // bytes) survive.
        }
        let mut s = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(s.num_examples(), 50, "WAL replay must restore every append");
        assert_eq!(s.num_groups(), 7);
        let mut count = 0;
        let keys = s.keys();
        for k in &keys {
            assert!(s.visit_group(k, |_| count += 1).unwrap());
        }
        assert_eq!(count, 50);
    }

    #[test]
    fn crash_between_header_swap_and_wal_reset_does_not_double_apply() {
        // The nastiest checkpoint window: header (with the new state) is
        // durable, but the WAL truncation never happened. Simulated by
        // saving the WAL right before checkpoint and restoring it after.
        let vfs = MemVfs::new();
        let dir = mem_dir("epoch");
        let wal_path = dir.join("x.pwal");
        {
            let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
            for i in 0..20 {
                let g = format!("g{}", i % 4);
                s.append(g.as_bytes(), &Example::text(&format!("t{i}"))).unwrap();
            }
            s.commit().unwrap();
            let saved_wal = vfs.file_bytes(&wal_path).unwrap();
            s.checkpoint().unwrap(); // header swap + wal reset
            drop(s);
            vfs.install(&wal_path, saved_wal); // reset "never happened"
        }
        let mut s = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(
            s.num_examples(),
            20,
            "stale-epoch WAL records must be recognized as already committed"
        );
        let mut count = 0;
        for k in &s.keys() {
            assert!(s.visit_group(k, |_| count += 1).unwrap());
        }
        assert_eq!(count, 20);
        // And the store keeps working: new appends land in the new epoch.
        s.append(b"g0", &Example::text("new")).unwrap();
        s.commit().unwrap();
        drop(s);
        let s2 = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(s2.num_examples(), 21);
    }

    #[test]
    fn oversized_group_key_is_rejected_before_logging() {
        let vfs = MemVfs::new();
        let dir = mem_dir("bigkey");
        let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
        let big = vec![b'g'; 4000];
        assert!(s.append(&big, &Example::text("t")).is_err());
        // The reject must not have poisoned the WAL: appends keep working
        // and the store reopens (replays) cleanly.
        s.append(b"ok", &Example::text("t")).unwrap();
        s.commit().unwrap();
        drop(s);
        let s2 = PagedStore::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(s2.num_examples(), 1);
    }

    #[test]
    fn torn_header_is_detected_not_misparsed() {
        let vfs = MemVfs::new();
        let dir = mem_dir("tornheader");
        {
            let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
            s.append(b"g", &Example::text("t")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        // Flip a byte inside the checksummed span (the epoch field), as a
        // torn in-place header write would.
        let pstore = dir.join("x.pstore");
        let mut bytes = vfs.file_bytes(&pstore).unwrap();
        bytes[40] ^= 0xFF;
        vfs.install(&pstore, bytes);
        let err = PagedReader::open_with(&vfs, &dir, "x", 16).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        assert!(PagedStore::open_with(&vfs, &dir, "x", 16).is_err());
    }

    /// A VFS that serves a torn image for the first N reads of a chosen
    /// file's page 0, then the real bytes — a deterministic stand-in for
    /// a reader racing the checkpoint's in-place header rewrite (no
    /// wall-clock, no flakes).
    struct TornHeaderVfs {
        inner: MemVfs,
        victim: PathBuf,
        torn: Vec<u8>,
        remaining: std::sync::atomic::AtomicU32,
    }

    impl Vfs for TornHeaderVfs {
        fn open(&self, path: &Path, mode: OpenMode) -> io::Result<Arc<dyn VfsFile>> {
            let inner = self.inner.open(path, mode)?;
            if path == self.victim {
                // The handle must be 'static (Arc<dyn VfsFile>), so the
                // torn state is shared into it rather than borrowed.
                Ok(Arc::new(TornHeaderFile {
                    inner,
                    torn: self.torn.clone(),
                    remaining: Arc::new(std::sync::atomic::AtomicU32::new(
                        self.remaining.load(std::sync::atomic::Ordering::Relaxed),
                    )),
                }))
            } else {
                Ok(inner)
            }
        }
        fn create_dir_all(&self, path: &Path) -> io::Result<()> {
            self.inner.create_dir_all(path)
        }
        fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
            self.inner.list_dir(dir)
        }
    }

    /// The handle [`TornHeaderVfs::open`] hands out for the victim file.
    struct TornHeaderFile {
        inner: Arc<dyn VfsFile>,
        torn: Vec<u8>,
        remaining: Arc<std::sync::atomic::AtomicU32>,
    }

    impl VfsFile for TornHeaderFile {
        fn read_at(&self, buf: &mut [u8], offset: u64) -> io::Result<usize> {
            use std::sync::atomic::Ordering;
            if (offset as usize) < self.torn.len() {
                let left = self.remaining.load(Ordering::Relaxed);
                if left > 0 {
                    self.remaining.store(left - 1, Ordering::Relaxed);
                    let src = &self.torn[offset as usize..];
                    let n = buf.len().min(src.len());
                    buf[..n].copy_from_slice(&src[..n]);
                    return Ok(n);
                }
            }
            self.inner.read_at(buf, offset)
        }
        fn write_all_at(&self, buf: &[u8], offset: u64) -> io::Result<()> {
            self.inner.write_all_at(buf, offset)
        }
        fn set_len(&self, len: u64) -> io::Result<()> {
            self.inner.set_len(len)
        }
        fn sync(&self) -> io::Result<()> {
            self.inner.sync()
        }
        fn len(&self) -> io::Result<u64> {
            self.inner.len()
        }
    }

    #[test]
    fn torn_header_read_is_retried_until_the_writer_finishes() {
        // A reader racing the checkpoint's in-place header rewrite sees a
        // torn page 0, detects it by CRC, and retries until the rewrite
        // completes. Deterministic: the VFS serves the torn image for the
        // first 3 header reads (well inside the ~20-retry budget), then
        // the real bytes — no wall-clock race.
        let mem = MemVfs::new();
        let dir = mem_dir("tornretry");
        {
            let mut s = PagedStore::create_with(&mem, &dir, "x", 16).unwrap();
            s.append(b"g", &Example::text("t")).unwrap();
            s.commit().unwrap();
            s.checkpoint().unwrap();
        }
        let pstore = dir.join("x.pstore");
        let good = mem.file_bytes(&pstore).unwrap();
        let mut torn = good[..crate::store::PAGE_SIZE].to_vec();
        torn[40] ^= 0xFF; // mid-rewrite image: checksum cannot match
        let vfs = TornHeaderVfs {
            inner: mem,
            victim: pstore,
            torn,
            remaining: std::sync::atomic::AtomicU32::new(3),
        };
        let r = PagedReader::open_with(&vfs, &dir, "x", 16).unwrap();
        assert_eq!(r.num_examples(), 1, "retry must land on the completed header");
    }

    #[test]
    fn failed_append_poisons_the_store_and_is_never_replayed() {
        // An append whose *apply* fails (here: an injected I/O error on a
        // cache-eviction write-back or data flush mid-append) withdraws
        // its WAL frame and poisons the handle: the half-mutated
        // tree/data state cannot be trusted, so further mutations are
        // refused, and reopening recovers the last committed state — the
        // failed example can never be resurrected.
        use crate::store::vfs::{FaultPlan, FaultVfs};
        use std::sync::Arc;
        let fv = FaultVfs::new(Arc::new(MemVfs::new()));
        let dir = mem_dir("failedappend");
        // Tiny cache: appends constantly evict, giving the injected
        // failure a write site inside apply().
        let mut s = PagedStore::create_with(&fv, &dir, "x", 2).unwrap();
        for i in 0..40 {
            let g = format!("g{}", i % 5);
            s.append(g.as_bytes(), &Example::text(&format!("t{i}"))).unwrap();
        }
        s.commit().unwrap();
        fv.set_plan(FaultPlan {
            fail_write: Some(fv.writes_attempted() + 1),
            ..Default::default()
        });
        let mut hit = false;
        for i in 40..400 {
            let g = format!("g{}", i % 5);
            if s.append(g.as_bytes(), &Example::text(&format!("t{i}"))).is_err() {
                hit = true;
                break;
            }
        }
        assert!(hit, "the injected write failure must hit an append");
        fv.disarm();
        // The handle is poisoned: every further mutation is refused.
        let err = s.append(b"g0", &Example::text("nope")).unwrap_err();
        assert!(format!("{err:#}").contains("poisoned"), "{err:#}");
        assert!(s.commit().is_err());
        assert!(s.checkpoint().is_err());
        assert!(
            s.visit_group(b"g0", |_| {}).is_err(),
            "tree walks through the poisoned handle are refused too"
        );
        drop(s);
        // Reopen: recovery lands on the last committed state; neither the
        // failed append nor anything after it exists.
        let s2 = PagedStore::open_with(&fv, &dir, "x", 8).unwrap();
        assert_eq!(
            s2.num_examples(),
            40,
            "recovery must land exactly on the last committed state"
        );
    }

    #[test]
    fn store_reads_its_own_uncommitted_appends() {
        let vfs = MemVfs::new();
        let dir = mem_dir("readback");
        let mut s = PagedStore::create_with(&vfs, &dir, "x", 16).unwrap();
        s.append(b"g", &Example::text("one")).unwrap();
        s.append(b"g", &Example::text("two")).unwrap();
        let mut texts = Vec::new();
        assert!(s
            .visit_group(b"g", |ex| texts.push(ex.get_str("text").unwrap().to_string()))
            .unwrap());
        assert_eq!(texts, vec!["one".to_string(), "two".to_string()]);
    }
}
